"""End-to-end collaborative serving driver (the paper-kind e2e example):
batched tile requests stream through the satellite-ground cascade over
several simulated orbital passes, with energy/bandwidth ledgers and a
straggler deadline.

The ground segment speaks the ContactPlan API: one persistent plan
stream — ``ContactPlan.rotating`` carrying its pointer across passes —
feeds ``Fleet.contact_round(plan=...)``, so every window goes through
the batched lane-stacked planner (no legacy per-window rotation calls).
``--overlap`` defers each pass's ground recount to a worker thread that
hides behind the next pass's ingest; ``--depth K`` keeps up to K
passes' recounts in flight as a bounded pipeline (bit-identical results
either way; the final ``finalize()`` syncs).

  PYTHONPATH=src python examples/serve_collaborative.py [--passes 3]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.contact import ContactPlan
from repro.core.fleet import Fleet
from repro.core.pipeline import PipelineConfig
from repro.data.synthetic import SceneSpec, make_scene, revisit_frames
from repro.launch.serve import get_counters
from repro.runtime.supervisor import DeadlineBatcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--passes", type=int, default=3)
    ap.add_argument("--bandwidth", type=float, default=50.0)
    ap.add_argument("--deadline-s", type=float, default=120.0)
    ap.add_argument("--overlap", action="store_true",
                    help="overlap each pass's ground recount with the "
                         "next pass's ingest (async ground segment; "
                         "shorthand for --depth 1)")
    ap.add_argument("--depth", type=int, default=None, metavar="K",
                    help="bounded recount pipeline depth: up to K passes' "
                         "recounts in flight (0 = synchronous)")
    args = ap.parse_args()
    overlapped = bool(args.overlap or args.depth)

    space, ground = get_counters()
    rng = np.random.default_rng(7)
    spec = SceneSpec("orbit", 512, (20, 30), (10, 24), cloud_fraction=0.25)

    batcher = DeadlineBatcher(deadline_s=args.deadline_s)
    # ONE persistent single-satellite Fleet: energy/byte ledgers carry
    # across passes and every contact goes through the batched planner
    fleet = Fleet(space, ground,
                  PipelineConfig(method="targetfuse", score_thresh=0.25,
                                 bandwidth_mbps=args.bandwidth),
                  n_sats=1, async_ground=args.overlap,
                  async_depth=args.depth)
    station = {"ptr": 0}  # the persistent plan stream's rotation pointer

    def one_pass(i):
        img, b, c = make_scene(rng, spec)
        frames = revisit_frames(rng, img, b, c, 2)
        [ing] = fleet.ingest([frames])
        # next plan in the stream: one entitlement window, pointer carried
        plan, station["ptr"] = ContactPlan.rotating(
            fleet.n_sats, stations=1, start=station["ptr"])
        [(_, win)] = fleet.contact_round(plan=plan)
        print(f"  pass {i}: {ing.n_tiles} tiles, "
              f"{ing.tiles_processed_space} counted onboard, "
              f"{win.tiles_downlinked} downlinked "
              f"({win.bytes_spent / 1e6:.2f} MB)")
        return win

    print(f"== collaborative serving: {args.passes} orbital passes "
          f"({'overlapped' if overlapped else 'synchronous'} ground "
          f"recount) ==")
    _, dropped = batcher.run(range(args.passes), one_pass)
    if dropped:
        print(f"  straggler mitigation: {len(dropped)} passes re-queued "
              f"(missed the {args.deadline_s}s contact deadline)")
    [r] = fleet.finalize()
    s = fleet.summary()
    print(f"aggregate: CMAE={r.cmae:.3f} pred={r.total_pred:.0f} "
          f"true={r.total_true:.0f} "
          f"rel err={abs(r.total_pred - r.total_true) / max(r.total_true, 1):.3f} "
          f"energy={r.energy_spent_j:.1f}/{r.energy_budget_j:.1f}J "
          f"bytes={r.bytes_downlinked / 1e6:.2f}MB "
          f"of {r.bytes_budget / 1e6:.2f}MB")
    print(f"ground segment: {s['windows_served']} windows, "
          f"{s['windows_per_s']:.1f} windows/s"
          + (f", depth-{s['async_depth']} recount pipeline, "
             f"{s['recount_hidden_frac']:.0%} hidden"
             if overlapped else ""))


if __name__ == "__main__":
    main()
