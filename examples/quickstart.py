"""Quickstart: the TargetFuse pipeline on one synthetic EO frame.

  PYTHONPATH=src python examples/quickstart.py

Walks the paper's Fig. 3 workflow end to end with the public API:
tile -> color-moment features -> k-means dedup -> onboard counting ->
two-threshold selection -> bandwidth-aware throttling -> ground recount
-> aggregated counts + CMAE.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiling
from repro.core.dedup import dedup
from repro.core.throttle import contact_budget_bytes, throttle
from repro.core.cascade import count_tiles_batched
from repro.core.metrics import cmae
from repro.data.synthetic import SceneSpec, make_scene, tile_counts
from repro.launch.serve import get_counters


def main():
    print("== TargetFuse quickstart ==")
    spec = SceneSpec("demo", 512, (24, 32), (10, 24), cloud_fraction=0.2)
    rng = np.random.default_rng(42)
    img, boxes, classes = make_scene(rng, spec)
    true = tile_counts(boxes, spec.scene_px, 128)
    print(f"scene: {img.shape}, {len(boxes)} objects, "
          f"{(spec.scene_px // 128) ** 2} tiles")

    (sp_params, sp_cfg), (gd_params, gd_cfg) = get_counters()

    # 1) adaptive tiling
    tiles = tiling.tile_image(jnp.asarray(img), 128)
    tiles_sp = tiling.resize_tiles(tiles, sp_cfg.input_size)
    tiles_gd = tiling.resize_tiles(tiles, gd_cfg.input_size)

    # 2) clustering-based dedup
    res = dedup(tiles_sp, k=8, key=jax.random.PRNGKey(0))
    print(f"dedup: {int(res.rep_mask.sum())} representatives / {len(tiles)} tiles")

    # 3) onboard counting (space tier)
    counts_sp, conf = count_tiles_batched(sp_params, sp_cfg,
                                          np.asarray(tiles_sp), score_thresh=0.25)

    # 4) bandwidth-aware throttling (Algorithm 2)
    budget = contact_budget_bytes(50.0, 6.0)  # 50 Mbps x 6 s slice
    sizes = jnp.full(len(tiles), 128.0 * 128 * 3)
    tr = throttle(jnp.asarray(conf), sizes, budget, 0.10, 0.80, "dynamic_conf")
    print(f"throttle: {int(tr.space.sum())} counted in space, "
          f"{int(tr.downlink.sum())} downlinked, {int(tr.discard.sum())} discarded "
          f"({float(tr.bytes_used) / 1e6:.2f} MB of {budget / 1e6:.2f} MB)")

    # 5) ground recount of downlinked tiles
    down = np.where(np.asarray(tr.downlink))[0]
    counts_gd = np.zeros(len(tiles))
    if len(down):
        c, _ = count_tiles_batched(gd_params, gd_cfg, np.asarray(tiles_gd)[down],
                                   score_thresh=0.25)
        counts_gd[down] = c

    # 6) aggregate
    pred = np.where(np.asarray(tr.downlink), counts_gd,
                    np.where(np.asarray(tr.space), counts_sp, 0.0))
    print(f"counts: true={true.sum()} pred={pred.sum():.0f} "
          f"CMAE={cmae(pred, true):.3f}")
    space_only = cmae(counts_sp, true)
    print(f"vs space-only CMAE={space_only:.3f} "
          f"({space_only / max(cmae(pred, true), 1e-9):.1f}x better)")


if __name__ == "__main__":
    main()
