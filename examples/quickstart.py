"""Quickstart: the TargetFuse pipeline on one synthetic EO scene via the
Mission API.

  PYTHONPATH=src python examples/quickstart.py

A Mission executes the paper's Fig. 3 workflow as an explicit stage
graph — ingest(frames) runs Capture -> RoiFilter -> Dedup ->
OnboardCount under the energy budget; contact_window() runs Select ->
Downlink -> GroundRecount -> Aggregate under the byte budget — with the
five baselines available as registered selection policies.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.mission import Mission
from repro.core.pipeline import PipelineConfig
from repro.core.policies import available_policies
from repro.data.synthetic import SceneSpec, make_scene, revisit_frames
from repro.launch.serve import get_counters


def main():
    print("== TargetFuse quickstart (Mission API) ==")
    spec = SceneSpec("demo", 512, (24, 32), (10, 24), cloud_fraction=0.2)
    rng = np.random.default_rng(42)
    img, boxes, classes = make_scene(rng, spec)
    frames = revisit_frames(rng, img, boxes, classes, 2)
    print(f"scene: {img.shape}, {len(boxes)} objects, "
          f"{(spec.scene_px // 128) ** 2} tiles x {len(frames)} revisits")
    print(f"registered selection policies: {', '.join(available_policies())}")

    space, ground = get_counters()

    # full system, streamed: onboard stages at ingest, ground stages at
    # the contact window
    mission = Mission(space, ground,
                      PipelineConfig(method="targetfuse", score_thresh=0.25))
    ing = mission.ingest(frames)
    print(f"ingest: {ing.n_tiles} tiles, {ing.tiles_processed_space} counted "
          f"onboard within {ing.energy_granted_j:.1f} J")
    win = mission.contact_window()
    print(f"contact window: {win.tiles_downlinked} tiles downlinked "
          f"({win.bytes_spent / 1e6:.2f} MB of {win.budget_bytes / 1e6:.2f} MB)")
    r = mission.result()
    print(f"counts: true={r.total_true:.0f} pred={r.total_pred:.0f} "
          f"CMAE={r.cmae:.3f}")

    # same frames through the space-only policy for comparison
    so = Mission(space, ground,
                 PipelineConfig(method="space_only",
                                score_thresh=0.25)).run(frames)
    print(f"vs space-only CMAE={so.cmae:.3f} "
          f"({so.cmae / max(r.cmae, 1e-9):.1f}x better)")


if __name__ == "__main__":
    main()
