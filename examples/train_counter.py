"""Train the onboard + ground counters for a few hundred steps on
synthetic EO scenes (the training-path e2e example), with checkpointing
through the fault-tolerant supervisor.

  PYTHONPATH=src python examples/train_counter.py --steps 300
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.cascade import fit_counter
from repro.core.metrics import cmae
from repro.core.cascade import count_tiles_batched
from repro.core import tiling
from repro.data.synthetic import SceneSpec, make_scene, tile_counts
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ground-steps", type=int, default=800)
    args = ap.parse_args()

    spec = SceneSpec("train", 512, (20, 30), (10, 24), cloud_fraction=0.2)
    rng = np.random.default_rng(0)
    scenes = [make_scene(rng, spec) for _ in range(8)]

    sp_cfg = reduced(get_config("targetfuse-space"))
    gd_cfg = reduced(get_config("targetfuse-ground"))
    print(f"space tier:  {sp_cfg.widths} x{sp_cfg.n_blocks_per_stage}")
    print(f"ground tier: {gd_cfg.widths} x{gd_cfg.n_blocks_per_stage}")

    print(f"training space counter ({args.steps} steps)...")
    sp_params, sp_loss = fit_counter(sp_cfg, scenes, 128, args.steps,
                                     jax.random.PRNGKey(0), log_every=100)
    print(f"training ground counter ({args.ground_steps} steps)...")
    gd_params, gd_loss = fit_counter(gd_cfg, scenes, 128, args.ground_steps,
                                     jax.random.PRNGKey(1), log_every=200)

    # held-out evaluation
    errs_s, errs_g = [], []
    for _ in range(3):
        img, b, c = make_scene(rng, spec)
        true = tile_counts(b, spec.scene_px, 128)
        t = tiling.tile_image(jnp.asarray(img), 128)
        cs, _ = count_tiles_batched(sp_params, sp_cfg,
                                    np.asarray(tiling.resize_tiles(t, sp_cfg.input_size)),
                                    score_thresh=0.25)
        cg, _ = count_tiles_batched(gd_params, gd_cfg,
                                    np.asarray(tiling.resize_tiles(t, gd_cfg.input_size)),
                                    score_thresh=0.25)
        errs_s.append(cmae(cs, true))
        errs_g.append(cmae(cg, true))
    print(f"final losses: space {sp_loss:.3f} / ground {gd_loss:.3f}")
    print(f"held-out CMAE: space {np.mean(errs_s):.3f} / ground {np.mean(errs_g):.3f} "
          f"(accuracy asymmetry x{np.mean(errs_s) / max(np.mean(errs_g), 1e-9):.1f})")


if __name__ == "__main__":
    main()
