"""Multi-satellite constellation simulation on the streaming Mission API:
N satellites each own a persistent Mission (energy + byte ledgers carry
across orbital passes); ground-station contact windows rotate — one
satellite downlinks per window while the others keep ingesting, so
un-downlinked passes wait in the satellite's queue until its next
contact.

  PYTHONPATH=src python examples/constellation_sim.py --sats 4 --windows 2
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.mission import Mission
from repro.core.pipeline import PipelineConfig
from repro.core.throttle import contact_budget_bytes
from repro.data.synthetic import SceneSpec, make_scene, revisit_frames
from repro.launch.serve import get_counters


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sats", type=int, default=4)
    ap.add_argument("--windows", type=int, default=2,
                    help="contact windows per satellite")
    ap.add_argument("--bandwidth", type=float, default=50.0)
    args = ap.parse_args()

    space, ground = get_counters()
    spec = SceneSpec("track", 512, (16, 28), (10, 24), cloud_fraction=0.3)
    n_rounds = args.sats * args.windows

    print(f"== {args.sats}-satellite constellation, "
          f"{args.windows} contact windows each ==")
    missions = [
        Mission(space, ground,
                PipelineConfig(method="targetfuse", score_thresh=0.25,
                               bandwidth_mbps=args.bandwidth, seed=s))
        for s in range(args.sats)
    ]
    rngs = [np.random.default_rng(100 + s) for s in range(args.sats)]
    # each round: every satellite flies one pass; ONE rotates into contact
    window_bytes = contact_budget_bytes(args.bandwidth, 360.0) / n_rounds
    for w in range(n_rounds):
        for s, m in enumerate(missions):
            img, b, c = make_scene(rngs[s], spec)
            m.ingest(revisit_frames(rngs[s], img, b, c, 2))
        sat = w % args.sats
        rep = missions[sat].contact_window(window_bytes)
        print(f"  window {w}: sat{sat} drained {rep.segments} passes, "
              f"downlinked {rep.tiles_downlinked} tiles "
              f"({rep.bytes_spent / 1e6:.2f} MB of "
              f"{rep.budget_bytes / 1e6:.2f} MB)")

    agg_pred = agg_true = agg_bytes = agg_budget = 0.0
    for s, m in enumerate(missions):
        r = m.finalize()  # passes with no remaining contact: onboard-only
        agg_pred += r.total_pred
        agg_true += r.total_true
        agg_bytes += m.bytes_spent  # per-window-capped actual spend
        agg_budget += r.bytes_budget
        print(f"  sat{s}: CMAE={r.cmae:.3f} "
              f"proc={r.tiles_processed_space}/{r.tiles_total} "
              f"down={r.tiles_downlinked} "
              f"energy={r.energy_spent_j:.1f}/{r.energy_budget_j:.1f}J "
              f"bytes={r.bytes_downlinked / 1e6:.2f}MB")
        # budget consistency: the onboard energy classes the cap governs
        # (capture/compute/aggregate) never overdraw the granted harvest
        led = m.ledger
        assert led.e_cap + led.e_com + led.e_agg <= led.budget_j + 1e-6, \
            "onboard energy overdraw"
    assert agg_bytes <= agg_budget + 1e-6, "byte overdraw"
    print(f"constellation aggregate count: pred={agg_pred:.0f} "
          f"true={agg_true:.0f} "
          f"rel err={abs(agg_pred - agg_true) / max(agg_true, 1):.3f}, "
          f"downlink {agg_bytes / 1e6:.1f} MB within "
          f"{agg_budget / 1e6:.1f} MB of windows")


if __name__ == "__main__":
    main()
