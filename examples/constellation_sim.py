"""Multi-satellite constellation simulation on the vectorized Fleet
engine: N satellites share one stacked budget ledger and one set of
compiled capture/counting programs; every round each satellite flies a
pass over fresh ground (eclipse/sunlit harvest profile feeding its
energy grant) and rotating ground stations drain one satellite per
window at elevation-dependent bandwidth.

  PYTHONPATH=src python examples/constellation_sim.py --sats 4 --rounds 4

``--oracle`` runs the same scenario through the looped sequential
per-Mission path (the parity oracle the fleet is exact-equal to);
``--check`` runs both and asserts exact equality of every satellite's
per-tile predictions.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.fleet import run_scenario
from repro.core.pipeline import PipelineConfig
from repro.data.scenarios import (FleetScenarioSpec, GroundStation,
                                  generate_scenario)
from repro.data.synthetic import SceneSpec
from repro.launch.serve import get_counters


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sats", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=4,
                    help="orbital pass rounds (one contact per station each)")
    ap.add_argument("--bandwidth", type=float, default=50.0)
    ap.add_argument("--oracle", action="store_true",
                    help="run the looped per-Mission parity oracle instead")
    ap.add_argument("--check", action="store_true",
                    help="run BOTH paths and assert exact parity")
    args = ap.parse_args()

    space, ground = get_counters()
    spec = FleetScenarioSpec(
        n_sats=args.sats, n_rounds=args.rounds, frames_per_pass=2,
        stations=(GroundStation("gs0", bandwidth_mbps=args.bandwidth),),
        scene_mix=(SceneSpec("track", 512, (16, 28), (10, 24),
                             cloud_fraction=0.3),),
        seed=7)
    scenario = generate_scenario(spec)
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25,
                          bandwidth_mbps=args.bandwidth)

    path = "oracle (looped Missions)" if args.oracle else "fleet"
    print(f"== {args.sats}-satellite constellation, {args.rounds} rounds, "
          f"{path} path ==")
    for rnd in scenario.rounds:
        sunlit = sum(p.sunlit for p in rnd.passes)
        for c in rnd.contacts:
            print(f"  round {rnd.index}: {sunlit}/{args.sats} sats sunlit; "
                  f"{c.station.name} -> sat{c.sat} at "
                  f"{c.bandwidth_mbps:.1f} Mbps "
                  f"({c.budget_bytes / 1e6:.2f} MB window)")

    results, driver = run_scenario(space, ground, pcfg, scenario,
                                   fleet=not args.oracle)
    if args.check:
        other, _ = run_scenario(space, ground, pcfg, scenario,
                                fleet=args.oracle)
        for i, (a, b) in enumerate(zip(results, other)):
            np.testing.assert_array_equal(a.per_tile_pred, b.per_tile_pred)
            assert a.summary() == b.summary(), f"sat{i} summary mismatch"
        print("parity check: fleet == looped Missions (exact)")

    agg_pred = agg_true = agg_bytes = agg_budget = 0.0
    for s, r in enumerate(results):
        agg_pred += r.total_pred
        agg_true += r.total_true
        agg_budget += r.bytes_budget
        print(f"  sat{s}: CMAE={r.cmae:.3f} "
              f"proc={r.tiles_processed_space}/{r.tiles_total} "
              f"down={r.tiles_downlinked} "
              f"energy={r.energy_spent_j:.1f}/{r.energy_budget_j:.1f}J "
              f"bytes={r.bytes_downlinked / 1e6:.2f}MB")

    # budget consistency: the energy cap governs onboard counting, so
    # compute spend never overdraws the granted harvest (capture is
    # charged unconditionally — imaging happens even through an eclipse
    # round's zero grant — so it sits outside the cap)
    if args.oracle:
        missions = driver
        agg_bytes = sum(m.bytes_spent for m in missions)
        for m in missions:
            assert m.ledger.e_com <= m.ledger.budget_j + 1e-9, \
                "onboard compute overdraw"
    else:
        fleet = driver
        led = fleet.ledger
        agg_bytes = float(led.bytes_spent.sum())
        assert (led.e_com <= led.budget_j + 1e-9).all(), \
            "onboard compute overdraw"
    assert agg_bytes <= agg_budget + 1e-6, "byte overdraw"
    print(f"constellation aggregate count: pred={agg_pred:.0f} "
          f"true={agg_true:.0f} "
          f"rel err={abs(agg_pred - agg_true) / max(agg_true, 1):.3f}, "
          f"downlink {agg_bytes / 1e6:.1f} MB within "
          f"{agg_budget / 1e6:.1f} MB of windows")


if __name__ == "__main__":
    main()
