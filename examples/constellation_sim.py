"""Multi-satellite constellation simulation: N satellites share ground
stations; each runs the TargetFuse pipeline over its own ground track;
contact windows rotate (only one satellite downlinks per window).

  PYTHONPATH=src python examples/constellation_sim.py --sats 4
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.pipeline import PipelineConfig, run_pipeline
from repro.data.synthetic import SceneSpec, make_scene, revisit_frames
from repro.launch.serve import get_counters


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sats", type=int, default=4)
    ap.add_argument("--windows", type=int, default=2)
    args = ap.parse_args()

    space, ground = get_counters()
    spec = SceneSpec("track", 512, (16, 28), (10, 24), cloud_fraction=0.3)

    print(f"== {args.sats}-satellite constellation, "
          f"{args.windows} contact windows each ==")
    agg_pred = agg_true = agg_bytes = 0.0
    for s in range(args.sats):
        rng = np.random.default_rng(100 + s)
        img, b, c = make_scene(rng, spec)
        frames = revisit_frames(rng, img, b, c, 2)
        # contact share: each sat gets 1/sats of the window budget
        pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25,
                              contacts_per_day=4.0 * args.windows / args.sats,
                              seed=s)
        r = run_pipeline(frames, space, ground, pcfg)
        agg_pred += r.total_pred
        agg_true += r.total_true
        agg_bytes += r.bytes_downlinked
        print(f"  sat{s}: CMAE={r.cmae:.3f} "
              f"proc={r.tiles_processed_space}/{r.tiles_total} "
              f"down={r.tiles_downlinked} bytes={r.bytes_downlinked / 1e6:.2f}MB")
    print(f"constellation aggregate count: pred={agg_pred:.0f} "
          f"true={agg_true:.0f} "
          f"rel err={abs(agg_pred - agg_true) / max(agg_true, 1):.3f}, "
          f"total downlink {agg_bytes / 1e6:.1f} MB")


if __name__ == "__main__":
    main()
