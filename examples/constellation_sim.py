"""Multi-satellite constellation simulation on the vectorized Fleet
engine: N satellites share one stacked budget ledger and one set of
compiled capture/counting programs; every round each satellite flies a
pass over fresh ground (eclipse/sunlit harvest profile feeding its
energy grant) and rotating ground stations drain one satellite per
window at elevation-dependent bandwidth.

  PYTHONPATH=src python examples/constellation_sim.py --sats 4 --rounds 4

Contact rounds execute as declarative ContactPlans: each scenario
round's contact events become one lane-stacked plan
(``Round.contact_plan``) that the batched ground-segment core drains —
no per-window host loop. ``--async-ground`` additionally overlaps each
round's batched ground recount with the next round's ingest dispatch;
``--async-depth K`` deepens that overlap into a bounded pipeline that
keeps up to K rounds' recounts in flight (exact at every depth).

``--oracle`` runs the same scenario through the looped sequential
per-Mission path (the parity oracle the fleet is exact-equal to);
``--check`` runs both and asserts exact equality of every satellite's
per-tile predictions. ``--devices N`` shards the fleet along a ``sats``
device mesh (on CPU, force host devices first:
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) — with
``--check`` that asserts the sharded fleet against the sequential
oracle.

``--geometry orbital`` swaps the toy phase-offset scenario for the
orbital geometry engine (:mod:`repro.orbits`): a Walker-delta
constellation is batch-propagated over the horizon, contact windows
come from extracted ground-station passes (elevation-priced bandwidth,
duration-integrated budgets) over a globally dispersed site network
(``--stations N``), and harvest grants come from cylindrical
Earth-shadow eclipse fractions. The fleet/contact tiers are untouched —
``--check`` asserts the same exact parity on the orbital event stream.

``--faults SEED`` turns on deterministic fault injection
(:mod:`repro.core.faults`): dropped windows, station outages,
mid-window truncations, corrupted downlink segments with bounded
retry, and satellite blackouts, all drawn from the seed (rates via
``--drop-rate`` etc.). With ``--check``, the faulty batched fleet is
asserted bit-equal to the faulty scalar FIFO reference instead of the
oracle, and the run's ledgers are asserted non-negative.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.fleet import run_scenario
from repro.core.fleet_sharding import sats_mesh
from repro.core.pipeline import PipelineConfig
from repro.data.scenarios import (FleetScenarioSpec, GroundStation,
                                  generate_scenario)
from repro.data.synthetic import SceneSpec
from repro.launch.serve import get_counters


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sats", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=4,
                    help="orbital pass rounds (one contact per station each)")
    ap.add_argument("--bandwidth", type=float, default=50.0)
    ap.add_argument("--geometry", choices=("toy", "orbital"), default="toy",
                    help="scenario geometry: 'toy' phase-offset model "
                         "(default) or the batched orbital engine")
    ap.add_argument("--stations", type=int, default=None,
                    help="ground stations (default: 1 toy, 3 orbital)")
    ap.add_argument("--min-elev", type=float, default=5.0,
                    help="orbital pass-extraction elevation mask (deg)")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the fleet across this many devices "
                         "(sats mesh axis)")
    ap.add_argument("--oracle", action="store_true",
                    help="run the looped per-Mission parity oracle instead")
    ap.add_argument("--check", action="store_true",
                    help="run BOTH paths and assert exact parity")
    ap.add_argument("--async-ground", action="store_true",
                    help="overlap each round's batched ground recount "
                         "with the next round's ingest (exact either way; "
                         "shorthand for --async-depth 1)")
    ap.add_argument("--async-depth", type=int, default=None, metavar="K",
                    help="bounded ground-recount pipeline depth: keep up "
                         "to K rounds' recounts in flight behind later "
                         "rounds' ingest (0 = synchronous; exact at "
                         "every depth)")
    ap.add_argument("--ingest-overlap", action="store_true",
                    help="round-pipeline ingest itself: defer each "
                         "round's device->host fetches behind the next "
                         "round's dispatch (exact either way)")
    ap.add_argument("--faults", type=int, default=None, metavar="SEED",
                    help="inject a deterministic fault schedule drawn "
                         "from this seed (drops, outages, truncations, "
                         "corruption+retry, blackouts)")
    ap.add_argument("--drop-rate", type=float, default=0.15)
    ap.add_argument("--truncate-rate", type=float, default=0.15)
    ap.add_argument("--corrupt-rate", type=float, default=0.25)
    ap.add_argument("--blackout-rate", type=float, default=0.1)
    ap.add_argument("--outage-rate", type=float, default=0.25)
    ap.add_argument("--max-retries", type=int, default=2)
    args = ap.parse_args()
    if args.faults is not None and args.oracle:
        ap.error("--faults needs the fleet executors (drop --oracle)")

    mesh = sats_mesh(args.devices)  # None for --devices 1
    space, ground = get_counters()
    scene_mix = (SceneSpec("track", 512, (16, 28), (10, 24),
                           cloud_fraction=0.3),)
    if args.geometry == "orbital":
        from repro.orbits.schedule import default_sites
        n_st = args.stations or 3
        sites = default_sites(n_st)
        stations = tuple(
            GroundStation(f"gs{k}", bandwidth_mbps=args.bandwidth,
                          site=sites[k]) for k in range(n_st))
        spec = FleetScenarioSpec(
            n_sats=args.sats, n_rounds=args.rounds, frames_per_pass=2,
            stations=stations, scene_mix=scene_mix, seed=7,
            geometry="orbital", min_elev_deg=args.min_elev)
    else:
        stations = tuple(
            GroundStation(f"gs{k}", bandwidth_mbps=args.bandwidth)
            for k in range(args.stations or 1))
        spec = FleetScenarioSpec(
            n_sats=args.sats, n_rounds=args.rounds, frames_per_pass=2,
            stations=stations, scene_mix=scene_mix, seed=7)
    scenario = generate_scenario(spec)
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25,
                          bandwidth_mbps=args.bandwidth)
    faults = None
    if args.faults is not None:
        faults = spec.fault_plan(
            args.faults, drop_rate=args.drop_rate,
            truncate_rate=args.truncate_rate,
            corrupt_rate=args.corrupt_rate,
            blackout_rate=args.blackout_rate,
            outage_rate=args.outage_rate, max_retries=args.max_retries)

    path = ("oracle (looped Missions)" if args.oracle else
            f"fleet ({args.devices} device(s))")
    print(f"== {args.sats}-satellite constellation, {args.rounds} rounds, "
          f"{args.geometry} geometry, {path} path ==")
    if args.geometry == "orbital":
        n_windows = sum(len(r.contacts) for r in scenario.rounds)
        print(f"  {len(stations)} sites, min elevation {args.min_elev:.0f} "
              f"deg -> {n_windows} extracted pass windows")
    for rnd in scenario.rounds:
        sunlit = sum(p.sunlit for p in rnd.passes)
        for c in rnd.contacts:
            print(f"  round {rnd.index}: {sunlit}/{args.sats} sats sunlit; "
                  f"{c.station.name} -> sat{c.sat} at "
                  f"{c.bandwidth_mbps:.1f} Mbps "
                  f"({c.budget_bytes / 1e6:.2f} MB window)")

    results, driver = run_scenario(space, ground, pcfg, scenario,
                                   fleet=not args.oracle, mesh=mesh,
                                   async_ground=args.async_ground,
                                   async_depth=args.async_depth,
                                   ingest_overlap=args.ingest_overlap,
                                   faults=faults)
    if args.check:
        if faults is not None:
            # segment-granular faults need the Fleet executors: gate the
            # faulty batched planner against the scalar FIFO reference
            other, _ = run_scenario(space, ground, pcfg, scenario,
                                    faults=faults, contact_reference=True)
            what_ref = "scalar FIFO reference (faulty)"
        else:
            other, _ = run_scenario(space, ground, pcfg, scenario,
                                    fleet=args.oracle)
            what_ref = "looped Missions"
        for i, (a, b) in enumerate(zip(results, other)):
            np.testing.assert_array_equal(a.per_tile_pred, b.per_tile_pred)
            assert a.summary() == b.summary(), f"sat{i} summary mismatch"
        what = (f"sharded fleet ({args.devices} devices)"
                if mesh is not None else "fleet")
        print(f"parity check: {what} == {what_ref} (exact)")

    for s, r in enumerate(results):
        print(f"  sat{s}: CMAE={r.cmae:.3f} "
              f"proc={r.tiles_processed_space}/{r.tiles_total} "
              f"down={r.tiles_downlinked} "
              f"energy={r.energy_spent_j:.1f}/{r.energy_budget_j:.1f}J "
              f"bytes={r.bytes_downlinked / 1e6:.2f}MB")

    # budget consistency: the energy cap governs onboard counting, so
    # compute spend never overdraws the granted harvest (capture is
    # charged unconditionally — imaging happens even through an eclipse
    # round's zero grant — so it sits outside the cap)
    agg_budget = sum(r.bytes_budget for r in results)
    if args.oracle:
        missions = driver
        agg_pred = sum(r.total_pred for r in results)
        agg_true = sum(r.total_true for r in results)
        agg_bytes = sum(m.bytes_spent for m in missions)
        for m in missions:
            assert m.ledger.e_com <= m.ledger.budget_j + 1e-9, \
                "onboard compute overdraw"
    else:
        fleet = driver
        s = fleet.summary()  # the fleet-aggregate scalars, ready-made
        agg_pred, agg_true, agg_bytes = (s["total_pred"], s["total_true"],
                                         s["bytes_spent"])
        led = fleet.ledger
        assert (led.e_com <= led.budget_j + 1e-9).all(), \
            "onboard compute overdraw"
        if faults is not None:
            # degraded-mode invariants: reconciliation never leaves a
            # lane negative or double-credits a refund
            for f in ("budget_j", "e_down", "bytes_budget", "bytes_spent"):
                assert (getattr(led, f) >= 0.0).all(), \
                    f"ledger lane {f} went negative under faults"
            assert s["fault_bytes_refunded"] <= s["fault_bytes_wasted"], \
                "refunded more than was wasted"
            print(f"faults (seed {args.faults}): "
                  f"{s['fault_windows_dropped']} windows dropped "
                  f"({s['fault_budget_folded'] / 1e6:.2f} MB folded fwd), "
                  f"{s['fault_windows_truncated']} truncated, "
                  f"{s['fault_segments_corrupted']} segments corrupted "
                  f"({s['fault_segments_requeued']} retried, "
                  f"{s['fault_segments_lost']} lost), "
                  f"{s['fault_blackout_passes']} blackout passes; "
                  f"{s['fault_bytes_refunded'] / 1e6:.2f} MB refunded")
        print(f"fleet runtime: {s['n_devices']} device(s), "
              f"dedup_batched={s['dedup_batched']}, "
              f"ingest {s['tiles_per_s']:.0f} tiles/s "
              f"({s['tiles_per_s_per_sat']:.0f}/sat)")
        if s["ingest_overlap"]:
            print(f"ingest pipeline: {s['ingest_rounds_deferred']} rounds "
                  f"deferred, dispatch {s['ingest_dispatch_s']:.2f}s, "
                  f"fetch {s['host_fetch_s']:.2f}s of "
                  f"{s['device_compute_s']:.2f}s in flight "
                  f"({s['ingest_hidden_frac']:.0%} hidden)")
        print(f"ground segment: {s['windows_served']} windows in "
              f"{s['contact_s']:.2f}s ({s['windows_per_s']:.1f} windows/s, "
              f"{s['bytes_downlinked_per_s'] / 1e6:.1f} MB/s downlinked)"
              + (f"; depth-{s['async_depth']} recount pipeline "
                 f"({s['recount_max_in_flight']} rounds in flight peak): "
                 f"{s['recount_s']:.2f}s recounted, "
                 f"{s['recount_hidden_frac']:.0%} hidden behind ingest"
                 if s["async_ground"] else ""))
    assert agg_bytes <= agg_budget + 1e-6, "byte overdraw"
    print(f"constellation aggregate count: pred={agg_pred:.0f} "
          f"true={agg_true:.0f} "
          f"rel err={abs(agg_pred - agg_true) / max(agg_true, 1):.3f}, "
          f"downlink {agg_bytes / 1e6:.1f} MB within "
          f"{agg_budget / 1e6:.1f} MB of windows")


if __name__ == "__main__":
    main()
