"""Fig. 11: counting performance across the three datasets under
unlimited downlink.

Claim checked (the headline): TargetFuse reduces counting error vs
Space-Only — paper reports 3.4x on average; we report the measured
ratio per dataset analogue. Ground-Only approaches the lowest CMAE.
"""
from __future__ import annotations

from benchmarks.common import BENCH_DATASETS, frames_for, run_method

UNLIMITED = dict(bandwidth_mbps=100000.0, contact_s=3600.0)


def run():
    rows = []
    ratios = []
    from benchmarks.common import tuned_thresholds
    for name, spec in BENCH_DATASETS.items():
        frames = frames_for(spec)
        p, q = tuned_thresholds(spec)
        res = {}
        for m in ("space_only", "ground_only", "tiansuan", "kodan", "targetfuse"):
            r = run_method(frames, m, conf_p=p, conf_q=q, **UNLIMITED)
            res[m] = r.cmae
            rows.append((f"fig11_{name}_{m}", 0.0, f"cmae={r.cmae:.3f}"))
        ratios.append(res["space_only"] / max(res["targetfuse"], 1e-9))
    rows.append(("fig11_error_reduction_vs_space_only", 0.0,
                 f"avg={sum(ratios) / len(ratios):.2f}x"))
    return rows
