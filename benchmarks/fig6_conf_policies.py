"""Fig. 6: the three downlink policies (Low-Conf-First / Fixed Conf /
Dynamic Conf) across contact times and conf_p sweep.

Claim checked: Dynamic Conf >= the other policies across contact times;
conf_p has an interior optimum when bandwidth is ample.
"""
from __future__ import annotations

from benchmarks.common import MINI, frames_for, run_method


def run():
    frames = frames_for(MINI)
    rows = []
    for contact_s in (60.0, 180.0, 360.0, 720.0):
        for policy in ("low_conf_first", "fixed_conf", "dynamic_conf"):
            r = run_method(frames, "targetfuse", policy=policy,
                           contact_s=contact_s)
            rows.append((f"fig6_{policy}_t{int(contact_s)}", 0.0,
                         f"cmae={r.cmae:.3f};down={r.tiles_downlinked}"))
    for conf_p in (0.0, 0.1, 0.2, 0.35, 0.5):
        r = run_method(frames, "targetfuse", conf_p=conf_p)
        rows.append((f"fig6_confp_{conf_p}", 0.0, f"cmae={r.cmae:.3f}"))
    return rows
