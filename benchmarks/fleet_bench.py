"""Constellation throughput: the vectorized Fleet engine vs the looped
sequential-Mission oracle, plus the device-mesh sharded-runtime sweep.

**Size sweep** — for each fleet size (default 2/8/32 satellites,
override with the ``FLEET_BENCH_SATS`` env var, e.g.
``FLEET_BENCH_SATS=2,8``), one deterministic multi-round scenario
(eclipse/sunlit harvest, rotating variable-bandwidth contact windows) is
generated ONCE and executed by both arms, so timing excludes scene
synthesis and the two arms consume byte-identical inputs. Each size runs
one untimed warm pass of BOTH arms and then interleaves the timed
iterations — the speedup measured is steady-state execution (shared
frame buckets + shared counting batches + the vmapped multi-sat dedup
core), not compile amortization, which benchmarks/pipeline_bench.py
already covers. The acceptance gate is
>= 1.25x over the loop at 8 satellites — recalibrated from the original
2x when size-tiered counting batches (`cascade._tier_batch`) sped up
the looped baseline's small per-satellite batches by ~2x: both arms got
faster in absolute terms, so the fleet's *relative* margin is
structurally smaller now (its remaining edge is shared frame buckets,
shared trailing-batch padding, and the single vmapped dedup call).

**Stations sweep** — the contact tier: a dense ground-segment scenario
(default 32 satellites x 8 stations per round, override with
``FLEET_BENCH_CONTACT_SATS`` / ``FLEET_BENCH_STATIONS`` or
``--stations N``) executed three ways over identical events — the
batched ContactPlan planner (lane-stacked select_batch + vectorized
ledger charges + shared recount batches), the scalar FIFO-loop
reference (one ``Mission.contact_window`` per window, the pre-plan
contact tier), and the async arm (``async_ground=True``: each round's
batched ground recount deferred to a worker thread that overlaps the
next round's ingest). Timed via the fleets' cumulative ``contact_s``
(best of interleaved iterations after a warm pass of every arm), so the
speedup is contact-tier-only and steady-state. Gates (full-size sweep
only, and ratio gates only on >= ``PERF_GATES_MIN_CORES``-core boxes;
parity always): batched >= 1.5x the looped reference; the async
arm hides >= 50% of recount wall time behind foreground work
(``recount_hidden_frac`` = 1 - sync-wait / recount); and all three
arms' per-tile predictions/summaries agree at 0.0 deviation.

**Depth sweep** — the bounded recount pipeline: the stations-sweep
scenario executed at every ``FLEET_BENCH_DEPTHS`` pipeline depth
(default 0/1/2 — synchronous, the single-slot overlap, and two rounds
in flight with backpressure). Per-depth contact wall and recount
accounting (``recount_s`` / ``recount_wait_s`` / ``hidden_frac``, best
across interleaved iterations), the ``wait_s <= recount_s`` accounting
invariant asserted per arm, a 0.0-deviation parity gate across ALL
depths (always enforced), and the depth-scaling gate — depth 2 hides at
least the recount fraction depth 1 hides (full-size sweeps on
>= ``PERF_GATES_MIN_CORES``-core boxes only, recorded always).

**Devices sweep** — the same fixed-size scenario (``FLEET_BENCH_SHARD_SATS``,
default 8 satellites) executed by the sharded fleet runtime at 1/2/4
devices (``FLEET_BENCH_DEVICES``). Each device count runs in a fresh
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
(the flag must precede jax init), placing the fleet's stacked arrays
along the ``sats`` mesh axis. Ingest runs the vmapped dedup core — no
per-satellite Python loop — at every device count. The parity gate:
per-tile predictions and per-sat summaries across ALL device counts
must match the single-device arm within ``SHARD_PARITY_TOL`` (0.0 — the
documented bit-equal-on-CPU dedup tolerance; ``run.py fleet --strict``
turns a violation into a nonzero exit). On forced host devices the
"devices" share one CPU's cores, so sharded wall-clock mostly
demonstrates structure (real gains need real accelerators); the
recorded numbers are honest either way.

**Overlap sweep** — round-pipelined ingest: the size-sweep scenario
driven with contact rounds only every second round (back-to-back ingest
calls are what the deferred tail hides behind), executed with
``Fleet(ingest_overlap=...)`` off and on (``FLEET_BENCH_OVERLAP``,
default ``0,1``). Timed via the fleets' cumulative ``ingest_s`` (best
of interleaved iterations after a warm pass per arm). Gates: both arms'
per-tile predictions and summaries agree at 0.0 deviation (always);
the overlap arm hides >= ``INGEST_HIDE_GATE`` of its deferred-fetch
wall (``ingest_hidden_frac``) on full-size sweeps on
>= ``PERF_GATES_MIN_CORES``-core boxes. The churn gate rides along and
is enforced EVERYWHERE (it counts uploads, not wall time): a round
re-presenting the previous round's control arrays (gather indices,
lane/cluster vectors, dedup key stacks) must hit the content-keyed
transfer cache (``repro.core.xfer``) — i.e. issue strictly fewer
``device_put``s than the pre-cache engine, which paid
``device_puts + cache_reuses`` uploads for the identical work.

**Faults sweep** — the robustness tier: one scenario
(``FLEET_BENCH_FAULT_SATS``, default 8 satellites) executed under
deterministic fault injection at increasing fault rates
(``FLEET_BENCH_FAULT_RATES``, default 0/5/10/25% applied to window
drops and segment corruption, plus pinned corruption of round 0's
windows so corruption provably fires — and is provably re-served by
the rotation — at every nonzero rate), on the dense multi-window
scenario, recording detection error and contact throughput per rate.

**Orbital sweep** — the contact tier driven by the orbital geometry
engine (``geometry="orbital"``, ``FLEET_BENCH_ORBITAL_SATS``, default
16 satellites over the ``FLEET_BENCH_STATIONS`` site network): contact
windows come from extracted passes (elevation-priced bandwidth,
duration-integrated budgets — a heavy-tailed window mix, recorded as
budget p90/p50 skew) instead of the round-robin rotation. Batched
ContactPlan vs FIFO-loop reference, 0.0-deviation parity gate; set
``FLEET_BENCH_ORBITAL_SATS=0`` to disable. Three gates ride along: (1) the **disabled-path
overhead** of the fault subsystem — ``FaultPlan.none()`` vs
``faults=None`` — stays < 2% (full-size sweep only, and only when the
box's same-arm timing noise floor can resolve 2%; the parity of the
two arms is asserted always); (2) the **retry arm** (bounded
retry-with-backoff) recovers at least the no-retry arm's ground-kept
downlinked bytes at EVERY rate (identical fault draws via
``FaultPlan.with_retries``); (3) the **async watchdog arm** — an
injected ground-worker crash recovered by the watchdog — matches the
synchronous arm bit-exactly.

Writes ``BENCH_fleet.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

JSON_PATH = "BENCH_fleet.json"
DEFAULT_SATS = (2, 8, 32)
DEFAULT_DEVICES = (1, 2, 4)
DEFAULT_DEPTHS = (0, 1, 2)
DEFAULT_FAULT_RATES = (0.0, 0.05, 0.10, 0.25)
SHARD_PARITY_TOL = 0.0  # documented dedup tolerance: bit-equal on CPU
SPEEDUP_GATE = 1.25     # fleet vs loop at 8 sats (see module docstring)
CONTACT_PARITY_TOL = 0.0   # batched planner vs FIFO reference: bit-equal
CONTACT_SPEEDUP_GATE = 1.5  # batched vs looped contact tier, 32x8 sweep
ASYNC_HIDE_GATE = 0.5      # recount wall time hidden behind ingest
INGEST_HIDE_GATE = 0.3     # deferred ingest fetch wall hidden behind dispatch
SIZE_SPEEDUP_FLOOR = 1.0   # fleet vs loop at the largest size sweep
FAULT_OVERHEAD_GATE = 0.02  # FaultPlan.none() vs faults=None wall overhead
# The perf-RATIO gates (fleet speedup @8 sats, contact speedup, async
# hidden fraction, fault-off overhead) were calibrated on a multi-core
# runner: the batched/async arms win precisely by exploiting intra-op
# parallelism, so on a 1-core box the ratios are structurally different
# (and wall-clock noise can't resolve a 2% overhead bound at all). On
# such boxes every number is still measured and recorded — only the
# ratio-gate ENFORCEMENT is skipped (gate value null in the JSON, with
# cpu_cores/perf_gates_enforced recording why). Parity/robustness gates
# (0.0 deviation, retry recovery, watchdog bit-exactness) are machine-
# independent and always enforced.
PERF_GATES_MIN_CORES = 2


def _perf_gates_enforced() -> bool:
    return (os.cpu_count() or 1) >= PERF_GATES_MIN_CORES


def _ints_from_env(name, default):
    env = os.environ.get(name, "")
    if not env:
        return default
    return tuple(int(x) for x in env.replace(",", " ").split())


def _bench_knobs():
    return (int(os.environ.get("FLEET_BENCH_ROUNDS", "3")),
            int(os.environ.get("FLEET_BENCH_ITERS", "3")),
            int(os.environ.get("FLEET_BENCH_FRAMES", "1")))


def _spec_for(n_sats, seed):
    from repro.data.scenarios import FleetScenarioSpec, GroundStation
    from repro.data.synthetic import SceneSpec

    n_rounds, _, frames_per_pass = _bench_knobs()
    scene = SceneSpec("fleet", 384, (10, 20), (10, 24), cloud_fraction=0.25)
    return FleetScenarioSpec(
        n_sats=n_sats, n_rounds=n_rounds,
        frames_per_pass=frames_per_pass,
        stations=(GroundStation("gs0"),
                  GroundStation("gs1", bandwidth_mbps=30.0)),
        scene_mix=(scene,), seed=seed)


def _contact_spec(n_sats, n_stations, seed):
    """Dense ground-segment scenario: every round offers ``n_stations``
    rotating windows at staggered bandwidths, so pending passes pile up
    between a satellite's contacts and windows drain multi-segment."""
    from repro.data.scenarios import FleetScenarioSpec, GroundStation
    from repro.data.synthetic import SceneSpec

    n_rounds, _, frames_per_pass = _bench_knobs()
    scene = SceneSpec("contact", 384, (10, 20), (10, 24), cloud_fraction=0.25)
    stations = tuple(
        GroundStation(f"gs{k}", bandwidth_mbps=30.0 + 5.0 * (k % 5),
                      contact_s=240.0 + 30.0 * (k % 3))
        for k in range(n_stations))
    return FleetScenarioSpec(
        n_sats=n_sats, n_rounds=n_rounds, frames_per_pass=frames_per_pass,
        stations=stations, scene_mix=(scene,), seed=seed)


def _stations_sweep(rows, report):
    """Batched ContactPlan vs FIFO-loop reference vs async overlap (see
    module docstring). Returns the report row (None when disabled)."""
    import numpy as np

    from benchmarks.common import counters
    from repro.core.fleet import run_scenario
    from repro.core.pipeline import PipelineConfig
    from repro.data.scenarios import generate_scenario

    n_stations = int(os.environ.get("FLEET_BENCH_STATIONS", "8"))
    n_sats = int(os.environ.get("FLEET_BENCH_CONTACT_SATS", "32"))
    if n_stations <= 0:
        return None
    n_rounds, iters, _ = _bench_knobs()
    space, ground = counters()
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    sc = generate_scenario(_contact_spec(n_sats, n_stations, seed=6))

    def arm(**kw):
        return run_scenario(space, ground, pcfg, sc, fleet=True, **kw)

    arms = (("batched", {}), ("reference", {"contact_reference": True}),
            ("async", {"async_ground": True}))
    for _, kw in arms:  # warm: every compile (lane-stacked throttle,
        arm(**kw)       # per-depth select programs) lands untimed
    best, res_by = {}, {}
    for _ in range(iters):
        for name, kw in arms:  # interleaved: drift hits all arms evenly
            res, fl = arm(**kw)
            s = fl.summary()
            if name not in best or s["contact_s"] < best[name]["contact_s"]:
                best[name] = s
            res_by[name] = res

    max_dev = 0.0
    for name in ("reference", "async"):
        for a, b in zip(res_by["batched"], res_by[name]):
            if a.per_tile_pred.size:
                max_dev = max(max_dev, float(np.max(np.abs(
                    a.per_tile_pred - b.per_tile_pred))))
            assert a.summary() == b.summary(), \
                f"contact-plan {name} arm summary mismatch"
    sb, sr, sa = best["batched"], best["reference"], best["async"]
    speedup = sr["contact_s"] / sb["contact_s"]
    hidden = sa["recount_hidden_frac"]
    row = {
        "n_sats": n_sats, "stations": n_stations, "rounds": n_rounds,
        "windows_served": sb["windows_served"],
        "batched_contact_s": sb["contact_s"],
        "reference_contact_s": sr["contact_s"],
        "speedup": speedup,
        "windows_per_s": sb["windows_per_s"],
        "bytes_downlinked_per_s": sb["bytes_downlinked_per_s"],
        "async_contact_s": sa["contact_s"],
        "async_recount_s": sa["recount_s"],
        "async_recount_wait_s": sa["recount_wait_s"],
        "async_recount_hidden_frac": hidden,
        "pred_max_dev": max_dev,
        # perf gates apply to the full-size sweep only (smoke configs
        # shrink the scenario and measure structure, not throughput)
        "full_size": n_sats >= 32 and n_stations >= 8,
    }
    report[f"contact_{n_sats}sats_{n_stations}st"] = row
    rows.append((f"contact_{n_sats}sats_{n_stations}st",
                 sb["contact_s"] * 1e6,
                 f"speedup={speedup:.2f}x hidden={hidden:.2f} "
                 f"wps={sb['windows_per_s']:.1f} dev={max_dev:.1e}"))
    return row


def _depth_sweep(rows, report):
    """Bounded recount-pipeline depth sweep (``FLEET_BENCH_DEPTHS``,
    default 0,1,2) over the stations-sweep scenario: per-depth contact
    wall and recount accounting, a 0.0-deviation parity gate across
    every depth, the ``wait_s <= recount_s`` accounting invariant per
    arm, and the depth-scaling gate — depth 2 must hide at least the
    recount fraction depth 1 hides (full-size sweeps on
    >= ``PERF_GATES_MIN_CORES``-core boxes only; recorded always).
    Hidden fractions are the best (max) across iterations, matching the
    best-wall convention of the other arms."""
    import numpy as np

    from benchmarks.common import counters
    from repro.core.fleet import run_scenario
    from repro.core.pipeline import PipelineConfig
    from repro.data.scenarios import generate_scenario

    depths = tuple(_ints_from_env("FLEET_BENCH_DEPTHS", DEFAULT_DEPTHS))
    n_stations = int(os.environ.get("FLEET_BENCH_STATIONS", "8"))
    n_sats = int(os.environ.get("FLEET_BENCH_CONTACT_SATS", "32"))
    if not depths or n_stations <= 0:
        return None
    n_rounds, iters, _ = _bench_knobs()
    space, ground = counters()
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    sc = generate_scenario(_contact_spec(n_sats, n_stations, seed=6))

    def arm(depth):
        return run_scenario(space, ground, pcfg, sc, fleet=True,
                            async_depth=depth)

    for d in depths:
        arm(d)  # warm: compiles land untimed
    best, hidden, res_by = {}, {}, {}
    for _ in range(iters):
        for d in depths:  # interleaved: drift hits all depths evenly
            res, fl = arm(d)
            s = fl.summary()
            assert s["recount_wait_s"] <= s["recount_s"], (
                f"depth={d}: wait_s={s['recount_wait_s']} > "
                f"recount_s={s['recount_s']}")
            if d not in best or s["contact_s"] < best[d]["contact_s"]:
                best[d] = s
            hidden[d] = max(hidden.get(d, 0.0), s["recount_hidden_frac"])
            res_by[d] = res

    max_dev = 0.0
    base = res_by[depths[0]]
    for d in depths[1:]:
        for a, b in zip(base, res_by[d]):
            if a.per_tile_pred.size:
                max_dev = max(max_dev, float(np.max(np.abs(
                    a.per_tile_pred - b.per_tile_pred))))
            assert a.summary() == b.summary(), \
                f"depth sweep: depth={d} summary mismatch vs depth={depths[0]}"
    row = {
        "n_sats": n_sats, "stations": n_stations, "rounds": n_rounds,
        "depths": list(depths),
        "pred_max_dev": max_dev,
        "full_size": n_sats >= 32 and n_stations >= 8,
        "per_depth": {
            str(d): {
                "contact_s": best[d]["contact_s"],
                "recount_s": best[d]["recount_s"],
                "recount_wait_s": best[d]["recount_wait_s"],
                "hidden_frac": hidden[d],
                "max_in_flight": best[d]["recount_max_in_flight"],
            } for d in depths},
    }
    report["depth_sweep"] = row
    frac = " ".join(f"d{d}={hidden[d]:.2f}" for d in depths)
    rows.append(("depth_sweep",
                 best[depths[-1]]["contact_s"] * 1e6,
                 f"hidden: {frac} dev={max_dev:.1e}"))
    return row


def _orbital_spec(n_sats, n_stations, seed):
    """The stations-sweep scenario re-based on real orbital geometry:
    contacts come from extracted passes over a globally dispersed site
    network (heavy-tailed pass mix — many low-elevation grazes, few
    long overhead passes), harvest grants from eclipse fractions."""
    from repro.data.scenarios import FleetScenarioSpec, GroundStation
    from repro.data.synthetic import SceneSpec
    from repro.orbits.schedule import default_sites

    n_rounds, _, frames_per_pass = _bench_knobs()
    scene = SceneSpec("orbital", 384, (10, 20), (10, 24), cloud_fraction=0.25)
    sites = default_sites(n_stations)
    stations = tuple(
        GroundStation(f"gs{k}", bandwidth_mbps=30.0 + 5.0 * (k % 5),
                      contact_s=240.0 + 30.0 * (k % 3), site=sites[k])
        for k in range(n_stations))
    return FleetScenarioSpec(
        n_sats=n_sats, n_rounds=n_rounds, frames_per_pass=frames_per_pass,
        stations=stations, scene_mix=(scene,), seed=seed,
        geometry="orbital", min_elev_deg=5.0)


def _orbital_sweep(rows, report):
    """The contact tier fed by the orbital geometry engine: batched
    ContactPlan vs FIFO-loop reference over pass-derived windows.
    Parity gate always (0.0 deviation); the interesting report numbers
    are the pass-mix skew the extracted schedule exhibits."""
    import numpy as np

    from benchmarks.common import counters
    from repro.core.fleet import run_scenario
    from repro.core.pipeline import PipelineConfig
    from repro.data.scenarios import generate_scenario

    n_sats = int(os.environ.get("FLEET_BENCH_ORBITAL_SATS", "16"))
    n_stations = int(os.environ.get("FLEET_BENCH_STATIONS", "8"))
    if n_sats <= 0 or n_stations <= 0:
        return None
    n_rounds, iters, _ = _bench_knobs()
    space, ground = counters()
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    sc = generate_scenario(_orbital_spec(n_sats, n_stations, seed=6))
    budgets = np.array([c.budget_bytes
                        for r in sc.rounds for c in r.contacts])
    n_windows = budgets.size

    def arm(**kw):
        return run_scenario(space, ground, pcfg, sc, fleet=True, **kw)

    arms = (("batched", {}), ("reference", {"contact_reference": True}))
    for _, kw in arms:
        arm(**kw)
    best, res_by = {}, {}
    for _ in range(iters):
        for name, kw in arms:
            res, fl = arm(**kw)
            s = fl.summary()
            if name not in best or s["contact_s"] < best[name]["contact_s"]:
                best[name] = s
            res_by[name] = res

    max_dev = 0.0
    for a, b in zip(res_by["batched"], res_by["reference"]):
        if a.per_tile_pred.size:
            max_dev = max(max_dev, float(np.max(np.abs(
                a.per_tile_pred - b.per_tile_pred))))
        assert a.summary() == b.summary(), \
            "orbital contact reference arm summary mismatch"
    sb = best["batched"]
    row = {
        "n_sats": n_sats, "stations": n_stations, "rounds": n_rounds,
        "geometry": "orbital",
        "n_windows": int(n_windows),
        "windows_served": sb["windows_served"],
        "batched_contact_s": sb["contact_s"],
        "reference_contact_s": best["reference"]["contact_s"],
        "budget_p50_bytes": float(np.median(budgets)) if n_windows else 0.0,
        "budget_p90_bytes": (float(np.percentile(budgets, 90))
                             if n_windows else 0.0),
        "budget_skew_p90_over_p50": (
            float(np.percentile(budgets, 90) / max(np.median(budgets), 1e-9))
            if n_windows else 0.0),
        "pred_max_dev": max_dev,
    }
    report[f"orbital_{n_sats}sats_{n_stations}st"] = row
    rows.append((f"fleet_orbital_{n_sats}sats_{n_stations}st",
                 sb["contact_s"] * 1e6,
                 f"windows={n_windows} "
                 f"skew={row['budget_skew_p90_over_p50']:.2f}x "
                 f"dev={max_dev:.1e}"))
    return row


def _overlap_sweep(rows, report):
    """Round-pipelined ingest arms (module docstring): overlap off vs
    on over identical rounds, parity at 0.0 always, plus the
    count-based transfer-cache churn gate. Returns the row (None when
    disabled)."""
    import numpy as np

    from benchmarks.common import counters
    from repro.core import xfer
    from repro.core.fleet import Fleet
    from repro.core.pipeline import PipelineConfig
    from repro.data.scenarios import generate_scenario

    arms = tuple(int(x) for x in os.environ.get(
        "FLEET_BENCH_OVERLAP", "0,1").replace(",", " ").split())
    n_sats = int(os.environ.get("FLEET_BENCH_OVERLAP_SATS", "32"))
    if not arms or n_sats <= 0:
        return None
    n_rounds, iters, _ = _bench_knobs()
    space, ground = counters()
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    sc = generate_scenario(_spec_for(n_sats, seed=9))

    def drive(overlap):
        fl = Fleet(space, ground, pcfg, n_sats=n_sats,
                   ingest_overlap=bool(overlap))
        for k, rnd in enumerate(sc.rounds):
            fl.ingest(rnd.frames_per_sat(n_sats),
                      rnd.harvest_per_sat(n_sats))
            # contact only every second round: consecutive ingest
            # rounds are exactly what the deferred tail hides behind
            if rnd.contacts and k % 2 == 1:
                fl.contact_round(plan=rnd.contact_plan(n_sats))
        res = fl.finalize()
        return res, fl.summary()

    for ov in arms:
        drive(ov)  # warm: compiles land untimed
    best, res_by = {}, {}
    for _ in range(iters):
        for ov in arms:  # interleaved: drift hits both arms evenly
            res, s = drive(ov)
            if ov not in best or s["ingest_s"] < best[ov]["ingest_s"]:
                best[ov] = s
            res_by[ov] = res

    max_dev = 0.0
    base = res_by[arms[0]]
    for ov in arms[1:]:
        for a, b in zip(base, res_by[ov]):
            if a.per_tile_pred.size:
                max_dev = max(max_dev, float(np.max(np.abs(
                    a.per_tile_pred - b.per_tile_pred))))
            assert a.summary() == b.summary(), \
                f"ingest overlap={ov} arm summary mismatch"

    # -- churn gate: repeat-round upload counts through the xfer cache ----
    churn_sats = min(n_sats, 8)
    churn_sc = (sc if churn_sats == n_sats
                else generate_scenario(_spec_for(churn_sats, seed=9)))
    fl = Fleet(space, ground, pcfg, n_sats=churn_sats)
    rnd = churn_sc.rounds[0]
    frames = rnd.frames_per_sat(fl.n_sats)
    harvest = rnd.harvest_per_sat(fl.n_sats)
    xfer.clear_cache()
    xfer.reset_transfer_stats()
    fl.ingest(frames, harvest)
    first = xfer.transfer_stats()
    xfer.reset_transfer_stats()
    fl.ingest(frames, harvest)
    repeat = xfer.transfer_stats()
    pre_cache = repeat["device_puts"] + repeat["cache_reuses"]

    son = best.get(1) or best.get(arms[-1])
    soff = best.get(0) or best.get(arms[0])
    hidden = son["ingest_hidden_frac"] if son else None
    speedup = (soff["ingest_s"] / son["ingest_s"]
               if son and soff and son is not soff else None)
    row = {
        "n_sats": n_sats, "rounds": n_rounds, "arms": list(arms),
        "ingest_s_off": soff["ingest_s"] if soff else None,
        "ingest_s_on": son["ingest_s"] if son else None,
        "ingest_speedup": speedup,
        "ingest_hidden_frac": hidden,
        "ingest_dispatch_s": son["ingest_dispatch_s"] if son else None,
        "device_compute_s": son["device_compute_s"] if son else None,
        "host_fetch_s": son["host_fetch_s"] if son else None,
        "rounds_deferred": son["ingest_rounds_deferred"] if son else None,
        "pred_max_dev": max_dev,
        "first_round_device_puts": first["device_puts"],
        "repeat_round_device_puts": repeat["device_puts"],
        "repeat_round_cache_reuses": repeat["cache_reuses"],
        "pre_cache_round_puts": pre_cache,
        "transfer_saved_frac": (repeat["cache_reuses"] / pre_cache
                                if pre_cache else 0.0),
        "full_size": n_sats >= 32,
    }
    report["ingest_overlap"] = row
    rows.append(("ingest_overlap",
                 (son["ingest_s"] if son else 0.0) * 1e6,
                 f"speedup={speedup if speedup is None else round(speedup, 2)}"
                 f"x hidden={hidden} dev={max_dev:.1e} "
                 f"xfer={repeat['device_puts']}/{pre_cache}"))
    return row


def _jitguard_sweep(rows, report):
    """Runtime jit-recompilation sanitizer: drive identical fleet
    ingest rounds under :class:`repro.analysis.JitGuard` and record the
    XLA compilations each round triggers. Round 1 traces and compiles
    the programs; every later round re-presents bit-identical shapes,
    so rounds >= 2 must compile ZERO new programs. Count-based and
    machine-independent (like the transfer-cache churn gate), so the
    gate is enforced everywhere — a single recompile in steady state is
    the shape-churn class PR 9 eliminated. ``FLEET_BENCH_JITGUARD_SATS=0``
    disables; on jax builds with no compilation-count source the gate
    reports null."""
    from benchmarks.common import counters
    from repro.analysis.jitguard import JitGuard
    from repro.core.fleet import Fleet
    from repro.core.pipeline import PipelineConfig
    from repro.data.scenarios import generate_scenario

    n_sats = int(os.environ.get("FLEET_BENCH_JITGUARD_SATS", "4"))
    n_rounds = max(2, int(os.environ.get("FLEET_BENCH_JITGUARD_ROUNDS", "4")))
    if n_sats <= 0:
        return None
    space, ground = counters()
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    sc = generate_scenario(_spec_for(n_sats, seed=9))
    rnd = sc.rounds[0]
    frames = rnd.frames_per_sat(n_sats)
    harvest = rnd.harvest_per_sat(n_sats)
    fl = Fleet(space, ground, pcfg, n_sats=n_sats)

    per_round, mode = [], "unsupported"
    for k in range(n_rounds):
        with JitGuard(f"fleet round {k + 1}") as g:
            fl.ingest(frames, harvest)
        mode = g.mode
        per_round.append(g.compilations if g.supported else None)
    fl.finalize()

    supported = mode != "unsupported"
    steady = sum(per_round[1:]) if supported else None
    row = {
        "n_sats": n_sats, "rounds": n_rounds, "counter_mode": mode,
        "recompiles_per_round": per_round,
        "warmup_round_compiles": per_round[0],
        "steady_rounds_compiles": steady,
    }
    report["jitguard"] = row
    rows.append(("fleet_jitguard", 0.0,
                 f"mode={mode} warmup={per_round[0]} steady={steady}"))
    return row


def _floats_from_env(name, default):
    env = os.environ.get(name, "")
    if not env:
        return default
    return tuple(float(x) for x in env.replace(",", " ").split())


def _faults_sweep(rows, report):
    """Fault-injection sweep + the robustness gates (module docstring).
    Returns the summary dict (None when disabled)."""
    import numpy as np

    from benchmarks.common import counters
    from repro.core.faults import FaultPlan
    from repro.core.fleet import run_scenario
    from repro.core.pipeline import PipelineConfig
    from repro.data.scenarios import generate_scenario

    rates = _floats_from_env("FLEET_BENCH_FAULT_RATES", DEFAULT_FAULT_RATES)
    n_sats = int(os.environ.get("FLEET_BENCH_FAULT_SATS", "8"))
    if not rates or n_sats <= 0:
        return None
    n_rounds, iters, _ = _bench_knobs()
    space, ground = counters()
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    # the DENSE scenario (4 windows/round), not the 2-station one: retry
    # re-delivery needs a satellite to be served AGAIN after its failed
    # transmission — with one window per sat per scenario the retry and
    # no-retry arms are indistinguishable (recovery would only happen at
    # the zero-byte finalize flush, which transmits nothing)
    n_stations = min(4, max(1, n_sats // 2))
    sc = generate_scenario(_contact_spec(n_sats, n_stations, seed=8))
    full_size = n_sats >= 8

    def arm(**kw):
        return run_scenario(space, ground, pcfg, sc, fleet=True, **kw)

    # -- disabled-path overhead: FaultPlan.none() vs faults=None ----------
    # a 2% bound needs a stabler estimator than best-of-``iters``: run
    # more interleaved reps, take best-of each arm, and derive a noise
    # floor from the SAME-arm spread (best vs second-best of the off
    # arm) — when one arm against itself varies by more than the gate,
    # the box cannot resolve the bound and enforcement is skipped
    reps = max(iters, 5)
    res_off, _ = arm()                              # untimed warm runs
    res_none, _ = arm(faults=FaultPlan.none())
    for a, b in zip(res_off, res_none):  # parity always, 0.0 deviation
        np.testing.assert_array_equal(a.per_tile_pred, b.per_tile_pred)
        assert a.summary() == b.summary(), \
            "FaultPlan.none() arm diverged from faults=None"
    ts_off, ts_none = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        arm()
        t1 = time.perf_counter()
        arm(faults=FaultPlan.none())
        ts_off.append(t1 - t0)
        ts_none.append(time.perf_counter() - t1)
    t_off, t_none = min(ts_off), min(ts_none)
    overhead = t_none / t_off - 1.0
    noise_floor = sorted(ts_off)[1] / t_off - 1.0
    overhead_resolvable = noise_floor < FAULT_OVERHEAD_GATE

    # -- fault-rate sweep: retry vs no-retry arms over identical draws ----
    # every nonzero-rate plan also PINS corruption at pos 0 of round 0's
    # windows: rate-drawn sites can land on lanes that never transmit
    # (energy-starved sats, empty selections), and a corruption that
    # never fires would make the retry-vs-no-retry comparison vacuous.
    # Round 0 specifically, so the rotation re-serves the failed
    # satellite within the scenario and the retry arm's re-transmission
    # actually lands (not just the zero-byte finalize flush)
    pinned = frozenset((0, w, 0) for w in range(n_stations))
    per_rate = []
    for rate in rates:
        fp = FaultPlan(seed=17, drop_rate=rate, corrupt_rate=rate,
                       segment_corruptions=pinned if rate else frozenset(),
                       max_retries=2)
        res_r, fl_r = arm(faults=fp)
        res_n, fl_n = arm(faults=fp.with_retries(0))
        sr, sn = fl_r.summary(), fl_n.summary()

        def _err(res):
            pred = sum(r.total_pred for r in res)
            true = sum(r.total_true for r in res)
            return abs(pred - true) / max(true, 1.0)

        row = {
            "rate": rate,
            "detection_rel_err": _err(res_r),
            "detection_rel_err_no_retry": _err(res_n),
            "windows_per_s": sr["windows_per_s"],
            "windows_dropped": sr["fault_windows_dropped"],
            "segments_corrupted": sr["fault_segments_corrupted"],
            "segments_lost": sr["fault_segments_lost"],
            "bytes_delivered": sr["fault_bytes_delivered"],
            "bytes_delivered_no_retry": sn["fault_bytes_delivered"],
            "retry_recovers": (sr["fault_bytes_delivered"]
                               >= sn["fault_bytes_delivered"]),
        }
        per_rate.append(row)
        report[f"faults_rate_{int(rate * 100)}pct"] = row
        rows.append((f"faults_rate_{int(rate * 100)}pct",
                     sr["contact_s"] * 1e6,
                     f"err={row['detection_rel_err']:.3f} "
                     f"wps={row['windows_per_s']:.1f} "
                     f"lost={row['segments_lost']} "
                     f"recovered={row['retry_recovers']}"))

    # -- async watchdog arm: injected worker crash, bit-exact recovery ----
    fp_crash = FaultPlan(seed=17, drop_rate=0.1, corrupt_rate=0.1,
                         worker_faults={0: "crash"})
    res_w, fl_w = arm(faults=fp_crash, async_ground=True, watchdog_s=10.0)
    res_s, _ = arm(faults=fp_crash)
    watchdog_dev = 0.0
    for a, b in zip(res_w, res_s):
        if a.per_tile_pred.size:
            watchdog_dev = max(watchdog_dev, float(np.max(np.abs(
                a.per_tile_pred - b.per_tile_pred))))
        assert a.summary() == b.summary(), \
            "watchdog arm summary diverged from the synchronous arm"
    sw = fl_w.summary()

    out = {
        "n_sats": n_sats, "rounds": n_rounds, "rates": list(rates),
        "none_plan_overhead": overhead,
        "overhead_noise_floor": noise_floor,
        "overhead_resolvable": overhead_resolvable,
        "no_faults_s": t_off, "none_plan_s": t_none,
        "retry_recovers_all_rates": all(r["retry_recovers"]
                                        for r in per_rate),
        "watchdog_pred_max_dev": watchdog_dev,
        "watchdog_recoveries": sw["fault_watchdog_recoveries"],
        "worker_crashes": sw["fault_worker_crashes"],
        "full_size": full_size,
    }
    report["faults"] = out
    rows.append(("faults_summary", t_none * 1e6,
                 f"overhead={overhead:+.3f} "
                 f"noise={noise_floor:+.3f} "
                 f"recovers={out['retry_recovers_all_rates']} "
                 f"watchdog_dev={watchdog_dev:.1e}"))
    return out


def _best(fn, iters):
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def _best_pair(fn_a, fn_b, iters):
    """Best-of-``iters`` for two arms with INTERLEAVED iterations, after
    one untimed warm run of each — machine-speed drift hits both arms
    evenly, and per-size compiles (the stacked fleet cores specialize on
    lane count) never land in a timed iteration."""
    out_a = fn_a()
    out_b = fn_b()
    ts_a, ts_b = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        out_a = fn_a()
        ts_a.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        out_b = fn_b()
        ts_b.append(time.perf_counter() - t0)
    return min(ts_a), out_a, min(ts_b), out_b


def _child_devices(n_devices: int) -> None:
    """Run the sharded arm at ``n_devices`` and dump timings +
    per-tile predictions JSON (spawned with the forced-host-device
    XLA flag already in the environment)."""
    import jax
    import numpy as np

    from benchmarks.common import counters
    from repro.core.fleet import run_scenario
    from repro.core.fleet_sharding import sats_mesh
    from repro.core.pipeline import PipelineConfig

    assert len(jax.devices()) >= n_devices, (
        f"{len(jax.devices())} devices visible, {n_devices} requested")
    from repro.data.scenarios import generate_scenario

    n_sats = int(os.environ.get("FLEET_BENCH_SHARD_SATS", "8"))
    _, iters, _ = _bench_knobs()
    mesh = sats_mesh(n_devices)  # None at 1 device = unsharded fleet
    space, ground = counters()
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)

    sc = generate_scenario(_spec_for(n_sats, seed=5))
    # warm on the exact scenario: every compile (incl. the lane-count-
    # specialized stacked cores) lands before the timed iterations
    run_scenario(space, ground, pcfg, sc, fleet=True, mesh=mesh)
    t, (res, fleet) = _best(
        lambda: run_scenario(space, ground, pcfg, sc, fleet=True, mesh=mesh),
        iters)
    summary = fleet.summary()
    json.dump({
        "n_devices": n_devices,
        "fleet_s": t,
        "tiles": int(sum(r.tiles_total for r in res)),
        "dedup_batched": summary["dedup_batched"],
        "tiles_per_s": summary["tiles_per_s"],
        "preds": [np.asarray(r.per_tile_pred).tolist() for r in res],
        "summaries": [r.summary() for r in res],
    }, sys.stdout)


def _spawn_devices(n_devices: int) -> dict:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    flag = f"--xla_force_host_platform_device_count={n_devices}"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flag).strip()
    p = subprocess.run(
        [sys.executable, "-m", "benchmarks.fleet_bench",
         "--child-devices", str(n_devices)],
        cwd=root, env=env, capture_output=True, text=True)
    if p.returncode != 0:
        raise RuntimeError(f"fleet_bench child devices={n_devices} "
                           f"failed:\n{p.stderr[-4000:]}")
    return json.loads(p.stdout)


def _size_sweep(rows, report):
    import numpy as np

    from benchmarks.common import counters
    from repro.core.fleet import run_scenario
    from repro.core.pipeline import PipelineConfig
    from repro.data.scenarios import generate_scenario

    space, ground = counters()
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    _, iters, _ = _bench_knobs()

    for n_sats in _ints_from_env("FLEET_BENCH_SATS", DEFAULT_SATS):
        sc = generate_scenario(_spec_for(n_sats, seed=5))
        t_fleet, (res_f, fleet), t_loop, (res_l, _) = _best_pair(
            lambda: run_scenario(space, ground, pcfg, sc, fleet=True),
            lambda: run_scenario(space, ground, pcfg, sc, fleet=False),
            iters)
        max_dev = 0.0
        for a, b in zip(res_f, res_l):
            if a.per_tile_pred.size:
                max_dev = max(max_dev, float(np.max(np.abs(
                    a.per_tile_pred - b.per_tile_pred))))
            assert a.summary() == b.summary(), "fleet/loop summary mismatch"
        tiles = sum(r.tiles_total for r in res_f)
        speedup = t_loop / t_fleet
        fs = fleet.summary()
        report[f"sats_{n_sats}"] = {
            "n_sats": n_sats, "rounds": _bench_knobs()[0],
            "frames_per_pass": _bench_knobs()[2], "tiles": tiles,
            "fleet_s": t_fleet, "loop_s": t_loop, "speedup": speedup,
            "fleet_tiles_per_s": tiles / t_fleet,
            "fleet_tiles_per_s_per_sat": tiles / t_fleet / n_sats,
            "loop_tiles_per_s": tiles / t_loop,
            "dedup_batched": fs["dedup_batched"],
            "pred_max_dev": max_dev,
        }
        rows.append((f"fleet_{n_sats}sats", t_fleet * 1e6,
                     f"speedup={speedup:.2f}x tps={tiles / t_fleet:.0f} "
                     f"tps/sat={tiles / t_fleet / n_sats:.0f} "
                     f"dev={max_dev:.1e}"))


def _devices_sweep(rows, report):
    import numpy as np

    devices = _ints_from_env("FLEET_BENCH_DEVICES", DEFAULT_DEVICES)
    if not devices:
        return None
    if 1 not in devices:
        # the parity gate and speedup_vs_1dev are defined against the
        # single-device arm — always run it, whatever the env asked for
        devices = (1, *devices)
    arms = [_spawn_devices(d) for d in sorted(set(devices))]
    base = arms[0]
    max_dev = 0.0
    for arm in arms:
        assert arm["dedup_batched"], \
            "sharded arm fell back to the per-sat dedup loop"
        for p_base, p_arm, s_base, s_arm in zip(
                base["preds"], arm["preds"],
                base["summaries"], arm["summaries"]):
            if p_base:
                max_dev = max(max_dev, float(np.max(np.abs(
                    np.asarray(p_base) - np.asarray(p_arm)))))
            assert s_base == s_arm, (
                f"per-sat summary mismatch between devices="
                f"{base['n_devices']} and devices={arm['n_devices']}")
    base_t = base["fleet_s"]
    for arm in arms:
        d = arm["n_devices"]
        report[f"devices_{d}"] = {
            "n_devices": d,
            "n_sats": int(os.environ.get("FLEET_BENCH_SHARD_SATS", "8")),
            "fleet_s": arm["fleet_s"],
            "tiles": arm["tiles"],
            "tiles_per_s": arm["tiles"] / arm["fleet_s"],
            "speedup_vs_1dev": base_t / arm["fleet_s"],
            "dedup_batched": arm["dedup_batched"],
        }
        rows.append((f"fleet_devices_{d}", arm["fleet_s"] * 1e6,
                     f"tps={arm['tiles'] / arm['fleet_s']:.0f} "
                     f"vs1dev={base_t / arm['fleet_s']:.2f}x"))
    return max_dev


def run(json_path: str = None):
    if json_path is None:
        # smoke configs redirect the report (FLEET_BENCH_JSON) so tiny
        # CI runs never clobber the committed BENCH_fleet.json
        json_path = os.environ.get("FLEET_BENCH_JSON", JSON_PATH)
    rows, report = [], {}
    _size_sweep(rows, report)
    contact = _stations_sweep(rows, report)
    depth = _depth_sweep(rows, report)
    orbital = _orbital_sweep(rows, report)
    overlap = _overlap_sweep(rows, report)
    jitg = _jitguard_sweep(rows, report)
    faults = _faults_sweep(rows, report)
    shard_dev = _devices_sweep(rows, report)

    perf_on = _perf_gates_enforced()
    report["_summary"] = {
        "cpu_cores": os.cpu_count(),
        "perf_gates_enforced": perf_on,
        "speedup_at_8_sats": report.get("sats_8", {}).get("speedup"),
        "speedup_gate": SPEEDUP_GATE,
        "gate_speedup_at_8_sats": (report["sats_8"]["speedup"] >= SPEEDUP_GATE
                                   if "sats_8" in report and perf_on
                                   else None),
        "speedup_at_32_sats": report.get("sats_32", {}).get("speedup"),
        "gate_speedup_at_32_sats": (
            report["sats_32"]["speedup"] > SIZE_SPEEDUP_FLOOR
            if "sats_32" in report and perf_on else None),
        # the PR-8-era 0.99x at 32 sats, diagnosed while building the
        # transfer-count instrumentation this sweep now carries:
        "sats_32_root_cause": (
            "per-round churn, not batching: each of the 32-sat rounds "
            "re-uploaded bit-identical control arrays (counting gather "
            "indices, dedup lane/cluster vectors and PRNG key stacks), "
            "rebuilt NamedSharding placements, materialized full frames "
            "just to read .shape, and blocked on fleet-wide "
            "device->host syncs (roi_std, dedup assignments, counting "
            "results, the energy-cap round-trip) between every round's "
            "dispatch. The churn grows with fleet size while the looped "
            "baseline pays none of it, so on a 1-core runner it erased "
            "the batching margin at 32 sats. Eliminated by the "
            "content-keyed transfer cache (repro.core.xfer), cached "
            "mesh placements (FleetSharding.placement), np.shape frame "
            "probes, and the ingest_overlap deferred-fetch tail."),
        "max_pred_dev": max(r["pred_max_dev"] for k, r in report.items()
                            if k.startswith("sats_")),
        "sharded_pred_max_dev": shard_dev,
        "shard_parity_tol": SHARD_PARITY_TOL,
        "contact_speedup": contact["speedup"] if contact else None,
        "contact_speedup_gate": CONTACT_SPEEDUP_GATE,
        "gate_contact_speedup": (
            contact["speedup"] >= CONTACT_SPEEDUP_GATE
            if contact and contact["full_size"] and perf_on else None),
        "contact_pred_max_dev": (contact["pred_max_dev"]
                                 if contact else None),
        "contact_parity_tol": CONTACT_PARITY_TOL,
        "orbital_pred_max_dev": (orbital["pred_max_dev"]
                                 if orbital else None),
        "orbital_budget_skew": (orbital["budget_skew_p90_over_p50"]
                                if orbital else None),
        "async_recount_hidden_frac": (
            contact["async_recount_hidden_frac"] if contact else None),
        "async_hide_gate": ASYNC_HIDE_GATE,
        "gate_async_hidden": (
            contact["async_recount_hidden_frac"] >= ASYNC_HIDE_GATE
            if contact and contact["full_size"] and perf_on else None),
        "ingest_overlap_speedup": (overlap["ingest_speedup"]
                                   if overlap else None),
        "ingest_hidden_frac": (overlap["ingest_hidden_frac"]
                               if overlap else None),
        "ingest_hide_gate": INGEST_HIDE_GATE,
        "gate_ingest_hidden": (
            overlap["ingest_hidden_frac"] >= INGEST_HIDE_GATE
            if overlap and overlap["ingest_hidden_frac"] is not None
            and overlap["full_size"] and perf_on else None),
        "ingest_overlap_pred_max_dev": (overlap["pred_max_dev"]
                                        if overlap else None),
        "transfer_repeat_round_puts": (overlap["repeat_round_device_puts"]
                                       if overlap else None),
        "transfer_pre_cache_puts": (overlap["pre_cache_round_puts"]
                                    if overlap else None),
        "transfer_saved_frac": (overlap["transfer_saved_frac"]
                                if overlap else None),
        # count-based, so machine-independent: enforced EVERYWHERE
        "gate_transfer_cache": (
            overlap["repeat_round_device_puts"]
            < overlap["pre_cache_round_puts"] if overlap else None),
        "jit_recompiles_per_round": (jitg["recompiles_per_round"]
                                     if jitg else None),
        "jit_steady_rounds_compiles": (jitg["steady_rounds_compiles"]
                                       if jitg else None),
        "jit_counter_mode": jitg["counter_mode"] if jitg else None,
        # count-based, so machine-independent: enforced EVERYWHERE
        # (null only when disabled or the jax build exposes no counter)
        "gate_jit_steady_state": (
            jitg["steady_rounds_compiles"] == 0
            if jitg and jitg["steady_rounds_compiles"] is not None
            else None),
        "depth_pred_max_dev": depth["pred_max_dev"] if depth else None,
        "depth_hidden_fracs": (
            {d: v["hidden_frac"] for d, v in depth["per_depth"].items()}
            if depth else None),
        "gate_depth2_hidden_ge_depth1": (
            depth["per_depth"]["2"]["hidden_frac"]
            >= depth["per_depth"]["1"]["hidden_frac"]
            if depth and "1" in depth["per_depth"]
            and "2" in depth["per_depth"]
            and depth["full_size"] and perf_on else None),
        "fault_none_plan_overhead": (faults["none_plan_overhead"]
                                     if faults else None),
        "fault_overhead_gate": FAULT_OVERHEAD_GATE,
        "gate_fault_overhead": (
            faults["none_plan_overhead"] < FAULT_OVERHEAD_GATE
            if faults and faults["full_size"] and perf_on
            and faults["overhead_resolvable"] else None),
        "gate_fault_retry_recovers": (faults["retry_recovers_all_rates"]
                                      if faults else None),
        "fault_watchdog_pred_max_dev": (faults["watchdog_pred_max_dev"]
                                        if faults else None),
    }
    rows.append(("fleet_summary", 0.0,
                 f"speedup@8={report['_summary']['speedup_at_8_sats']} "
                 f"contact={report['_summary']['contact_speedup']} "
                 f"hidden={report['_summary']['async_recount_hidden_frac']} "
                 f"max_dev={report['_summary']['max_pred_dev']:.1e} "
                 f"shard_dev={shard_dev}"))
    with open(json_path, "w") as f:
        json.dump(report, f, indent=2)
    # fail loudly AFTER the report lands on disk (run.py --strict turns
    # any gate into a nonzero exit); smoke configs without an 8-sat row
    # or a full-size contact sweep skip the perf gates by design, and so
    # do sub-``PERF_GATES_MIN_CORES`` boxes (gate value null, see the
    # constant's comment) — parity/robustness gates always apply
    if shard_dev is not None and shard_dev > SHARD_PARITY_TOL:
        raise AssertionError(
            f"sharded parity gate: pred_max_dev={shard_dev:.3e} exceeds "
            f"the documented dedup tolerance {SHARD_PARITY_TOL} across "
            f"the device sweep (see {json_path})")
    if contact and contact["pred_max_dev"] > CONTACT_PARITY_TOL:
        raise AssertionError(
            f"contact-plan parity gate: pred_max_dev="
            f"{contact['pred_max_dev']:.3e} exceeds "
            f"{CONTACT_PARITY_TOL} across batched/reference/async arms "
            f"(see {json_path})")
    if orbital and orbital["pred_max_dev"] > CONTACT_PARITY_TOL:
        raise AssertionError(
            f"orbital contact parity gate: pred_max_dev="
            f"{orbital['pred_max_dev']:.3e} exceeds {CONTACT_PARITY_TOL} "
            f"between batched and reference arms on the pass-derived "
            f"schedule (see {json_path})")
    if report["_summary"]["gate_speedup_at_8_sats"] is False:
        raise AssertionError(
            f"fleet speedup gate: {report['sats_8']['speedup']:.2f}x < "
            f"{SPEEDUP_GATE}x at 8 satellites (see {json_path})")
    if report["_summary"]["gate_speedup_at_32_sats"] is False:
        raise AssertionError(
            f"fleet size-scaling gate: {report['sats_32']['speedup']:.2f}x "
            f"<= {SIZE_SPEEDUP_FLOOR}x at 32 satellites — per-round churn "
            f"is back (see sats_32_root_cause in {json_path})")
    if overlap and overlap["pred_max_dev"] > CONTACT_PARITY_TOL:
        raise AssertionError(
            f"ingest-overlap parity gate: pred_max_dev="
            f"{overlap['pred_max_dev']:.3e} exceeds {CONTACT_PARITY_TOL} "
            f"between overlap arms (see {json_path})")
    if report["_summary"]["gate_transfer_cache"] is False:
        raise AssertionError(
            f"transfer-cache churn gate: a repeat round issued "
            f"{overlap['repeat_round_device_puts']} device_puts, not fewer "
            f"than the pre-cache engine's "
            f"{overlap['pre_cache_round_puts']} (see {json_path})")
    if report["_summary"]["gate_jit_steady_state"] is False:
        raise AssertionError(
            f"jit steady-state gate: rounds >= 2 of an identical-shape "
            f"fleet ingest compiled "
            f"{jitg['steady_rounds_compiles']} new XLA program(s) "
            f"(per-round {jitg['recompiles_per_round']}) — shape churn "
            f"is back; every steady-state round must hit the jit cache "
            f"(see {json_path})")
    if report["_summary"]["gate_ingest_hidden"] is False:
        raise AssertionError(
            f"ingest overlap gate: hidden fraction "
            f"{overlap['ingest_hidden_frac']:.2f} < {INGEST_HIDE_GATE} of "
            f"deferred-fetch wall time (see {json_path})")
    if report["_summary"]["gate_contact_speedup"] is False:
        raise AssertionError(
            f"contact-plan speedup gate: {contact['speedup']:.2f}x < "
            f"{CONTACT_SPEEDUP_GATE}x at {contact['n_sats']} sats x "
            f"{contact['stations']} stations (see {json_path})")
    if report["_summary"]["gate_async_hidden"] is False:
        raise AssertionError(
            f"async overlap gate: hidden fraction "
            f"{contact['async_recount_hidden_frac']:.2f} < "
            f"{ASYNC_HIDE_GATE} of recount wall time (see {json_path})")
    if depth and depth["pred_max_dev"] > CONTACT_PARITY_TOL:
        raise AssertionError(
            f"depth-sweep parity gate: pred_max_dev="
            f"{depth['pred_max_dev']:.3e} exceeds {CONTACT_PARITY_TOL} "
            f"across pipeline depths {depth['depths']} (see {json_path})")
    if report["_summary"]["gate_depth2_hidden_ge_depth1"] is False:
        raise AssertionError(
            f"depth-scaling gate: depth-2 hidden fraction "
            f"{depth['per_depth']['2']['hidden_frac']:.2f} < depth-1's "
            f"{depth['per_depth']['1']['hidden_frac']:.2f} "
            f"(see {json_path})")
    if faults:
        if faults["watchdog_pred_max_dev"] > 0.0:
            raise AssertionError(
                f"watchdog parity gate: async crash-recovery arm deviates "
                f"{faults['watchdog_pred_max_dev']:.3e} from the "
                f"synchronous arm (see {json_path})")
        if not faults["retry_recovers_all_rates"]:
            raise AssertionError(
                f"retry gate: the bounded-retry arm delivered fewer "
                f"ground-kept bytes than the no-retry arm at some fault "
                f"rate (see {json_path})")
        if report["_summary"]["gate_fault_overhead"] is False:
            raise AssertionError(
                f"fault-subsystem overhead gate: FaultPlan.none() costs "
                f"{faults['none_plan_overhead']:+.1%} vs faults=None "
                f"(>= {FAULT_OVERHEAD_GATE:.0%}, see {json_path})")
    return rows


if __name__ == "__main__":
    if "--child-devices" in sys.argv:
        sys.path.insert(0, os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "src"))
        _child_devices(int(sys.argv[sys.argv.index("--child-devices") + 1]))
    else:
        if "--devices" in sys.argv:  # e.g. --devices 1,2,4
            os.environ["FLEET_BENCH_DEVICES"] = \
                sys.argv[sys.argv.index("--devices") + 1]
        if "--stations" in sys.argv:  # e.g. --stations 8
            os.environ["FLEET_BENCH_STATIONS"] = \
                sys.argv[sys.argv.index("--stations") + 1]
        for name, us, derived in run():
            print(f"{name},{us:.1f},{derived}")
