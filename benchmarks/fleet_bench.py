"""Constellation throughput: the vectorized Fleet engine vs the looped
sequential-Mission oracle on identical scenarios.

For each fleet size (default 2/8/32 satellites, override with the
``FLEET_BENCH_SATS`` env var, e.g. ``FLEET_BENCH_SATS=2,8``), one
deterministic multi-round scenario (eclipse/sunlit harvest, rotating
variable-bandwidth contact windows) is generated ONCE and executed by
both arms, so timing excludes scene synthesis and the two arms consume
byte-identical inputs. Both paths are compile-warmed on a small
scenario first — the speedup measured here is steady-state execution
(shared frame buckets + shared counting batches), not compile
amortization, which benchmarks/pipeline_bench.py already covers.

Per size: fleet and loop wall-clock (best of ``iters``), speedup,
per-satellite tile throughput, and an exact-parity check of per-tile
predictions between the arms. Writes ``BENCH_fleet.json``; the
acceptance gate is >= 2x at 8 satellites.
"""
from __future__ import annotations

import json
import os
import time

JSON_PATH = "BENCH_fleet.json"
DEFAULT_SATS = (2, 8, 32)


def _sats_from_env():
    env = os.environ.get("FLEET_BENCH_SATS", "")
    if not env:
        return DEFAULT_SATS
    return tuple(int(x) for x in env.replace(",", " ").split())


def run(json_path: str = None):
    import numpy as np

    from benchmarks.common import counters
    from repro.core.fleet import run_scenario
    from repro.core.pipeline import PipelineConfig
    from repro.data.scenarios import (FleetScenarioSpec, GroundStation,
                                      generate_scenario)
    from repro.data.synthetic import SceneSpec

    if json_path is None:
        # smoke configs redirect the report (FLEET_BENCH_JSON) so tiny
        # CI runs never clobber the committed BENCH_fleet.json
        json_path = os.environ.get("FLEET_BENCH_JSON", JSON_PATH)
    space, ground = counters()
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    scene = SceneSpec("fleet", 384, (10, 20), (10, 24), cloud_fraction=0.25)
    n_rounds = int(os.environ.get("FLEET_BENCH_ROUNDS", "3"))
    iters = int(os.environ.get("FLEET_BENCH_ITERS", "3"))
    frames_per_pass = int(os.environ.get("FLEET_BENCH_FRAMES", "1"))

    def spec_for(n_sats, seed):
        return FleetScenarioSpec(
            n_sats=n_sats, n_rounds=n_rounds,
            frames_per_pass=frames_per_pass,
            stations=(GroundStation("gs0"),
                      GroundStation("gs1", bandwidth_mbps=30.0)),
            scene_mix=(scene,), seed=seed)

    # compile-warm both arms (shared XLA cache: every bucketed program
    # the timed runs need exists after this)
    warm = generate_scenario(spec_for(2, seed=1))
    run_scenario(space, ground, pcfg, warm, fleet=True)
    run_scenario(space, ground, pcfg, warm, fleet=False)

    def best(fn):
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn()
            ts.append(time.perf_counter() - t0)
        return min(ts), out

    rows, report = [], {}
    for n_sats in _sats_from_env():
        sc = generate_scenario(spec_for(n_sats, seed=5))
        t_fleet, (res_f, _) = best(
            lambda: run_scenario(space, ground, pcfg, sc, fleet=True))
        t_loop, (res_l, _) = best(
            lambda: run_scenario(space, ground, pcfg, sc, fleet=False))
        max_dev = 0.0
        for a, b in zip(res_f, res_l):
            if a.per_tile_pred.size:
                max_dev = max(max_dev, float(np.max(np.abs(
                    a.per_tile_pred - b.per_tile_pred))))
            assert a.summary() == b.summary(), "fleet/loop summary mismatch"
        tiles = sum(r.tiles_total for r in res_f)
        speedup = t_loop / t_fleet
        report[f"sats_{n_sats}"] = {
            "n_sats": n_sats, "rounds": n_rounds,
            "frames_per_pass": frames_per_pass, "tiles": tiles,
            "fleet_s": t_fleet, "loop_s": t_loop, "speedup": speedup,
            "fleet_tiles_per_s": tiles / t_fleet,
            "fleet_tiles_per_s_per_sat": tiles / t_fleet / n_sats,
            "loop_tiles_per_s": tiles / t_loop,
            "pred_max_dev": max_dev,
        }
        rows.append((f"fleet_{n_sats}sats", t_fleet * 1e6,
                     f"speedup={speedup:.2f}x tps={tiles / t_fleet:.0f} "
                     f"tps/sat={tiles / t_fleet / n_sats:.0f} "
                     f"dev={max_dev:.1e}"))

    report["_summary"] = {
        "speedup_at_8_sats": report.get("sats_8", {}).get("speedup"),
        "gate_2x_at_8_sats": (report["sats_8"]["speedup"] >= 2.0
                              if "sats_8" in report else None),
        "max_pred_dev": max(r["pred_max_dev"] for k, r in report.items()
                            if not k.startswith("_")),
    }
    rows.append(("fleet_summary", 0.0,
                 f"speedup@8={report['_summary']['speedup_at_8_sats']} "
                 f"max_dev={report['_summary']['max_pred_dev']:.1e}"))
    with open(json_path, "w") as f:
        json.dump(report, f, indent=2)
    if report["_summary"]["gate_2x_at_8_sats"] is False:
        # fail loudly (run.py --strict turns this into a nonzero exit);
        # smoke configs without an 8-sat row skip the gate by design
        raise AssertionError(
            f"fleet speedup gate: {report['sats_8']['speedup']:.2f}x < 2x "
            f"at 8 satellites (see {json_path})")
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
