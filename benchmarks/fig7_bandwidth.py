"""Fig. 7: CMAE vs satellite-ground bandwidth, all five methods.

Claims checked: CMAE decreases with bandwidth for every method except
Space-Only; TargetFuse beats TIANSUAN across the sweep and approaches
the Kodan upper bound; bandwidth efficiency vs TIANSUAN.
"""
from __future__ import annotations

from benchmarks.common import MINI, frames_for, run_method

METHODS = ("space_only", "ground_only", "tiansuan", "kodan", "targetfuse")
BWS = (5.0, 15.0, 30.0, 50.0, 100.0)


def run():
    from benchmarks.common import tuned_thresholds
    frames = frames_for(MINI)
    p, q = tuned_thresholds(MINI)
    rows = []
    tf_err, ti_err, tf_bytes, ti_bytes = {}, {}, {}, {}
    for bw in BWS:
        for m in METHODS:
            r = run_method(frames, m, conf_p=p, conf_q=q, bandwidth_mbps=bw)
            rows.append((f"fig7_{m}_bw{int(bw)}", 0.0,
                         f"cmae={r.cmae:.3f};MB={r.bytes_downlinked / 1e6:.2f}"))
            if m == "targetfuse":
                tf_err[bw], tf_bytes[bw] = r.cmae, r.bytes_downlinked
            if m == "tiansuan":
                ti_err[bw], ti_bytes[bw] = r.cmae, r.bytes_downlinked
    # bandwidth efficiency: bytes TIANSUAN needs for its best CMAE vs bytes
    # TargetFuse needs to match-or-beat that CMAE
    best_ti = min(ti_err.values())
    ti_cost = min(b for bw, b in ti_bytes.items() if ti_err[bw] <= best_ti + 1e-9)
    tf_match = [b for bw, b in tf_bytes.items() if tf_err[bw] <= best_ti]
    eff = (ti_cost / min(tf_match)) if tf_match and min(tf_match) > 0 else float("inf")
    rows.append(("fig7_bandwidth_efficiency_vs_tiansuan", 0.0, f"x={eff:.1f}"))
    return rows
