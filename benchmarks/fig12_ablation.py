"""Fig. 12: ablations on the two key designs.

(a) clustering-based dedup cuts downlink volume (paper: clustering
    downlinks ~32.8% of the no-clustering volume);
(b) Dynamic Conf vs Fixed Conf across contact time (dynamic wins until
    bandwidth suffices, then they converge).
"""
from __future__ import annotations

from benchmarks.common import MINI, frames_for, run_method


def run():
    frames = frames_for(MINI, n_scenes=2, revisits=4)  # revisit-heavy
    rows = []
    # (a) downlink-volume ablation runs UNCAPPED: the claim is about how
    # many bytes each variant *wants* to transmit (paper: clustering
    # downlinks ~1/3 of the no-clustering volume)
    ample = dict(bandwidth_mbps=100000.0, contact_s=3600.0,
                 energy_budget_j=2_000_000.0)
    r_c = run_method(frames, "targetfuse", use_dedup=True, **ample)
    r_n = run_method(frames, "targetfuse", use_dedup=False, **ample)
    frac = r_c.bytes_downlinked / max(r_n.bytes_downlinked, 1.0)
    rows.append(("fig12a_clustering", 0.0,
                 f"cmae={r_c.cmae:.3f};MB={r_c.bytes_downlinked / 1e6:.2f}"))
    rows.append(("fig12a_no_clustering", 0.0,
                 f"cmae={r_n.cmae:.3f};MB={r_n.bytes_downlinked / 1e6:.2f}"))
    rows.append(("fig12a_downlink_volume_ratio", 0.0, f"{frac:.2f}"))
    # (b) dynamic vs fixed across contact time, with a wide downlink
    # band so the policies actually differ when bandwidth binds
    for contact in (30.0, 60.0, 120.0, 240.0, 480.0):
        rd = run_method(frames, "targetfuse", policy="dynamic_conf",
                        contact_s=contact, conf_q=0.8)
        rf = run_method(frames, "targetfuse", policy="fixed_conf",
                        contact_s=contact, conf_q=0.8)
        rows.append((f"fig12b_t{int(contact)}", 0.0,
                     f"dynamic={rd.cmae:.3f};fixed={rf.cmae:.3f}"))
    return rows
