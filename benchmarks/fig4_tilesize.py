"""Fig. 4: tile size vs mAP accuracy and execution time, + Algorithm 1.

Claim checked: accuracy has an interior optimum over tile size while
execution time decreases monotonically with tile size; the ternary
search lands near the measured optimum with few evaluations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import MINI, counters, time_us
from repro.core import tiling
from repro.core.metrics import ap50
from repro.core.cascade import count_tiles
from repro.data.synthetic import clip_boxes_to_tile, make_scene
from repro.models import detector

SIZES = (32, 64, 128, 192, 256, 384)


def _map_and_time(space, scenes, tile_size):
    params, cfg = space
    pred_b, pred_s, gts = [], [], []
    total_us = 0.0
    for img, boxes, classes in scenes:
        t = tiling.tile_image(jnp.asarray(img), tile_size)
        tr = tiling.resize_tiles(t, cfg.input_size)
        total_us += time_us(
            lambda x: count_tiles(params, cfg, x, 0.25)[0], tr, iters=1)
        raw = detector.forward(params, cfg, tr)
        bxs, scs = detector.decode(raw, cfg)
        g = img.shape[0] // tile_size
        scale = tile_size / cfg.input_size
        for ty in range(g):
            for tx in range(g):
                i = ty * g + tx
                keep = np.asarray(detector.nms_keep(bxs[i], scs[i], 0.25, 0.25))
                pred_b.append(np.asarray(bxs[i])[keep] * scale)
                pred_s.append(np.asarray(scs[i])[keep])
                gb, _ = clip_boxes_to_tile(boxes, classes, tx, ty, tile_size)
                gts.append(gb)
    return ap50(pred_b, pred_s, gts), total_us / len(scenes)


def run():
    space, _ = counters()
    rng = np.random.default_rng(11)
    scenes = [make_scene(rng, MINI) for _ in range(2)]
    rows = []
    curve = {}
    for s in SIZES:
        m, us = _map_and_time(space, scenes, s)
        curve[s] = m
        rows.append((f"fig4_tile{s}", us, f"mAP50={m:.3f}"))
    best_measured = max(curve, key=curve.get)
    s_best, cache = tiling.optimal_tile_size(
        lambda s: _map_and_time(space, scenes, int(s))[0], 32, 384, eps=48)
    rows.append(("fig4_alg1_choice", 0.0,
                 f"s_best={s_best};measured_opt={best_measured};evals={len(cache)}"))
    return rows
