"""Kernel micro-benchmarks: us/call of each kernel's public op (XLA
fallback path on CPU; on TPU the same entry points hit the Pallas
kernels) + interpret-mode overhead note."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_us
from repro.kernels import ops, ref


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    q = jax.random.normal(key, (4, 256, 8, 128), jnp.float32)
    k = jax.random.normal(key, (4, 256, 2, 128), jnp.float32)
    v = jax.random.normal(key, (4, 256, 2, 128), jnp.float32)
    attn = jax.jit(lambda q, k, v: ops.attention(q, k, v, causal=True))
    rows.append(("kernel_attention_b4s256h8", time_us(attn, q, k, v),
                 "gqa causal fwd"))

    tiles = jax.random.uniform(key, (512, 64, 64, 3))
    mom = jax.jit(ops.tile_moments)
    rows.append(("kernel_tile_moments_512x64", time_us(mom, tiles),
                 "3 moments fused"))

    x = jax.random.normal(key, (4096, 9))
    c = jax.random.normal(key, (64, 9))
    ka = jax.jit(ops.kmeans_assign)
    rows.append(("kernel_kmeans_assign_4096x64", time_us(ka, x, c),
                 "dist+argmin fused"))

    b1 = jax.random.uniform(key, (512, 4))
    b2 = jax.random.uniform(key, (512, 4))
    iou = jax.jit(ops.iou_matrix)
    rows.append(("kernel_iou_512x512", time_us(iou, b1, b2), "nms matrix"))

    xq = jax.random.randint(key, (256, 512), -127, 128, jnp.int8)
    wq = jax.random.randint(key, (512, 256), -127, 128, jnp.int8)
    xs = jnp.ones((256,))
    ws = jnp.ones((256,))
    i8 = jax.jit(ops.int8_matmul)
    rows.append(("kernel_int8_matmul_256x512x256", time_us(i8, xq, wq, xs, ws),
                 "quantized onboard path"))
    return rows
