"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Select figures with
``python -m benchmarks.run fig7 fig11`` (all by default). Pass
``--json PATH`` to also write the rows as a ``name ->
{us_per_call, derived}`` dict (the ``BENCH_*.json`` trajectory files).
By default a module that raises is reported as an ERROR row and the
harness keeps going (exit 0); ``--strict`` makes any module failure
exit nonzero — CI smoke runs use it so bench-embedded gates (e.g. the
fleet/loop parity assert) actually fail the build.
"""
from __future__ import annotations

import json
import sys
import time

FIGS = ("fig4", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12",
        "pipeline", "fleet", "kernels", "orbits")


def main() -> None:
    argv = sys.argv[1:]
    strict = "--strict" in argv
    if strict:
        argv.remove("--strict")
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        try:
            json_path = argv[i + 1]
        except IndexError:
            sys.exit("--json requires a PATH argument")
        del argv[i:i + 2]
    want = [a for a in argv if not a.startswith("-")] or list(FIGS)
    mods = []
    if "fig4" in want:
        from benchmarks import fig4_tilesize as m
        mods.append(m)
    if "fig6" in want:
        from benchmarks import fig6_conf_policies as m
        mods.append(m)
    if "fig7" in want:
        from benchmarks import fig7_bandwidth as m
        mods.append(m)
    if "fig8" in want:
        from benchmarks import fig8_energy as m
        mods.append(m)
    if "fig9" in want:
        from benchmarks import fig9_hardware as m
        mods.append(m)
    if "fig10" in want:
        from benchmarks import fig10_counters as m
        mods.append(m)
    if "fig11" in want:
        from benchmarks import fig11_datasets as m
        mods.append(m)
    if "fig12" in want:
        from benchmarks import fig12_ablation as m
        mods.append(m)
    if "pipeline" in want:
        from benchmarks import pipeline_bench as m
        mods.append(m)
    if "fleet" in want:
        from benchmarks import fleet_bench as m
        mods.append(m)
    if "kernels" in want:
        from benchmarks import kernel_bench as m
        mods.append(m)
    if "orbits" in want:
        from benchmarks import orbits_bench as m
        mods.append(m)

    results = {}
    failed = []
    print("name,us_per_call,derived")
    for mod in mods:
        t0 = time.time()
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
                results[name] = {"us_per_call": us, "derived": derived}
        except Exception as e:  # keep the harness running for later figs
            print(f"{mod.__name__},0.0,ERROR={e!r}", flush=True)
            results[mod.__name__] = {"us_per_call": 0.0, "derived": f"ERROR={e!r}"}
            failed.append(mod.__name__)
        print(f"# {mod.__name__} done in {time.time() - t0:.0f}s",
              file=sys.stderr)

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {json_path}", file=sys.stderr)
    if strict and failed:
        sys.exit(f"--strict: benchmark module(s) failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
