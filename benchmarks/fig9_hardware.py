"""Fig. 9: hardware comparison at fixed energy (150 KJ/day, xview-like,
space tier = yolov3-tiny-class counter).

Claim checked: the low-power tier (RPI4) achieves lower CMAE than Atlas
for the same contact time (it affords more onboard processing).
"""
from __future__ import annotations

from benchmarks.common import BENCH_DATASETS, frames_for, run_method
from repro.core.energy import ATLAS, RPI4


def run():
    frames = frames_for(BENCH_DATASETS["xview"])
    rows = []
    reduction = {}
    for hw in (RPI4, ATLAS):
        for contact in (90.0, 180.0, 360.0):
            r = run_method(frames, "targetfuse", hardware=hw,
                           energy_budget_j=150_000, contact_s=contact)
            reduction[(hw.name, contact)] = r.cmae
            rows.append((f"fig9_{hw.name}_t{int(contact)}", 0.0,
                         f"cmae={r.cmae:.3f};proc={r.tiles_processed_space}"))
    avg_rpi = sum(v for (h, _), v in reduction.items() if h == "rpi4") / 3
    avg_atl = sum(v for (h, _), v in reduction.items() if h == "atlas") / 3
    pct = 100.0 * (avg_atl - avg_rpi) / max(avg_atl, 1e-9)
    rows.append(("fig9_rpi4_cmae_reduction_pct", 0.0, f"{pct:.0f}%"))
    return rows
