"""Fig. 10: counting performance across onboard DNN counters at 50 Mbps.

Claim checked: under the cascade, the choice of onboard counter barely
moves CMAE (the ground tier recovers low-confidence tiles), and
TargetFuse ~ Kodan for each counter.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import MINI, counters, frames_for
from repro.configs import get_config, reduced
from repro.core.cascade import fit_counter
from repro.core.mission import Mission
from repro.core.pipeline import PipelineConfig
from repro.data.synthetic import make_scene

_cache = {}


def _space_counter(arch: str):
    if arch not in _cache:
        cfg = reduced(get_config(arch))
        rng = np.random.default_rng(0)
        scenes = [make_scene(rng, MINI) for _ in range(6)]
        params, _ = fit_counter(cfg, scenes, 128, 400, jax.random.PRNGKey(0))
        _cache[arch] = (params, cfg)
    return _cache[arch]


def run():
    frames = frames_for(MINI)
    _, ground = counters()
    rows = []
    for arch in ("targetfuse-space", "ssd-mobilenetv2"):
        space = _space_counter(arch)
        for method in ("targetfuse", "kodan", "space_only"):
            pcfg = PipelineConfig(method=method, score_thresh=0.25,
                                  bandwidth_mbps=50.0)
            r = Mission(space, ground, pcfg).run(frames)
            rows.append((f"fig10_{arch}_{method}", 0.0,
                         f"cmae={r.summary()['cmae']:.3f}"))
    return rows
