"""End-to-end pipeline throughput: device-resident engine vs the seed
host-orchestrated reference path.

Two fig11-style (dataset-analogue, unlimited-downlink) workloads:

* **method sweep** — one standard frame set per dataset x all five
  baseline methods: per-method frames/sec + tiles/sec and the parity
  gate (per-tile predictions bit-identical-or-within-1e-5). Both arms
  run INTERLEAVED in ONE subprocess, each cell warmed once and then
  timed best-of-2 — steady-state throughput. (Cold-cache isolation is
  pointless here, and sequential whole-arm subprocesses measured
  minutes apart pick up >2x machine-speed drift on throttled CI boxes,
  which used to swamp the per-cell signal.)
* **pass sequence** — successive targetfuse runs over frame sets of
  VARYING size per dataset, like successive orbital passes. This is the
  headline number and is deliberately timed cold, single-shot, each arm
  in a fresh subprocess so neither inherits the other's XLA compile
  cache: every pass presents new array shapes, so the seed path
  recompiles its counting/ROI programs per pass while the engine's
  fixed-shape programs (frame buckets, size-tiered count batches) are
  compiled once, ever — the per-distinct-shape recompiles are exactly
  the cost the engine removes.

Writes ``BENCH_pipeline.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

METHODS = ("space_only", "ground_only", "tiansuan", "kodan", "targetfuse")
UNLIMITED = dict(bandwidth_mbps=100000.0, contact_s=3600.0)
# (n_scenes, revisits) per orbital pass. Frame counts are distinct within
# each dataset AND across the two same-resolution datasets (xview/dota are
# both 768 px), so no two reference-path runs can share compiled programs
# — each pass presents genuinely new shapes, as successive real passes do.
PASSES = {
    "xview": ((1, 2), (2, 4), (1, 5), (2, 2), (1, 3)),
    "dota": ((1, 7), (3, 3), (2, 5), (2, 6), (1, 13)),
    "uavod": ((1, 2), (2, 4), (1, 5), (2, 2), (1, 3)),
}
JSON_PATH = "BENCH_pipeline.json"


def _child(arm: str) -> None:
    """``sweep``: both arms interleaved, steady-state. ``ref`` /
    ``engine``: that arm's cold pass sequence. Dumps JSON to stdout."""
    import time

    import numpy as np

    from benchmarks.common import BENCH_DATASETS, counters, frames_for
    from repro.core.mission import Mission
    from repro.core.pipeline import PipelineConfig

    space, ground = counters()

    if arm == "sweep":
        out = {"ref": {}, "engine": {}}
        for name, spec in BENCH_DATASETS.items():
            frames = frames_for(spec)
            for m in METHODS:
                cell = {}
                for use_engine in (False, True):
                    pcfg = PipelineConfig(method=m, score_thresh=0.25,
                                          use_engine=use_engine, **UNLIMITED)
                    Mission(space, ground, pcfg).run(frames)  # compile warm
                    cell[use_engine] = [pcfg, None, None]  # dt, result
                for _ in range(2):  # interleaved best-of-2 per arm
                    for use_engine in (False, True):
                        pcfg, dt, _ = cell[use_engine]
                        t0 = time.perf_counter()
                        r = Mission(space, ground, pcfg).run(frames)
                        dt1 = time.perf_counter() - t0
                        cell[use_engine] = [
                            pcfg, dt1 if dt is None else min(dt, dt1), r]
                for use_engine, key in ((False, "ref"), (True, "engine")):
                    _, dt, r = cell[use_engine]
                    out[key][f"{name}_{m}"] = {
                        "s": dt,
                        "frames_per_s": len(frames) / dt,
                        "tiles_per_s": r.tiles_total / dt,
                        "cmae": r.cmae,
                        "pred": np.asarray(r.per_tile_pred).tolist(),
                    }
        json.dump(out, sys.stdout)
        return

    use_engine = arm == "engine"
    out = {"passes": {}}
    for name, spec in BENCH_DATASETS.items():
        for i, (ns, rv) in enumerate(PASSES[name]):
            frames = frames_for(spec, n_scenes=ns, revisits=rv, seed=10 + i)
            pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25,
                                  use_engine=use_engine, **UNLIMITED)
            t0 = time.perf_counter()
            r = Mission(space, ground, pcfg).run(frames)
            dt = time.perf_counter() - t0
            out["passes"][f"{name}_pass{i}"] = {
                "s": dt,
                "tiles": r.tiles_total,
                "frames_per_s": len(frames) / dt,
                "tiles_per_s": r.tiles_total / dt,
                "pred": np.asarray(r.per_tile_pred).tolist(),
            }
    json.dump(out, sys.stdout)


def _spawn(arm: str) -> dict:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    p = subprocess.run(
        [sys.executable, "-m", "benchmarks.pipeline_bench", "--child", arm],
        cwd=root, env=env, capture_output=True, text=True)
    if p.returncode != 0:
        raise RuntimeError(f"pipeline_bench child '{arm}' failed:\n{p.stderr[-4000:]}")
    return json.loads(p.stdout)


def run(json_path: str = JSON_PATH):
    import numpy as np

    from benchmarks.common import counters
    counters()  # train/cache once; the child processes just load

    sweep = _spawn("sweep")
    ref = _spawn("ref")
    eng = _spawn("engine")

    rows, report, max_dev = [], {"sweep": {}, "passes": {}}, 0.0

    def dev_of(r, e):
        return float(np.max(np.abs(np.asarray(r["pred"])
                                   - np.asarray(e["pred"])))) if r["pred"] else 0.0

    for k, r in sweep["ref"].items():
        e = sweep["engine"][k]
        dev = dev_of(r, e)
        max_dev = max(max_dev, dev)
        report["sweep"][k] = {
            "ref_s": r["s"], "engine_s": e["s"], "speedup": r["s"] / e["s"],
            "engine_frames_per_s": e["frames_per_s"],
            "engine_tiles_per_s": e["tiles_per_s"],
            "cmae": e["cmae"], "pred_max_dev": dev,
        }
        rows.append((f"pipeline_{k}", e["s"] * 1e6,
                     f"fps={e['frames_per_s']:.2f} tps={e['tiles_per_s']:.0f} "
                     f"speedup={r['s'] / e['s']:.2f}x dev={dev:.1e}"))

    ref_pass = eng_pass = 0.0
    for k, r in ref["passes"].items():
        e = eng["passes"][k]
        dev = dev_of(r, e)
        max_dev = max(max_dev, dev)
        ref_pass += r["s"]
        eng_pass += e["s"]
        report["passes"][k] = {
            "ref_s": r["s"], "engine_s": e["s"], "speedup": r["s"] / e["s"],
            "tiles": r["tiles"], "engine_tiles_per_s": e["tiles_per_s"],
            "pred_max_dev": dev,
        }
        rows.append((f"pipeline_{k}", e["s"] * 1e6,
                     f"tiles={r['tiles']} tps={e['tiles_per_s']:.0f} "
                     f"speedup={r['s'] / e['s']:.2f}x dev={dev:.1e}"))

    headline = ref_pass / eng_pass
    # machine provenance, mirroring fleet_bench: speedups measured on a
    # sub-2-core box are structure, not throughput — record why any
    # ratio gate downstream treats them as unenforceable
    from benchmarks.fleet_bench import _perf_gates_enforced
    report["_summary"] = {
        "cpu_cores": os.cpu_count(),
        "perf_gates_enforced": _perf_gates_enforced(),
        "targetfuse_pass_sequence_speedup": headline,
        "ref_pass_total_s": ref_pass, "engine_pass_total_s": eng_pass,
        "max_pred_dev": max_dev,
    }
    rows.append(("pipeline_targetfuse_speedup", eng_pass * 1e6,
                 f"{headline:.2f}x (ref {ref_pass:.1f}s -> engine "
                 f"{eng_pass:.1f}s) max_pred_dev={max_dev:.1e}"))
    with open(json_path, "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child(sys.argv[sys.argv.index("--child") + 1])
    else:
        for name, us, derived in run():
            print(f"{name},{us:.1f},{derived}")
