"""Fig. 8: CMAE across computational energy budgets x hardware x contact
time.

Claims checked: longer contact -> lower CMAE at a fixed energy budget;
more energy -> lower CMAE; RPi4-class beats Atlas-class at equal budget
(it processes ~2x the tiles per joule).
"""
from __future__ import annotations

from benchmarks.common import MINI, frames_for, run_method
from repro.core.energy import ATLAS, RPI4


def run():
    frames = frames_for(MINI)
    rows = []
    for hw in (RPI4, ATLAS):
        for budget in (40_000, 80_000, 150_000, 260_000):
            for contact in (180.0, 360.0):
                r = run_method(frames, "targetfuse", hardware=hw,
                               energy_budget_j=budget, contact_s=contact)
                rows.append((
                    f"fig8_{hw.name}_E{budget // 1000}k_t{int(contact)}", 0.0,
                    f"cmae={r.cmae:.3f};proc={r.tiles_processed_space}"))
    return rows
