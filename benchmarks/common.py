"""Shared benchmark scaffolding: cached counters, eval frame sets,
timing helper."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.mission import Mission
from repro.core.pipeline import PipelineConfig
from repro.data.synthetic import DATASETS, SceneSpec, make_scene, revisit_frames

MINI = SceneSpec("mini", 512, (20, 30), (10, 24), cloud_fraction=0.2)

# scaled-down dataset analogues the benchmarks sweep (Table I)
BENCH_DATASETS = {
    "xview": SceneSpec("xview", 768, (30, 60), (8, 20), cloud_fraction=0.3),
    "dota": SceneSpec("dota", 768, (22, 45), (10, 32), cloud_fraction=0.3),
    "uavod": SceneSpec("uavod", 512, (8, 24), (12, 40), cloud_fraction=0.2),
}

_counters = None


def counters():
    """Train-once (disk-cached) reduced counters shared by all figures."""
    global _counters
    if _counters is None:
        from repro.launch.serve import get_counters
        _counters = get_counters(train_steps=(500, 1400), scene=MINI)
    return _counters


def frames_for(spec: SceneSpec, n_scenes=2, revisits=3, seed=1):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_scenes):
        img, b, c = make_scene(rng, spec)
        out += revisit_frames(rng, img, b, c, revisits)
    return out


def time_us(fn, *args, warmup=1, iters=3):
    """Median wall time of fn(*args) in microseconds (post-warmup)."""
    for _ in range(warmup):
        r = fn(*args)
    jax.block_until_ready(r) if hasattr(r, "block_until_ready") else None
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        if hasattr(r, "block_until_ready"):
            r.block_until_ready()
        else:
            jax.tree_util.tree_map(
                lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def run_method(frames, method, **kw):
    """One-window Mission run of a registered selection policy."""
    space, ground = counters()
    pcfg = PipelineConfig(method=method, score_thresh=0.25, **kw)
    return Mission(space, ground, pcfg).run(frames)


_thresholds = {}


def tuned_thresholds(spec: SceneSpec, seed=99):
    """Paper-faithful (conf_p, conf_q) selection: small grid search on a
    held-out calibration frame set (§III-D: 'strategically selecting the
    optimal confidence threshold is crucial'). Cached per dataset."""
    key = spec.name
    if key in _thresholds:
        return _thresholds[key]
    frames = frames_for(spec, n_scenes=1, revisits=2, seed=seed)
    best = (0.10, 0.55)
    best_cmae = np.inf
    for p in (0.02, 0.10, 0.25):
        for q in (0.5, 0.7, 0.85):
            if q <= p:
                continue
            r = run_method(frames, "targetfuse", conf_p=p, conf_q=q)
            if r.cmae < best_cmae:
                best_cmae, best = r.cmae, (p, q)
    _thresholds[key] = best
    return best
