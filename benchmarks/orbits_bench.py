"""Orbital geometry engine benchmarks: catalog-scale batched
propagation, the visibility grid, pass extraction, and eclipse masking.

**Propagation rows** — the headline: >= 4096 satellites x >= 1440 time
steps (``ORBITS_BENCH_SATS`` / ``ORBITS_BENCH_STEPS``) batch-propagated
through ONE jitted program (``propagate_jit``), timed post-warmup so
the number is steady-state execution, not compile time. A second row
propagates a full-catalog-sized scattered shell (14,368 objects — the
CelesTrak catalog size OrbVeil's validation batch-propagates in tens of
ms) over a short screening grid. The gate is sats x steps throughput
(``THROUGHPUT_GATE``), enforced only on full-size runs on
>= ``PERF_GATES_MIN_CORES``-core boxes (same policy as fleet_bench:
smoke configs and starved CI runners record honest numbers, null
gates).

**Visibility / eclipse rows** — the elevation grid
(stations x sats x times, one jitted program), the host-side
segment-scan pass extraction over that grid, and the cylindrical
Earth-shadow mask. The pass-extraction row also reports the pass-mix
skew (median vs p90 duration, max-elevation quartiles) — the
heavy-tailed many-grazes/few-overhead-passes distribution the orbital
scenario path feeds the contact tier.

Writes ``BENCH_orbits.json`` (redirect with ``ORBITS_BENCH_JSON`` —
smoke configs must not clobber the committed full-size report). Gate
failures raise AFTER the report lands, so ``run.py orbits --strict``
exits nonzero while the JSON still records what happened.
"""
from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.orbits import (elevation_deg, extract_passes, shell, sun_direction,
                          station_ecef, walker_delta)
from repro.orbits.propagation import propagate_jit
from repro.orbits.visibility import _eclipse_jit
from repro.orbits.schedule import default_sites

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_orbits.json")
# sats x steps per wall-second through the jitted propagator. Modest on
# purpose: a single contended CI core does ~0.5M; any >= 2-core box
# clears 1M with headroom. The honest number is always recorded.
THROUGHPUT_GATE = 1.0e6
PERF_GATES_MIN_CORES = 2
# the acceptance floor for the headline row
FULL_SATS, FULL_STEPS = 4096, 1440
CATALOG_SIZE = 14_368  # CelesTrak catalog size (OrbVeil validation)


def _perf_gates_enforced() -> bool:
    return (os.cpu_count() or 1) >= PERF_GATES_MIN_CORES


def _time_s(fn, *args, iters=3):
    out = fn(*args)
    out.block_until_ready()  # warm: compile + first dispatch
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _prop_args(elements, times):
    return [jnp.asarray(v) for v in elements.arrays()] + [jnp.asarray(times)]


def _propagation(rows, report):
    n_sats = int(os.environ.get("ORBITS_BENCH_SATS", str(FULL_SATS)))
    n_steps = int(os.environ.get("ORBITS_BENCH_STEPS", str(FULL_STEPS)))
    times = np.arange(n_steps, dtype=np.float64) * 60.0
    els = walker_delta(n_sats, max(d for d in range(1, int(np.sqrt(n_sats)) + 1)
                                   if n_sats % d == 0), 53.0, 550.0)
    t = _time_s(propagate_jit, *_prop_args(els, times))
    tput = n_sats * n_steps / t
    report["propagation"] = {
        "n_sats": n_sats, "n_steps": n_steps, "seconds": t,
        "sat_steps_per_s": tput,
        "full_size": n_sats >= FULL_SATS and n_steps >= FULL_STEPS,
    }
    rows.append((f"orbits_prop_{n_sats}x{n_steps}", t * 1e6,
                 f"{tput / 1e6:.2f}M sat-steps/s one jitted program"))

    # the full-catalog screening shape (short grid: sizing, not horizon)
    cat_steps = min(n_steps, 90)
    cat = shell(CATALOG_SIZE, 53.0, 550.0)
    tc = _time_s(propagate_jit,
                 *_prop_args(cat, np.arange(cat_steps, dtype=np.float64)
                             * 60.0))
    report["propagation_catalog"] = {
        "n_sats": CATALOG_SIZE, "n_steps": cat_steps, "seconds": tc,
        "sat_steps_per_s": CATALOG_SIZE * cat_steps / tc,
        "ms_per_step_full_catalog": tc / cat_steps * 1e3,
    }
    rows.append((f"orbits_catalog_{CATALOG_SIZE}x{cat_steps}", tc * 1e6,
                 f"{tc / cat_steps * 1e3:.1f} ms per full-catalog step"))
    return times, els


def _visibility(rows, report, times, els):
    # memory-aware: the elevation grid is stations x sats x times f32 —
    # cap the sats/steps slab so smoke and full runs both fit easily
    n_st = int(os.environ.get("ORBITS_BENCH_STATIONS", "8"))
    n_sats = min(els.n_sats, 1024)
    n_steps = min(times.shape[0], FULL_STEPS)
    sub = shell(n_sats, 53.0, 550.0)
    t_grid = times[:n_steps]
    pos = propagate_jit(*_prop_args(sub, t_grid))
    pos.block_until_ready()
    sites = np.stack([station_ecef(*s) for s in default_sites(n_st)])

    tv = _time_s(lambda: elevation_deg(pos, t_grid, sites))
    report["visibility"] = {
        "n_stations": n_st, "n_sats": n_sats, "n_steps": n_steps,
        "seconds": tv,
        "station_sat_steps_per_s": n_st * n_sats * n_steps / tv,
    }
    rows.append((f"orbits_elev_{n_st}x{n_sats}x{n_steps}", tv * 1e6,
                 f"{n_st * n_sats * n_steps / tv / 1e6:.2f}M "
                 f"station-sat-steps/s"))

    elev = np.asarray(elevation_deg(pos, t_grid, sites))
    t0 = time.perf_counter()
    ps = extract_passes(elev, t_grid, 10.0)
    tp = time.perf_counter() - t0
    dur = np.sort(ps.duration_s)
    skew = (float(np.percentile(dur, 90) / max(np.median(dur), 1e-9))
            if ps.n_passes else 0.0)
    report["passes"] = {
        "seconds": tp, "n_passes": ps.n_passes,
        "duration_p50_s": float(np.median(dur)) if ps.n_passes else 0.0,
        "duration_p90_s": (float(np.percentile(dur, 90))
                           if ps.n_passes else 0.0),
        "duration_max_s": float(dur[-1]) if ps.n_passes else 0.0,
        "p90_over_p50": skew,
        "max_elev_p50_deg": (float(np.median(ps.max_elev_deg))
                             if ps.n_passes else 0.0),
        "max_elev_p90_deg": (float(np.percentile(ps.max_elev_deg, 90))
                             if ps.n_passes else 0.0),
    }
    rows.append((f"orbits_passes_{ps.n_passes}", tp * 1e6,
                 f"segment-scan extraction; p90/p50 duration "
                 f"{skew:.2f}x (skewed pass mix)"))

    te = _time_s(lambda: _eclipse_jit(pos, sun_direction(t_grid)))
    report["eclipse"] = {
        "n_sats": n_sats, "n_steps": n_steps, "seconds": te,
        "sat_steps_per_s": n_sats * n_steps / te,
    }
    rows.append((f"orbits_eclipse_{n_sats}x{n_steps}", te * 1e6,
                 "cylindrical shadow mask, one jitted program"))


def run(json_path: str = None):
    if json_path is None:
        json_path = os.environ.get("ORBITS_BENCH_JSON", JSON_PATH)
    rows, report = [], {}
    times, els = _propagation(rows, report)
    _visibility(rows, report, times, els)

    perf_on = _perf_gates_enforced()
    prop = report["propagation"]
    report["_summary"] = {
        "cpu_cores": os.cpu_count(),
        "perf_gates_enforced": perf_on,
        "sat_steps_per_s": prop["sat_steps_per_s"],
        "throughput_gate": THROUGHPUT_GATE,
        "gate_throughput": (prop["sat_steps_per_s"] >= THROUGHPUT_GATE
                            if prop["full_size"] and perf_on else None),
        "pass_skew_p90_over_p50": report["passes"]["p90_over_p50"],
    }
    rows.append(("orbits_summary", 0.0,
                 f"prop={prop['sat_steps_per_s'] / 1e6:.2f}M sat-steps/s "
                 f"gate={report['_summary']['gate_throughput']} "
                 f"skew={report['passes']['p90_over_p50']:.2f}x"))
    with open(json_path, "w") as f:
        json.dump(report, f, indent=2)
    # gates raise AFTER the report lands (run.py --strict semantics)
    if report["_summary"]["gate_throughput"] is False:
        raise AssertionError(
            f"propagation throughput gate: "
            f"{prop['sat_steps_per_s'] / 1e6:.2f}M sat-steps/s < "
            f"{THROUGHPUT_GATE / 1e6:.2f}M at "
            f"{prop['n_sats']}x{prop['n_steps']} (see {json_path})")
    return rows
