"""Vision Transformer (ViT-L/16, ViT-H/14) — pre-norm, cls token,
learned position embeddings, GELU MLP, scan-over-layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import VisionConfig
from repro.models import layers as L
from repro.kernels import ops as kops


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def _init_block(key, cfg: VisionConfig):
    d, f = cfg.d_model, cfg.d_ff
    dt = _dt(cfg)
    ks = jax.random.split(key, 6)
    return {
        "ln1_s": jnp.ones((d,), dt), "ln1_b": jnp.zeros((d,), dt),
        "ln2_s": jnp.ones((d,), dt), "ln2_b": jnp.zeros((d,), dt),
        "wqkv": L.dense_init(ks[0], d, 3 * d, dt),
        "bqkv": jnp.zeros((3 * d,), dt),
        "wo": L.dense_init(ks[1], d, d, dt),
        "bo": jnp.zeros((d,), dt),
        "w_in": L.dense_init(ks[2], d, f, dt),
        "b_in": jnp.zeros((f,), dt),
        "w_out": L.dense_init(ks[3], f, d, dt),
        "b_out": jnp.zeros((d,), dt),
    }


def init(key, cfg: VisionConfig):
    dt = _dt(cfg)
    n_tok = (cfg.img_res // cfg.patch) ** 2 + 1  # + cls
    ks = jax.random.split(key, 5)
    params = {
        "patch_w": L.conv_init(ks[0], cfg.patch, cfg.patch, 3, cfg.d_model, dt),
        "patch_b": jnp.zeros((cfg.d_model,), dt),
        "cls": L.truncated_normal(ks[1], (1, 1, cfg.d_model), dt, 0.02),
        "pos": L.truncated_normal(ks[2], (1, n_tok, cfg.d_model), dt, 0.02),
        "blocks": jax.vmap(lambda k: _init_block(k, cfg))(
            jax.random.split(ks[3], cfg.n_layers)
        ),
        "ln_f_s": jnp.ones((cfg.d_model,), dt),
        "ln_f_b": jnp.zeros((cfg.d_model,), dt),
        "head": L.dense_init(ks[4], cfg.d_model, cfg.n_classes, dt, 0.02),
    }
    return params


def _block(p, cfg, x):
    b, s, d = x.shape
    h = L.layernorm(x, p["ln1_s"], p["ln1_b"])
    qkv = jnp.einsum("bsd,dk->bsk", h, p["wqkv"]) + p["bqkv"]
    q, k, v = jnp.split(qkv.reshape(b, s, 3 * cfg.n_heads, d // cfg.n_heads), 3, axis=2)
    a = kops.attention(q, k, v, causal=False)
    x = x + jnp.einsum("bsd,dk->bsk", a.reshape(b, s, d), p["wo"]) + p["bo"]
    h = L.layernorm(x, p["ln2_s"], p["ln2_b"])
    return x + L.gelu_mlp(h, p["w_in"], p["b_in"], p["w_out"], p["b_out"])


def forward(params, cfg: VisionConfig, images, train: bool = False):
    """images (B, H, W, 3) -> logits (B, n_classes)."""
    x = L.conv2d(images.astype(_dt(cfg)), params["patch_w"], stride=cfg.patch,
                 padding="VALID") + params["patch_b"]
    b, gh, gw, d = x.shape
    x = x.reshape(b, gh * gw, d)
    # interpolate pos embedding if resolution differs from init (cls_384)
    pos = params["pos"]
    n_img = pos.shape[1] - 1
    if gh * gw != n_img:
        side = int(round(n_img ** 0.5))
        grid = pos[:, 1:, :].reshape(1, side, side, d)
        grid = jax.image.resize(grid.astype(jnp.float32), (1, gh, gw, d), "bilinear").astype(pos.dtype)
        pos = jnp.concatenate([pos[:, :1, :], grid.reshape(1, gh * gw, d)], axis=1)
    cls = jnp.broadcast_to(params["cls"], (b, 1, d)).astype(x.dtype)
    x = jnp.concatenate([cls, x], axis=1) + pos

    def body(xb, p):
        return _block(p, cfg, xb), None

    if cfg.remat != "none" and train:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.dots_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["blocks"])
    else:
        for i in range(cfg.n_layers):
            x, _ = body(x, jax.tree_util.tree_map(lambda a: a[i], params["blocks"]))
    x = L.layernorm(x, params["ln_f_s"], params["ln_f_b"])
    return jnp.einsum("bd,dc->bc", x[:, 0, :], params["head"]).astype(jnp.float32)
