"""Decoder-only LM trunk covering all four assigned LM archs.

Features: RoPE, GQA (optional qk-norm), SwiGLU dense FFN, MoE FFN
(shared + routed top-k, group-wise capacity dispatch), MLA attention
with compressed KV cache (naive and absorbed decode paths),
scan-over-layers with optional remat, KV-cache prefill/decode.

Layer layout: `first_dense_layers` dense-FFN layers (stacked+scanned)
followed by the remaining layers (MoE if cfg.moe else dense), also
stacked+scanned — two homogeneous scans max.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import layers as L
from repro.models.moe import init_moe, moe_block
from repro.sharding import ctx
from repro.kernels import ops as kops


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_attn(key, cfg: LMConfig):
    dt = _dt(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    if cfg.mla is not None:
        m = cfg.mla
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        p = {
            "wq": L.dense_init(ks[0], d, cfg.n_heads * qk_head, dt),
            "w_dkv": L.dense_init(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim, dt),
            "kv_norm": jnp.ones((m.kv_lora_rank,), dt),
            "w_uk": L.dense_init(ks[2], m.kv_lora_rank, cfg.n_heads * m.qk_nope_head_dim, dt),
            "w_uv": L.dense_init(ks[3], m.kv_lora_rank, cfg.n_heads * m.v_head_dim, dt),
            "wo": L.dense_init(ks[4], cfg.n_heads * m.v_head_dim, d, dt),
        }
        return p
    p = {
        "wq": L.dense_init(ks[0], d, cfg.n_heads * cfg.head_dim, dt),
        "wk": L.dense_init(ks[1], d, cfg.n_kv_heads * cfg.head_dim, dt),
        "wv": L.dense_init(ks[2], d, cfg.n_kv_heads * cfg.head_dim, dt),
        "wo": L.dense_init(ks[3], cfg.n_heads * cfg.head_dim, d, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dt)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dt)
    return p


def _init_dense_ffn(key, cfg: LMConfig):
    dt = _dt(cfg)
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": L.dense_init(k1, d, f, dt),
        "w_up": L.dense_init(k2, d, f, dt),
        "w_down": L.dense_init(k3, f, d, dt),
    }


def _init_block(key, cfg: LMConfig, use_moe: bool):
    dt = _dt(cfg)
    k1, k2 = jax.random.split(key)
    blk = {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "ln2": jnp.ones((cfg.d_model,), dt),
        "attn": _init_attn(k1, cfg),
    }
    if use_moe:
        blk["moe"] = init_moe(k2, cfg)
    else:
        blk["mlp"] = _init_dense_ffn(k2, cfg)
    return blk


def init(key, cfg: LMConfig):
    ke, kb, kh = jax.random.split(key, 3)
    dt = _dt(cfg)
    params = {
        "embed": L.truncated_normal(ke, (cfg.vocab_size, cfg.d_model), dt, 0.02),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    n_dense = cfg.moe.first_dense_layers if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if cfg.moe else 0
    if cfg.moe is None:
        n_dense, n_moe = cfg.n_layers, 0
    keys = jax.random.split(kb, cfg.n_layers)
    if n_dense:
        params["blocks_dense"] = jax.vmap(lambda k: _init_block(k, cfg, False))(
            keys[:n_dense]
        )
    if n_moe:
        params["blocks_moe"] = jax.vmap(lambda k: _init_block(k, cfg, True))(
            keys[n_dense:]
        )
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(kh, cfg.d_model, cfg.vocab_size, dt, 0.02)
    return params


# ---------------------------------------------------------------------------
# block apply
# ---------------------------------------------------------------------------


def _gqa_attend(p, cfg: LMConfig, x, positions, mode, cache=None, pos=None):
    """mode: 'train' | 'prefill' | 'decode'. Returns (out, new_cache, aux)."""
    b, s, d = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"]).reshape(b, s, hk, hd)
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"]).reshape(b, s, hk, hd)
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    new_cache = None
    if mode in ("train", "prefill"):
        # Attention layout: head-parallel when heads divide the model
        # axis; otherwise sequence-parallel q with K/V replicated over
        # "model" — without this, XLA contracts over a sharded head_dim
        # and ALL-REDUCES the full (S, S) logits per layer (TB/device
        # at 32k for 24-head phi4).
        msize = ctx.axis_size("model")
        batch_ax = ("pod", "data")
        if msize and h % msize == 0 and hk % msize == 0:
            q = ctx.constrain(q, batch_ax, None, "model", None)
            k = ctx.constrain(k, batch_ax, None, "model", None)
            v = ctx.constrain(v, batch_ax, None, "model", None)
        elif msize:
            q = ctx.constrain(q, batch_ax, "model", None, None)
            k = ctx.constrain(k, batch_ax, None, None, None)
            v = ctx.constrain(v, batch_ax, None, None, None)
        o = kops.attention(q, k, v, causal=True)
        if mode == "prefill":
            new_cache = {"k": k, "v": v}
    else:  # decode: s == 1, cache holds full-length k/v
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        kv_len = jnp.full((b,), pos + 1, jnp.int32)
        o = kops.decode_attention(q, ck, cv, kv_len=kv_len)
        new_cache = {"k": ck, "v": cv}
    out = jnp.einsum("bsk,kd->bsd", o.reshape(b, s, h * hd), p["wo"])
    return out, new_cache


def _mla_attend(p, cfg: LMConfig, x, positions, mode, cache=None, pos=None,
                absorb: bool = True):
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"]).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    ckv_full = jnp.einsum("bsd,dk->bsk", x, p["w_dkv"])
    c_kv, k_rope = ckv_full[..., : m.kv_lora_rank], ckv_full[..., m.kv_lora_rank:]
    c_kv = L.rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # 1 head

    def expand_kv(ckv):
        k_nope = jnp.einsum("bsl,lk->bsk", ckv, p["w_uk"]).reshape(-1, ckv.shape[1], h, nope)
        vv = jnp.einsum("bsl,lk->bsk", ckv, p["w_uv"]).reshape(-1, ckv.shape[1], h, vd)
        return k_nope, vv

    new_cache = None
    if mode in ("train", "prefill"):
        k_nope, v = expand_kv(c_kv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, rope_d))], -1)
        qq = jnp.concatenate([q_nope, q_rope], -1)
        # pad v to qk head dim so the fused kernel sees uniform head_dim
        o = kops.attention(qq, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, nope + rope_d - vd))), causal=True)
        o = o[..., :vd]
        if mode == "prefill":
            new_cache = {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}
    else:  # decode
        cc = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, pos, 0))
        cr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope[:, :, 0, :], (0, pos, 0))
        new_cache = {"c_kv": cc, "k_rope": cr}
        kv_len = jnp.full((b,), pos + 1, jnp.int32)
        if absorb:
            # project q_nope into latent space: (b,1,h,nope) @ (lora,h*nope)^T
            w_uk = p["w_uk"].reshape(m.kv_lora_rank, h, nope)
            q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, w_uk)  # (b,1,h,lora)
            # scores: latent part + rope part — cache stays in storage
            # dtype (f32 casts of a 512k-long latent cache are terabytes)
            scale = 1.0 / math.sqrt(nope + rope_d)
            sc = (
                jnp.einsum("bshl,btl->bhst", q_lat, cc,
                           preferred_element_type=jnp.float32)
                + jnp.einsum("bshr,btr->bhst", q_rope, cr,
                             preferred_element_type=jnp.float32)
            ) * scale
            t_idx = jnp.arange(cc.shape[1])
            valid = t_idx[None, :] < kv_len[:, None]
            sc = jnp.where(valid[:, None, None, :], sc, -1e30)
            w = jax.nn.softmax(sc, axis=-1)
            o_lat = jnp.einsum("bhst,btl->bshl", w.astype(cc.dtype), cc,
                               preferred_element_type=jnp.float32)
            w_uv = p["w_uv"].reshape(m.kv_lora_rank, h, vd)
            o = jnp.einsum("bshl,lhv->bshv", o_lat.astype(x.dtype), w_uv).astype(x.dtype)
        else:
            k_nope, v = expand_kv(cc)
            k = jnp.concatenate(
                [k_nope, jnp.broadcast_to(cr[:, :, None, :], (*cr.shape[:2], h, rope_d))], -1
            )
            qq = jnp.concatenate([q_nope, q_rope], -1)
            o = kops.decode_attention(qq, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, nope + rope_d - vd))), kv_len=kv_len)
            o = o[..., :vd]
    out = jnp.einsum("bsk,kd->bsd", o.reshape(b, s, h * vd), p["wo"])
    return out, new_cache


def _block(p, cfg: LMConfig, x, positions, mode, use_moe, cache=None, pos=None,
           absorb=True):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, new_cache = _mla_attend(p["attn"], cfg, h, positions, mode, cache, pos, absorb)
    else:
        a, new_cache = _gqa_attend(p["attn"], cfg, h, positions, mode, cache, pos)
    x = x + a
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if use_moe:
        f, aux = moe_block(p["moe"], cfg, h)
    else:
        f = L.swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
        aux = jnp.zeros((), jnp.float32)
    return x + f, new_cache, aux


def _remat_policy(cfg):
    if cfg.remat == "full":
        return None  # save nothing
    if cfg.remat == "dots_saveable":
        return jax.checkpoint_policies.dots_saveable
    return None


def _scan_blocks(blocks, cfg, x, positions, mode, use_moe, caches=None,
                 pos=None, absorb=True):
    """Run a homogeneous stack (stacked on axis 0): lax.scan when
    cfg.scan_layers (compact HLO), python unroll otherwise (exact
    dry-run cost accounting)."""

    def body(carry, xs):
        xb, aux_acc = carry
        p, c = xs
        y, new_c, aux = _block(p, cfg, xb, positions, mode, use_moe, c, pos, absorb)
        return (y, aux_acc + aux), new_c

    body_fn = body
    if cfg.remat != "none" and mode == "train":
        body_fn = jax.checkpoint(body, policy=_remat_policy(cfg))

    if cfg.scan_layers:
        (x, aux), new_caches = jax.lax.scan(
            body_fn, (x, jnp.zeros((), jnp.float32)), (blocks, caches)
        )
        return x, new_caches, aux

    n_layers = jax.tree_util.tree_leaves(blocks)[0].shape[0]
    carry = (x, jnp.zeros((), jnp.float32))
    outs = []
    for i in range(n_layers):
        p_i = jax.tree_util.tree_map(lambda a: a[i], blocks)
        c_i = (None if caches is None
               else jax.tree_util.tree_map(lambda a: a[i], caches))
        carry, new_c = body_fn(carry, (p_i, c_i))
        outs.append(new_c)
    x, aux = carry
    if outs and outs[0] is not None:
        new_caches = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
    else:
        new_caches = None
    return x, new_caches, aux


def _trunk(params, cfg: LMConfig, x, positions, mode, caches=None, pos=None,
           absorb=True):
    """Runs all blocks. caches: dict with same keys as params block stacks."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {}
    for name, use_moe in (("blocks_dense", False), ("blocks_moe", True)):
        if name not in params:
            continue
        c = caches[name] if caches is not None else None
        x, nc, aux = _scan_blocks(params[name], cfg, x, positions, mode, use_moe, c, pos, absorb)
        aux_total = aux_total + aux
        new_caches[name] = nc
    return x, new_caches, aux_total


def _make_cache_placeholder(cfg, n_layers, b, s_max, dtype):
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((n_layers, b, s_max, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((n_layers, b, s_max, m.qk_rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros((n_layers, b, s_max, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((n_layers, b, s_max, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


def init_cache(cfg: LMConfig, batch: int, max_len: int):
    """Full KV cache pytree (stacked per homogeneous block group)."""
    dt = _dt(cfg)
    n_dense = cfg.moe.first_dense_layers if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if cfg.moe else 0
    caches = {}
    if n_dense:
        caches["blocks_dense"] = _make_cache_placeholder(cfg, n_dense, batch, max_len, dt)
    if n_moe:
        caches["blocks_moe"] = _make_cache_placeholder(cfg, n_moe, batch, max_len, dt)
    return caches


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def _logits(params, cfg, x):
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, w)
    return jnp.einsum("bsd,dv->bsv", x, w)


def forward_train(params, cfg: LMConfig, tokens):
    """tokens (B,S) -> logits (B,S,V), aux loss scalar."""
    b, s = tokens.shape
    x = params["embed"][tokens]  # gather
    positions = jnp.arange(s)[None, :].astype(jnp.int32)
    x, _, aux = _trunk(params, cfg, x, positions, "train")
    return _logits(params, cfg, x), aux


def loss_fn(params, cfg: LMConfig, tokens, labels):
    logits, aux = forward_train(params, cfg, tokens)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # label log-prob via one-hot contraction: shards cleanly over a
    # vocab-sharded logits tensor (take_along_axis would force XLA to
    # all-gather the full (B,S,V) logits — hundreds of GB/device)
    onehot = jax.nn.one_hot(labels, cfg.vocab_size, dtype=logits.dtype)
    ll = jnp.einsum("bsv,bsv->bs", logits, onehot)
    ce = jnp.mean(lse - ll)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


def prefill(params, cfg: LMConfig, tokens):
    """tokens (B,S) -> (last-token logits (B,V), cache)."""
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(s)[None, :].astype(jnp.int32)
    x, caches, _ = _trunk(params, cfg, x, positions, "prefill")
    return _logits(params, cfg, x[:, -1:, :])[:, 0], caches


def decode_step(params, cfg: LMConfig, token, caches, pos, absorb: bool = True):
    """token (B,1) int32; caches from init_cache/prefill; pos scalar int32.

    Returns (logits (B,V), new_caches).
    """
    x = params["embed"][token]
    positions = jnp.full(token.shape, pos, jnp.int32)
    x, new_caches, _ = _trunk(params, cfg, x, positions, "decode", caches, pos, absorb)
    return _logits(params, cfg, x)[:, 0], new_caches
