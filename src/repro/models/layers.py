"""Shared neural-net primitives (pure JAX, no flax).

Conventions:
- params are nested dicts of jnp arrays; ``init_*`` builds them, the
  matching ``apply``-style fns are pure.
- scan-over-layers models stack per-layer params on a leading axis.
- all matmuls go through einsum so sharding propagation stays clean.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, dtype, stddev):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * stddev).astype(dtype)


def dense_init(key, d_in, d_out, dtype, stddev=None):
    stddev = stddev if stddev is not None else 1.0 / math.sqrt(d_in)
    return truncated_normal(key, (d_in, d_out), dtype, stddev)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layernorm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def groupnorm(x, scale, bias, groups=32, eps=1e-5):
    """GroupNorm over the channel (last) axis of NHWC tensors."""
    dt = x.dtype
    b, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xf = x.astype(jnp.float32).reshape(b, h, w, g, c // g)
    mu = jnp.mean(xf, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xf, axis=(1, 2, 4), keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    xf = xf.reshape(b, h, w, c)
    return (xf * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D). positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations / MLP
# ---------------------------------------------------------------------------


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    h = h * jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", h, w_down)


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_in) + b_in)
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out


# ---------------------------------------------------------------------------
# Embedding helpers
# ---------------------------------------------------------------------------


def sinusoidal_embedding(t, dim, max_period=10000.0):
    """t: (B,) float/int -> (B, dim) classic transformer/diffusion timestep emb."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


# ---------------------------------------------------------------------------
# Conv helpers (NHWC)
# ---------------------------------------------------------------------------


def conv_init(key, kh, kw, c_in, c_out, dtype):
    stddev = 1.0 / math.sqrt(kh * kw * c_in)
    return truncated_normal(key, (kh, kw, c_in, c_out), dtype, stddev)


def conv2d(x, w, stride=1, padding="SAME", groups=1):
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def batchnorm_train(x, scale, bias, eps=1e-5):
    """Returns (y, batch_mean, batch_var) — caller maintains running stats."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=(0, 1, 2))
    var = jnp.var(xf, axis=(0, 1, 2))
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(x.dtype), mu, var


def batchnorm_eval(x, scale, bias, mean, var, eps=1e-5):
    xf = x.astype(jnp.float32)
    y = (xf - mean) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(x.dtype)
