"""Diffusion substrate shared by DiT and UNet: DDPM cosine schedule,
eps-prediction training loss, DDIM sampler as a lax.scan (one forward
per step, matching the assignment's sampler semantics).
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

N_TRAIN_STEPS = 1000


def alphas_cumprod(n=N_TRAIN_STEPS):
    t = jnp.arange(n + 1, dtype=jnp.float32) / n
    f = jnp.cos((t + 0.008) / 1.008 * math.pi / 2) ** 2
    a = jnp.clip(f / f[0], 1e-5, 1.0)
    return a[1:]


def add_noise(latents, noise, t):
    """q(x_t | x_0): t int (B,) in [0, N)."""
    a = alphas_cumprod()[t][:, None, None, None]
    return jnp.sqrt(a) * latents + jnp.sqrt(1 - a) * noise


def train_loss(eps_fn: Callable, latents, key):
    """eps_fn(x_t, t) -> eps_hat. Returns scalar MSE loss."""
    b = latents.shape[0]
    kt, kn = jax.random.split(key)
    t = jax.random.randint(kt, (b,), 0, N_TRAIN_STEPS)
    noise = jax.random.normal(kn, latents.shape, jnp.float32)
    x_t = add_noise(latents.astype(jnp.float32), noise, t)
    eps = eps_fn(x_t, t)
    return jnp.mean(jnp.square(eps - noise))


def ddim_step(eps_fn: Callable, x, t_cur, t_prev):
    """One deterministic DDIM update from t_cur to t_prev (ints)."""
    a = alphas_cumprod()
    a_cur = a[t_cur]
    a_prev = jnp.where(t_prev >= 0, a[jnp.maximum(t_prev, 0)], 1.0)
    eps = eps_fn(x, jnp.full((x.shape[0],), t_cur, jnp.int32))
    x0 = (x - jnp.sqrt(1 - a_cur) * eps) / jnp.sqrt(a_cur)
    return jnp.sqrt(a_prev) * x0 + jnp.sqrt(1 - a_prev) * eps


def sample(eps_fn: Callable, key, shape, n_steps: int):
    """Full DDIM sampler: n_steps forwards via lax.scan."""
    ts = jnp.linspace(N_TRAIN_STEPS - 1, 0, n_steps + 1).astype(jnp.int32)
    x = jax.random.normal(key, shape, jnp.float32)

    def body(x, i):
        return ddim_step(eps_fn, x, ts[i], ts[i + 1]), None

    x, _ = jax.lax.scan(body, x, jnp.arange(n_steps))
    return x
