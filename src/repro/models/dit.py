"""DiT (Diffusion Transformer, DiT-S/2) — adaLN-Zero conditioning,
patchified VAE latents (stub VAE: 8x downsample, 4 channels),
scan-over-layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import DiffusionConfig
from repro.models import layers as L
from repro.kernels import ops as kops


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def latent_res(cfg: DiffusionConfig, img_res=None):
    return (img_res or cfg.img_res) // cfg.latent_factor


def _init_block(key, cfg):
    d = cfg.d_model
    dt = _dt(cfg)
    ks = jax.random.split(key, 5)
    return {
        "wqkv": L.dense_init(ks[0], d, 3 * d, dt),
        "wo": L.dense_init(ks[1], d, d, dt),
        "w_in": L.dense_init(ks[2], d, 4 * d, dt),
        "w_out": L.dense_init(ks[3], 4 * d, d, dt),
        # adaLN-zero: 6 gates/shifts/scales from conditioning; zero-init
        "ada_w": jnp.zeros((d, 6 * d), dt),
        "ada_b": jnp.zeros((6 * d,), dt),
    }


def init(key, cfg: DiffusionConfig):
    dt = _dt(cfg)
    d = cfg.d_model
    c = cfg.latent_ch
    p = cfg.patch
    ks = jax.random.split(key, 8)
    return {
        "patch_w": L.dense_init(ks[0], c * p * p, d, dt),
        "patch_b": jnp.zeros((d,), dt),
        "t_w1": L.dense_init(ks[1], 256, d, dt), "t_b1": jnp.zeros((d,), dt),
        "t_w2": L.dense_init(ks[2], d, d, dt), "t_b2": jnp.zeros((d,), dt),
        "y_emb": L.truncated_normal(ks[3], (cfg.n_classes + 1, d), dt, 0.02),
        "blocks": jax.vmap(lambda k: _init_block(k, cfg))(
            jax.random.split(ks[4], cfg.n_layers)
        ),
        "final_ada_w": jnp.zeros((d, 2 * d), dt),
        "final_ada_b": jnp.zeros((2 * d,), dt),
        "final_w": jnp.zeros((d, p * p * c * 2), dt),
        "final_b": jnp.zeros((p * p * c * 2,), dt),
    }


def _modulate(x, shift, scale):
    return x * (1 + scale[:, None, :]) + shift[:, None, :]


def _block(p, cfg, x, cond):
    b, s, d = x.shape
    ada = jnp.einsum("bd,dk->bk", jax.nn.silu(cond), p["ada_w"]) + p["ada_b"]
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(ada, 6, axis=-1)
    h = _modulate(_ln(x), sh1, sc1)
    qkv = jnp.einsum("bsd,dk->bsk", h, p["wqkv"])
    q, k, v = jnp.split(qkv.reshape(b, s, 3 * cfg.n_heads, d // cfg.n_heads), 3, axis=2)
    a = kops.attention(q, k, v, causal=False).reshape(b, s, d)
    x = x + g1[:, None, :] * jnp.einsum("bsd,dk->bsk", a, p["wo"])
    h = _modulate(_ln(x), sh2, sc2)
    h = jnp.einsum("bsd,df->bsf", h, p["w_in"])
    h = jax.nn.gelu(h)
    h = jnp.einsum("bsf,fd->bsd", h, p["w_out"])
    return x + g2[:, None, :] * h


def _ln(x):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)


def forward(params, cfg: DiffusionConfig, latents, t, y, train: bool = False):
    """latents (B, Hl, Wl, C); t (B,) timesteps; y (B,) class ids.

    Returns (eps_pred, sigma_pred) each (B, Hl, Wl, C).
    """
    dt = _dt(cfg)
    b, hl, wl, c = latents.shape
    p = cfg.patch
    gh, gw = hl // p, wl // p
    x = latents.astype(dt).reshape(b, gh, p, gw, p, c).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(b, gh * gw, p * p * c)
    x = jnp.einsum("bsk,kd->bsd", x, params["patch_w"]) + params["patch_b"]
    # 2D sin-cos position embedding (resolution-agnostic -> gen_1024 works)
    d = cfg.d_model
    ph = L.sinusoidal_embedding(jnp.arange(gh), d // 2)
    pw = L.sinusoidal_embedding(jnp.arange(gw), d // 2)
    pos = jnp.concatenate([
        jnp.broadcast_to(ph[:, None, :], (gh, gw, d // 2)),
        jnp.broadcast_to(pw[None, :, :], (gh, gw, d // 2)),
    ], -1).reshape(1, gh * gw, d)
    x = x + pos.astype(dt)

    temb = L.sinusoidal_embedding(t, 256).astype(dt)
    cond = jnp.einsum("bk,kd->bd", temb, params["t_w1"]) + params["t_b1"]
    cond = jax.nn.silu(cond)
    cond = jnp.einsum("bd,dk->bk", cond, params["t_w2"]) + params["t_b2"]
    cond = cond + params["y_emb"][y]

    def body(xb, pb):
        return _block(pb, cfg, xb, cond), None

    if cfg.remat != "none" and train:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.dots_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["blocks"])
    else:
        for i in range(cfg.n_layers):
            x, _ = body(x, jax.tree_util.tree_map(lambda a: a[i], params["blocks"]))

    ada = jnp.einsum("bd,dk->bk", jax.nn.silu(cond), params["final_ada_w"]) + params["final_ada_b"]
    sh, sc = jnp.split(ada, 2, axis=-1)
    x = _modulate(_ln(x), sh, sc)
    x = jnp.einsum("bsd,dk->bsk", x, params["final_w"]) + params["final_b"]
    x = x.reshape(b, gh, gw, p, p, 2 * c).transpose(0, 1, 3, 2, 4, 5).reshape(b, hl, wl, 2 * c)
    return x[..., :c].astype(jnp.float32), x[..., c:].astype(jnp.float32)
