"""SD-1.5-class UNet — res blocks with time embedding, spatial
transformer blocks (self-attn + cross-attn over a ctx_len text stub +
GEGLU FF) at the configured levels, skip-connected encoder/decoder.

Modality frontend is a stub per the assignment: the model consumes VAE
latents (img_res/8, 4ch) and precomputed text embeddings (B, 77, 768).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import DiffusionConfig
from repro.models import layers as L
from repro.kernels import ops as kops


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def _init_res(key, c_in, c_out, temb_dim, dt):
    ks = jax.random.split(key, 4)
    p = {
        "gn1_s": jnp.ones((c_in,), dt), "gn1_b": jnp.zeros((c_in,), dt),
        "w1": L.conv_init(ks[0], 3, 3, c_in, c_out, dt),
        "temb_w": L.dense_init(ks[1], temb_dim, c_out, dt),
        "temb_b": jnp.zeros((c_out,), dt),
        "gn2_s": jnp.ones((c_out,), dt), "gn2_b": jnp.zeros((c_out,), dt),
        "w2": L.conv_init(ks[2], 3, 3, c_out, c_out, dt),
    }
    if c_in != c_out:
        p["skip_w"] = L.conv_init(ks[3], 1, 1, c_in, c_out, dt)
    return p


def _init_xformer(key, c, ctx_dim, dt):
    ks = jax.random.split(key, 10)
    return {
        "gn_s": jnp.ones((c,), dt), "gn_b": jnp.zeros((c,), dt),
        "proj_in": L.dense_init(ks[0], c, c, dt),
        "ln1_s": jnp.ones((c,), dt), "ln1_b": jnp.zeros((c,), dt),
        "sa_qkv": L.dense_init(ks[1], c, 3 * c, dt),
        "sa_o": L.dense_init(ks[2], c, c, dt),
        "ln2_s": jnp.ones((c,), dt), "ln2_b": jnp.zeros((c,), dt),
        "ca_q": L.dense_init(ks[3], c, c, dt),
        "ca_k": L.dense_init(ks[4], ctx_dim, c, dt),
        "ca_v": L.dense_init(ks[5], ctx_dim, c, dt),
        "ca_o": L.dense_init(ks[6], c, c, dt),
        "ln3_s": jnp.ones((c,), dt), "ln3_b": jnp.zeros((c,), dt),
        "ff_in": L.dense_init(ks[7], c, 8 * c, dt),  # GEGLU: 2x4c
        "ff_out": L.dense_init(ks[8], 4 * c, c, dt),
        "proj_out": L.dense_init(ks[9], c, c, dt),
    }


def init(key, cfg: DiffusionConfig):
    dt = _dt(cfg)
    ch = cfg.ch
    temb_dim = 4 * ch
    chans = [ch * m for m in cfg.ch_mult]
    ks = iter(jax.random.split(key, 256))
    p = {
        "t_w1": L.dense_init(next(ks), ch, temb_dim, dt), "t_b1": jnp.zeros((temb_dim,), dt),
        "t_w2": L.dense_init(next(ks), temb_dim, temb_dim, dt), "t_b2": jnp.zeros((temb_dim,), dt),
        "conv_in": L.conv_init(next(ks), 3, 3, cfg.latent_ch, ch, dt),
    }
    # down path
    down = []
    c_prev = ch
    for lvl, c in enumerate(chans):
        blocks = []
        for _ in range(cfg.n_res_blocks):
            blk = {"res": _init_res(next(ks), c_prev, c, temb_dim, dt)}
            if lvl in cfg.attn_levels:
                blk["attn"] = _init_xformer(next(ks), c, cfg.ctx_dim, dt)
            blocks.append(blk)
            c_prev = c
        stage = {"blocks": blocks}
        if lvl + 1 < len(chans):
            stage["down_w"] = L.conv_init(next(ks), 3, 3, c, c, dt)
        down.append(stage)
    p["down"] = down
    # mid
    p["mid"] = {
        "res1": _init_res(next(ks), c_prev, c_prev, temb_dim, dt),
        "attn": _init_xformer(next(ks), c_prev, cfg.ctx_dim, dt),
        "res2": _init_res(next(ks), c_prev, c_prev, temb_dim, dt),
    }
    # up path (consumes skips: n_res_blocks+1 per level, reverse order)
    up = []
    skip_chans = [ch] + [c for lvl, c in enumerate(chans) for _ in range(cfg.n_res_blocks)]
    # skips pushed after conv_in and each down block and each downsample
    full_skips = [ch]
    c_prev2 = ch
    for lvl, c in enumerate(chans):
        for _ in range(cfg.n_res_blocks):
            full_skips.append(c)
            c_prev2 = c
        if lvl + 1 < len(chans):
            full_skips.append(c)
    c_cur = chans[-1]
    for lvl in reversed(range(len(chans))):
        c = chans[lvl]
        blocks = []
        for _ in range(cfg.n_res_blocks + 1):
            skip_c = full_skips.pop()
            blk = {"res": _init_res(next(ks), c_cur + skip_c, c, temb_dim, dt)}
            if lvl in cfg.attn_levels:
                blk["attn"] = _init_xformer(next(ks), c, cfg.ctx_dim, dt)
            blocks.append(blk)
            c_cur = c
        stage = {"blocks": blocks}
        if lvl > 0:
            stage["up_w"] = L.conv_init(next(ks), 3, 3, c, c, dt)
        up.append(stage)
    p["up"] = up
    p["gn_out_s"] = jnp.ones((ch,), dt)
    p["gn_out_b"] = jnp.zeros((ch,), dt)
    p["conv_out"] = L.conv_init(next(ks), 3, 3, ch, cfg.latent_ch, dt)
    return p


def _res(p, x, temb):
    h = jax.nn.silu(L.groupnorm(x, p["gn1_s"], p["gn1_b"]))
    h = L.conv2d(h, p["w1"])
    h = h + (jnp.einsum("bd,dc->bc", jax.nn.silu(temb), p["temb_w"]) + p["temb_b"])[:, None, None, :]
    h = jax.nn.silu(L.groupnorm(h, p["gn2_s"], p["gn2_b"]))
    h = L.conv2d(h, p["w2"])
    skip = L.conv2d(x, p["skip_w"]) if "skip_w" in p else x
    return h + skip


def _xformer(p, cfg, x, ctx):
    b, hh, ww, c = x.shape
    heads = max(1, c // 64)
    res = x
    h = L.groupnorm(x, p["gn_s"], p["gn_b"]).reshape(b, hh * ww, c)
    h = jnp.einsum("bsc,cd->bsd", h, p["proj_in"])
    # self-attention
    y = L.layernorm(h, p["ln1_s"], p["ln1_b"])
    qkv = jnp.einsum("bsc,ck->bsk", y, p["sa_qkv"]).reshape(b, hh * ww, 3 * heads, c // heads)
    q, k, v = jnp.split(qkv, 3, axis=2)
    a = kops.attention(q, k, v, causal=False).reshape(b, hh * ww, c)
    h = h + jnp.einsum("bsc,cd->bsd", a, p["sa_o"])
    # cross-attention over text ctx
    y = L.layernorm(h, p["ln2_s"], p["ln2_b"])
    q = jnp.einsum("bsc,ck->bsk", y, p["ca_q"]).reshape(b, hh * ww, heads, c // heads)
    k = jnp.einsum("btc,ck->btk", ctx.astype(y.dtype), p["ca_k"]).reshape(b, -1, heads, c // heads)
    v = jnp.einsum("btc,ck->btk", ctx.astype(y.dtype), p["ca_v"]).reshape(b, -1, heads, c // heads)
    a = kops.attention(q, k, v, causal=False).reshape(b, hh * ww, c)
    h = h + jnp.einsum("bsc,cd->bsd", a, p["ca_o"])
    # GEGLU FF
    y = L.layernorm(h, p["ln3_s"], p["ln3_b"])
    u = jnp.einsum("bsc,ck->bsk", y, p["ff_in"])
    u1, u2 = jnp.split(u, 2, axis=-1)
    h = h + jnp.einsum("bsf,fc->bsc", u1 * jax.nn.gelu(u2), p["ff_out"])
    h = jnp.einsum("bsc,cd->bsd", h, p["proj_out"]).reshape(b, hh, ww, c)
    return h + res


def forward(params, cfg: DiffusionConfig, latents, t, ctx, train: bool = False):
    """latents (B,Hl,Wl,4), t (B,), ctx (B,ctx_len,ctx_dim) -> eps (B,Hl,Wl,4)."""
    dt = _dt(cfg)
    x = latents.astype(dt)
    temb = L.sinusoidal_embedding(t, cfg.ch).astype(dt)
    temb = jnp.einsum("bc,cd->bd", temb, params["t_w1"]) + params["t_b1"]
    temb = jnp.einsum("bd,dk->bk", jax.nn.silu(temb), params["t_w2"]) + params["t_b2"]

    def maybe_ckpt(fn):
        if cfg.remat != "none" and train:
            return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
        return fn

    h = L.conv2d(x, params["conv_in"])
    skips = [h]
    n_levels = len(params["down"])
    for lvl, stage in enumerate(params["down"]):
        for blk in stage["blocks"]:
            def down_blk(h, blk=blk):
                h = _res(blk["res"], h, temb)
                if "attn" in blk:
                    h = _xformer(blk["attn"], cfg, h, ctx)
                return h
            h = maybe_ckpt(down_blk)(h)
            skips.append(h)
        if "down_w" in stage:
            h = L.conv2d(h, stage["down_w"], stride=2)
            skips.append(h)

    m = params["mid"]
    h = _res(m["res1"], h, temb)
    h = _xformer(m["attn"], cfg, h, ctx)
    h = _res(m["res2"], h, temb)

    for i, stage in enumerate(params["up"]):
        for blk in stage["blocks"]:
            skip = skips.pop()
            def up_blk(h, blk=blk, skip=skip):
                h = jnp.concatenate([h, skip], axis=-1)
                h = _res(blk["res"], h, temb)
                if "attn" in blk:
                    h = _xformer(blk["attn"], cfg, h, ctx)
                return h
            h = maybe_ckpt(up_blk)(h)
        if "up_w" in stage:
            b, hh, ww, c = h.shape
            h = jax.image.resize(h, (b, hh * 2, ww * 2, c), "nearest")
            h = L.conv2d(h, stage["up_w"])

    h = jax.nn.silu(L.groupnorm(h, params["gn_out_s"], params["gn_out_b"]))
    return L.conv2d(h, params["conv_out"]).astype(jnp.float32)
