"""Mixture-of-Experts FFN: shared experts + routed top-k.

Dispatch is the sort-based group-wise formulation (Megablocks-style
permutation realized with XLA sort/scatter, per token group):

  tokens are split into groups of <= GROUP tokens; inside a group the
  (token, k) expert copies are sorted by expert id, ranked within their
  expert segment, and scattered into a dense (E, C, D) buffer with
  capacity C = ceil(k * group * capacity_factor / E). Expert FFNs then
  run as one einsum over (G, E, C, D) x (E, D, F) — MXU-shaped, and the
  expert dim shards over the "model" mesh axis (expert parallelism).

This avoids the (T, k, E, C) one-hot dispatch tensor (terabytes at our
shapes) while keeping FLOP waste bounded by capacity_factor.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding import ctx

GROUP = 4096  # max tokens per dispatch group


def n_experts_padded(e):
    return max(e.pad_experts_to, e.n_routed) if e.pad_experts_to else e.n_routed


def init_moe(key, cfg):
    e = cfg.moe
    et = n_experts_padded(e)
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    sh = e.n_shared * e.d_expert
    p = {
        "router": L.dense_init(ks[0], d, e.n_routed, jnp.float32),
        "w_gate": L.truncated_normal(ks[1], (et, d, e.d_expert), dt, 1 / math.sqrt(d)),
        "w_up": L.truncated_normal(ks[2], (et, d, e.d_expert), dt, 1 / math.sqrt(d)),
        "w_down": L.truncated_normal(ks[3], (et, e.d_expert, d), dt, 1 / math.sqrt(e.d_expert)),
    }
    if e.n_shared:
        p["shared"] = {
            "w_gate": L.dense_init(ks[4], d, sh, dt),
            "w_up": L.dense_init(ks[5], d, sh, dt),
            "w_down": L.dense_init(ks[6], sh, d, dt),
        }
    return p


def _group_shape(n_tokens: int):
    g = min(n_tokens, GROUP)
    while n_tokens % g:
        g //= 2
    return n_tokens // g, g


def _dispatch(xg, probs, k, n_exp, cap):
    """xg (S,D), probs (S,E) -> buf (E*C, D), combine metadata."""
    s, d = xg.shape
    topw, topi = jax.lax.top_k(probs, k)  # (S,k)
    topw = topw / (jnp.sum(topw, -1, keepdims=True) + 1e-9)
    flat_e = topi.reshape(-1)
    order = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(s * k) - seg_start
    valid = rank < cap
    slot = jnp.where(valid, sorted_e * cap + rank, n_exp * cap)
    tok = order // k
    buf = jnp.zeros((n_exp * cap + 1, d), xg.dtype).at[slot].set(xg[tok])
    meta = (order, slot, valid, topw, tok)
    return buf[: n_exp * cap], meta


def _combine(y_flat, meta, s, k):
    """y_flat (E*C, D) expert outputs -> (S, D) weighted combine."""
    order, slot, valid, topw, tok = meta
    safe = jnp.where(valid, slot, 0)
    y = y_flat[safe] * valid[:, None].astype(y_flat.dtype)
    w = topw.reshape(-1)[order].astype(y_flat.dtype)
    out = jnp.zeros((s, y_flat.shape[-1]), y_flat.dtype).at[tok].add(y * w[:, None])
    return out


def moe_block(p, cfg, x):
    """x (B,S,D) -> (out (B,S,D), aux_loss scalar)."""
    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    n_groups, group = _group_shape(t)
    xf = x.reshape(n_groups, group, d)

    et = n_experts_padded(e)
    logits = jnp.einsum("gsd,de->gse", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    if et > e.n_routed:  # padded experts: unroutable
        probs = jnp.pad(probs, ((0, 0), (0, 0), (0, et - e.n_routed)))

    cap = max(1, math.ceil(e.top_k * group * e.capacity_factor / e.n_routed))
    bufs, metas = jax.vmap(lambda xg, pg: _dispatch(xg, pg, e.top_k, et, cap))(xf, probs)
    bufs = bufs.reshape(n_groups, et, cap, d)

    # force expert parallelism: expert dim over "model", groups over the
    # data axes — without the constraint XLA replicates the expert
    # einsums across the model axis (measured 16x FLOP waste)
    bufs = ctx.constrain(bufs, ("pod", "data"), "model", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", bufs, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", bufs, p["w_up"])
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = ctx.constrain(y, ("pod", "data"), "model", None, None)
    y = y.reshape(n_groups, et * cap, d)

    out = jax.vmap(lambda yg, m: _combine(yg, m, group, e.top_k))(y, metas)
    out = out.reshape(b, s, d)

    if e.n_shared:
        sp = p["shared"]
        out = out + L.swiglu(x, sp["w_gate"], sp["w_up"], sp["w_down"])

    # load-balance + router-z aux losses
    top1 = jnp.argmax(probs[..., : e.n_routed], axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, e.n_routed, dtype=jnp.float32), axis=(0, 1))
    pbar = jnp.mean(probs[..., : e.n_routed], axis=(0, 1))
    aux = e.n_routed * jnp.sum(f * pbar)
    zloss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return out, aux + 1e-3 * zloss
