"""Single-shot detector 'counters' (the paper's Table II models).

Grid detector in the YOLO family: stride-2 conv stages + 1x1 head
emitting (box4, obj1, class C) per cell/anchor. Space tier
(targetfuse-space ~ YOLOv3-tiny) is shallow; ground tier
(targetfuse-ground ~ YOLOV3) is deeper and wider — reproducing the
accuracy asymmetry the cascade exploits.

Counting: decode -> NMS (IoU Pallas kernel) -> count above threshold;
tile confidence = mean detection score (paper's ``scores.mean()``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import DetectorConfig
from repro.models import layers as L
from repro.kernels import ops as kops


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def init(key, cfg: DetectorConfig):
    dt = _dt(cfg)
    ks = iter(jax.random.split(key, 4 * len(cfg.widths) * cfg.n_blocks_per_stage + 4))
    p = {"stem": L.conv_init(next(ks), 3, 3, 3, cfg.widths[0], dt), "stages": []}
    prev = cfg.widths[0]
    for w in cfg.widths[1:]:
        stage = []
        stage.append({"w": L.conv_init(next(ks), 3, 3, prev, w, dt),
                      "b": jnp.zeros((w,), dt)})
        for _ in range(cfg.n_blocks_per_stage - 1):
            stage.append({"w": L.conv_init(next(ks), 3, 3, w, w, dt),
                          "b": jnp.zeros((w,), dt)})
        p["stages"].append(stage)
        prev = w
    p["head_w"] = L.truncated_normal(
        next(ks), (1, 1, prev, cfg.n_anchors * (5 + cfg.n_classes)), dt, 0.01)
    p["head_b"] = jnp.zeros((cfg.n_anchors * (5 + cfg.n_classes),), dt)
    return p


def grid_size(cfg: DetectorConfig, input_size=None):
    return (input_size or cfg.input_size) // (2 ** len(cfg.widths[1:]))


def forward(params, cfg: DetectorConfig, images):
    """images (B, S, S, 3) in [0,1] -> raw head (B, G, G, A, 5+C)."""
    x = images.astype(_dt(cfg))
    x = jax.nn.leaky_relu(L.conv2d(x, params["stem"]), 0.1)
    for stage in params["stages"]:
        first = True
        for blk in stage:
            x = L.conv2d(x, blk["w"], stride=2 if first else 1) + blk["b"]
            x = jax.nn.leaky_relu(x, 0.1)
            first = False
    x = L.conv2d(x, params["head_w"]) + params["head_b"]
    b, g, _, _ = x.shape
    return x.reshape(b, g, g, cfg.n_anchors, 5 + cfg.n_classes).astype(jnp.float32)


def loss_fn(params, cfg: DetectorConfig, images, targets):
    """targets (B,G,G,A,5+C): [x,y,w,h (cell units), obj, onehot-class]."""
    raw = forward(params, cfg, images)
    obj_t = targets[..., 4]
    obj_logit = raw[..., 4]
    bce = (jnp.maximum(obj_logit, 0) - obj_logit * obj_t
           + jnp.log1p(jnp.exp(-jnp.abs(obj_logit))))
    # positives are ~1% of cells; upweight them so objectness converges
    w = jnp.where(obj_t > 0, 16.0, 1.0)
    obj_loss = jnp.sum(w * bce) / jnp.sum(w)
    pos = obj_t[..., None]
    box_loss = jnp.sum(pos * jnp.square(jax.nn.sigmoid(raw[..., :4]) - targets[..., :4]))
    box_loss = box_loss / jnp.maximum(jnp.sum(obj_t), 1.0)
    cls_logits = raw[..., 5:]
    cls_t = targets[..., 5:]
    logp = jax.nn.log_softmax(cls_logits, axis=-1)
    cls_loss = -jnp.sum(obj_t * jnp.sum(cls_t * logp, -1)) / jnp.maximum(jnp.sum(obj_t), 1.0)
    return 2.0 * obj_loss + 5.0 * box_loss + cls_loss, {
        "obj": obj_loss, "box": box_loss, "cls": cls_loss}


def decode(raw, cfg: DetectorConfig, input_size=None):
    """raw (B,G,G,A,5+C) -> (boxes (B,N,4) xyxy in px, scores (B,N))."""
    b, g = raw.shape[0], raw.shape[1]
    s = input_size or cfg.input_size
    cell = s / g
    cy = (jnp.arange(g) + 0.5)[None, :, None, None]
    cx = (jnp.arange(g) + 0.5)[None, None, :, None]
    box = jax.nn.sigmoid(raw[..., :4])
    # xy offset within cell [-0.5, 0.5]; wh up to 4 cells
    bx = (cx + box[..., 0] - 0.5) * cell
    by = (cy + box[..., 1] - 0.5) * cell
    bw = box[..., 2] * 4 * cell
    bh = box[..., 3] * 4 * cell
    boxes = jnp.stack([bx - bw / 2, by - bh / 2, bx + bw / 2, by + bh / 2], -1)
    obj = jax.nn.sigmoid(raw[..., 4])
    cls = jax.nn.softmax(raw[..., 5:], -1).max(-1)
    scores = obj * cls
    n = g * g * cfg.n_anchors
    return boxes.reshape(b, n, 4), scores.reshape(b, n)


def nms_keep(boxes, scores, iou_thresh=0.5, score_thresh=0.3, max_det=128):
    """Greedy NMS for one image: (N,4),(N,) -> keep mask (N,) bool.

    Vectorized greedy suppression over the top-`max_det` candidates using
    the IoU matrix kernel (paper §IV-A2 'global matrix of bounding box
    predictions').
    """
    n = boxes.shape[0]
    k = min(max_det, n)
    top_s, top_i = jax.lax.top_k(scores, k)
    top_b = boxes[top_i]
    iou = kops.iou_matrix(top_b, top_b)
    above = top_s > score_thresh

    def body(i, keep):
        sup = (iou[i] > iou_thresh) & (jnp.arange(k) > i) & keep[i]
        return keep & ~sup

    keep = jax.lax.fori_loop(0, k, body, above)
    mask = jnp.zeros((n,), bool).at[top_i].set(keep)
    return mask


def count_and_confidence(raw, cfg: DetectorConfig, score_thresh=0.3,
                         iou_thresh=0.5, input_size=None):
    """Per-tile object count + mean-score confidence after NMS.

    raw (B,G,G,A,5+C) -> (count (B,) f32, conf (B,) f32 in [0,1]).
    """
    boxes, scores = decode(raw, cfg, input_size)

    def one(bx, sc):
        keep = nms_keep(bx, sc, iou_thresh, score_thresh)
        cnt = jnp.sum(keep.astype(jnp.float32))
        conf = jnp.sum(sc * keep) / jnp.maximum(cnt, 1.0)
        return cnt, conf

    return jax.vmap(one)(boxes, scores)
