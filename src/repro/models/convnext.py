"""ConvNeXt (-B) — 4-stage hierarchy, 7x7 depthwise conv + LN + inverted
bottleneck MLP blocks, patchify stem, LN-per-downsample.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import VisionConfig
from repro.models import layers as L


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def _init_block(key, dim, dt):
    ks = jax.random.split(key, 3)
    return {
        "dw": L.conv_init(ks[0], 7, 7, 1, dim, dt),  # depthwise: HWIO with I=1
        "ln_s": jnp.ones((dim,), dt), "ln_b": jnp.zeros((dim,), dt),
        "pw1": L.dense_init(ks[1], dim, 4 * dim, dt),
        "b1": jnp.zeros((4 * dim,), dt),
        "pw2": L.dense_init(ks[2], 4 * dim, dim, dt),
        "b2": jnp.zeros((dim,), dt),
        "gamma": jnp.full((dim,), 1e-6, dt),
    }


def init(key, cfg: VisionConfig):
    dt = _dt(cfg)
    ks = jax.random.split(key, 2 + 2 * len(cfg.depths))
    params = {
        "stem_w": L.conv_init(ks[0], 4, 4, 3, cfg.dims[0], dt),
        "stem_b": jnp.zeros((cfg.dims[0],), dt),
        "stem_ln_s": jnp.ones((cfg.dims[0],), dt),
        "stem_ln_b": jnp.zeros((cfg.dims[0],), dt),
        "stages": [],
        "downs": [],
    }
    stages = []
    downs = []
    for i, (dep, dim) in enumerate(zip(cfg.depths, cfg.dims)):
        stages.append(
            jax.vmap(lambda k, dim=dim: _init_block(k, dim, dt))(
                jax.random.split(ks[2 + i], dep)
            )
        )
        if i + 1 < len(cfg.dims):
            kd = jax.random.split(ks[2 + len(cfg.depths) + i], 1)[0]
            downs.append({
                "ln_s": jnp.ones((dim,), dt), "ln_b": jnp.zeros((dim,), dt),
                "w": L.conv_init(kd, 2, 2, dim, cfg.dims[i + 1], dt),
                "b": jnp.zeros((cfg.dims[i + 1],), dt),
            })
    params["stages"] = stages
    params["downs"] = downs
    kh = jax.random.split(ks[1], 1)[0]
    params["head_ln_s"] = jnp.ones((cfg.dims[-1],), dt)
    params["head_ln_b"] = jnp.zeros((cfg.dims[-1],), dt)
    params["head"] = L.dense_init(kh, cfg.dims[-1], cfg.n_classes, dt, 0.02)
    return params


def _block(p, x):
    dim = x.shape[-1]
    h = L.conv2d(x, p["dw"], groups=dim)
    h = L.layernorm(h, p["ln_s"], p["ln_b"])
    h = jnp.einsum("bhwc,cf->bhwf", h, p["pw1"]) + p["b1"]
    h = jax.nn.gelu(h)
    h = jnp.einsum("bhwf,fc->bhwc", h, p["pw2"]) + p["b2"]
    return x + p["gamma"] * h


def forward(params, cfg: VisionConfig, images, train: bool = False):
    x = L.conv2d(images.astype(_dt(cfg)), params["stem_w"], stride=4,
                 padding="VALID") + params["stem_b"]
    x = L.layernorm(x, params["stem_ln_s"], params["stem_ln_b"])
    for i, stage in enumerate(params["stages"]):
        def body(xb, p):
            return _block(p, xb), None
        if cfg.remat != "none" and train:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.dots_saveable)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, stage)
        else:
            n = jax.tree_util.tree_leaves(stage)[0].shape[0]
            for j in range(n):
                x, _ = body(x, jax.tree_util.tree_map(lambda a: a[j], stage))
        if i < len(params["downs"]):
            d = params["downs"][i]
            x = L.layernorm(x, d["ln_s"], d["ln_b"])
            x = L.conv2d(x, d["w"], stride=2, padding="VALID") + d["b"]
    x = jnp.mean(x, axis=(1, 2))
    x = L.layernorm(x, params["head_ln_s"], params["head_ln_b"])
    return jnp.einsum("bd,dc->bc", x, params["head"]).astype(jnp.float32)
