"""ResNet-152 — bottleneck blocks with BatchNorm.

BN is functional: params hold (scale, bias), a separate ``bn_state``
pytree holds running (mean, var). ``forward(..., train=True)`` uses
batch statistics (a sharded batch turns the reduction into a global
all-reduce — sync-BN for free under pjit) and returns updated running
stats; eval uses the running stats.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import VisionConfig
from repro.models import layers as L

BN_MOM = 0.9


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def _bn_init(c, dt):
    return {"scale": jnp.ones((c,), dt), "bias": jnp.zeros((c,), dt)}


def _bn_state_init(c):
    return {"mean": jnp.zeros((c,), jnp.float32), "var": jnp.ones((c,), jnp.float32)}


def _init_bottleneck(key, c_in, c_mid, c_out, dt, has_proj):
    ks = jax.random.split(key, 4)
    p = {
        "w1": L.conv_init(ks[0], 1, 1, c_in, c_mid, dt), "bn1": _bn_init(c_mid, dt),
        "w2": L.conv_init(ks[1], 3, 3, c_mid, c_mid, dt), "bn2": _bn_init(c_mid, dt),
        "w3": L.conv_init(ks[2], 1, 1, c_mid, c_out, dt), "bn3": _bn_init(c_out, dt),
    }
    s = {"bn1": _bn_state_init(c_mid), "bn2": _bn_state_init(c_mid), "bn3": _bn_state_init(c_out)}
    if has_proj:
        p["proj_w"] = L.conv_init(ks[3], 1, 1, c_in, c_out, dt)
        p["proj_bn"] = _bn_init(c_out, dt)
        s["proj_bn"] = _bn_state_init(c_out)
    return p, s


def init(key, cfg: VisionConfig) -> Tuple[dict, dict]:
    dt = _dt(cfg)
    w = cfg.width
    ks = jax.random.split(key, 2 + sum(cfg.depths))
    params = {"stem_w": L.conv_init(ks[0], 7, 7, 3, w, dt), "stem_bn": _bn_init(w, dt)}
    state = {"stem_bn": _bn_state_init(w)}
    c_in = w
    ki = 1
    blocks, bstates = [], []
    for i, dep in enumerate(cfg.depths):
        c_mid = w * (2 ** i)
        c_out = c_mid * 4
        stage_p, stage_s = [], []
        for b in range(dep):
            p, s = _init_bottleneck(ks[ki], c_in, c_mid, c_out, dt, b == 0)
            ki += 1
            stage_p.append(p)
            stage_s.append(s)
            c_in = c_out
        blocks.append(stage_p)
        bstates.append(stage_s)
    params["stages"] = blocks
    state["stages"] = bstates
    params["head"] = L.dense_init(ks[ki], c_in, cfg.n_classes, dt, 0.02)
    return params, state


def _bn(x, p, s, train):
    if train:
        y, mu, var = L.batchnorm_train(x, p["scale"], p["bias"])
        new_s = {
            "mean": BN_MOM * s["mean"] + (1 - BN_MOM) * mu,
            "var": BN_MOM * s["var"] + (1 - BN_MOM) * var,
        }
        return y, new_s
    return L.batchnorm_eval(x, p["scale"], p["bias"], s["mean"], s["var"]), s


def _bottleneck(p, s, x, stride, train):
    h, s1 = _bn(L.conv2d(x, p["w1"]), p["bn1"], s["bn1"], train)
    h = jax.nn.relu(h)
    h, s2 = _bn(L.conv2d(h, p["w2"], stride=stride), p["bn2"], s["bn2"], train)
    h = jax.nn.relu(h)
    h, s3 = _bn(L.conv2d(h, p["w3"]), p["bn3"], s["bn3"], train)
    new_s = {"bn1": s1, "bn2": s2, "bn3": s3}
    if "proj_w" in p:
        sc, sp = _bn(L.conv2d(x, p["proj_w"], stride=stride), p["proj_bn"], s["proj_bn"], train)
        new_s["proj_bn"] = sp
    else:
        sc = x
    return jax.nn.relu(h + sc), new_s


def forward(params, state, cfg: VisionConfig, images, train: bool = False):
    """-> (logits (B, n_classes), new_bn_state)."""
    x = L.conv2d(images.astype(_dt(cfg)), params["stem_w"], stride=2)
    x, stem_s = _bn(x, params["stem_bn"], state["stem_bn"], train)
    x = jax.nn.relu(x)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME")
    new_state = {"stem_bn": stem_s, "stages": []}
    for i, (stage_p, stage_s) in enumerate(zip(params["stages"], state["stages"])):
        new_stage = []
        for b, (p, s) in enumerate(zip(stage_p, stage_s)):
            stride = 2 if (b == 0 and i > 0) else 1
            fn = lambda p, s, x: _bottleneck(p, s, x, stride, train)
            if cfg.remat != "none" and train:
                fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
            x, ns = fn(p, s, x)
            new_stage.append(ns)
        new_state["stages"].append(new_stage)
    x = jnp.mean(x, axis=(1, 2))
    return jnp.einsum("bd,dc->bc", x, params["head"]).astype(jnp.float32), new_state
