"""Fault-tolerant checkpointing (orbax-free).

- step-numbered directories, atomic (write-to-tmp + os.replace) so a
  crash mid-save can never corrupt the latest checkpoint
- restore-latest with automatic skip of incomplete/corrupt steps
- optional async save on a background thread (training never blocks on
  the filesystem)
- arbitrary pytrees (params / optimizer state / data-pipeline cursors)
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"
_COMMIT = "COMMITTED"
_NP_NATIVE = {"bool", "int8", "uint8", "int16", "uint16", "int32", "uint32",
              "int64", "uint64", "float16", "float32", "float64",
              "complex64", "complex128"}


def _paths_of(tree) -> Tuple[list, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save(ckpt_dir: str, step: int, tree, keep: int = 3,
         async_: bool = False) -> Optional[threading.Thread]:
    """Save `tree` under ckpt_dir/step_{step:08d} atomically."""
    os.makedirs(ckpt_dir, exist_ok=True)
    keys, vals, _ = _paths_of(tree)
    host_vals = [np.asarray(v) for v in jax.device_get(vals)]

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        # numpy can't serialize ml_dtypes (bf16 etc.) -> store raw bytes
        def enc(v):
            if v.dtype.name not in _NP_NATIVE:
                return np.ascontiguousarray(v).view(np.uint8)
            return v
        np.savez(os.path.join(tmp, _ARRAYS),
                 **{f"a{i}": enc(v) for i, v in enumerate(host_vals)})
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump({"step": step, "keys": keys,
                       "dtypes": [str(v.dtype) for v in host_vals],
                       "shapes": [list(v.shape) for v in host_vals]}, f)
        with open(os.path.join(tmp, _COMMIT), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, _COMMIT)):
                out.append(int(d[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, template, step: Optional[int] = None):
    """-> (step, tree shaped like `template`). Raises if none available."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, _ARRAYS))
    vals = []
    for i, (dt, shape) in enumerate(zip(manifest["dtypes"], manifest["shapes"])):
        v = data[f"a{i}"]
        if dt not in _NP_NATIVE:  # stored as raw bytes
            v = v.view(np.dtype(dt)).reshape(shape)
        vals.append(v)
    keys, tvals, treedef = _paths_of(template)
    if keys != manifest["keys"]:
        raise ValueError(
            f"checkpoint structure mismatch: {len(manifest['keys'])} saved "
            f"keys vs {len(keys)} template keys")
    out = [np.asarray(v).astype(t.dtype) if hasattr(t, "dtype") else v
           for v, t in zip(vals, tvals)]
    return step, jax.tree_util.tree_unflatten(treedef, out)
