"""Fault-tolerant training supervisor.

Production behaviors for 1000+-node runs, realized at any scale:

- checkpoint-every-N with atomic saves (see repro.checkpoint.ckpt) and
  automatic resume-from-latest on (re)start -> node failure = restart
  container, supervisor picks up where the last commit left off.
- bad-step rejection: a non-finite loss discards that step's update
  (params are only replaced by the post-check values).
- simulated failure injection for tests (`fail_at_step`).
- straggler mitigation for serving: `DeadlineBatcher` drops sub-batches
  that miss the contact-window deadline (bounded staleness), matching
  the paper's hard downlink window.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class SupervisorConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    async_save: bool = True
    max_steps: int = 1000
    fail_at_step: Optional[int] = None  # test hook


@dataclass
class TrainReport:
    steps_run: int
    resumed_from: Optional[int]
    losses: list = field(default_factory=list)
    rejected_steps: int = 0


def run_training(state, step_fn: Callable, data_fn: Callable,
                 cfg: SupervisorConfig) -> tuple:
    """Drive `step_fn(state, batch) -> (state, loss)` with checkpointing.

    `state` is any pytree (params, opt state, rng, ...). Returns
    (final_state, TrainReport). On entry, resumes from the latest
    committed checkpoint if one exists.
    """
    start = 0
    resumed = None
    try:
        start, state = ckpt.restore(cfg.ckpt_dir, state)
        resumed = start
    except (FileNotFoundError, ValueError):
        pass

    report = TrainReport(steps_run=0, resumed_from=resumed)
    pending = None
    for step in range(start, cfg.max_steps):
        if cfg.fail_at_step is not None and step == cfg.fail_at_step:
            raise SimulatedFailure(f"injected failure at step {step}")
        batch = data_fn(step)
        new_state, loss = step_fn(state, batch)
        loss_v = float(loss)
        if not np.isfinite(loss_v):
            report.rejected_steps += 1  # drop the update, keep old state
        else:
            state = new_state
            report.losses.append(loss_v)
        report.steps_run += 1
        if (step + 1) % cfg.ckpt_every == 0 or step + 1 == cfg.max_steps:
            if pending is not None:
                pending.join()
            pending = ckpt.save(cfg.ckpt_dir, step + 1, state, keep=cfg.keep,
                                async_=cfg.async_save)
    if pending is not None:
        pending.join()
    return state, report


# ---------------------------------------------------------------------------
# serving-side straggler mitigation
# ---------------------------------------------------------------------------


@dataclass
class DeadlineBatcher:
    """Aggregates per-shard results within a hard deadline; shards that
    miss it are dropped and their tiles re-queued for the next window
    (the satellite cannot extend a contact window for a straggler)."""

    deadline_s: float
    clock: Callable[[], float] = time.monotonic

    def run(self, work_items, fn):
        """fn(item) -> result. Returns (results, dropped_items)."""
        t0 = self.clock()
        results, dropped = [], []
        for item in work_items:
            if self.clock() - t0 > self.deadline_s:
                dropped.append(item)
                continue
            results.append(fn(item))
        return results, dropped


# ---------------------------------------------------------------------------
# elastic re-sharding
# ---------------------------------------------------------------------------


def reshard_state(state, new_mesh, spec_fn):
    """Re-lay-out `state` for a different mesh (elastic scale up/down).

    spec_fn(state) -> pytree of PartitionSpec for the new mesh. All
    arrays are pulled to host then re-placed with the new shardings —
    correct for any old/new device-count pair.
    """
    from jax.sharding import NamedSharding
    host = jax.device_get(state)
    specs = spec_fn(host)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)), host, specs)
