"""Synthetic EO scene generator (host-side, numpy).

Replaces xView/DOTA/UAVOD10 (no offline access) with procedurally
generated geospatial scenes whose object counts are exact by
construction: textured background + planted objects (vehicles/
buildings/planes as compact colored blobs) with ground-truth boxes.

Revisit simulation (paper §IV-A4): the satellite re-images the same
ground area along its track; frames are near-duplicates under small
shift/rotation/illumination jitter — exactly what clustering-based
dedup is built to exploit. 50% of frames are flipped/rotated, matching
the paper's augmentation protocol.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass(frozen=True)
class SceneSpec:
    name: str
    scene_px: int
    objects_per_scene: Tuple[int, int]   # (lo, hi)
    object_px: Tuple[int, int]           # (lo, hi)
    n_classes: int = 8
    cloud_fraction: float = 0.3          # prob a region is cloud-obscured
    texture_scale: int = 64


# Scaled-down analogues of Table I (same relative size/density character).
XVIEW_LIKE = SceneSpec("xview", 1024, (40, 80), (8, 20))
DOTA_LIKE = SceneSpec("dota", 1536, (30, 60), (10, 32))
UAVOD_LIKE = SceneSpec("uavod", 768, (8, 24), (12, 40))
DATASETS = {s.name: s for s in (XVIEW_LIKE, DOTA_LIKE, UAVOD_LIKE)}

_CLASS_COLORS = np.array([
    [0.9, 0.2, 0.2], [0.2, 0.9, 0.2], [0.2, 0.3, 0.9], [0.9, 0.9, 0.2],
    [0.9, 0.2, 0.9], [0.2, 0.9, 0.9], [0.95, 0.6, 0.1], [0.7, 0.7, 0.7],
])


def _smooth_noise(rng, size, scale):
    small = rng.random((size // scale + 2, size // scale + 2, 3))
    idx = np.linspace(0, small.shape[0] - 1.001, size)
    xi, yi = np.meshgrid(idx, idx, indexing="ij")
    x0, y0 = xi.astype(int), yi.astype(int)
    fx, fy = (xi - x0)[..., None], (yi - y0)[..., None]
    a = small[x0, y0] * (1 - fx) * (1 - fy) + small[x0 + 1, y0] * fx * (1 - fy)
    a += small[x0, y0 + 1] * (1 - fx) * fy + small[x0 + 1, y0 + 1] * fx * fy
    return a


def make_scene(rng: np.random.Generator, spec: SceneSpec):
    """-> (image (S,S,3) f32 in [0,1], boxes (M,4) xyxy px, classes (M,))."""
    s = spec.scene_px
    img = 0.25 + 0.35 * _smooth_noise(rng, s, spec.texture_scale)
    img += 0.03 * rng.standard_normal((s, s, 3))
    n_obj = int(rng.integers(*spec.objects_per_scene))
    boxes, classes = [], []
    for _ in range(n_obj):
        w = int(rng.integers(*spec.object_px))
        h = int(rng.integers(*spec.object_px))
        x = int(rng.integers(0, s - w))
        y = int(rng.integers(0, s - h))
        c = int(rng.integers(0, spec.n_classes))
        col = _CLASS_COLORS[c] * (0.8 + 0.4 * rng.random())
        yy, xx = np.mgrid[y:y + h, x:x + w]
        cy, cx = y + h / 2, x + w / 2
        inside = (((yy - cy) / (h / 2)) ** 2 + ((xx - cx) / (w / 2)) ** 2) <= 1.0
        region = img[y:y + h, x:x + w]
        region[inside] = col * 0.85 + 0.15 * region[inside]
        boxes.append([x, y, x + w, y + h])
        classes.append(c)
    # cloud occlusion (the paper: 67% of observations cloud-degraded)
    if rng.random() < spec.cloud_fraction:
        cs = int(rng.integers(s // 4, s // 2))
        cx0 = int(rng.integers(0, s - cs))
        cy0 = int(rng.integers(0, s - cs))
        cloud = 0.85 + 0.1 * _smooth_noise(rng, cs, max(cs // 4, 2))
        img[cy0:cy0 + cs, cx0:cx0 + cs] = (
            0.7 * cloud + 0.3 * img[cy0:cy0 + cs, cx0:cx0 + cs]
        )
        keep = []
        for i, (x1, y1, x2, y2) in enumerate(boxes):
            cxm, cym = (x1 + x2) / 2, (y1 + y2) / 2
            if not (cx0 < cxm < cx0 + cs and cy0 < cym < cy0 + cs):
                keep.append(i)
        boxes = [boxes[i] for i in keep]
        classes = [classes[i] for i in keep]
    img = np.clip(img, 0.0, 1.0).astype(np.float32)
    b = np.asarray(boxes, np.float32).reshape(-1, 4)
    c = np.asarray(classes, np.int32).reshape(-1)
    return img, b, c


def revisit_frames(rng, img, boxes, classes, n_frames: int, max_shift: int = 24):
    """Simulate repeated passes over the same ground area."""
    s = img.shape[0]
    frames = []
    for i in range(n_frames):
        dx, dy = int(rng.integers(-max_shift, max_shift + 1)), int(rng.integers(-max_shift, max_shift + 1))
        f = np.roll(img, (dy, dx), axis=(0, 1))
        b = boxes.copy()
        if len(b):
            b[:, [0, 2]] = (b[:, [0, 2]] + dx) % s
            b[:, [1, 3]] = (b[:, [1, 3]] + dy) % s
            ok = (b[:, 2] > b[:, 0]) & (b[:, 3] > b[:, 1])  # drop wrapped boxes
            b, cl = b[ok], classes[ok]
        else:
            cl = classes
        f = np.clip(f * (0.92 + 0.16 * rng.random()), 0, 1)  # illumination
        if rng.random() < 0.5:  # paper: flip/rotate 50% of images
            rot = int(rng.integers(1, 4))
            f = np.rot90(f, rot).copy()
            b2 = b.copy()
            for _ in range(rot):
                if len(b2):
                    x1, y1, x2, y2 = b2[:, 0].copy(), b2[:, 1].copy(), b2[:, 2].copy(), b2[:, 3].copy()
                    b2 = np.stack([y1, s - x2, y2, s - x1], axis=1)
            b = b2
        frames.append((f.astype(np.float32), b, cl))
    return frames


def tile_counts(boxes, scene_px: int, tile_size: int):
    """Ground-truth object count per tile (object assigned to the tile
    holding its center). -> (G*G,) int array, row-major tiles."""
    g = (scene_px + tile_size - 1) // tile_size
    counts = np.zeros((g, g), np.int64)
    for x1, y1, x2, y2 in boxes:
        cx, cy = (x1 + x2) / 2, (y1 + y2) / 2
        tx, ty = min(int(cx // tile_size), g - 1), min(int(cy // tile_size), g - 1)
        counts[ty, tx] += 1
    return counts.reshape(-1)


def boxes_to_targets(boxes, classes, grid: int, n_anchors: int, n_classes: int,
                     input_size: int, scale: float = 1.0):
    """Build a (G,G,A,5+C) detector training target from GT boxes.

    ``scale`` maps scene px -> model-input px when tiles were resized.
    """
    t = np.zeros((grid, grid, n_anchors, 5 + n_classes), np.float32)
    cell = input_size / grid
    for (x1, y1, x2, y2), c in zip(boxes, classes):
        x1, y1, x2, y2 = x1 * scale, y1 * scale, x2 * scale, y2 * scale
        cx, cy = (x1 + x2) / 2, (y1 + y2) / 2
        gx, gy = min(int(cx / cell), grid - 1), min(int(cy / cell), grid - 1)
        a = 0
        while a < n_anchors and t[gy, gx, a, 4] > 0:
            a += 1
        if a == n_anchors:
            continue
        t[gy, gx, a, 0] = np.clip(cx / cell - gx, 0, 1)          # x in cell
        t[gy, gx, a, 1] = np.clip(cy / cell - gy, 0, 1)
        t[gy, gx, a, 2] = np.clip((x2 - x1) / (4 * cell), 0, 1)  # w, up to 4 cells
        t[gy, gx, a, 3] = np.clip((y2 - y1) / (4 * cell), 0, 1)
        t[gy, gx, a, 4] = 1.0
        t[gy, gx, a, 5 + int(c)] = 1.0
    return t


def clip_boxes_to_tile(boxes, classes, tx, ty, tile_size):
    """Boxes of one scene -> boxes local to tile (tx,ty), center-assigned."""
    out_b, out_c = [], []
    for (x1, y1, x2, y2), c in zip(boxes, classes):
        cx, cy = (x1 + x2) / 2, (y1 + y2) / 2
        if tx * tile_size <= cx < (tx + 1) * tile_size and ty * tile_size <= cy < (ty + 1) * tile_size:
            out_b.append([x1 - tx * tile_size, y1 - ty * tile_size,
                          x2 - tx * tile_size, y2 - ty * tile_size])
            out_c.append(c)
    return np.asarray(out_b, np.float32).reshape(-1, 4), np.asarray(out_c, np.int32)
