"""Orbital scenario generator for constellation simulations.

Produces deterministic multi-round fleet scenarios — the workloads that
drive :class:`repro.core.fleet.Fleet` and its looped-Mission parity
oracle with the *same* event stream:

* **Passes** — every round, every satellite images a fresh ground area
  (heterogeneous per-satellite scene mixes; revisit frames within the
  pass) and harvests solar energy according to a simple eclipse/sunlit
  orbit-phase profile. The harvest feeds ``EnergyLedger.grant`` via
  ``Mission.ingest(..., energy_budget_j=...)``, so eclipsed passes run
  onboard counting on whatever ledger headroom earlier sunlit passes
  banked — the paper's harvest-limited compute regime (§III-A-1).
* **Contacts** — ground stations rotate over the fleet round-robin; each
  window's byte budget varies with a per-pass elevation factor on the
  station bandwidth (low passes near the horizon drain slower), scaled
  by ``window_budget_scale`` so window budgets sit in the same
  day-fraction regime as ``PipelineConfig`` tile entitlements.

Everything is generated eagerly from one seed, so the fleet path and the
oracle consume byte-identical frames/budgets (exact-parity testing) and
benchmark timing excludes scene synthesis.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.core.throttle import contact_budget_bytes
from repro.data.synthetic import SceneSpec, make_scene, revisit_frames

# default per-satellite ground-track scene (small: fleet workloads scale
# by satellite count, not scene size)
TRACK = SceneSpec("track", 384, (10, 20), (10, 24), cloud_fraction=0.25)


@dataclass(frozen=True)
class GroundStation:
    name: str
    bandwidth_mbps: float = 50.0
    contact_s: float = 360.0
    # (lat_deg, lon_deg) ground site; required by geometry="orbital"
    # (the toy path never looks at it, so existing specs are unchanged)
    site: Optional[Tuple[float, float]] = None


@dataclass(frozen=True)
class FleetScenarioSpec:
    """Knobs of one generated scenario (all rounds derive from ``seed``)."""

    n_sats: int = 4
    n_rounds: int = 4
    frames_per_pass: int = 2
    stations: Tuple[GroundStation, ...] = (GroundStation("gs0"),)
    scene_mix: Tuple[SceneSpec, ...] = (TRACK,)  # sat i -> mix[i % len]
    # eclipse/sunlit harvest profile
    orbit_rounds: int = 8            # rounds per orbital revolution
    eclipse_fraction: float = 0.35   # fraction of the orbit in shadow
    harvest_w: float = 3.0           # mean panel output while sunlit (W)
    pass_s: float = 600.0            # seconds of flight per round
    # per-window bandwidth variability (elevation factor range)
    elevation_range: Tuple[float, float] = (0.5, 1.0)
    # scales station windows into the simulated day-fraction regime
    # (a full 50 Mbps x 6 min window is ~2.25 GB — far beyond a slice)
    window_budget_scale: float = 1e-3
    seed: int = 0
    # geometry backend: "toy" keeps the phase-offset model above
    # bit-identical; "orbital" routes through repro.orbits (batched
    # Keplerian propagation, real passes, eclipse-derived harvest)
    geometry: str = "toy"
    # orbital-path knobs (ignored by the toy path)
    alt_km: float = 550.0
    inc_deg: float = 53.0
    n_planes: int = 0                # 0 = auto near-square Walker grid
    min_elev_deg: float = 10.0       # pass-extraction horizon mask
    time_step_s: float = 15.0        # propagation grid resolution

    def __post_init__(self):
        """Fail-at-build validation, same contract as ``ContactPlan``:
        a malformed spec raises here, not rounds later inside the
        generator or the energy ledger."""
        if self.geometry not in ("toy", "orbital"):
            raise ValueError(f"FleetScenarioSpec: unknown geometry "
                             f"{self.geometry!r} (expected 'toy' or "
                             f"'orbital')")
        if self.n_sats < 1 or self.n_rounds < 1:
            raise ValueError(f"FleetScenarioSpec: need n_sats >= 1 and "
                             f"n_rounds >= 1, got {self.n_sats}/"
                             f"{self.n_rounds}")
        if not self.stations:
            raise ValueError("FleetScenarioSpec: stations must be non-empty "
                             "(a fleet with no ground segment can never "
                             "downlink)")
        if not 0.0 <= self.eclipse_fraction < 1.0:
            raise ValueError(f"FleetScenarioSpec: eclipse_fraction "
                             f"{self.eclipse_fraction} outside [0, 1)")
        if self.orbit_rounds < 1:
            raise ValueError(f"FleetScenarioSpec: orbit_rounds must be >= 1, "
                             f"got {self.orbit_rounds}")
        if self.pass_s <= 0.0 or self.harvest_w <= 0.0:
            raise ValueError(f"FleetScenarioSpec: pass_s and harvest_w must "
                             f"be positive, got {self.pass_s}/"
                             f"{self.harvest_w}")
        lo, hi = self.elevation_range
        if not 0.0 <= lo <= hi <= 1.0:
            raise ValueError(f"FleetScenarioSpec: elevation_range "
                             f"({lo}, {hi}) must satisfy 0 <= lo <= hi <= 1 "
                             f"(it is a bandwidth factor range)")
        if self.alt_km <= 0.0 or self.time_step_s <= 0.0:
            raise ValueError(f"FleetScenarioSpec: alt_km and time_step_s "
                             f"must be positive, got {self.alt_km}/"
                             f"{self.time_step_s}")
        if not 0.0 <= self.min_elev_deg < 90.0:
            raise ValueError(f"FleetScenarioSpec: min_elev_deg "
                             f"{self.min_elev_deg} outside [0, 90)")
        if self.n_planes < 0:
            raise ValueError(f"FleetScenarioSpec: n_planes must be >= 0 "
                             f"(0 = auto), got {self.n_planes}")

    def fault_plan(self, seed: Optional[int] = None, **knobs):
        """Fault-bearing rounds for this scenario: a deterministic
        :class:`~repro.core.faults.FaultPlan` sized to the spec —
        station outages are drawn as round spans over the spec's real
        station names; the per-event classes stay lazy rate draws.
        ``knobs`` are :func:`repro.core.faults.scenario_faults` rates
        (``drop_rate``, ``truncate_rate``, ``corrupt_rate``,
        ``blackout_rate``, ``outage_rate``, ``max_retries``,
        ``refund_policy``, ``worker_faults``)."""
        from repro.core.faults import scenario_faults
        return scenario_faults(self, seed, **knobs)


@dataclass
class PassEvent:
    sat: int
    frames: list
    harvest_j: float
    sunlit: bool


@dataclass
class ContactEvent:
    sat: int
    station: GroundStation
    bandwidth_mbps: float     # elevation-degraded effective bandwidth
    budget_bytes: float


@dataclass
class Round:
    index: int
    passes: List[PassEvent] = field(default_factory=list)
    contacts: List[ContactEvent] = field(default_factory=list)

    def frames_per_sat(self, n_sats: int) -> list:
        out = [[] for _ in range(n_sats)]
        for p in self.passes:
            out[p.sat] = p.frames
        return out

    def harvest_per_sat(self, n_sats: int) -> list:
        out: list = [None] * n_sats
        for p in self.passes:
            out[p.sat] = p.harvest_j
        return out

    def contact_plan(self, n_sats: int):
        """This round's contact events as a declarative, validated
        :class:`~repro.core.contact.ContactPlan` — the scenario
        generator's schedule drives ``Fleet.contact_round(plan=...)``
        directly (budgets/stations land in the plan's lane arrays)."""
        from repro.core.contact import ContactPlan
        return ContactPlan.from_contacts(self.contacts, n_sats)


@dataclass
class FleetScenario:
    spec: FleetScenarioSpec
    rounds: List[Round]

    @property
    def n_frames(self) -> int:
        return sum(len(p.frames) for r in self.rounds for p in r.passes)


def elevation_bandwidth(elev_deg: float, station: GroundStation, *,
                        factor: Optional[float] = None) -> float:
    """Elevation-dependent effective bandwidth (Mbps) for one window.

    The ONE scaling rule both geometry paths share: effective bandwidth
    is the station bandwidth times a factor in [0, 1]. The orbital path
    passes a real elevation (degrees), mapped through ``sin`` — the
    slant-range/air-mass shape that makes horizon grazes slow and
    overhead passes full-rate. The toy path draws its factor directly
    from ``elevation_range`` and passes it via ``factor``; the clamp is
    an exact no-op on [0, 1], keeping the toy path bit-identical to the
    pre-helper inline scaling.
    """
    if factor is None:
        factor = float(np.sin(np.radians(np.clip(elev_deg, 0.0, 90.0))))
    return station.bandwidth_mbps * min(max(float(factor), 0.0), 1.0)


def orbit_phase(spec: FleetScenarioSpec, rnd: int, sat: int) -> float:
    """[0, 1) orbital phase: satellites are phase-staggered along the
    ring; phase advances by 1/orbit_rounds per round."""
    return (rnd / max(spec.orbit_rounds, 1) + sat / max(spec.n_sats, 1)) % 1.0


def harvest_profile(spec: FleetScenarioSpec, rnd: int, sat: int
                    ) -> Tuple[float, bool]:
    """-> (harvest_j, sunlit) for one pass.

    Phase below ``eclipse_fraction`` is Earth-shadowed (zero harvest);
    the sunlit arc ramps sinusoidally with sun elevation, so grants vary
    smoothly instead of toggling between two constants.
    """
    p = orbit_phase(spec, rnd, sat)
    if p < spec.eclipse_fraction:
        return 0.0, False
    arc = (p - spec.eclipse_fraction) / max(1.0 - spec.eclipse_fraction, 1e-9)
    power = spec.harvest_w * (0.6 + 0.4 * float(np.sin(np.pi * arc)))
    return power * spec.pass_s, True


def generate_scenario(spec: FleetScenarioSpec) -> FleetScenario:
    """Deterministically expand a spec into concrete rounds.

    Scene content is drawn per satellite from independent seeded
    generators, so two scenarios with the same seed are byte-identical
    regardless of consumption order.

    ``geometry="orbital"`` routes through the orbital geometry engine
    (lazy import — :mod:`repro.orbits` depends on this module); the
    default toy path below is bit-identical to its pre-geometry form.
    """
    if spec.geometry == "orbital":
        from repro.orbits.schedule import generate_orbital_scenario
        return generate_orbital_scenario(spec)
    rngs = [np.random.default_rng(10_000 * spec.seed + s)
            for s in range(spec.n_sats)]
    contact_rng = np.random.default_rng(10_000 * spec.seed + 9999)
    rounds = []
    for r in range(spec.n_rounds):
        rnd = Round(index=r)
        for s in range(spec.n_sats):
            scene = spec.scene_mix[s % len(spec.scene_mix)]
            img, b, c = make_scene(rngs[s], scene)
            frames = revisit_frames(rngs[s], img, b, c, spec.frames_per_pass)
            harvest_j, sunlit = harvest_profile(spec, r, s)
            rnd.passes.append(PassEvent(sat=s, frames=frames,
                                        harvest_j=harvest_j, sunlit=sunlit))
        for k, station in enumerate(spec.stations):
            sat = (r * len(spec.stations) + k) % spec.n_sats
            lo, hi = spec.elevation_range
            elev = float(contact_rng.uniform(lo, hi))
            bw = elevation_bandwidth(0.0, station, factor=elev)
            budget = (contact_budget_bytes(bw, station.contact_s)
                      * spec.window_budget_scale)
            rnd.contacts.append(ContactEvent(sat=sat, station=station,
                                             bandwidth_mbps=bw,
                                             budget_bytes=budget))
        rounds.append(rnd)
    return FleetScenario(spec=spec, rounds=rounds)
