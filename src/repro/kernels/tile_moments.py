"""Color-moments featurizer Pallas kernel (dedup front-end, paper §III-C).

Every captured tile passes through this before clustering, so it is the
highest-call-count op in the onboard pipeline. It is purely
bandwidth-bound: one pass over each (H, W, C) tile computes all three
moments (mean, stddev, skewness) per channel fused — vs. three separate
reductions (3x HBM traffic) in the naive formulation.

Grid: one step per block of BN tiles; the (BN, H*W, C) block sits in
VMEM; power sums Σx, Σx², Σx³ are accumulated in one read.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 64


def _kernel(t_ref, out_ref):
    x = t_ref[...].astype(jnp.float32)  # (BN, HW, C)
    hw = x.shape[1]
    s1 = jnp.sum(x, axis=1) / hw  # mean (BN, C)
    xc = x - s1[:, None, :]
    m2 = jnp.sum(xc * xc, axis=1) / hw
    m3 = jnp.sum(xc * xc * xc, axis=1) / hw
    sd = jnp.sqrt(m2 + 1e-12)
    skew = jnp.cbrt(m3)
    out_ref[...] = jnp.concatenate([s1, sd, skew], axis=-1)


def tile_moments(tiles, *, bn: int = DEFAULT_BN, interpret: bool = False):
    """tiles: (N, H, W, C) -> (N, 3C) float32 color moments."""
    n, h, w, c = tiles.shape
    n_pad = -n % bn
    tp = jnp.pad(tiles, ((0, n_pad), (0, 0), (0, 0), (0, 0)))
    tp = tp.reshape(n + n_pad, h * w, c)
    out = pl.pallas_call(
        _kernel,
        grid=((n + n_pad) // bn,),
        in_specs=[pl.BlockSpec((bn, h * w, c), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bn, 3 * c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, 3 * c), jnp.float32),
        interpret=interpret,
    )(tp)
    return out[:n]
