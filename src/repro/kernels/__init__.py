"""Pallas TPU kernels (+ ref oracles and dispatching ops)."""
