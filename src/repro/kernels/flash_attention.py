"""Flash attention (online-softmax) Pallas TPU kernel.

Tiling: grid = (B*Hq, Sq/BQ, Skv/BK); the kv axis is innermost and
"arbitrary" (sequential) so the (BQ, D) f32 accumulator plus the (BQ,)
running max / sum live in VMEM scratch across kv steps. BQ = BK = 128
keeps both MXU matmuls (q·kᵀ and p·v) on 128-aligned shapes.

GQA is handled in the k/v index_map (query head h reads kv head
h // rep), so grouped K/V are never materialized per-query-head.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._pltpu_compat import CompilerParams as _CompilerParams

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, sm_scale: float, n_kv_blocks: int,
                  bq: int, bk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * sm_scale  # (BQ, D)
    k = k_ref[0].astype(jnp.float32)  # (BK, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BK)

    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    m_ref[...] = m_cur
    v = v_ref[0].astype(jnp.float32)  # (BK, D)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = False, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, interpret: bool = False):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D). Returns (B, Sq, Hq, D).

    Requires Sq % bq == 0 and Skv % bk == 0 (wrappers pad otherwise).
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    qr = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    n_q, n_kv = sq // bq, skv // bk
    grid = (b * hq, n_q, n_kv)

    kernel = functools.partial(
        _flash_kernel, causal=causal, sm_scale=1.0 / math.sqrt(d),
        n_kv_blocks=n_kv, bq=bq, bk=bk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh // rep, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
