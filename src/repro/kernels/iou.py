"""Pairwise-IoU Pallas kernel (NMS over the global detection matrix,
paper §IV-A2). Grid of (BN_a, BN_b) box blocks; each step computes a
(BN, BN) IoU tile entirely in VMEM/VREGs — the O(N²) matrix never
exists in HBM at f32 unless requested.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 128


def _kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)  # (BN, 4)
    b = b_ref[...].astype(jnp.float32)  # (BM, 4)
    ax1, ay1, ax2, ay2 = a[:, 0:1], a[:, 1:2], a[:, 2:3], a[:, 3:4]
    bx1, by1 = b[None, :, 0], b[None, :, 1]
    bx2, by2 = b[None, :, 2], b[None, :, 3]
    ix = jnp.maximum(jnp.minimum(ax2, bx2) - jnp.maximum(ax1, bx1), 0.0)
    iy = jnp.maximum(jnp.minimum(ay2, by2) - jnp.maximum(ay1, by1), 0.0)
    inter = ix * iy
    area_a = jnp.maximum(ax2 - ax1, 0.0) * jnp.maximum(ay2 - ay1, 0.0)
    area_b = jnp.maximum(bx2 - bx1, 0.0) * jnp.maximum(by2 - by1, 0.0)
    union = area_a + area_b - inter
    o_ref[...] = inter / jnp.maximum(union, 1e-9)


def iou_matrix(boxes_a, boxes_b, *, bn: int = DEFAULT_BN, interpret: bool = False):
    """boxes_a: (N,4), boxes_b: (M,4) xyxy -> (N, M) f32 IoU."""
    n, m = boxes_a.shape[0], boxes_b.shape[0]
    pn, pm = -n % bn, -m % bn
    ap = jnp.pad(boxes_a, ((0, pn), (0, 0)))
    bp = jnp.pad(boxes_b, ((0, pm), (0, 0)))
    out = pl.pallas_call(
        _kernel,
        grid=((n + pn) // bn, (m + pm) // bn),
        in_specs=[
            pl.BlockSpec((bn, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n + pn, m + pm), jnp.float32),
        interpret=interpret,
    )(ap, bp)
    return out[:n, :m]
