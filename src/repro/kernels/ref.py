"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

These are also the XLA fallback implementations used on CPU (and in the
multi-pod dry-run, which lowers on the CPU backend).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention(q, k, v, *, causal: bool = False, q_offset=0,
              kv_len: Optional[jnp.ndarray] = None):
    """Multi-head (GQA-aware) attention oracle.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D), Hq % Hkv == 0.
    kv_len: (B,) valid cache lengths (masks the tail), for decode.

    Mixed precision: K/V stay in their storage dtype (the matmuls
    accumulate in f32 via preferred_element_type) — materializing f32
    casts of a 32k-long KV cache costs terabytes of HBM traffic.
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    qf = (q.astype(jnp.float32) / math.sqrt(d)).astype(q.dtype)
    qf = qf.reshape(b, sq, hkv, rep, d)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qf, k,
                        preferred_element_type=jnp.float32)
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(skv)
        mask = kpos[None, :] <= qpos[:, None]
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    if kv_len is not None:
        valid = jnp.arange(skv)[None, :] < kv_len[:, None]
        logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def tile_moments(tiles):
    """Color moments featurizer (paper §III-C): per-tile, per-channel
    mean / stddev / skewness. tiles: (N, H, W, C) -> (N, 3*C) float32."""
    x = tiles.astype(jnp.float32)
    mu = jnp.mean(x, axis=(1, 2))  # (N, C)
    var = jnp.mean(jnp.square(x - mu[:, None, None, :]), axis=(1, 2))
    sd = jnp.sqrt(var + 1e-12)
    m3 = jnp.mean((x - mu[:, None, None, :]) ** 3, axis=(1, 2))
    skew = jnp.cbrt(m3)
    return jnp.concatenate([mu, sd, skew], axis=-1)


def kmeans_assign(x, centroids):
    """x: (N, D), centroids: (K, D) -> (assign (N,) int32, sqdist (N,) f32)."""
    xf = x.astype(jnp.float32)
    cf = centroids.astype(jnp.float32)
    d2 = (
        jnp.sum(xf * xf, -1, keepdims=True)
        - 2.0 * xf @ cf.T
        + jnp.sum(cf * cf, -1)[None, :]
    )
    a = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    return a, jnp.maximum(jnp.min(d2, axis=-1), 0.0)


def iou_matrix(boxes_a, boxes_b):
    """boxes: (N,4)/(M,4) as (x1,y1,x2,y2) -> IoU (N,M) float32."""
    a = boxes_a.astype(jnp.float32)
    b = boxes_b.astype(jnp.float32)
    ax1, ay1, ax2, ay2 = a[:, 0:1], a[:, 1:2], a[:, 2:3], a[:, 3:4]
    bx1, by1, bx2, by2 = b[None, :, 0], b[None, :, 1], b[None, :, 2], b[None, :, 3]
    ix = jnp.maximum(jnp.minimum(ax2, bx2) - jnp.maximum(ax1, bx1), 0.0)
    iy = jnp.maximum(jnp.minimum(ay2, by2) - jnp.maximum(ay1, by1), 0.0)
    inter = ix * iy
    area_a = jnp.maximum(ax2 - ax1, 0.0) * jnp.maximum(ay2 - ay1, 0.0)
    area_b = jnp.maximum(bx2 - bx1, 0.0) * jnp.maximum(by2 - by1, 0.0)
    union = area_a + area_b - inter
    return inter / jnp.maximum(union, 1e-9)


def int8_matmul(x_q, w_q, x_scale, w_scale):
    """Quantized matmul oracle.

    x_q: (M, K) int8, w_q: (K, N) int8; x_scale: (M,), w_scale: (N,)
    per-row / per-column scales -> (M, N) float32.
    """
    acc = jnp.dot(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * x_scale[:, None] * w_scale[None, :]
