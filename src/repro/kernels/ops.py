"""Public kernel entry points with platform dispatch.

Models call these; on TPU (and when shapes are tile-aligned) they route
to the Pallas kernels, otherwise to the pure-jnp oracle in ref.py — so
the same model code runs on the CPU dry-run and on real hardware.

Set ``force`` to 'pallas' / 'ref' to override (tests use
``interpret=True`` through the kernel modules directly as well).
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul as _int8_pallas
from repro.kernels.iou import iou_matrix as _iou_pallas
from repro.kernels.kmeans_assign import kmeans_assign as _kmeans_pallas
from repro.kernels.tile_moments import tile_moments as _moments_pallas

_FORCE = os.environ.get("REPRO_KERNELS", "auto")  # auto | pallas | ref


def _on_tpu() -> bool:
    if _FORCE == "pallas":
        return True
    if _FORCE == "ref":
        return False
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


def attention(q, k, v, *, causal: bool = False):
    """GQA attention: q (B,Sq,Hq,D), k/v (B,Skv,Hkv,D)."""
    sq, skv, d = q.shape[1], k.shape[1], q.shape[-1]
    aligned = sq % 128 == 0 and skv % 128 == 0 and d % 128 == 0
    if _on_tpu() and aligned:
        return flash_attention(q, k, v, causal=causal)
    return ref.attention(q, k, v, causal=causal)


def decode_attention(q, k, v, *, kv_len):
    """Single-token decode: q (B,1,Hq,D) against a full-length cache with
    per-batch valid lengths kv_len (B,)."""
    return ref.attention(q, k, v, causal=False, kv_len=kv_len)


def tile_moments(tiles, *, interpret: Optional[bool] = None):
    if _on_tpu():
        return _moments_pallas(tiles)
    if interpret:
        return _moments_pallas(tiles, interpret=True)
    return ref.tile_moments(tiles)


def kmeans_assign(x, centroids, *, interpret: Optional[bool] = None):
    if _on_tpu():
        return _kmeans_pallas(x, centroids)
    if interpret:
        return _kmeans_pallas(x, centroids, interpret=True)
    return ref.kmeans_assign(x, centroids)


def iou_matrix(a, b, *, interpret: Optional[bool] = None):
    if _on_tpu():
        return _iou_pallas(a, b)
    if interpret:
        return _iou_pallas(a, b, interpret=True)
    return ref.iou_matrix(a, b)


def int8_matmul(x_q, w_q, x_scale, w_scale, *, interpret: Optional[bool] = None):
    if _on_tpu():
        return _int8_pallas(x_q, w_q, x_scale, w_scale)
    if interpret:
        return _int8_pallas(x_q, w_q, x_scale, w_scale, interpret=True)
    return ref.int8_matmul(x_q, w_q, x_scale, w_scale)


def quantize_int8(x, axis=-1):
    """Symmetric per-row int8 quantization helper: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, jnp.squeeze(scale, axis)
