"""Fused k-means assignment Pallas kernel (dedup hot loop, paper §III-C).

One grid step loads a (BN, D) block of tile-features plus the full
(K, D) centroid table into VMEM, computes all pairwise squared
distances with one MXU matmul (-2 x·cᵀ) plus rank-1 norms, and fuses the
argmin — assignments never round-trip distances through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 256


def _kernel(x_ref, c_ref, assign_ref, dist_ref):
    x = x_ref[...].astype(jnp.float32)  # (BN, D)
    c = c_ref[...].astype(jnp.float32)  # (K, D)
    x2 = jnp.sum(x * x, -1, keepdims=True)
    c2 = jnp.sum(c * c, -1)[None, :]
    d2 = x2 - 2.0 * jax.lax.dot_general(x, c, (((1,), (1,)), ((), ()))) + c2
    assign_ref[...] = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    dist_ref[...] = jnp.maximum(jnp.min(d2, axis=-1), 0.0)


def kmeans_assign(x, centroids, *, bn: int = DEFAULT_BN, interpret: bool = False):
    """x: (N, D), centroids: (K, D) -> ((N,) int32 assignment, (N,) f32 d²).

    N is padded to a multiple of bn internally.
    """
    n, d = x.shape
    k = centroids.shape[0]
    n_pad = -n % bn
    xp = jnp.pad(x, ((0, n_pad), (0, 0)))
    grid = ((n + n_pad) // bn,)
    assign, dist = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n + n_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(xp, centroids)
    return assign[:n], dist[:n]
