"""Int8 quantized matmul Pallas kernel — the low-power onboard inference
path (beyond-paper: the space-tier counter runs weight+activation
quantized, modelling the RPi-class power envelope on the MXU).

Grid (M/BM, N/BN, K/BK), K innermost; int32 accumulator in VMEM scratch;
per-row activation scales and per-column weight scales are applied once
on the final K step. 128-cubed blocks keep the MXU int8 path saturated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._pltpu_compat import CompilerParams as _CompilerParams

DEFAULT_B = 128


def _kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )

    @pl.when(ki == n_k - 1)
    def _finish():
        xs = xs_ref[...].astype(jnp.float32)  # (BM,)
        ws = ws_ref[...].astype(jnp.float32)  # (BN,)
        o_ref[...] = acc_ref[...].astype(jnp.float32) * xs[:, None] * ws[None, :]


def int8_matmul(x_q, w_q, x_scale, w_scale, *, bm: int = DEFAULT_B,
                bn: int = DEFAULT_B, bk: int = DEFAULT_B,
                interpret: bool = False):
    """x_q (M,K) int8 @ w_q (K,N) int8 -> (M,N) f32, scaled per row/col."""
    m, k = x_q.shape
    n = w_q.shape[1]
    pm, pn, pk = -m % bm, -n % bn, -k % bk
    xp = jnp.pad(x_q, ((0, pm), (0, pk)))
    wp = jnp.pad(w_q, ((0, pk), (0, pn)))
    xsp = jnp.pad(x_scale, (0, pm))
    wsp = jnp.pad(w_scale, (0, pn))
    grid = ((m + pm) // bm, (n + pn) // bn, (k + pk) // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xp, wp, xsp, wsp)
    return out[:m, :n]
