"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA [arXiv:2412.08905; hf].

Note: phi-4-mini uses partial RoPE in HF; we apply full RoPE (documented
in DESIGN.md as an adaptation — does not change FLOP/byte structure).
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="phi4-mini-3.8b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    rope_theta=10000.0,
    tie_embeddings=True,
)
