"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from repro.configs.base import LMConfig, MoESpec

CONFIG = LMConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    rope_theta=1000000.0,
    moe=MoESpec(n_routed=60, n_shared=4, top_k=4, d_expert=1408,
                pad_experts_to=64),
)
