"""unet-sd15 [diffusion] — img_res=512 latent_res=64 ch=320
ch_mult=1-2-4-4 n_res_blocks=2 attn at the first three levels
ctx_dim=768 [arXiv:2112.10752; paper]."""
from repro.configs.base import DiffusionConfig

CONFIG = DiffusionConfig(
    name="unet-sd15",
    kind="unet",
    img_res=512,
    ch=320,
    ch_mult=(1, 2, 4, 4),
    n_res_blocks=2,
    attn_levels=(0, 1, 2),
    ctx_dim=768,
    ctx_len=77,
)
