"""The paper's onboard (space-tier) counter: a YOLOv3-tiny-class
single-shot detector (416x416 input, shallow trunk). Table II row 2."""
from repro.configs.base import DetectorConfig

# 6 stride-2 stages -> 13x13 grid at 416 px, ~6 GFLOP/tile forward --
# matching YOLOv3-tiny's published compute (5.6 GFLOPs @416).
CONFIG = DetectorConfig(
    name="targetfuse-space",
    input_size=416,
    widths=(16, 32, 64, 128, 256, 512),
    n_blocks_per_stage=2,
)
