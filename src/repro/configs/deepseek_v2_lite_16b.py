"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408
vocab=102400, MLA kv_lora=512, MoE 64 routed + 2 shared top-6
[arXiv:2405.04434; hf].

The assignment line reads "2 shared+160 routed top-6" but its own header
says "MoE 64e top-6" and the published DeepSeek-V2-Lite has 64 routed
experts; we use 64 (see DESIGN.md assumption table). Layer 0 uses a dense
FFN (d_ff=10944 in HF; we keep the assigned d_ff for the dense layer).
"""
from repro.configs.base import LMConfig, MLASpec, MoESpec

CONFIG = LMConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,
    vocab_size=102400,
    rope_theta=10000.0,
    moe=MoESpec(n_routed=64, n_shared=2, top_k=6, d_expert=1408,
                first_dense_layers=1),
    mla=MLASpec(kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64,
                v_head_dim=128),
)
