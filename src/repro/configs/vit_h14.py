"""vit-h14 [vision] — img_res=224 patch=14 n_layers=32 d_model=1280
n_heads=16 d_ff=5120 [arXiv:2010.11929; paper]."""
from repro.configs.base import VisionConfig

CONFIG = VisionConfig(
    name="vit-h14",
    kind="vit",
    img_res=224,
    patch=14,
    n_layers=32,
    d_model=1280,
    n_heads=16,
    d_ff=5120,
)
