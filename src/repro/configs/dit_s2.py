"""dit-s2 [diffusion] — img_res=256 patch=2 n_layers=12 d_model=384
n_heads=6 [arXiv:2212.09748; paper]. Operates on 8x-downsampled VAE
latents (latent stub), 4 channels."""
from repro.configs.base import DiffusionConfig

CONFIG = DiffusionConfig(
    name="dit-s2",
    kind="dit",
    img_res=256,
    patch=2,
    n_layers=12,
    d_model=384,
    n_heads=6,
)
