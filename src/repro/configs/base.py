"""Config system: typed architecture configs, input-shape sets, registry.

Every assigned architecture gets one module in this package exporting
``CONFIG``; the registry below maps public arch ids (``--arch qwen3-8b``)
to those modules. Shape sets are family-scoped (each arch is paired with
its own family's shapes, per the assignment).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoESpec:
    """Mixture-of-experts block spec (routed + always-on shared experts)."""

    n_routed: int
    n_shared: int
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    # Layers that use a dense FFN instead of MoE (e.g. deepseek layer 0).
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    # Pad the expert dim to this count for clean expert parallelism
    # (qwen2-moe: 60 -> 64 so experts shard over a 16-way model axis;
    # padded experts are masked out of the router and never receive
    # tokens). 0 = no padding.
    pad_experts_to: int = 0


@dataclass(frozen=True)
class MLASpec:
    """Multi-head Latent Attention (DeepSeek-V2) spec."""

    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class LMConfig:
    name: str
    family: str = "lm"
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 512
    vocab_size: int = 1024
    rope_theta: float = 10000.0
    qk_norm: bool = False
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoESpec] = None
    mla: Optional[MLASpec] = None
    param_dtype: str = "bfloat16"
    # remat policy for train_step: 'none' | 'full' | 'dots_saveable'
    remat: str = "dots_saveable"
    # scan-over-layers (compact HLO) vs python unroll (exact dry-run cost
    # accounting: XLA cost_analysis counts a while-loop body only once)
    scan_layers: bool = True

    @property
    def n_params(self) -> int:
        """Total parameter count (embedding + trunk)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.mla is not None:
            m = self.mla
            q = d * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            kv_a = d * (m.kv_lora_rank + m.qk_rope_head_dim)
            kv_b = m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            o = self.n_heads * m.v_head_dim * d
            attn = q + kv_a + kv_b + o
        else:
            attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.moe is not None:
            e = self.moe
            moe_ffn = (e.n_routed + e.n_shared) * 3 * d * e.d_expert + d * e.n_routed
            dense_ffn = 3 * d * self.d_ff
            ffn_total = (
                e.first_dense_layers * dense_ffn
                + (L - e.first_dense_layers) * moe_ffn
            )
            return emb + L * attn + ffn_total
        return emb + L * (attn + 3 * d * self.d_ff)

    @property
    def n_active_params(self) -> int:
        """Params touched per token (MoE: shared + top_k routed only)."""
        if self.moe is None:
            return self.n_params
        d, L, e = self.d_model, self.n_layers, self.moe
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.mla is not None:
            m = self.mla
            attn = (
                d * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
            )
        else:
            attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv_heads * 2)
        active_moe = (e.top_k + e.n_shared) * 3 * d * e.d_expert + d * e.n_routed
        dense_ffn = 3 * d * self.d_ff
        ffn_total = (
            e.first_dense_layers * dense_ffn + (L - e.first_dense_layers) * active_moe
        )
        return emb + L * attn + ffn_total


@dataclass(frozen=True)
class VisionConfig:
    name: str
    family: str = "vision"
    kind: str = "vit"  # 'vit' | 'convnext' | 'resnet'
    img_res: int = 224
    n_classes: int = 1000
    # vit
    patch: int = 16
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    d_ff: int = 3072
    # convnext / resnet
    depths: Tuple[int, ...] = ()
    dims: Tuple[int, ...] = ()
    width: int = 64
    param_dtype: str = "bfloat16"
    remat: str = "dots_saveable"
    scan_layers: bool = True

    @property
    def n_params(self) -> int:
        if self.kind == "vit":
            d = self.d_model
            emb = 3 * self.patch * self.patch * d + d  # patch embed (+cls)
            blk = 4 * d * d + 2 * d * self.d_ff
            head = d * self.n_classes
            return emb + self.n_layers * blk + head
        if self.kind == "convnext":
            total = 3 * 4 * 4 * self.dims[0]
            for i, (dep, dim) in enumerate(zip(self.depths, self.dims)):
                blk = 7 * 7 * dim + dim * 4 * dim * 2  # dwconv + 2 pw
                total += dep * blk
                if i + 1 < len(self.dims):
                    total += dim * self.dims[i + 1] * 2 * 2  # downsample conv
            return total + self.dims[-1] * self.n_classes
        # resnet bottleneck
        w = self.width
        total = 3 * 7 * 7 * w
        in_ch = w
        for i, dep in enumerate(self.depths):
            mid = w * (2**i)
            out = mid * 4
            for b in range(dep):
                total += in_ch * mid + 3 * 3 * mid * mid + mid * out
                if b == 0 and in_ch != out:
                    total += in_ch * out
                in_ch = out
        return total + in_ch * self.n_classes

    @property
    def n_active_params(self) -> int:
        return self.n_params


@dataclass(frozen=True)
class DiffusionConfig:
    name: str
    family: str = "diffusion"
    kind: str = "dit"  # 'dit' | 'unet'
    img_res: int = 256
    latent_factor: int = 8  # VAE downsample; latent_res = img_res // 8
    latent_ch: int = 4
    # dit
    patch: int = 2
    n_layers: int = 12
    d_model: int = 384
    n_heads: int = 6
    n_classes: int = 1000
    # unet
    ch: int = 320
    ch_mult: Tuple[int, ...] = (1, 2, 4, 4)
    n_res_blocks: int = 2
    attn_levels: Tuple[int, ...] = (0, 1, 2)  # levels (by downsample) with attn
    ctx_dim: int = 768
    ctx_len: int = 77
    param_dtype: str = "bfloat16"
    remat: str = "dots_saveable"
    scan_layers: bool = True

    @property
    def n_params(self) -> int:
        if self.kind == "dit":
            d = self.d_model
            emb = self.latent_ch * self.patch * self.patch * d
            blk = 4 * d * d + 2 * d * 4 * d + 6 * d * d  # attn + mlp + adaLN
            out = d * self.patch * self.patch * self.latent_ch * 2
            return emb + self.n_layers * blk + out + 256 * d + self.n_classes * d
        # unet: estimate from channel schedule
        total = 0
        ch = self.ch
        chans = [ch * m for m in self.ch_mult]
        prev = ch
        for lvl, c in enumerate(chans):
            for _ in range(self.n_res_blocks):
                total += 3 * 3 * prev * c + 3 * 3 * c * c + 4 * ch * c
                if lvl in self.attn_levels:
                    total += 4 * c * c + 2 * c * self.ctx_dim + 8 * c * c
                prev = c
            if lvl + 1 < len(chans):
                total += 3 * 3 * c * c
        total *= 2  # down + up paths (approx.)
        total += 2 * (3 * 3 * chans[-1] * chans[-1])  # mid block
        total += 3 * 3 * self.latent_ch * ch * 2
        return total

    @property
    def n_active_params(self) -> int:
        return self.n_params


@dataclass(frozen=True)
class DetectorConfig:
    """Paper's own DNN counters (YOLO-style single-shot detectors)."""

    name: str
    family: str = "detector"
    input_size: int = 416
    widths: Tuple[int, ...] = (16, 32, 64, 128, 256)
    n_blocks_per_stage: int = 1
    n_classes: int = 8
    n_anchors: int = 3
    param_dtype: str = "float32"
    remat: str = "none"

    @property
    def n_params(self) -> int:
        total = 3 * 3 * 3 * self.widths[0]
        prev = self.widths[0]
        for w in self.widths[1:]:
            total += (3 * 3 * prev * w) * self.n_blocks_per_stage
            prev = w
        total += prev * self.n_anchors * (5 + self.n_classes)
        return total

    @property
    def n_active_params(self) -> int:
        return self.n_params


# ---------------------------------------------------------------------------
# Input shapes (per family, per the assignment)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode' | 'gen' | 'cls' | 'serve'
    seq_len: int = 0
    global_batch: int = 0
    img_res: int = 0
    steps: int = 0


LM_SHAPES = (
    ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    ShapeSpec("long_500k", "decode", seq_len=524288, global_batch=1),
)

DIFFUSION_SHAPES = (
    ShapeSpec("train_256", "train", img_res=256, global_batch=256, steps=1000),
    ShapeSpec("gen_1024", "gen", img_res=1024, global_batch=4, steps=50),
    ShapeSpec("gen_fast", "gen", img_res=512, global_batch=16, steps=4),
    ShapeSpec("train_1024", "train", img_res=1024, global_batch=32, steps=1000),
)

VISION_SHAPES = (
    ShapeSpec("cls_224", "cls", img_res=224, global_batch=256),
    ShapeSpec("cls_384", "cls", img_res=384, global_batch=64),
    ShapeSpec("serve_b1", "serve", img_res=224, global_batch=1),
    ShapeSpec("serve_b128", "serve", img_res=224, global_batch=128),
)

DETECTOR_SHAPES = (
    ShapeSpec("tiles_416_b256", "serve", img_res=416, global_batch=256),
    ShapeSpec("train_416_b64", "train", img_res=416, global_batch=64),
)

FAMILY_SHAPES = {
    "lm": LM_SHAPES,
    "diffusion": DIFFUSION_SHAPES,
    "vision": VISION_SHAPES,
    "detector": DETECTOR_SHAPES,
}

# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCH_MODULES = {
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3p8b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2p7b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "dit-s2": "repro.configs.dit_s2",
    "unet-sd15": "repro.configs.unet_sd15",
    "convnext-b": "repro.configs.convnext_b",
    "vit-l16": "repro.configs.vit_l16",
    "vit-h14": "repro.configs.vit_h14",
    "resnet-152": "repro.configs.resnet_152",
    # the paper's own counters
    "targetfuse-space": "repro.configs.targetfuse_space",
    "targetfuse-ground": "repro.configs.targetfuse_ground",
    "ssd-mobilenetv2": "repro.configs.ssd_mobilenetv2",
}

ASSIGNED_ARCHS = tuple(k for k in _ARCH_MODULES if not k.startswith(("targetfuse", "ssd")))


def list_archs():
    return tuple(_ARCH_MODULES)


def get_config(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.CONFIG


def get_shapes(arch: str) -> Tuple[ShapeSpec, ...]:
    return FAMILY_SHAPES[get_config(arch).family]


def get_shape(arch: str, shape_name: str) -> ShapeSpec:
    for s in get_shapes(arch):
        if s.name == shape_name:
            return s
    raise KeyError(f"{arch}: unknown shape {shape_name!r}")


def all_cells():
    """Every assigned (arch, shape) cell — the 40-cell dry-run matrix."""
    out = []
    for arch in ASSIGNED_ARCHS:
        for s in get_shapes(arch):
            out.append((arch, s.name))
    return out


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------


def reduced(cfg):
    """Shrink a config to something a CPU smoke test can run one step of."""
    if isinstance(cfg, LMConfig):
        moe = cfg.moe
        if moe is not None:
            # capacity_factor = n_routed makes the reduced config provably
            # drop-free, so prefill/decode match the full forward exactly.
            moe = replace(moe, n_routed=min(moe.n_routed, 8), n_shared=min(moe.n_shared, 2), top_k=min(moe.top_k, 2), d_expert=64, capacity_factor=8.0, pad_experts_to=0)
        mla = cfg.mla
        if mla is not None:
            mla = replace(mla, kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
        return replace(
            cfg, name=cfg.name + "-smoke", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2), head_dim=16,
            d_ff=128, vocab_size=256, moe=moe, mla=mla,
            param_dtype="float32", remat="none",
        )
    if isinstance(cfg, VisionConfig):
        if cfg.kind == "vit":
            return replace(cfg, name=cfg.name + "-smoke", img_res=32, patch=8,
                           n_layers=2, d_model=32, n_heads=2, d_ff=64,
                           n_classes=10, param_dtype="float32", remat="none")
        if cfg.kind == "convnext":
            return replace(cfg, name=cfg.name + "-smoke", img_res=32,
                           depths=(1, 1, 1, 1), dims=(8, 16, 24, 32),
                           n_classes=10, param_dtype="float32", remat="none")
        return replace(cfg, name=cfg.name + "-smoke", img_res=32,
                       depths=(1, 1, 1, 1), width=8, n_classes=10,
                       param_dtype="float32", remat="none")
    if isinstance(cfg, DiffusionConfig):
        if cfg.kind == "dit":
            return replace(cfg, name=cfg.name + "-smoke", img_res=32,
                           n_layers=2, d_model=32, n_heads=2, n_classes=10,
                           param_dtype="float32", remat="none")
        return replace(cfg, name=cfg.name + "-smoke", img_res=64, ch=16,
                       ch_mult=(1, 2), n_res_blocks=1, attn_levels=(1,),
                       ctx_dim=32, ctx_len=8, param_dtype="float32", remat="none")
    if isinstance(cfg, DetectorConfig):
        # keep the tier asymmetry: widths scale down but the ground tier
        # stays wider/deeper than the space tier
        w = tuple(max(8, x // 2) for x in cfg.widths[:3])
        return replace(cfg, name=cfg.name + "-smoke", input_size=64,
                       widths=w, param_dtype="float32")
    raise TypeError(type(cfg))


def to_dict(cfg) -> dict:
    return dataclasses.asdict(cfg)
