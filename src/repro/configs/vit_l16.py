"""vit-l16 [vision] — img_res=224 patch=16 n_layers=24 d_model=1024
n_heads=16 d_ff=4096 [arXiv:2010.11929; paper]."""
from repro.configs.base import VisionConfig

CONFIG = VisionConfig(
    name="vit-l16",
    kind="vit",
    img_res=224,
    patch=16,
    n_layers=24,
    d_model=1024,
    n_heads=16,
    d_ff=4096,
)
