"""The paper's ground-tier counter: a YOLOv3-class detector (416x416,
deeper/wider trunk -> higher mAP). Table II row 1."""
from repro.configs.base import DetectorConfig

CONFIG = DetectorConfig(
    name="targetfuse-ground",
    input_size=416,
    widths=(32, 64, 128, 256, 512, 1024),
    n_blocks_per_stage=2,
)
