"""convnext-b [vision] — img_res=224 depths=3-3-27-3 dims=128-256-512-1024
[arXiv:2201.03545; paper]."""
from repro.configs.base import VisionConfig

CONFIG = VisionConfig(
    name="convnext-b",
    kind="convnext",
    img_res=224,
    depths=(3, 3, 27, 3),
    dims=(128, 256, 512, 1024),
)
