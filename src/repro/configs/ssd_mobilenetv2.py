"""ssd mobilenetv2 analogue (Table II row 3): small input (300x300->
we use 288 to keep stride alignment), narrow trunk."""
from repro.configs.base import DetectorConfig

CONFIG = DetectorConfig(
    name="ssd-mobilenetv2",
    input_size=288,
    widths=(16, 24, 48, 96, 160),
    n_blocks_per_stage=1,
)
