"""resnet-152 [vision] — img_res=224 depths=3-8-36-3 width=64
bottleneck=1 [arXiv:1512.03385; paper]."""
from repro.configs.base import VisionConfig

CONFIG = VisionConfig(
    name="resnet-152",
    kind="resnet",
    img_res=224,
    depths=(3, 8, 36, 3),
    width=64,
)
