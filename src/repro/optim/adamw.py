"""AdamW with global-norm clipping (pure JAX, optax-free)."""
from __future__ import annotations

from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(tree, max_norm):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree_util.tree_map(lambda x: x * scale, tree), n


def adamw(lr: Union[float, Callable], b1=0.9, b2=0.999, eps=1e-8,
          weight_decay=0.01, clip_norm=1.0):
    """Returns (init_fn, update_fn).

    update_fn(grads, state, params) -> (new_params, new_state, metrics).
    Optimizer state is kept in f32 regardless of param dtype (mixed
    precision: bf16 params, f32 moments).
    """
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update(grads, state, params):
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / bc1
            vhat = v2 / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m2, v2

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_m = jax.tree_util.tree_leaves(state.mu)
        flat_v = jax.tree_util.tree_leaves(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
        return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr_t}

    return init, update
