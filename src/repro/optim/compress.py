"""Gradient compression for bandwidth-constrained data parallelism.

Two codecs used by the distributed trainer (and directly relevant to
the paper's theme — every byte over a constrained link must earn its
keep):

- int8 stochastic-rounding quantization with per-tensor scale (8x
  compression of the DP all-reduce payload; unbiased in expectation).
- top-k sparsification with error feedback (residual accumulation), the
  classic deep-gradient-compression scheme.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_encode(g, key):
    """-> (q int8, scale f32 scalar). Stochastic rounding keeps E[dec]=g."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    x = gf / scale
    lo = jnp.floor(x)
    p = x - lo
    r = jax.random.uniform(key, g.shape)
    q = jnp.clip(lo + (r < p), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q, scale):
    return q.astype(jnp.float32) * scale


def int8_roundtrip_tree(grads, key):
    """Encode+decode every leaf (what the wire sees under int8 DP)."""
    leaves, tdef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = [int8_decode(*int8_encode(g, k)) for g, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(tdef, out)


def topk_encode(g, frac: float):
    """Keep the top `frac` fraction of entries by magnitude.

    -> (values, flat indices, residual) — residual feeds error feedback.
    """
    gf = g.astype(jnp.float32).reshape(-1)
    k = max(1, int(gf.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(gf), k)
    sel = gf[idx]
    residual = gf.at[idx].set(0.0).reshape(g.shape)
    return sel, idx, residual


def topk_decode(vals, idx, shape):
    out = jnp.zeros((int(jnp.prod(jnp.array(shape))),), jnp.float32)
    return out.at[idx].set(vals).reshape(shape)


def topk_roundtrip_tree(grads, residuals, frac: float):
    """Error-feedback top-k over a pytree.

    grads+residuals in -> (decoded sparse grads, new residuals).
    """
    leaves, tdef = jax.tree_util.tree_flatten(grads)
    res_leaves = jax.tree_util.tree_leaves(residuals)
    dec, new_res = [], []
    for g, r in zip(leaves, res_leaves):
        acc = g.astype(jnp.float32) + r
        vals, idx, resid = topk_encode(acc, frac)
        dec.append(topk_decode(vals, idx, g.shape))
        new_res.append(resid)
    return (jax.tree_util.tree_unflatten(tdef, dec),
            jax.tree_util.tree_unflatten(tdef, new_res))
