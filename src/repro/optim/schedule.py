"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(peak_lr: float, warmup_steps: int, total_steps: int,
                       floor: float = 0.0):
    def fn(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + (peak_lr - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, cos)
    return fn


def constant(lr: float):
    return lambda step: jnp.float32(lr)
