"""Bandwidth-aware downlinking throttling (paper §III-D, Algorithm 2).

Two-threshold selection logic on the onboard counter's confidence:
  conf <  conf_p              -> discard tile
  conf >  conf_q              -> accept the space count
  conf in [conf_p, conf_q]    -> downlink candidate

Candidates fill the contact-window byte budget under one of the three
policies the paper studies (Fig. 6):
  low_conf_first : ascending confidence; leftovers counted in space
  fixed_conf     : descending confidence; leftovers counted in space
                   only if conf > conf_q (i.e. never -> dropped)
  dynamic_conf   : descending confidence; leftovers counted in space
                   (conf_q effectively lowers itself to the
                   bandwidth-determined cutoff)

Everything is realized as sort + prefix-sum + masks so it jits, shards
(tile dim is the batch dim) and lowers in the dry-run.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

POLICIES = ("low_conf_first", "fixed_conf", "dynamic_conf")


class ThrottleResult(NamedTuple):
    discard: jnp.ndarray      # (N,) bool  conf < conf_p
    space: jnp.ndarray        # (N,) bool  counted onboard
    downlink: jnp.ndarray     # (N,) bool  transmitted to ground
    dropped: jnp.ndarray      # (N,) bool  middle tiles lost (fixed_conf)
    bytes_used: jnp.ndarray   # scalar f32


def throttle(conf: jnp.ndarray, sizes: jnp.ndarray, budget_bytes,
             conf_p: float, conf_q: float, policy: str = "dynamic_conf",
             active: jnp.ndarray = None) -> ThrottleResult:
    """conf (N,), sizes (N,) bytes, scalar budget -> masks (Algorithm 2).

    ``active``: optional (N,) bool — tiles that exist at all (padding /
    dedup-suppressed tiles are False and take no budget).
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}")
    n = conf.shape[0]
    active = jnp.ones((n,), bool) if active is None else active
    conf = jnp.where(active, conf, -1.0)

    discard = active & (conf < conf_p)
    high = active & (conf > conf_q)
    middle = active & ~discard & ~high

    # --- budget fill over middle tiles (Algorithm 2 lines 12-18) ---
    if policy == "low_conf_first":
        key = jnp.where(middle, conf, jnp.inf)          # ascending conf
    else:
        key = jnp.where(middle, -conf, jnp.inf)         # descending conf
    order = jnp.argsort(key)                             # middles first
    sz = jnp.where(middle, sizes, 0.0)[order]
    fits = (jnp.cumsum(sz) <= budget_bytes) & middle[order]
    downlink = jnp.zeros((n,), bool).at[order].set(fits)
    bytes_used = jnp.sum(jnp.where(downlink, sizes, 0.0))

    leftover = middle & ~downlink
    if policy == "fixed_conf":
        dropped = leftover                                # conf <= conf_q by construction
        space = high
    else:
        dropped = jnp.zeros((n,), bool)
        space = high | leftover
    return ThrottleResult(discard, space, downlink, dropped, bytes_used)


# jitted entry for the hot bucketed path: one compiled program per
# (n_pad, policy) instead of ~15 eagerly dispatched ops per call —
# bit-identical to the eager call (enforced by tests/test_core.py)
_throttle_jit = jax.jit(throttle, static_argnames=("policy",))


def _throttle_stack(conf, sizes, budgets, conf_p, conf_q, active, *,
                    policy: str):
    """:func:`throttle` vmapped over a leading lane axis — each lane is
    one contact window's candidate set with its own budget/thresholds.
    Lanes are independent (per-row sort/cumsum/masks), so per-lane
    outputs are bit-equal to calling the scalar program lane by lane."""
    return jax.vmap(
        lambda c, s, b, p, q, a: throttle(c, s, b, p, q, policy, a)
    )(conf, sizes, budgets, conf_p, conf_q, active)


_throttle_stack_jit = jax.jit(_throttle_stack, static_argnames=("policy",))


def throttle_padded_batch(conf, tile_bytes, budgets, conf_p, conf_q,
                          policy: str = "dynamic_conf", n_pad: int = None,
                          sharding=None):
    """Lane-stacked :func:`throttle_padded`: L windows' candidate sets in
    ONE compiled program instead of L dispatches.

    ``conf``: list of L host (n_l,) confidence vectors (ragged);
    ``tile_bytes`` / ``budgets`` / ``conf_p`` / ``conf_q``: (L,) per-lane
    scalars (lists or arrays). All lanes are padded to ``n_pad`` slots
    (default: the max lane length) with inactive entries — identical
    padding-invariance as :func:`throttle_padded`, so per-lane masks are
    bit-equal to the scalar bucketed call whatever each lane's own
    bucket would have been. The LANE axis is bucketed too: the stack is
    padded to a power-of-two lane count with inert lanes (all-inactive,
    zero budget), so the compiled-program count stays log-bounded in
    the windows-per-step instead of growing with every distinct lane
    count a contact schedule produces. ``sharding``: optional
    :class:`~repro.core.fleet_sharding.FleetSharding`; on-mesh the lane
    stack is placed along the device mesh (lanes are independent, so
    placement never changes a lane's masks).

    Returns ``[(space, downlink), ...]`` host boolean mask pairs over
    each lane's real ``n_l`` slots.
    """
    ns = [int(np.shape(c)[0]) for c in conf]
    L = len(ns)
    n_pad = max(ns + [1]) if n_pad is None else int(n_pad)
    if n_pad < max(ns + [0]):
        raise ValueError(
            f"throttle_padded_batch: n_pad={n_pad} < max lane length "
            f"{max(ns)} would drop real tiles")
    L_pad = 1 << max(L - 1, 0).bit_length()  # pow2 lane bucket
    conf_pad = np.full((L_pad, n_pad), -1.0)
    act = np.zeros((L_pad, n_pad), bool)
    for i, (c, n) in enumerate(zip(conf, ns)):
        conf_pad[i, :n] = c
        act[i, :n] = True

    def lanes(v):  # (L,) per-lane scalars, zero-filled pad lanes
        out = np.zeros(L_pad, np.float64)
        out[:L] = np.asarray(v, np.float64)
        return out

    sizes = np.ascontiguousarray(
        np.broadcast_to(lanes(tile_bytes)[:, None], (L_pad, n_pad)))
    args = [jnp.asarray(conf_pad), jnp.asarray(sizes),
            jnp.asarray(lanes(budgets)), jnp.asarray(lanes(conf_p)),
            jnp.asarray(lanes(conf_q)), jnp.asarray(act)]
    if sharding is not None and sharding.on_mesh:
        # zero pad lanes (budget 0, all-inactive) are inert in their own
        # rows; sliced off below before anything reads them
        args = [sharding.shard(a) for a in args]
    tr = _throttle_stack_jit(*args, policy=policy)
    space = np.asarray(tr.space)[:L]
    down = np.asarray(tr.downlink)[:L]
    return [(space[i, :n], down[i, :n]) for i, n in enumerate(ns)]


def throttle_padded(conf, tile_bytes: float, budget_bytes, conf_p: float,
                    conf_q: float, policy: str = "dynamic_conf",
                    n_pad: int = None):
    """Shape-stable host-facing wrapper around :func:`throttle`.

    Pads ``conf`` (host array, (n,)) to ``n_pad`` slots with inactive
    entries (conf = -1, active = False) so the compiled program is
    reused per bucket size rather than per workload size; pad slots sort
    last and take no budget. Returns host ``(space, downlink)`` boolean
    masks over the real ``n`` slots — bit-identical to the unpadded
    call.
    """
    n = int(np.shape(conf)[0])
    n_pad = n_pad if n_pad is not None else n
    if n_pad < n:
        raise ValueError(
            f"throttle_padded: n_pad={n_pad} < n={n} would drop real tiles; "
            f"pass a bucket >= n (n_pad == n is the no-padding boundary)")
    conf_pad = np.full(n_pad, -1.0)
    conf_pad[:n] = conf
    act = np.zeros(n_pad, bool)
    act[:n] = True
    tr = _throttle_jit(jnp.asarray(conf_pad), jnp.full(n_pad, tile_bytes),
                       float(budget_bytes), conf_p, conf_q, policy,
                       active=jnp.asarray(act))
    return np.asarray(tr.space)[:n], np.asarray(tr.downlink)[:n]


_BUDGET_TINY = float(np.finfo(np.float64).tiny)


def clamp_budget_bytes(n_bytes: float) -> float:
    """Clamp a window byte budget to exact 0.0 when it is negative or has
    underflowed to a denormal (same degenerate-window philosophy as
    :func:`contact_budget_bytes`: a budget below one representable normal
    float of bytes is not a budget). Normal positive budgets pass through
    unchanged, so clamping is a bit-exact no-op on every real window."""
    n_bytes = float(n_bytes)
    return n_bytes if n_bytes >= _BUDGET_TINY else 0.0


def contact_budget_bytes(bandwidth_mbps: float, contact_s: float) -> float:
    """Contact-window byte budget (paper §IV-A3: e.g. 100 Mbps x 6 min).

    Degenerate windows — zero or negative contact time (a pass that
    never rises above the horizon mask) or non-positive bandwidth —
    yield a zero budget rather than a nonsensical one (each operand is
    clamped, so two negatives cannot multiply into a positive budget).
    """
    return max(bandwidth_mbps, 0.0) * 1e6 / 8.0 * max(contact_s, 0.0)


def bandwidth_efficiency(err_baseline: float, err_system: float,
                         bytes_baseline: float, bytes_system: float) -> float:
    """Error-reduction per downlinked byte, relative to a baseline
    (the paper's '9.6x bandwidth efficiency' metric)."""
    eff_sys = max(err_baseline - err_system, 0.0) / max(bytes_system, 1.0)
    return eff_sys
