"""Clustering-based data deduplication (paper §III-C).

Tiles are embedded with the color-moments featurizer (rotation/
translation-invariant global channel statistics — matching the paper's
requirement that contexts survive 'geographic label transformations'),
k-means-clustered into geographic contexts, and only the tile nearest
each centroid is processed/downlinked. Cluster sizes are retained so the
representative's count stands for the whole context.

The pipeline engine computes tile moments once per frame batch and
enters through :func:`dedup_from_moments`; :func:`dedup` keeps the
featurize-from-raw-tiles entry point.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

# shape-stable dedup: inputs are zero-padded to power-of-two bucket sizes
# so the compiled program count grows with log(workload), not workload
_N_BUCKET = 64
_K_BUCKET = 16
_FAR = 1e15  # sentinel for unused centroid slots (d2 stays finite in f32)


def bucket_size(v: int, floor: int = _N_BUCKET) -> int:
    """Next power-of-two bucket >= max(v, floor) for shape-stable padding."""
    b = floor
    while b < v:
        b *= 2
    return b


def dedup_pad_size(n: int) -> int:
    """Input bucket `dedup_from_moments` expects for a pre-padded gather."""
    return bucket_size(n, 2 * _N_BUCKET)


class DedupResult(NamedTuple):
    assign: jnp.ndarray        # (N,) int32 cluster id
    centroids: jnp.ndarray     # (K, D)
    rep_mask: jnp.ndarray      # (N,) bool — True for cluster representatives
    cluster_sizes: jnp.ndarray  # (K,) int32
    rep_idx: jnp.ndarray       # (K,) int32 index of each cluster's representative


def normalize_moments(f: jnp.ndarray) -> jnp.ndarray:
    """(N, D) raw color moments -> centered features.

    Centered per feature but scaled by one GLOBAL factor: per-feature
    z-scoring would blow up low-information dimensions (e.g. nearly
    constant tile stds) into pure noise axes and break the clustering.
    """
    mu = jnp.mean(f, 0, keepdims=True)
    scale = jnp.std(f) + 1e-6
    return (f - mu) / scale


def features(tiles: jnp.ndarray) -> jnp.ndarray:
    """(N, H, W, C) -> (N, 3C) normalized color-moment features."""
    return normalize_moments(kops.tile_moments(tiles))


def _kmeanspp_init(x, k, key):
    """k-means++ (greedy D² farthest-point) initialization, incremental.

    Maintains a running min-d² vector updated against only the newest
    centroid: O(N·D) per pick instead of re-scoring all K centroids
    (O(N·K·D)) on every scan step like `_kmeanspp_init_scan`.
    """
    n = x.shape[0]
    first = jax.random.randint(key, (), 0, n)
    cent0 = x[first]
    _, d2 = kops.kmeans_assign(x, cent0[None])

    def pick(carry, i):
        cents, d2 = carry
        nxt = jnp.argmax(d2)  # greedy farthest point (deterministic)
        c = x[nxt]
        cents = jax.lax.dynamic_update_slice(cents, c[None], (i, 0))
        _, d2_new = kops.kmeans_assign(x, c[None])
        return (cents, jnp.minimum(d2, d2_new)), None

    cents = jnp.tile(cent0[None], (k, 1))
    (cents, _), _ = jax.lax.scan(pick, (cents, d2), jnp.arange(1, k))
    return cents


def _kmeanspp_init_scan(x, k, key):
    """Pre-engine init: full kmeans_assign against all K slots per pick.

    Kept as the equivalence reference for `_kmeanspp_init` (the unfilled
    slots duplicate centroid 0, so the min-distance — and therefore the
    pick sequence — is identical).
    """
    n = x.shape[0]
    first = jax.random.randint(key, (), 0, n)
    cent0 = x[first]

    def pick(carry, key_i):
        cents, i = carry
        _, d2 = kops.kmeans_assign(x, cents)
        nxt = jnp.argmax(d2)
        cents = jax.lax.dynamic_update_slice(cents, x[nxt][None], (i, 0))
        return (cents, i + 1), None

    cents = jnp.tile(cent0[None], (k, 1))
    (cents, _), _ = jax.lax.scan(pick, (cents, 1), jnp.arange(k - 1))
    return cents


def kmeans(x: jnp.ndarray, k: int, key, iters: int = 10):
    """k-means with k-means++ init. Returns (assign, centroids, d2)."""
    cent = _kmeanspp_init(x, k, key)

    def step(cent, _):
        assign, _ = kops.kmeans_assign(x, cent)
        one = jax.nn.one_hot(assign, k, dtype=x.dtype)  # (N, K)
        tot = jnp.einsum("nk,nd->kd", one, x)
        cnt = jnp.sum(one, 0)[:, None]
        new = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1), cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    assign, d2 = kops.kmeans_assign(x, cent)
    return assign, cent, d2


def dedup(tiles: jnp.ndarray, k: int, key, iters: int = 10) -> DedupResult:
    """Full dedup pass: featurize -> cluster -> pick representatives."""
    return dedup_from_moments(kops.tile_moments(tiles), k, key, iters)


@partial(jax.jit, static_argnames=("k_pad", "iters"))
def _dedup_padded_core(m_pad, n, k, key, *, k_pad: int, iters: int):
    """Shape-stable featurize + k-means over padded raw moments.

    ``m_pad`` is (n_pad, D) with real rows [:n]; rows past ``n`` may
    hold ANY finite values (zero padding or junk from a padded gather) —
    the first masked `where` zeroes them, after which every path is a
    pure function of the real rows. ``n`` and ``k`` are dynamic scalars,
    so ONE compilation per (n_pad, k_pad) bucket serves every workload
    size — successive orbital passes of different sizes stop triggering
    fresh XLA compiles of the clustering scans. Pad rows carry weight 0
    in every centroid update and never win the farthest-point argmax;
    unused centroid slots sit at a far sentinel no point can select, so
    real clusters evolve exactly as if the pads were absent.
    """
    n_pad, d = m_pad.shape
    mask = jnp.arange(n_pad) < n
    maskc = mask[:, None]
    nf = n.astype(jnp.float32)

    # masked normalize_moments (same two-pass mean / global-std formula)
    m0 = jnp.where(maskc, m_pad, 0.0)
    mu = jnp.sum(m0, 0, keepdims=True) / nf
    gmu = jnp.sum(m0) / (nf * d)
    var = jnp.sum(jnp.where(maskc, jnp.square(m_pad - gmu), 0.0)) / (nf * d)
    scale = jnp.sqrt(var) + 1e-6
    x = jnp.where(maskc, (m_pad - mu) / scale, 0.0)

    # --- incremental k-means++ init (O(N·D) per pick), masked ---
    first = jax.random.randint(key, (), 0, n)
    cent0 = x[first]
    _, d2 = kops.kmeans_assign(x, cent0[None])
    far = jnp.full((d,), jnp.float32(_FAR), x.dtype)

    def pick(carry, i):
        cents, d2 = carry
        nxt = jnp.argmax(jnp.where(mask, d2, -jnp.inf))
        c = jnp.where(i < k, x[nxt], far)
        cents = jax.lax.dynamic_update_slice(cents, c[None], (i, 0))
        _, d2n = kops.kmeans_assign(x, c[None])
        d2 = jnp.where(i < k, jnp.minimum(d2, d2n), d2)
        return (cents, d2), None

    cents = jnp.tile(cent0[None], (k_pad, 1))
    (cents, _), _ = jax.lax.scan(pick, (cents, d2), jnp.arange(1, k_pad))

    # --- Lloyd iterations, masked ---
    def step(cent, _):
        assign, _ = kops.kmeans_assign(x, cent)
        one = jax.nn.one_hot(assign, k_pad, dtype=x.dtype) * maskc
        tot = jnp.einsum("nk,nd->kd", one, x)
        cnt = jnp.sum(one, 0)[:, None]
        new = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1), cent)
        return new, None

    cent, _ = jax.lax.scan(step, cents, None, length=iters)
    return x, cent


def dedup_from_moments(moments: jnp.ndarray, k: int, key, iters: int = 10,
                       n: int = None) -> DedupResult:
    """Dedup pass over raw color moments: featurize -> cluster -> reps.

    The canonical clustering path — the engine AND the reference host
    path both enter here, so identical real rows yield bit-identical
    results. ``moments`` is (N, 3C); pass ``n`` when the trailing rows
    are padding from an already-bucketed gather (their values are
    ignored). Everything runs on power-of-two padded shapes: one
    compiled program per size bucket serves every workload.
    """
    n = int(moments.shape[0]) if n is None else int(n)
    d = int(moments.shape[1])
    # floored at 2x the base bucket so small passes share the compiled
    # core with mid-size ones (the masked arithmetic is size-agnostic)
    n_pad = dedup_pad_size(n)
    # tie k's bucket to n's so one compiled core serves each size bucket
    # (k <= n/2 in every pipeline call; bucket up for odd explicit k)
    k_pad = (n_pad // 2 if int(k) <= n_pad // 2
             else bucket_size(int(k), _K_BUCKET))
    nj = jnp.int32(n)
    if int(moments.shape[0]) == n_pad:
        m_pad = jnp.asarray(moments)
    else:
        m_pad = jnp.zeros((n_pad, d), jnp.float32).at[:n].set(moments[:n])
    x_pad, cent = _dedup_padded_core(m_pad, nj, jnp.int32(k), key,
                                     k_pad=k_pad, iters=iters)

    # final assignment + representatives, eager on bucketed shapes
    # (nj stays an operand so these cached programs serve every n)
    assign, d2 = kops.kmeans_assign(x_pad, cent)
    mask = jnp.arange(n_pad) < nj
    big = jnp.float32(1e30)
    d2m = jnp.where(mask, d2, big)
    per_cluster = jnp.full((k_pad,), big).at[assign].min(d2m)
    is_min = d2m <= per_cluster[assign] + 0.0
    idxs = jnp.arange(n_pad)
    rep_idx = jnp.full((k_pad,), n_pad, jnp.int32).at[assign].min(
        jnp.where(is_min & mask, idxs, n_pad).astype(jnp.int32))
    rep_found = rep_idx < nj
    rep_clip = jnp.clip(rep_idx, 0, nj - 1)
    # scatter-max: duplicate empty-cluster writes can't clobber a real rep
    rep_mask = jnp.zeros((n_pad,), bool).at[rep_clip].max(rep_found)
    sizes = jnp.zeros((k_pad,), jnp.int32).at[assign].add(mask.astype(jnp.int32))
    return DedupResult(assign[:n], cent[:k], rep_mask[:n], sizes[:k],
                       rep_clip[:k])


def expanded_counts(rep_counts: jnp.ndarray, res: DedupResult) -> jnp.ndarray:
    """Counts measured on representatives only -> per-tile estimated counts
    (each tile inherits its cluster representative's count)."""
    return rep_counts[res.rep_idx][res.assign]
