"""Clustering-based data deduplication (paper §III-C).

Tiles are embedded with the color-moments featurizer (rotation/
translation-invariant global channel statistics — matching the paper's
requirement that contexts survive 'geographic label transformations'),
k-means-clustered into geographic contexts, and only the tile nearest
each centroid is processed/downlinked. Cluster sizes are retained so the
representative's count stands for the whole context.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


class DedupResult(NamedTuple):
    assign: jnp.ndarray        # (N,) int32 cluster id
    centroids: jnp.ndarray     # (K, D)
    rep_mask: jnp.ndarray      # (N,) bool — True for cluster representatives
    cluster_sizes: jnp.ndarray  # (K,) int32
    rep_idx: jnp.ndarray       # (K,) int32 index of each cluster's representative


def features(tiles: jnp.ndarray) -> jnp.ndarray:
    """(N, H, W, C) -> (N, 3C) color-moment features.

    Centered per feature but scaled by one GLOBAL factor: per-feature
    z-scoring would blow up low-information dimensions (e.g. nearly
    constant tile stds) into pure noise axes and break the clustering.
    """
    f = kops.tile_moments(tiles)
    mu = jnp.mean(f, 0, keepdims=True)
    scale = jnp.std(f) + 1e-6
    return (f - mu) / scale


def _kmeanspp_init(x, k, key):
    """k-means++ (greedy D² farthest-point) initialization."""
    n = x.shape[0]
    first = jax.random.randint(key, (), 0, n)
    cent0 = x[first]

    def pick(carry, key_i):
        cents, i = carry
        _, d2 = kops.kmeans_assign(x, cents)
        nxt = jnp.argmax(d2)  # greedy farthest point (deterministic)
        cents = jax.lax.dynamic_update_slice(cents, x[nxt][None], (i, 0))
        return (cents, i + 1), None

    cents = jnp.tile(cent0[None], (k, 1))
    (cents, _), _ = jax.lax.scan(pick, (cents, 1), jnp.arange(k - 1))
    return cents


def kmeans(x: jnp.ndarray, k: int, key, iters: int = 10):
    """k-means with k-means++ init. Returns (assign, centroids, d2)."""
    cent = _kmeanspp_init(x, k, key)

    def step(cent, _):
        assign, _ = kops.kmeans_assign(x, cent)
        one = jax.nn.one_hot(assign, k, dtype=x.dtype)  # (N, K)
        tot = jnp.einsum("nk,nd->kd", one, x)
        cnt = jnp.sum(one, 0)[:, None]
        new = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1), cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    assign, d2 = kops.kmeans_assign(x, cent)
    return assign, cent, d2


def dedup(tiles: jnp.ndarray, k: int, key, iters: int = 10) -> DedupResult:
    """Full dedup pass: featurize -> cluster -> pick representatives."""
    f = features(tiles)
    assign, cent, d2 = kmeans(f, k, key, iters)
    n = f.shape[0]
    # representative = argmin distance within each cluster
    big = jnp.float32(1e30)
    per_cluster = jnp.full((k,), big).at[assign].min(d2)
    is_min = d2 <= per_cluster[assign] + 0.0
    # break ties: lowest index wins
    idx = jnp.arange(n)
    cand = jnp.where(is_min, idx, n)
    rep_idx = jnp.full((k,), n, jnp.int32).at[assign].min(
        jnp.where(is_min, idx, n).astype(jnp.int32))
    rep_mask = jnp.zeros((n,), bool).at[jnp.clip(rep_idx, 0, n - 1)].set(rep_idx < n)
    sizes = jnp.zeros((k,), jnp.int32).at[assign].add(1)
    return DedupResult(assign, cent, rep_mask, sizes, jnp.clip(rep_idx, 0, n - 1))


def expanded_counts(rep_counts: jnp.ndarray, res: DedupResult) -> jnp.ndarray:
    """Counts measured on representatives only -> per-tile estimated counts
    (each tile inherits its cluster representative's count)."""
    return rep_counts[res.rep_idx][res.assign]
