"""Clustering-based data deduplication (paper §III-C).

Tiles are embedded with the color-moments featurizer (rotation/
translation-invariant global channel statistics — matching the paper's
requirement that contexts survive 'geographic label transformations'),
k-means-clustered into geographic contexts, and only the tile nearest
each centroid is processed/downlinked. Cluster sizes are retained so the
representative's count stands for the whole context.

The pipeline engine computes tile moments once per frame batch and
enters through :func:`dedup_from_moments`; :func:`dedup` keeps the
featurize-from-raw-tiles entry point.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import xfer
from repro.kernels import ops as kops

# shape-stable dedup: inputs are zero-padded to power-of-two bucket sizes
# so the compiled program count grows with log(workload), not workload
_N_BUCKET = 64
_K_BUCKET = 16
_FAR = 1e15  # sentinel for unused centroid slots (d2 stays finite in f32)


def bucket_size(v: int, floor: int = _N_BUCKET) -> int:
    """Next power-of-two bucket >= max(v, floor) for shape-stable padding."""
    b = floor
    while b < v:
        b *= 2
    return b


def dedup_pad_size(n: int) -> int:
    """Input bucket `dedup_from_moments` expects for a pre-padded gather."""
    return bucket_size(n, 2 * _N_BUCKET)


class DedupResult(NamedTuple):
    assign: jnp.ndarray        # (N,) int32 cluster id
    centroids: jnp.ndarray     # (K, D)
    rep_mask: jnp.ndarray      # (N,) bool — True for cluster representatives
    cluster_sizes: jnp.ndarray  # (K,) int32
    rep_idx: jnp.ndarray       # (K,) int32 index of each cluster's representative


def normalize_moments(f: jnp.ndarray) -> jnp.ndarray:
    """(N, D) raw color moments -> centered features.

    Centered per feature but scaled by one GLOBAL factor: per-feature
    z-scoring would blow up low-information dimensions (e.g. nearly
    constant tile stds) into pure noise axes and break the clustering.
    """
    mu = jnp.mean(f, 0, keepdims=True)
    scale = jnp.std(f) + 1e-6
    return (f - mu) / scale


def features(tiles: jnp.ndarray) -> jnp.ndarray:
    """(N, H, W, C) -> (N, 3C) normalized color-moment features."""
    return normalize_moments(kops.tile_moments(tiles))


def _kmeanspp_init(x, k, key):
    """k-means++ (greedy D² farthest-point) initialization, incremental.

    Maintains a running min-d² vector updated against only the newest
    centroid: O(N·D) per pick instead of re-scoring all K centroids
    (O(N·K·D)) on every scan step like `_kmeanspp_init_scan`.
    """
    n = x.shape[0]
    first = jax.random.randint(key, (), 0, n)
    cent0 = x[first]
    _, d2 = kops.kmeans_assign(x, cent0[None])

    def pick(carry, i):
        cents, d2 = carry
        nxt = jnp.argmax(d2)  # greedy farthest point (deterministic)
        c = x[nxt]
        cents = jax.lax.dynamic_update_slice(cents, c[None], (i, 0))
        _, d2_new = kops.kmeans_assign(x, c[None])
        return (cents, jnp.minimum(d2, d2_new)), None

    cents = jnp.tile(cent0[None], (k, 1))
    (cents, _), _ = jax.lax.scan(pick, (cents, d2), jnp.arange(1, k))
    return cents


def _kmeanspp_init_scan(x, k, key):
    """Pre-engine init: full kmeans_assign against all K slots per pick.

    Kept as the equivalence reference for `_kmeanspp_init` (the unfilled
    slots duplicate centroid 0, so the min-distance — and therefore the
    pick sequence — is identical).
    """
    n = x.shape[0]
    first = jax.random.randint(key, (), 0, n)
    cent0 = x[first]

    def pick(carry, key_i):
        cents, i = carry
        _, d2 = kops.kmeans_assign(x, cents)
        nxt = jnp.argmax(d2)
        cents = jax.lax.dynamic_update_slice(cents, x[nxt][None], (i, 0))
        return (cents, i + 1), None

    cents = jnp.tile(cent0[None], (k, 1))
    (cents, _), _ = jax.lax.scan(pick, (cents, 1), jnp.arange(k - 1))
    return cents


def kmeans(x: jnp.ndarray, k: int, key, iters: int = 10):
    """k-means with k-means++ init. Returns (assign, centroids, d2)."""
    cent = _kmeanspp_init(x, k, key)

    def step(cent, _):
        assign, _ = kops.kmeans_assign(x, cent)
        one = jax.nn.one_hot(assign, k, dtype=x.dtype)  # (N, K)
        tot = jnp.einsum("nk,nd->kd", one, x)
        cnt = jnp.sum(one, 0)[:, None]
        new = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1), cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    assign, d2 = kops.kmeans_assign(x, cent)
    return assign, cent, d2


def dedup(tiles: jnp.ndarray, k: int, key, iters: int = 10) -> DedupResult:
    """Full dedup pass: featurize -> cluster -> pick representatives."""
    return dedup_from_moments(kops.tile_moments(tiles), k, key, iters)


def _dedup_core_body(m_pad, n, k, key, *, k_pad: int, iters: int):
    """Shape-stable featurize + k-means over padded raw moments.

    ``m_pad`` is (n_pad, D) with real rows [:n]; rows past ``n`` may
    hold ANY finite values (zero padding or junk from a padded gather) —
    the first masked `where` zeroes them, after which every path is a
    pure function of the real rows. ``n`` and ``k`` are dynamic scalars,
    so ONE compilation per (n_pad, k_pad) bucket serves every workload
    size — successive orbital passes of different sizes stop triggering
    fresh XLA compiles of the clustering scans. Pad rows carry weight 0
    in every centroid update and never win the farthest-point argmax;
    unused centroid slots sit at a far sentinel no point can select, so
    real clusters evolve exactly as if the pads were absent.
    """
    n_pad, d = m_pad.shape
    mask = jnp.arange(n_pad) < n
    maskc = mask[:, None]
    nf = n.astype(jnp.float32)

    # masked normalize_moments (same two-pass mean / global-std formula)
    m0 = jnp.where(maskc, m_pad, 0.0)
    mu = jnp.sum(m0, 0, keepdims=True) / nf
    gmu = jnp.sum(m0) / (nf * d)
    var = jnp.sum(jnp.where(maskc, jnp.square(m_pad - gmu), 0.0)) / (nf * d)
    scale = jnp.sqrt(var) + 1e-6
    x = jnp.where(maskc, (m_pad - mu) / scale, 0.0)

    # --- incremental k-means++ init (O(N·D) per pick), masked ---
    first = jax.random.randint(key, (), 0, n)
    cent0 = x[first]
    _, d2 = kops.kmeans_assign(x, cent0[None])
    far = jnp.full((d,), jnp.float32(_FAR), x.dtype)

    def pick(carry, i):
        cents, d2 = carry
        nxt = jnp.argmax(jnp.where(mask, d2, -jnp.inf))
        c = jnp.where(i < k, x[nxt], far)
        cents = jax.lax.dynamic_update_slice(cents, c[None], (i, 0))
        _, d2n = kops.kmeans_assign(x, c[None])
        d2 = jnp.where(i < k, jnp.minimum(d2, d2n), d2)
        return (cents, d2), None

    cents = jnp.tile(cent0[None], (k_pad, 1))
    (cents, _), _ = jax.lax.scan(pick, (cents, d2), jnp.arange(1, k_pad))

    # --- Lloyd iterations, masked ---
    def step(cent, _):
        assign, _ = kops.kmeans_assign(x, cent)
        one = jax.nn.one_hot(assign, k_pad, dtype=x.dtype) * maskc
        tot = jnp.einsum("nk,nd->kd", one, x)
        cnt = jnp.sum(one, 0)[:, None]
        new = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1), cent)
        return new, None

    cent, _ = jax.lax.scan(step, cents, None, length=iters)
    return x, cent


_dedup_padded_core = partial(jax.jit, static_argnames=("k_pad", "iters"))(
    _dedup_core_body)


def _dedup_finalize_body(x_pad, cent, nj):
    """Final assignment + representative pick over the padded features.

    ``nj`` stays an operand so one compiled program per (n_pad, k_pad)
    bucket serves every workload size.
    """
    n_pad = x_pad.shape[0]
    k_pad = cent.shape[0]
    assign, d2 = kops.kmeans_assign(x_pad, cent)
    mask = jnp.arange(n_pad) < nj
    big = jnp.float32(1e30)
    d2m = jnp.where(mask, d2, big)
    per_cluster = jnp.full((k_pad,), big).at[assign].min(d2m)
    is_min = d2m <= per_cluster[assign] + 0.0
    idxs = jnp.arange(n_pad)
    rep_idx = jnp.full((k_pad,), n_pad, jnp.int32).at[assign].min(
        jnp.where(is_min & mask, idxs, n_pad).astype(jnp.int32))
    rep_found = rep_idx < nj
    rep_clip = jnp.clip(rep_idx, 0, nj - 1)
    # scatter-max: duplicate empty-cluster writes can't clobber a real rep
    rep_mask = jnp.zeros((n_pad,), bool).at[rep_clip].max(rep_found)
    sizes = jnp.zeros((k_pad,), jnp.int32).at[assign].add(mask.astype(jnp.int32))
    return assign, rep_mask, sizes, rep_clip


_dedup_finalize = jax.jit(_dedup_finalize_body)


# --- vmapped multi-satellite core (one call per bucket, no per-sat loop) ---

@partial(jax.jit, static_argnames=("k_pad", "iters"))
def _dedup_multi_core(m_pad, n, k, key, *, k_pad: int, iters: int):
    """:func:`_dedup_core_body` batched over a leading sat axis.

    Inputs stack one satellite per leading row: ``m_pad`` (S, n_pad, D),
    ``n``/``k`` (S,) int32, ``key`` (S, 2). The body is per-sample, so
    lane *i* computes exactly the sequential core's arithmetic for
    satellite *i* — batching (and sharding the sat axis across a device
    mesh) changes which device runs a lane, not what it computes.
    """
    return jax.vmap(
        lambda m, nn, kk, ke: _dedup_core_body(m, nn, kk, ke,
                                               k_pad=k_pad, iters=iters)
    )(m_pad, n, k, key)


_dedup_finalize_multi = jax.jit(jax.vmap(_dedup_finalize_body))


def _buckets_for(n: int, k: int):
    """(n_pad, k_pad) shape bucket of one dedup workload.

    n_pad is floored at 2x the base bucket so small passes share the
    compiled core with mid-size ones; k's bucket is tied to n's so one
    compiled core serves each size bucket (k <= n/2 in every pipeline
    call; bucket up for odd explicit k).
    """
    n_pad = dedup_pad_size(n)
    k_pad = (n_pad // 2 if int(k) <= n_pad // 2
             else bucket_size(int(k), _K_BUCKET))
    return n_pad, k_pad


def _pad_rows(moments, n: int, n_pad: int):
    d = int(moments.shape[1])
    if int(moments.shape[0]) == n_pad:
        return jnp.asarray(moments)
    return jnp.zeros((n_pad, d), jnp.float32).at[:n].set(moments[:n])


def dedup_from_moments(moments: jnp.ndarray, k: int, key, iters: int = 10,
                       n: int = None) -> DedupResult:
    """Dedup pass over raw color moments: featurize -> cluster -> reps.

    The canonical clustering path — the engine AND the reference host
    path both enter here, so identical real rows yield bit-identical
    results. ``moments`` is (N, 3C); pass ``n`` when the trailing rows
    are padding from an already-bucketed gather (their values are
    ignored). Everything runs on power-of-two padded shapes: one
    compiled program per size bucket serves every workload.
    """
    n = int(moments.shape[0]) if n is None else int(n)
    n_pad, k_pad = _buckets_for(n, k)
    nj = jnp.int32(n)
    m_pad = _pad_rows(moments, n, n_pad)
    x_pad, cent = _dedup_padded_core(m_pad, nj, jnp.int32(k), key,
                                     k_pad=k_pad, iters=iters)
    assign, rep_mask, sizes, rep_clip = _dedup_finalize(x_pad, cent, nj)
    return DedupResult(assign[:n], cent[:k], rep_mask[:n], sizes[:k],
                       rep_clip[:k])


def dedup_multi(parts, iters: int = 10, sharding=None):
    """Batched multi-satellite dedup: the whole constellation's
    clustering in one vmapped core call per shape bucket.

    ``parts``: list of ``(moments, k, key, n)`` — one entry per
    satellite, where ``moments`` is that satellite's (possibly already
    bucket-padded) raw color moments and ``n`` its real row count
    (``None`` = all rows real). Satellites are grouped by their
    (n_pad, k_pad) shape bucket; each group runs
    :func:`_dedup_multi_core` + the vmapped finalize ONCE, eliminating
    ingest's last per-satellite Python loop (~the k-means dispatch cost
    per sat per round). With a :class:`~repro.core.fleet_sharding.
    FleetSharding` mesh context, each group's sat axis is placed along
    the ``sats`` mesh axis (lane-padded to a device multiple with inert
    duplicate rows; pad lanes are dropped before results are read).

    Per-satellite results are bit-equal on CPU to calling
    :func:`dedup_from_moments` per satellite (enforced by
    tests/test_fleet.py); backends whose batched reductions reassociate
    should use the sequential path via ``Fleet(strict_parity=True)``.

    Returns a list of :class:`DedupResult` aligned with ``parts``.
    """
    from repro.core.fleet_sharding import ctx
    sh = ctx(sharding)
    groups = {}
    for slot, (moments, k, key, n) in enumerate(parts):
        n = int(moments.shape[0]) if n is None else int(n)
        bucket = _buckets_for(n, k)
        groups.setdefault(bucket, []).append((slot, moments, k, key, n))
    out = [None] * len(parts)
    for (n_pad, k_pad), items in groups.items():
        m = jnp.stack([_pad_rows(mo, n, n_pad) for _, mo, _, _, n in items])
        ns = np.asarray([n for *_, n in items], np.int32)
        ks = np.asarray([k for _, _, k, _, _ in items], np.int32)
        # keys are stacked host-side (keys come straight from host
        # seeds, so this forces no real compute) and uploaded through
        # the content-keyed transfer cache below — the fleet's dedup
        # seeds repeat every round, so steady-state rounds re-upload
        # neither the key stack nor the lane/cluster count vectors
        keys = np.stack([np.asarray(key) for _, _, _, key, _ in items])
        g = len(items)
        # lane-pad the sat axis to a power-of-two bucket (then to a
        # device multiple on-mesh): group sizes vary round to round and
        # fleet to fleet, and the stacked cores compile per lane count —
        # bucketing bounds that at log2(fleet) programs per shape bucket
        g_pad = sh.pad(bucket_size(g, 1))
        if g_pad != g:
            # inert pad lanes: repeat lane 0 (all-real shapes, so the
            # padded program never sees degenerate n=0 inputs)
            reps = np.zeros(g_pad - g, np.int64)
            m = jnp.concatenate([m, m[xfer.device_constant(reps)]])
            ns = np.concatenate([ns, ns[reps]])
            ks = np.concatenate([ks, ks[reps]])
            keys = np.concatenate([keys, keys[reps]])
        m = sh.device_put(m)
        ns_j = xfer.device_constant(ns, sharding=sh)
        ks_j = xfer.device_constant(ks, sharding=sh)
        keys = xfer.device_constant(keys, sharding=sh)
        x, cent = _dedup_multi_core(m, ns_j, ks_j, keys,
                                    k_pad=k_pad, iters=iters)
        assign, rep_mask, sizes, rep_clip = _dedup_finalize_multi(
            x, cent, ns_j)
        for i, (slot, _, k, _, n) in enumerate(items):
            out[slot] = DedupResult(assign[i, :n], cent[i, :k],
                                    rep_mask[i, :n], sizes[i, :k],
                                    rep_clip[i, :k])
    return out


def expanded_counts(rep_counts: jnp.ndarray, res: DedupResult) -> jnp.ndarray:
    """Counts measured on representatives only -> per-tile estimated counts
    (each tile inherits its cluster representative's count)."""
    return rep_counts[res.rep_idx][res.assign]
