"""FROZEN pre-refactor ``run_pipeline`` monolith — parity oracle only.

This is the exact PR-1 implementation of the end-to-end pipeline (the
~200-line function with the five baselines as inline ``pcfg.method``
branches) kept verbatim so the Mission stage-graph executor
(:mod:`repro.core.mission`) can be regression-tested bit-for-bit
against it on both the engine and reference paths.

Do NOT modify the behaviour of this module and do NOT import it from
production code — tests only. New functionality goes in
:mod:`repro.core.mission` / :mod:`repro.core.policies`.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.dedup as dd
from repro.core import engine, tiling
from repro.core.cascade import count_tiles_batched, count_tiles_batched_ref
from repro.core.energy import EnergyLedger, detector_gflops, max_tiles_within_budget
from repro.core.metrics import cmae
from repro.core.pipeline import PipelineConfig, PipelineResult
from repro.core.throttle import throttle


def budgets_for_legacy(pcfg: PipelineConfig, n_tiles: int) -> Tuple[float, float, float]:
    day_fraction = n_tiles / pcfg.tiles_per_day
    energy = pcfg.energy_budget_j * day_fraction
    byte_budget = (pcfg.bandwidth_mbps * 1e6 / 8.0 * pcfg.contact_s
                   * pcfg.contacts_per_day * day_fraction)
    tile_bytes = float(pcfg.real_tile_px ** 2 * 3)
    return energy, byte_budget, tile_bytes


def _prep_tiles(img, tile_size, input_size):
    t = tiling.tile_image(jnp.asarray(img), tile_size)
    return np.asarray(tiling.resize_tiles(t, input_size))


def run_pipeline_legacy(frames, space, ground, pcfg: PipelineConfig,
                        energy_cfgs=None) -> PipelineResult:
    """Verbatim pre-refactor pipeline (see module docstring)."""
    from repro.configs import get_config
    from repro.data.synthetic import tile_counts

    sp_params, sp_cfg = space
    gd_params, gd_cfg = ground
    if energy_cfgs is None:
        energy_cfgs = (get_config("targetfuse-space"), get_config("targetfuse-ground"))
    gfl_sp = detector_gflops(energy_cfgs[0])

    # ---- stage 0: tile every frame, collect ground truth ----
    if pcfg.use_engine:
        prep = engine.prepare_frames(frames, pcfg.tile_size,
                                     sp_cfg.input_size, gd_cfg.input_size)
        tiles_sp, tiles_gd, true, n = prep.tiles_sp, prep.tiles_gd, prep.true, prep.n
    else:
        prep = None
        all_tiles_sp, all_tiles_gd, all_true = [], [], []
        for img, boxes, _classes in frames:
            s = img.shape[0]
            all_true.append(tile_counts(boxes, s, pcfg.tile_size))
            all_tiles_sp.append(_prep_tiles(img, pcfg.tile_size, sp_cfg.input_size))
            all_tiles_gd.append(_prep_tiles(img, pcfg.tile_size, gd_cfg.input_size))
        tiles_sp = np.concatenate(all_tiles_sp)
        tiles_gd = np.concatenate(all_tiles_gd)
        true = np.concatenate(all_true).astype(np.float64)
        n = tiles_sp.shape[0]

    def count_sel(params, cfg, tiles, sel):
        if pcfg.use_engine:
            return count_tiles_batched(params, cfg, tiles, idx=sel,
                                       score_thresh=pcfg.score_thresh)
        return count_tiles_batched_ref(params, cfg, tiles[sel],
                                       score_thresh=pcfg.score_thresh)

    energy_j, budget_bytes, tile_bytes = budgets_for_legacy(pcfg, n)
    ledger = EnergyLedger(budget_j=energy_j)
    ledger.charge_capture(len(frames))

    pred = np.zeros(n, np.float64)
    bytes_down = 0.0

    # ---- ground_only: bent-pipe ----
    if pcfg.method == "ground_only":
        k = int(budget_bytes // tile_bytes)
        sel = np.arange(min(k, n))
        if len(sel):
            c, _ = count_sel(gd_params, gd_cfg, tiles_gd, sel)
            pred[sel] = c
        bytes_down = len(sel) * tile_bytes
        ledger.charge_downlink(bytes_down, pcfg.bandwidth_mbps)
        return _result(pred, true, bytes_down, budget_bytes, 0, len(sel), n, ledger)

    # ---- ROI filter (low-variance tiles are background/cloud) ----
    active = np.ones(n, bool)
    if pcfg.use_roi and pcfg.method in ("kodan", "targetfuse"):
        if prep is not None:
            raw_sd = prep.roi_std
        else:
            raw_sd = np.asarray(jnp.mean(jnp.std(jnp.asarray(tiles_sp),
                                                 axis=(1, 2)), axis=-1))
        active &= raw_sd > pcfg.roi_std_thresh

    # ---- dedup ----
    rep_of = np.arange(n)
    if pcfg.use_dedup and pcfg.method in ("kodan", "targetfuse") and active.sum() > 4:
        k = pcfg.k_clusters or max(2, int(active.sum()) // 2)
        idx_active = np.where(active)[0]
        if prep is not None:
            n_act = len(idx_active)
            idx_pad = np.zeros(dd.dedup_pad_size(n_act), np.int64)
            idx_pad[:n_act] = idx_active
            res = dd.dedup_from_moments(prep.moments[jnp.asarray(idx_pad)], k,
                                        jax.random.PRNGKey(pcfg.seed),
                                        n=n_act)
        else:
            res = dd.dedup(jnp.asarray(tiles_sp[idx_active]), k,
                           jax.random.PRNGKey(pcfg.seed))
        assign = np.asarray(res.assign)
        rep_local = np.asarray(res.rep_idx)
        rep_of[idx_active] = idx_active[rep_local[assign]]
        ledger.charge_aggregate(len(idx_active))

    reps = np.unique(rep_of[active])

    # ---- energy-capped onboard counting ----
    cap = max_tiles_within_budget(ledger.remaining * 0.95, gfl_sp, pcfg.hardware)
    process = reps[:cap] if len(reps) > cap else reps
    n_processed = len(process)
    ledger.charge_compute(n_processed, gfl_sp, pcfg.hardware)

    counts_sp = np.zeros(n)
    conf = np.full(n, -1.0)
    if n_processed:
        c, f = count_sel(sp_params, sp_cfg, tiles_sp, process)
        counts_sp[process] = c
        conf[process] = f
    counts_sp = counts_sp[rep_of]
    conf = conf[rep_of]
    processed_mask = np.isin(rep_of, process) & active

    # ---- selection + throttling ----
    if pcfg.method == "space_only":
        pred[processed_mask] = counts_sp[processed_mask]
        return _result(pred, true, 0.0, budget_bytes, n_processed, 0, n, ledger)

    if pcfg.method == "tiansuan":
        accept = processed_mask & (conf > pcfg.tiansuan_thresh)
        pred[accept] = counts_sp[accept]
        cand = np.where(active & ~accept)[0]
        cand_reps = np.unique(rep_of[cand])
        k = int(budget_bytes // tile_bytes)
        sel_reps = cand_reps[:k]
        if len(sel_reps):
            c, _ = count_sel(gd_params, gd_cfg, tiles_gd, sel_reps)
            counts_gd = np.zeros(n)
            counts_gd[sel_reps] = c
            got = np.isin(rep_of, sel_reps) & processed_mask & ~accept
            pred[got] = counts_gd[rep_of][got]
        bytes_down = len(sel_reps) * tile_bytes
        ledger.charge_downlink(bytes_down, pcfg.bandwidth_mbps)
        return _result(pred, true, bytes_down, budget_bytes, n_processed,
                       len(sel_reps), n, ledger)

    # kodan / targetfuse: two-threshold selection over representatives
    rep_mask = processed_mask & (rep_of == np.arange(n))
    rep_idx = np.where(rep_mask)[0]
    kodan = pcfg.method == "kodan"
    budget = np.float64(1e18) if kodan else np.float64(budget_bytes)
    n_rep = len(rep_idx)
    if pcfg.use_engine:
        n_pad = dd.bucket_size(max(n_rep, 1))
        conf_pad = np.full(n_pad, -1.0)
        conf_pad[:n_rep] = conf[rep_idx]
        act = np.zeros(n_pad, bool)
        act[:n_rep] = True
        tr = throttle(jnp.asarray(conf_pad), jnp.full(n_pad, tile_bytes),
                      budget, pcfg.conf_p, pcfg.conf_q, pcfg.policy,
                      active=jnp.asarray(act))
        space_m = np.asarray(tr.space)[:n_rep]
        down_m = np.asarray(tr.downlink)[:n_rep]
    else:
        tr = throttle(jnp.asarray(conf[rep_idx]),
                      jnp.full(n_rep, tile_bytes),
                      budget, pcfg.conf_p, pcfg.conf_q, pcfg.policy)
        space_m = np.asarray(tr.space)
        down_m = np.asarray(tr.downlink)
    down_reps = rep_idx[down_m]

    unproc_reps = np.where(active & (rep_of == np.arange(n))
                           & ~processed_mask)[0]
    bytes_down = len(down_reps) * tile_bytes
    k_extra = int(max(budget - bytes_down, 0.0) // tile_bytes)
    extra_reps = unproc_reps[:k_extra]
    down_all = np.concatenate([down_reps, extra_reps]).astype(np.int64)

    counts_gd = np.zeros(n)
    if len(down_all):
        c, _ = count_sel(gd_params, gd_cfg, tiles_gd, down_all)
        counts_gd[down_all] = c
    counts_gd = counts_gd[rep_of]

    rep_space = np.zeros(n, bool)
    rep_space[rep_idx[space_m]] = True
    rep_down = np.zeros(n, bool)
    rep_down[down_all] = True
    use_ground = rep_down[rep_of] & active
    use_space = rep_space[rep_of] & processed_mask & ~use_ground
    pred[use_space] = counts_sp[use_space]
    pred[use_ground] = counts_gd[use_ground]

    bytes_down = len(down_all) * tile_bytes
    ledger.charge_downlink(min(bytes_down, budget_bytes), pcfg.bandwidth_mbps)
    return _result(pred, true, bytes_down, budget_bytes, n_processed,
                   len(down_all), n, ledger)


def _result(pred, true, bytes_down, budget_bytes, n_proc, n_down, n,
            ledger) -> PipelineResult:
    return PipelineResult(
        cmae=cmae(pred, true),
        total_true=float(true.sum()),
        total_pred=float(pred.sum()),
        bytes_downlinked=float(bytes_down),
        bytes_budget=float(budget_bytes),
        tiles_processed_space=int(n_proc),
        tiles_downlinked=int(n_down),
        tiles_total=int(n),
        energy_spent_j=float(ledger.spent),
        energy_budget_j=float(ledger.budget_j),
        per_tile_pred=pred,
        per_tile_true=true,
    )
