"""Satellite-ground cascade: the two-tier counter pair.

The space tier (cheap counter, optionally int8-quantized) produces
(count, confidence) per tile; the ground tier (expensive counter)
recounts the downlinked tiles. Both tiers are jit-compiled batch
programs; counter training (`fit_counter`) lives here too so examples /
benchmarks / tests share one code path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DetectorConfig
from repro.core import tiling, xfer
from repro.core.dedup import bucket_size
from repro.models import detector
from repro.optim.adamw import adamw
from repro.optim.schedule import cosine_with_warmup


def _count_tiles_body(params, cfg: DetectorConfig, tiles,
                      score_thresh: float = 0.3, nms_iou: float = 0.25):
    raw = detector.forward(params, cfg, tiles)
    return detector.count_and_confidence(raw, cfg, score_thresh=score_thresh,
                                         iou_thresh=nms_iou)


@partial(jax.jit, static_argnames=("cfg", "score_thresh", "nms_iou"))
def count_tiles(params, cfg: DetectorConfig, tiles, score_thresh: float = 0.3,
                nms_iou: float = 0.25):
    """tiles (N, S, S, 3) already at cfg.input_size -> (counts, conf)."""
    return _count_tiles_body(params, cfg, tiles, score_thresh, nms_iou)


@partial(jax.jit, static_argnames=("cfg", "score_thresh", "nms_iou"))
def _count_tiles_chunks(params, cfg: DetectorConfig, chunks,
                        score_thresh: float, nms_iou: float):
    """:func:`count_tiles` vmapped over a stacked (n_chunks, batch, ...)
    axis; with the chunk axis placed along a ``sats`` device mesh, each
    device counts its share of the fleet's batches in parallel. The
    detector is per-sample, so per-chunk outputs are bit-equal to
    looping the single-chunk program."""
    return jax.vmap(lambda t: _count_tiles_body(params, cfg, t,
                                                score_thresh, nms_iou))(chunks)


def _tier_batch(n: int, batch: int, floor: int = 8) -> int:
    """Size-tiered effective batch: the smallest power-of-two tier in
    [floor, batch] covering ``n``. Small workloads (a handful of
    representatives, a short downlink) stop paying the full-batch
    padding — n=10 runs a 16-slot forward, not a 64-slot one — while the
    compiled-program count stays bounded at log2(batch/floor)+1 per cfg
    instead of growing with workload size like the seed path."""
    return min(bucket_size(n, floor), batch)


def _count_forward(params, cfg, t, batch: int, score_thresh, nms_iou,
                   sharding=None, defer: bool = False):
    """Shared forward tail: zero-pad rows to whole ``batch`` chunks, run
    the one fixed-shape compiled program per chunk, and transfer
    (counts, conf) to host in a single copy -> (2, n_rows_padded).

    With an on-mesh :class:`~repro.core.fleet_sharding.FleetSharding`
    and more than one chunk, the chunks are stacked, lane-padded to a
    device multiple, and counted in ONE sharded
    :func:`_count_tiles_chunks` call across the mesh.

    ``defer=True`` dispatches the forward and returns the stacked
    (2, n_rows_padded) *device* array WITHOUT the blocking host copy —
    the caller resolves it at its own round boundary (the fleet's
    ingest-overlap pipeline), so device compute keeps running behind
    whatever the foreground does next.
    """
    from repro.core.fleet_sharding import ctx
    sh = ctx(sharding)
    pad = -t.shape[0] % batch
    if pad:
        t = jnp.concatenate([t, jnp.zeros((pad, *t.shape[1:]), t.dtype)])
    t = t.reshape(-1, batch, *t.shape[1:])
    n_chunks = t.shape[0]
    if sh.on_mesh and n_chunks > 1:
        # pad the chunk axis to a power-of-two bucket x device multiple
        # (zero chunks are inert): the stacked forward compiles per
        # chunk count, and workloads present many distinct counts
        n_stack = sh.pad(bucket_size(n_chunks, 1))
        if n_stack != n_chunks:
            t = jnp.concatenate(
                [t, jnp.zeros((n_stack - n_chunks, *t.shape[1:]), t.dtype)])
        c, f = _count_tiles_chunks(params, cfg, sh.device_put(t),
                                   score_thresh, nms_iou)
        out = jnp.stack([c[:n_chunks].reshape(-1),
                         f[:n_chunks].reshape(-1)])
        # analysis: waive(host-sync): the designated single host copy of a
        # counting batch; callers passing defer=True skip even this one
        return out if defer else np.asarray(out)
    outs_c, outs_f = [], []
    for i in range(n_chunks):
        c, f = count_tiles(params, cfg, t[i], score_thresh, nms_iou)
        outs_c.append(c)
        outs_f.append(f)
    out = jnp.stack([jnp.concatenate(outs_c), jnp.concatenate(outs_f)])
    # analysis: waive(host-sync): same designated copy, small-batch path
    return out if defer else np.asarray(out)


def count_tiles_batched(params, cfg, tiles, batch: int = 64, score_thresh=0.3,
                        nms_iou: float = 0.25, idx=None):
    """Fixed-shape batching: EVERY batch — including the trailing one and
    small inputs — is padded up to a power-of-two size tier of `batch`
    (see :func:`_tier_batch`), so XLA compiles a handful of programs per
    cfg and reuses them for any n. Per-batch results stay on device; the
    host transfer happens once at the end.

    ``idx``: optional tile indices to count (a device-side gather). The
    index vector is padded to a whole number of batches, so selecting
    any subset of a bucketed tile array reuses a handful of compiled
    gathers instead of compiling per subset size — and the forward only
    ever runs at the tiered (batch, ...) shapes.

    (The detector is per-sample — convs + per-tile NMS — so padding
    never perturbs real tiles.)
    """
    n = int(len(idx)) if idx is not None else tiles.shape[0]
    if n == 0:
        return np.zeros((0,), np.float32), np.zeros((0,), np.float32)
    batch = _tier_batch(n, batch)
    if idx is not None:
        n_pad = -(-n // batch) * batch
        idx_pad = np.zeros(n_pad, np.int64)
        idx_pad[:n] = np.asarray(idx)
        # content-keyed upload cache: repeated-shape rounds gather with
        # the same index vectors, so steady state issues zero transfers
        t = jnp.asarray(tiles)[xfer.device_constant(idx_pad)]
    else:
        t = jnp.asarray(tiles)
    # padding trimmed host-side, so every device op ran at a bucketed shape
    out = _count_forward(params, cfg, t, batch, score_thresh, nms_iou)
    return out[0, :n], out[1, :n]


def count_tiles_multi(params, cfg, parts, batch: int = 64, score_thresh=0.3,
                      nms_iou: float = 0.25, sharding=None,
                      defer: bool = False):
    """Count several independent gathers in SHARED fixed-shape batches.

    ``parts``: list of ``(tiles, idx)`` — e.g. one per satellite of a
    fleet, each gathering its own tile subset from its own (bucketed)
    tile array. Each part's index vector is padded to a small bucket
    multiple (so gather/concat programs are reused across subset sizes),
    the gathers are concatenated, padded to a whole number of
    ``batch``-sized forward calls, and results are split back per part.
    Per-tile outputs are identical to calling
    :func:`count_tiles_batched` per part (the detector is per-sample, so
    batch composition never perturbs a tile), but the trailing-batch
    padding is paid once for the whole fleet instead of once per
    satellite — 8 satellites with ~10 representatives each run one
    64-slot forward instead of eight. ``sharding``: optional
    :class:`~repro.core.fleet_sharding.FleetSharding`; on-mesh, the
    shared batches are placed along the ``sats`` mesh axis and counted
    in one sharded forward call.

    Returns ``[(counts, conf), ...]`` aligned with ``parts``. With
    ``defer=True`` the forward is dispatched but the device->host result
    copy is NOT taken: a zero-argument resolver is returned instead,
    producing that same list when called — the fleet's ingest-overlap
    pipeline resolves it at the round's Aggregate/recount boundary while
    the detector forwards run behind later dispatch.
    """
    # pad each part's gather to a power-of-two bucket (floor 2): shapes
    # stay log-bounded per part size AND tiny parts pack tightly — a
    # 1-tile ground-recount window contributes 2 slots to the shared
    # batch instead of the 8-slot floor a per-part forward would pay,
    # which is where the batched contact tier beats the FIFO loop
    sizes = [int(len(idx)) for _, idx in parts]
    total = sum(sizes)
    empty = (np.zeros((0,), np.float32), np.zeros((0,), np.float32))
    if total == 0:
        out = [empty for _ in parts]
        return (lambda: out) if defer else out
    gathered, spans, off = [], [], 0
    for (tiles, idx), k in zip(parts, sizes):
        if not k:
            spans.append((0, 0))
            continue
        k_pad = bucket_size(k, 2)
        idx_pad = np.zeros(k_pad, np.int64)  # pad slots gather tile 0,
        idx_pad[:k] = np.asarray(idx)        # trimmed after the forward
        gathered.append(jnp.asarray(tiles)[xfer.device_constant(idx_pad)])
        spans.append((off, k))
        off += k_pad
    t = gathered[0] if len(gathered) == 1 else jnp.concatenate(gathered)
    fwd = _count_forward(params, cfg, t, _tier_batch(off, batch),
                         score_thresh, nms_iou, sharding=sharding,
                         defer=defer)
    if defer:
        def resolve():
            # analysis: waive(host-sync): the single deferred host copy —
            # callers resolve() at a pipeline boundary, not per round
            out = np.asarray(fwd)
            return [(out[0, o:o + k], out[1, o:o + k]) if k else empty
                    for o, k in spans]
        return resolve
    return [(fwd[0, o:o + k], fwd[1, o:o + k]) if k else empty
            for o, k in spans]


def count_tiles_batched_ref(params, cfg, tiles, batch: int = 64, score_thresh=0.3,
                            nms_iou: float = 0.25):
    """Seed host-side batching wrapper, kept as the parity/bench reference.

    Pads only when n > batch, so every distinct small-n call compiles a
    fresh XLA program — the behavior the fixed-shape version eliminates.
    """
    outs_c, outs_f = [], []
    tiles = np.asarray(tiles)
    n = tiles.shape[0]
    for i in range(0, n, batch):
        sl = tiles[i:i + batch]
        pad = 0
        if sl.shape[0] < batch and n > batch:
            pad = batch - sl.shape[0]
            sl = np.concatenate([sl, np.zeros((pad, *sl.shape[1:]), sl.dtype)])
        c, f = count_tiles(params, cfg, jnp.asarray(sl), score_thresh, nms_iou)
        c, f = np.asarray(c), np.asarray(f)
        if pad:
            c, f = c[:-pad], f[:-pad]
        outs_c.append(c)
        outs_f.append(f)
    return np.concatenate(outs_c), np.concatenate(outs_f)


# ---------------------------------------------------------------------------
# counter training (shared by examples / benchmarks / tests)
# ---------------------------------------------------------------------------


def _scene_targets(boxes, classes, n_tiles: int, g: int, grid: int,
                   n_anchors: int, n_classes: int, input_size: int,
                   tile_size: int):
    """Vectorized per-scene target tensor (n_tiles, G, G, A, 5+C).

    Matches the loop semantics of `clip_boxes_to_tile` +
    `boxes_to_targets`: boxes are center-assigned to tiles, localized,
    scaled to model-input px, and fill anchor slots in box order (boxes
    past `n_anchors` in a cell are dropped).
    """
    t = np.zeros((n_tiles, grid, grid, n_anchors, 5 + n_classes), np.float32)
    if len(boxes) == 0:
        return t
    b = np.asarray(boxes, np.float32)
    scale = np.float32(input_size / tile_size)
    cell = np.float32(input_size / grid)
    cx_s = (b[:, 0] + b[:, 2]) / 2
    cy_s = (b[:, 1] + b[:, 3]) / 2
    tx = np.minimum((cx_s // tile_size).astype(np.int64), g - 1)
    ty = np.minimum((cy_s // tile_size).astype(np.int64), g - 1)
    tile_idx = ty * g + tx
    # tile-local, model-input-px corner coordinates (float32 throughout,
    # scaled corner-first — matching the scalar arithmetic of the former
    # clip_boxes_to_tile + boxes_to_targets per-box loop bit-for-bit)
    x1 = (b[:, 0] - (tx * tile_size).astype(np.float32)) * scale
    x2 = (b[:, 2] - (tx * tile_size).astype(np.float32)) * scale
    y1 = (b[:, 1] - (ty * tile_size).astype(np.float32)) * scale
    y2 = (b[:, 3] - (ty * tile_size).astype(np.float32)) * scale
    cx, cy, w, h = (x1 + x2) / 2, (y1 + y2) / 2, x2 - x1, y2 - y1
    gx = np.minimum((cx / cell).astype(np.int64), grid - 1)
    gy = np.minimum((cy / cell).astype(np.int64), grid - 1)
    # anchor slot = occurrence index of the box within its (tile, cell)
    key = (tile_idx * grid + gy) * grid + gx
    order = np.argsort(key, kind="stable")
    sk = key[order]
    new_grp = np.r_[True, sk[1:] != sk[:-1]]
    starts = np.flatnonzero(new_grp)
    occ = np.empty(len(key), np.int64)
    occ[order] = np.arange(len(key)) - starts[np.cumsum(new_grp) - 1]
    m = occ < n_anchors
    ti, gyi, gxi, ai = tile_idx[m], gy[m], gx[m], occ[m]
    t[ti, gyi, gxi, ai, 0] = np.clip(cx[m] / cell - gxi.astype(np.float32), 0, 1)
    t[ti, gyi, gxi, ai, 1] = np.clip(cy[m] / cell - gyi.astype(np.float32), 0, 1)
    t[ti, gyi, gxi, ai, 2] = np.clip(w[m] / (4 * cell), 0, 1)
    t[ti, gyi, gxi, ai, 3] = np.clip(h[m] / (4 * cell), 0, 1)
    t[ti, gyi, gxi, ai, 4] = 1.0
    t[ti, gyi, gxi, ai, 5 + np.asarray(classes)[m].astype(np.int64)] = 1.0
    return t


def build_target_pool(cfg: DetectorConfig, scenes, tile_size: int):
    """(xs, ys) tile/target training pool for `fit_counter`.

    One vectorized pass per scene instead of the former O(tiles) nested
    Python loops over (ty, tx) cells.
    """
    grid = detector.grid_size(cfg)
    xs, ys = [], []
    for img, boxes, classes in scenes:
        s = img.shape[0]
        g = (s + tile_size - 1) // tile_size
        t = tiling.tile_image(jnp.asarray(img), tile_size)
        xs.append(np.asarray(tiling.resize_tiles(t, cfg.input_size)))
        ys.append(_scene_targets(boxes, classes, g * g, g, grid,
                                 cfg.n_anchors, cfg.n_classes,
                                 cfg.input_size, tile_size))
    return (np.concatenate(xs).astype(np.float32),
            np.concatenate(ys).astype(np.float32))


def fit_counter(cfg: DetectorConfig, scenes, tile_size: int, steps: int,
                key, batch: int = 16, lr: float = 3e-3, log_every: int = 0):
    """Train a counter on (image, boxes, classes) scenes.

    Tiles each scene, builds YOLO-style targets, runs AdamW. Returns
    (params, final_loss).
    """
    params = detector.init(key, cfg)
    xs, ys = build_target_pool(cfg, scenes, tile_size)

    opt_init, opt_update = adamw(cosine_with_warmup(lr, steps // 10 + 1, steps))
    opt_state = opt_init(params)

    @jax.jit
    def train_step(params, opt_state, xb, yb):
        (loss, _), grads = jax.value_and_grad(detector.loss_fn, has_aux=True)(
            params, cfg, xb, yb)
        params, opt_state, _ = opt_update(grads, opt_state, params)
        return params, opt_state, loss

    rng = np.random.default_rng(0)
    loss = None
    for step in range(steps):
        idx = rng.integers(0, len(xs), batch)
        params, opt_state, loss = train_step(params, opt_state,
                                             jnp.asarray(xs[idx]), jnp.asarray(ys[idx]))
        if log_every and step % log_every == 0:
            print(f"  step {step:4d} loss {float(loss):.4f}")
    return params, float(loss)
