"""Satellite-ground cascade: the two-tier counter pair.

The space tier (cheap counter, optionally int8-quantized) produces
(count, confidence) per tile; the ground tier (expensive counter)
recounts the downlinked tiles. Both tiers are jit-compiled batch
programs; counter training (`fit_counter`) lives here too so examples /
benchmarks / tests share one code path.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DetectorConfig
from repro.core import tiling
from repro.models import detector
from repro.optim.adamw import adamw
from repro.optim.schedule import cosine_with_warmup


@partial(jax.jit, static_argnames=("cfg", "score_thresh", "nms_iou"))
def count_tiles(params, cfg: DetectorConfig, tiles, score_thresh: float = 0.3,
                nms_iou: float = 0.25):
    """tiles (N, S, S, 3) already at cfg.input_size -> (counts, conf)."""
    raw = detector.forward(params, cfg, tiles)
    return detector.count_and_confidence(raw, cfg, score_thresh=score_thresh,
                                         iou_thresh=nms_iou)


def count_tiles_batched(params, cfg, tiles, batch: int = 64, score_thresh=0.3,
                        nms_iou: float = 0.25):
    """Host-side batching wrapper (keeps peak memory flat on CPU)."""
    outs_c, outs_f = [], []
    n = tiles.shape[0]
    for i in range(0, n, batch):
        sl = tiles[i:i + batch]
        pad = 0
        if sl.shape[0] < batch and n > batch:
            pad = batch - sl.shape[0]
            sl = np.concatenate([sl, np.zeros((pad, *sl.shape[1:]), sl.dtype)])
        c, f = count_tiles(params, cfg, jnp.asarray(sl), score_thresh, nms_iou)
        c, f = np.asarray(c), np.asarray(f)
        if pad:
            c, f = c[:-pad], f[:-pad]
        outs_c.append(c)
        outs_f.append(f)
    return np.concatenate(outs_c), np.concatenate(outs_f)


# ---------------------------------------------------------------------------
# counter training (shared by examples / benchmarks / tests)
# ---------------------------------------------------------------------------


def fit_counter(cfg: DetectorConfig, scenes, tile_size: int, steps: int,
                key, batch: int = 16, lr: float = 3e-3, log_every: int = 0):
    """Train a counter on (image, boxes, classes) scenes.

    Tiles each scene, builds YOLO-style targets, runs AdamW. Returns
    (params, final_loss).
    """
    from repro.data.synthetic import boxes_to_targets, clip_boxes_to_tile

    params = detector.init(key, cfg)
    grid = detector.grid_size(cfg)
    scale = cfg.input_size / tile_size

    # Pre-build the tile/target pool (host-side).
    xs, ys = [], []
    for img, boxes, classes in scenes:
        s = img.shape[0]
        g = s // tile_size
        t = np.asarray(tiling.tile_image(jnp.asarray(img), tile_size))
        t = np.asarray(tiling.resize_tiles(jnp.asarray(t), cfg.input_size))
        for ty in range(g):
            for tx in range(g):
                b, c = clip_boxes_to_tile(boxes, classes, tx, ty, tile_size)
                tgt = boxes_to_targets(b, c, grid, cfg.n_anchors, cfg.n_classes,
                                       cfg.input_size, scale)
                xs.append(t[ty * g + tx])
                ys.append(tgt)
    xs = np.stack(xs).astype(np.float32)
    ys = np.stack(ys).astype(np.float32)

    opt_init, opt_update = adamw(cosine_with_warmup(lr, steps // 10 + 1, steps))
    opt_state = opt_init(params)

    @jax.jit
    def train_step(params, opt_state, xb, yb):
        (loss, m), grads = jax.value_and_grad(detector.loss_fn, has_aux=True)(
            params, cfg, xb, yb)
        params, opt_state, om = opt_update(grads, opt_state, params)
        return params, opt_state, loss

    rng = np.random.default_rng(0)
    loss = None
    for step in range(steps):
        idx = rng.integers(0, len(xs), batch)
        params, opt_state, loss = train_step(params, opt_state,
                                             jnp.asarray(xs[idx]), jnp.asarray(ys[idx]))
        if log_every and step % log_every == 0:
            print(f"  step {step:4d} loss {float(loss):.4f}")
    return params, float(loss)
