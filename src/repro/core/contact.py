"""Declarative ground-segment contact tier: ContactPlan + the batched
lane-stacked executor + the overlapped ground recount.

The paper's satellite-ground collaboration (§III) runs on the *other*
side of the downlink: ground stations offer contact windows, the
selection policy decides what each window transmits, and the ground
tier recounts what arrives. Until this module, the fleet executed that
tier as a host-side Python loop — one scalar ``SelectionPolicy.select``
call and one throttle dispatch per window — which is exactly where a
100-station round stops scaling.

Three pieces replace the loop:

* :class:`ContactPlan` — a declarative description of ONE round's
  windows as ``(n_windows,)`` satellite-index / byte-budget / station
  arrays. Built from explicit windows (:meth:`ContactPlan.build`), the
  fleet's rotating default (:meth:`ContactPlan.rotating`), or directly
  from :mod:`repro.data.scenarios` contact events
  (:meth:`ContactPlan.from_contacts`). Malformed windows — an
  out-of-range satellite index, a NaN/negative/non-finite byte budget —
  raise ``ValueError`` at *build* time instead of failing deep inside
  the drain.

* :func:`execute_plan` — the batched ground-segment core. Windows open
  in plan order (budgets accrued in one vectorized
  :meth:`~repro.core.energy.FleetLedger.accrue_window_budgets` op),
  then the round drains in *steps*: at step ``p`` every window still
  holding a ``p``-th pending segment forms one lane of a
  :class:`~repro.core.policies.PolicyContextBatch`, Select runs as one
  ``select_batch`` call per policy class (the two-threshold policies'
  throttles collapse into ONE vmapped program), and Downlink charges
  every lane through vectorized ledger ops. FIFO-within-window
  semantics are preserved by construction: a window's remaining budget
  is its plan budget minus the prefix sum of its earlier segments'
  spends, and step ``p`` only ever sees that prefix — so the batched
  planner is bit-identical to draining each window through the scalar
  stage loop (:func:`execute_plan_reference`, differentially gated by
  tests/test_contact.py at 0.0 deviation for all five policies).

* :class:`GroundSegment` — the fleet's persistent contact executor: a
  bounded depth-``k`` recount pipeline. The ground recounts of a round
  are batched across all windows (shared fixed-shape counting batches,
  as before) and — with ``depth >= 1`` — dispatched to a worker thread
  so up to ``depth`` rounds' recounts stay in flight behind foreground
  ingest dispatch (jax releases the GIL while compiled programs
  execute, and CPU PJRT dispatch is async). :meth:`GroundSegment.execute`
  applies backpressure: when ``depth`` rounds are already queued, the
  oldest retires before a new round enters. The overlap is exact at
  every depth: each round's recount work is *snapshotted at dispatch*
  (which segments to recount under which frozen selection, which to
  Aggregate), GroundRecount and Aggregate read only that snapshot and
  charge nothing, concurrent rounds write disjoint segments (a segment
  is recounted only in the round where it delivered or was permanently
  lost), and ``Fleet.results()/finalize()`` sync before reading
  predictions. ``depth=0`` (the default) recounts inline — the
  synchronous fallback, bit-identical output at every depth.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cascade import count_tiles_multi
from repro.core.faults import FaultContext, WorkerCrash
from repro.core.mission import WindowReport, policy_context
from repro.core.policies import PolicyContextBatch
from repro.core.throttle import clamp_budget_bytes

__all__ = ["ContactPlan", "GroundSegment", "execute_plan",
           "execute_plan_reference"]


@dataclass(frozen=True)
class ContactPlan:
    """One contact round, declaratively: lane-stacked window arrays.

    ``sats[w]`` is window ``w``'s target satellite, ``budgets[w]`` its
    byte budget, ``entitlement[w]`` True when the window offers the
    satellite's pending entitlement instead of an explicit budget (the
    ``budget_bytes=None`` semantics of the legacy API — ``budgets[w]``
    is 0 and ignored there), and ``stations[w]`` a label for
    reports/logs. Windows execute in array order; a satellite may
    appear in several windows (the first drains its pending passes,
    later ones find nothing and only offer budget).

    Instances are validated — construct through :meth:`build`,
    :meth:`rotating`, or :meth:`from_contacts`.
    """

    sats: np.ndarray         # (n_windows,) int64
    budgets: np.ndarray      # (n_windows,) float64, finite and >= 0
    entitlement: np.ndarray  # (n_windows,) bool
    stations: Tuple[str, ...]
    n_sats: int

    @property
    def n_windows(self) -> int:
        return int(self.sats.shape[0])

    def __post_init__(self):
        sats = np.asarray(self.sats)
        budgets = np.asarray(self.budgets, np.float64)
        ent = np.asarray(self.entitlement, bool)
        if not (sats.ndim == budgets.ndim == ent.ndim == 1
                and sats.shape == budgets.shape == ent.shape):
            raise ValueError(
                "ContactPlan: sats/budgets/entitlement must be aligned "
                f"1-D arrays, got shapes {sats.shape}/{budgets.shape}/"
                f"{ent.shape}")
        if len(self.stations) != sats.shape[0]:
            raise ValueError(
                f"ContactPlan: {len(self.stations)} station labels for "
                f"{sats.shape[0]} windows")
        if not np.issubdtype(sats.dtype, np.integer):
            raise ValueError(
                f"ContactPlan: satellite indices must be integers, got "
                f"dtype {sats.dtype}")
        if sats.size:
            bad = (sats < 0) | (sats >= self.n_sats)
            if bad.any():
                w = int(np.flatnonzero(bad)[0])
                raise ValueError(
                    f"ContactPlan: window {w} targets satellite "
                    f"{int(sats[w])}, outside the {self.n_sats}-satellite "
                    f"fleet [0, {self.n_sats})")
            explicit = ~ent
            bad = explicit & ~np.isfinite(budgets)
            if bad.any():
                w = int(np.flatnonzero(bad)[0])
                raise ValueError(
                    f"ContactPlan: window {w} has a non-finite byte "
                    f"budget ({budgets[w]}); use budget=None for the "
                    f"pending-entitlement default")
            bad = explicit & (budgets < 0.0)
            if bad.any():
                w = int(np.flatnonzero(bad)[0])
                raise ValueError(
                    f"ContactPlan: window {w} has a negative byte budget "
                    f"({budgets[w]}); downlink budgets must be >= 0")
        object.__setattr__(self, "sats", np.ascontiguousarray(sats, np.int64))
        object.__setattr__(self, "budgets", np.ascontiguousarray(budgets))
        object.__setattr__(self, "entitlement", np.ascontiguousarray(ent))
        object.__setattr__(self, "stations", tuple(self.stations))

    # -- builders -----------------------------------------------------------

    @staticmethod
    def build(windows: Sequence[Tuple[int, Optional[float]]], n_sats: int,
              stations: Optional[Sequence[str]] = None) -> "ContactPlan":
        """From explicit ``[(sat, budget_bytes_or_None), ...]`` windows
        (the legacy ``Fleet.contact_round(windows=...)`` shape)."""
        sats = np.array([w[0] for w in windows], np.int64) \
            if windows else np.zeros(0, np.int64)
        ent = np.array([w[1] is None for w in windows], bool) \
            if windows else np.zeros(0, bool)
        budgets = np.array([0.0 if w[1] is None else w[1] for w in windows],
                           np.float64) if windows else np.zeros(0)
        if stations is None:
            stations = tuple(f"w{i}" for i in range(len(windows)))
        return ContactPlan(sats=sats, budgets=budgets, entitlement=ent,
                           stations=tuple(stations), n_sats=int(n_sats))

    @staticmethod
    def rotating(n_sats: int, stations: int, start: int = 0,
                 budget_bytes: Optional[float] = None
                 ) -> Tuple["ContactPlan", int]:
        """The rotating default: the next ``stations`` satellites
        round-robin from ``start``, each offered ``budget_bytes``
        (None = pending entitlement). Returns ``(plan, next_start)`` so
        the caller can carry the rotation pointer across rounds."""
        if int(n_sats) < 1:
            raise ValueError(
                f"ContactPlan.rotating: n_sats must be >= 1 to rotate "
                f"over, got {int(n_sats)}")
        if int(stations) < 0:
            raise ValueError(
                f"ContactPlan.rotating: stations must be >= 0, got "
                f"{int(stations)}")
        wins, ptr = [], int(start)
        for _ in range(int(stations)):
            wins.append((ptr, budget_bytes))
            ptr = (ptr + 1) % int(n_sats)
        return (ContactPlan.build(
            wins, n_sats,
            stations=tuple(f"gs{i}" for i in range(len(wins)))), ptr)

    @staticmethod
    def from_contacts(contacts, n_sats: int) -> "ContactPlan":
        """From :class:`repro.data.scenarios.ContactEvent` objects — the
        scenario generator's per-round contact schedule (toy round-robin
        or the orbital pass extractor's) becomes the round's plan
        directly. ``station`` may be a :class:`GroundStation`-like
        object (its ``name`` labels the window) or a plain string, so
        lightweight schedule sources need not build station objects."""
        return ContactPlan(
            sats=np.array([c.sat for c in contacts], np.int64),
            budgets=np.array([c.budget_bytes for c in contacts], np.float64),
            entitlement=np.zeros(len(contacts), bool),
            stations=tuple(getattr(c.station, "name", c.station)
                           for c in contacts),
            n_sats=int(n_sats))

    def window_budget(self, w: int) -> Optional[float]:
        """Window ``w``'s budget in the scalar API's terms
        (None = pending entitlement)."""
        return None if self.entitlement[w] else float(self.budgets[w])


# ---------------------------------------------------------------------------
# the batched executor core
# ---------------------------------------------------------------------------

def _select_downlink(fleet, plan: ContactPlan,
                     ctx: Optional[FaultContext] = None):
    """The synchronous half of a batched round: open every window, then
    drain Select + Downlink step-wise across lanes.

    ``ctx`` (a faulty round) adds the segment-granular fault hooks:
    mid-window truncation zeroes a window's remaining budget at its
    drawn drain step, and corrupted transmissions are detected (and
    refunded/re-queued) immediately after each step's Downlink charges —
    so every ledger lane sees the exact charge/refund float sequence of
    the scalar fault drain (:func:`_contact_window_faulty`).

    Returns ``(out, jobs)`` — the per-window ``(sat, WindowReport)``
    list (complete: reports never depend on the recount) and the jobs
    whose GroundRecount + Aggregate still have to run.
    """
    out: List[Optional[Tuple[int, WindowReport]]] = [None] * plan.n_windows
    jobs = []  # (slot, sat, mission, window, segs) — batched lanes
    open_sats, open_budgets = [], []
    for w in range(plan.n_windows):
        sat = int(plan.sats[w])
        m = fleet.missions[sat]
        if not fleet._contact_batchable[sat]:
            # custom stage graphs / reference-path satellites take the
            # exact scalar window drain, in plan order
            if ctx is None:
                out[w] = (sat, m.contact_window(plan.window_budget(w)))
            else:
                out[w] = (sat, _contact_window_faulty(
                    m, plan.window_budget(w), ctx,
                    int(ctx.orig_windows[w])))
            continue
        if m._window_is_noop():
            out[w] = (sat, m._drained_window_report())
            continue
        segs, window = m._open_window(plan.window_budget(w), accrue=False)
        open_sats.append(sat)
        open_budgets.append(window.budget)
        jobs.append((w, sat, m, window, segs))
    if open_sats:
        fleet.ledger.accrue_window_budgets(open_sats, open_budgets)

    truncs: Dict[int, int] = {}  # job index -> drain step the link dies at
    if ctx is not None:
        for j, (slot, _, _, _, segs) in enumerate(jobs):
            t = ctx.faults.truncated_at(ctx.rnd, int(ctx.orig_windows[slot]),
                                        len(segs))
            if t is not None and 0 <= t < len(segs):
                truncs[j] = t
                ctx.stats.windows_truncated += 1

    depth = max((len(segs) for *_, segs in jobs), default=0)
    for p in range(depth):
        for j, t in truncs.items():
            if t == p:  # the link died here: later segments see 0 budget
                jobs[j][3].remaining = 0.0
        if ctx is None:
            served = None
            lanes = [(sat, m, window, segs[p])
                     for _, sat, m, window, segs in jobs if len(segs) > p]
        else:
            served = [jb for jb in jobs if len(jb[4]) > p]
            lanes = [(sat, m, window, segs[p])
                     for _, sat, m, window, segs in served]
        for _, _, _, seg in lanes:
            # this attempt starts clean — also on clean rounds, which may
            # re-drain a segment a FAULTY round re-queued (the finalize
            # flush): stale flags would skip its recount/aggregate
            seg.requeued = False
            seg.corrupted = False
        # --- Select: one select_batch per policy class; each lane's
        # budget is its window's remaining prefix ---
        by_cls: Dict[type, list] = {}
        for lane in lanes:
            by_cls.setdefault(type(lane[1].policy), []).append(lane)
        for group in by_cls.values():
            ctxs = [policy_context(m, seg) for _, m, _, seg in group]
            batch = PolicyContextBatch.stack(
                ctxs, policies=[m.policy for _, m, _, seg in group],
                sharding=fleet.sharding)
            budgets = np.array([window.remaining
                                for _, _, window, _ in group], np.float64)
            sb = group[0][1].policy.select_batch(batch, budgets)
            for (_, _, _, seg), sel in zip(group, sb.selections):
                seg.selection = sel
        # --- Downlink: per-lane spend caps on the host (python-float
        # min, exactly the scalar stage), ledger charges vectorized ---
        sats_v, reqs, spends, bws = [], [], [], []
        for sat, m, window, seg in lanes:
            sel = seg.selection
            spend = min(sel.bytes_requested, window.remaining)
            window.remaining = clamp_budget_bytes(window.remaining - spend)
            seg.bytes_requested = sel.bytes_requested
            seg.bytes_spent = spend
            sats_v.append(sat)
            reqs.append(sel.bytes_requested)
            spends.append(spend)
            bws.append(m.pcfg.bandwidth_mbps)
        fleet.ledger.charge_downlink_windows(sats_v, reqs, spends, bws)
        if ctx is not None:
            _apply_corruption(fleet, ctx, served, p)

    for slot, sat, m, window, segs in jobs:
        out[slot] = (sat, m._window_report(window, segs))
    return out, jobs


def _apply_corruption(fleet, ctx: FaultContext, served, p: int) -> None:
    """Detect (deterministically) which of drain step ``p``'s
    transmissions the ground discards, reconcile the ledger per the
    refund policy, and route each failed segment to retry or permanent
    loss. Refunds land as ONE vectorized inverse-charge op immediately
    after the step's charges, so each lane's float sequence is exactly
    the scalar drain's charge-then-refund pair."""
    r_sats, r_spends, r_bws = [], [], []
    for slot, sat, m, _window, segs in served:
        seg = segs[p]
        ow = int(ctx.orig_windows[slot])
        if len(seg.selection.downlink) and \
                ctx.faults.segment_corrupted(ctx.rnd, ow, p):
            seg.corrupted = True
            ctx.stats.segments_corrupted += 1
            ctx.events.append((ow, p, "wasted", seg.bytes_spent))
            if ctx.faults.refund_policy == "refund" and seg.bytes_spent > 0.0:
                r_sats.append(sat)
                r_spends.append(seg.bytes_spent)
                r_bws.append(m.pcfg.bandwidth_mbps)
                ctx.events.append((ow, p, "refunded", seg.bytes_spent))
            if seg.retries < ctx.faults.max_retries:
                seg.retries += 1
                seg.eligible_round = ctx.rnd + seg.retries  # linear backoff
                seg.requeued = True
                ctx.requeue.append((m, seg))
                ctx.stats.segments_requeued += 1
            else:
                # permanently lost downlink-side: onboard-accepted counts
                # still land at Aggregate; ground-credited tiles read 0
                seg.counts_gd = np.zeros(seg.n)
                ctx.stats.segments_lost += 1
        elif seg.bytes_spent > 0.0:
            ctx.events.append((ow, p, "delivered", seg.bytes_spent))
    if r_sats:
        fleet.ledger.refund_downlink_windows(r_sats, r_spends, r_bws)


class _RecountWork:
    """One round's deferred recount, snapshotted at dispatch time.

    ``by_thresh`` maps score threshold -> ``[(mission, seg, downlink)]``
    recount items (the segment's *frozen* downlink selection, captured
    on the foreground thread), ``agg`` is the ``[(mission, seg,
    window)]`` Aggregate list. The snapshot is what makes depth >= 2
    race-free: a later round's foreground drain may re-open a requeued
    segment (resetting ``seg.requeued``/``seg.corrupted`` and rewriting
    ``seg.selection``) while this round's worker is still queued —
    flags and selections read at worker-run time would race, the
    dispatch-time snapshot cannot. A segment is recounted + aggregated
    only in the round where it delivered or was permanently lost, so
    concurrent rounds' snapshots write disjoint segments."""

    __slots__ = ("by_thresh", "agg")

    def __init__(self, by_thresh, agg):
        self.by_thresh = by_thresh
        self.agg = agg


def _recount_plan(fleet, jobs) -> _RecountWork:
    """Snapshot one round's recount work (foreground, at dispatch)."""
    by_thresh: Dict[float, list] = {}
    agg: list = []
    for _, _, m, window, segs in jobs:
        for seg in segs:
            if not seg.corrupted:
                by_thresh.setdefault(m.pcfg.score_thresh, []).append(
                    (m, seg, seg.selection.downlink))
            # else: the ground discarded this attempt's bytes — nothing
            # to recount (a retry re-transmits; a lost segment already
            # holds zero ground counts)
            if not seg.requeued:
                agg.append((m, seg, window))
            # else: retrying in a later round — no prediction yet
    return _RecountWork(by_thresh, agg)


def _recount_run(fleet, work: _RecountWork,
                 cancel: Optional[threading.Event] = None) -> None:
    """The deferrable half: ground recounts of EVERY window in the
    round share fixed-shape counting batches (grouped per threshold),
    then Aggregate fuses predictions. Reads only the dispatch-time
    snapshot (:func:`_recount_plan`) and charges nothing — safe to
    overlap with later rounds' ingest and with other queued rounds'
    recounts. ``cancel`` is checked between threshold groups, before
    every write-back, and before each Aggregate: a worker abandoned by
    the watchdog writes NOTHING after cancellation, so the synchronous
    recovery recount never sees concurrent mutation."""
    params, cfg = fleet.ground
    for thresh, items in work.by_thresh.items():
        if cancel is not None and cancel.is_set():
            return
        parts = [(seg.tiles_gd, down) for _, seg, down in items]
        results = count_tiles_multi(params, cfg, parts, score_thresh=thresh,
                                    sharding=fleet.sharding)
        if cancel is not None and cancel.is_set():
            return  # abandoned mid-count: discard, write nothing
        for (m, seg, down), (c, _) in zip(items, results):
            counts_gd = np.zeros(seg.n)
            if len(down):
                counts_gd[down] = c
            seg.counts_gd = counts_gd[seg.rep_of]
    for m, seg, window in work.agg:
        if cancel is not None and cancel.is_set():
            return
        m.contact_stages[3].run(m, seg, window)  # Aggregate


def _recount_aggregate(fleet, jobs,
                       cancel: Optional[threading.Event] = None) -> None:
    """Plan + run in one call — the inline (depth 0) recount path."""
    _recount_run(fleet, _recount_plan(fleet, jobs), cancel=cancel)


def _contact_window_faulty(m, budget_bytes, ctx: FaultContext,
                           orig_w: int) -> WindowReport:
    """``Mission.contact_window`` with the segment-granular fault hooks
    of one window: the scalar FIFO reference of the fault-aware batched
    drain (and the non-batchable-satellite path of a faulty round).
    Same stage sequence, same ledger arithmetic, same deterministic
    fault draws — differentially gated bit-equal to the batched path by
    tests/test_faults.py."""
    if m._window_is_noop():
        return m._drained_window_report()
    segs, window = m._open_window(budget_bytes)
    faults = ctx.faults
    t = faults.truncated_at(ctx.rnd, orig_w, len(segs))
    if t is not None and 0 <= t < len(segs):
        ctx.stats.windows_truncated += 1
    else:
        t = None
    select, downlink, recount, aggregate = m.contact_stages
    for p, seg in enumerate(segs):
        if t == p:
            window.remaining = 0.0
        seg.requeued = False
        seg.corrupted = False
        select.run(m, seg, window)
        downlink.run(m, seg, window)
        if len(seg.selection.downlink) and \
                faults.segment_corrupted(ctx.rnd, orig_w, p):
            seg.corrupted = True
            ctx.stats.segments_corrupted += 1
            ctx.events.append((orig_w, p, "wasted", seg.bytes_spent))
            if faults.refund_policy == "refund" and seg.bytes_spent > 0.0:
                m.bytes_ledger.spent -= seg.bytes_spent
                m.ledger.refund_downlink(seg.bytes_spent,
                                         m.pcfg.bandwidth_mbps)
                ctx.events.append((orig_w, p, "refunded", seg.bytes_spent))
            if seg.retries < faults.max_retries:
                seg.retries += 1
                seg.eligible_round = ctx.rnd + seg.retries  # linear backoff
                seg.requeued = True
                ctx.requeue.append((m, seg))
                ctx.stats.segments_requeued += 1
            else:
                seg.counts_gd = np.zeros(seg.n)
                ctx.stats.segments_lost += 1
                aggregate.run(m, seg, window)
        else:
            if seg.bytes_spent > 0.0:
                ctx.events.append((orig_w, p, "delivered", seg.bytes_spent))
            recount.run(m, seg, window)
            aggregate.run(m, seg, window)
    return m._window_report(window, segs)


def execute_plan(fleet, plan: ContactPlan, recount_inline: bool = True,
                 fault_ctx: Optional[FaultContext] = None):
    """Run one ContactPlan through the batched core. With
    ``recount_inline=False`` the recount jobs are returned instead of
    executed (the :class:`GroundSegment` overlap path). ``fault_ctx``
    makes it a faulty round (see :mod:`repro.core.faults`).

    Returns ``(out, jobs)``.
    """
    out, jobs = _select_downlink(fleet, plan, fault_ctx)
    if recount_inline and jobs:
        _recount_aggregate(fleet, jobs)
        jobs = []
    return out, jobs


def execute_plan_reference(fleet, plan: ContactPlan,
                           fault_ctx: Optional[FaultContext] = None):
    """The FIFO-loop reference: every window drains sequentially
    through the scalar Mission stage loop (Select -> Downlink ->
    GroundRecount -> Aggregate per segment) — the pre-plan contact tier,
    kept as the parity oracle and the bench baseline the batched
    executor is gated against (max deviation 0.0). A faulty round
    (``fault_ctx``) swaps each window's drain for the fault-aware scalar
    loop, which stays the bit-exact oracle of the fault-aware batched
    path."""
    if fault_ctx is None:
        return [(int(plan.sats[w]),
                 fleet.missions[int(plan.sats[w])].contact_window(
                     plan.window_budget(w)))
                for w in range(plan.n_windows)]
    return [(int(plan.sats[w]),
             _contact_window_faulty(
                 fleet.missions[int(plan.sats[w])], plan.window_budget(w),
                 fault_ctx, int(fault_ctx.orig_windows[w])))
            for w in range(plan.n_windows)]


# ---------------------------------------------------------------------------
# overlapped ground recount
# ---------------------------------------------------------------------------

class _InFlightRound:
    """One queued round of the recount pipeline: its dispatch-time work
    snapshot, the worker thread running it, that worker's cooperative
    cancel event, any exception it raised, and its wall time — recorded
    per round (never into the shared accumulator) so an abandoned
    worker's clock can simply be ignored at retirement."""

    __slots__ = ("work", "cancel", "thread", "err", "worker_s")

    def __init__(self, work: _RecountWork):
        self.work = work
        self.cancel = threading.Event()
        self.thread: Optional[threading.Thread] = None
        self.err: Optional[BaseException] = None
        self.worker_s = 0.0


class GroundSegment:
    """A fleet's persistent ground-segment executor: a bounded
    depth-``k`` recount pipeline.

    With ``depth >= 1``, :meth:`execute` returns after Select +
    Downlink (reports complete, budget state final) and queues the
    round's batched GroundRecount + Aggregate on a worker thread, so up
    to ``depth`` rounds' recounts stay in flight behind whatever the
    caller does next — typically later rounds' ingest dispatch. When
    the queue is full, :meth:`execute` applies backpressure: the oldest
    round retires (its worker joins and its results land) before the
    new round enters. :meth:`sync` retires every queued round in FIFO
    order (re-raising worker exceptions); ``Fleet.results()/finalize()``
    call it implicitly, so predictions are never read while a recount
    is in flight. ``depth=0`` recounts inline — the synchronous
    fallback, bit-identical output at every depth: each round's work is
    snapshotted at dispatch (:func:`_recount_plan`), recounts read only
    their snapshot and charge nothing, and concurrent rounds write
    disjoint segments.

    **Watchdog** (``watchdog_s``): each retirement joins with that
    timeout; a worker still alive past it is cancelled (a cooperative
    event — :func:`_recount_run` writes nothing once it is set; the
    daemon thread is abandoned if truly hung) and that round's recount
    re-runs synchronously. Recounts charge NOTHING and only overwrite
    per-segment outputs, so the retry is idempotent and the watchdog
    arm stays bit-equal to a synchronous round even if the stalled
    worker later limps home — cancelled workers cannot write. An
    injected :class:`~repro.core.faults.WorkerCrash` recovers the same
    way, per queued round; any real worker exception surfaces exactly
    once at :meth:`sync`, with every ledger lane intact and the
    remaining queued rounds still pending (the next sync retires them).

    **Lifecycle**: GroundSegment is a context manager. A clean ``with``
    exit syncs (surfacing errors normally); an exceptional exit calls
    :meth:`close`, which cancels every queued round and joins each
    worker briefly WITHOUT raising — so an exception between
    :meth:`execute` and :meth:`sync` can never leak a live thread or
    orphan pending recount work, at any depth.

    Wall-time accounting for the bench/summary: ``recount_s`` is the
    cumulative recount wall time (per-round worker wall when deferred,
    inline wall when not; a watchdog/crash recovery charges the blocked
    join + synchronous retry instead of the abandoned worker's clock),
    ``wait_s`` the time the foreground actually blocked on retirement
    (sync joins, backpressure joins, and recovery recounts alike).
    ``wait_s <= recount_s`` holds by construction per retired round.
    ``hidden_fraction`` = 1 - wait/recount is the share of recount time
    the pipeline hid behind foreground work.
    """

    def __init__(self, fleet, overlap: bool = False,
                 watchdog_s: Optional[float] = None,
                 depth: Optional[int] = None):
        if depth is None:
            depth = 1 if overlap else 0
        depth = int(depth)
        if depth < 0:
            raise ValueError(
                f"GroundSegment: pipeline depth must be >= 0 "
                f"(0 = synchronous), got {depth}")
        self.fleet = fleet
        self.depth = depth
        self.watchdog_s = watchdog_s
        self._queue: "deque[_InFlightRound]" = deque()
        self.recount_s = 0.0
        self.wait_s = 0.0
        self.rounds_deferred = 0
        self.max_in_flight = 0

    @property
    def overlap(self) -> bool:
        """True when recounts are deferred at all (depth >= 1)."""
        return self.depth > 0

    @property
    def in_flight(self) -> int:
        """Rounds currently queued in the pipeline."""
        return len(self._queue)

    def execute(self, plan: ContactPlan,
                fault_ctx: Optional[FaultContext] = None):
        # a contact round reads segment state (counts, processed masks,
        # ledger lanes) — any ingest-overlap tail still pending on the
        # fleet must land first (guarded: non-Fleet drivers lack it)
        resolve = getattr(self.fleet, "_resolve_ingest_pending", None)
        if resolve is not None:
            resolve()
        while self._queue and len(self._queue) >= self.depth:
            # backpressure: the oldest in-flight round retires before a
            # new one may enter the bounded pipeline
            self._retire(self._queue.popleft())
        out, jobs = execute_plan(self.fleet, plan,
                                 recount_inline=self.depth == 0,
                                 fault_ctx=fault_ctx)
        if jobs:  # pipeline path: snapshot and defer the recount
            self.rounds_deferred += 1
            rnd = _InFlightRound(_recount_plan(self.fleet, jobs))
            worker_fault = fault_ctx.worker if fault_ctx is not None else None
            stall_s = (fault_ctx.faults.stall_s if fault_ctx is not None
                       else 0.0)
            rnd.thread = threading.Thread(
                target=self._recount_job, args=(rnd, worker_fault, stall_s),
                daemon=True)
            self._queue.append(rnd)
            self.max_in_flight = max(self.max_in_flight, len(self._queue))
            rnd.thread.start()
        return out

    def execute_reference(self, plan: ContactPlan,
                          fault_ctx: Optional[FaultContext] = None):
        self.sync()
        return execute_plan_reference(self.fleet, plan, fault_ctx=fault_ctx)

    def _fault_stats(self):
        return getattr(self.fleet, "fault_stats", None)

    def _recount_job(self, rnd: _InFlightRound, worker_fault, stall_s):
        t0 = time.perf_counter()
        try:
            if worker_fault == "crash":
                stats = self._fault_stats()
                if stats is not None:
                    stats.worker_crashes += 1
                raise WorkerCrash("injected ground-worker crash")
            if worker_fault == "stall":
                stats = self._fault_stats()
                if stats is not None:
                    stats.worker_stalls += 1
                time.sleep(stall_s)
            _recount_run(self.fleet, rnd.work, cancel=rnd.cancel)
        except BaseException as e:  # surfaced (or recovered) at retirement
            rnd.err = e
        finally:
            # per-round clock, read only after a clean join: an
            # abandoned worker's wall time is never accounted
            rnd.worker_s = time.perf_counter() - t0

    def sync(self) -> None:
        """Retire every queued round in FIFO order (each join bounded
        by the watchdog timeout when one is set); recover injected
        crashes and watchdog-cancelled stalls by recounting that round
        synchronously, re-raise real worker exceptions exactly once —
        leaving later queued rounds pending for the next sync."""
        resolve = getattr(self.fleet, "_resolve_ingest_pending", None)
        if resolve is not None:
            resolve()
        while self._queue:
            self._retire(self._queue.popleft())

    def _retire(self, rnd: _InFlightRound) -> None:
        t0 = time.perf_counter()
        rnd.thread.join(self.watchdog_s)
        waited = time.perf_counter() - t0
        if rnd.thread.is_alive():
            # watchdog timeout: cancel the worker (it writes nothing
            # once the event is set; abandoned if truly hung — it is a
            # daemon) and take the round over synchronously
            rnd.cancel.set()
            self._recover(rnd, waited)
            return
        if isinstance(rnd.err, WorkerCrash):
            self._recover(rnd, waited)  # injected crash: recoverable
            return
        self.wait_s += waited
        self.recount_s += max(rnd.worker_s, waited)
        if rnd.err is not None:
            # real failure: surfaced exactly once; recounts charge
            # nothing, so every ledger lane is intact
            raise rnd.err

    def _recover(self, rnd: _InFlightRound, waited: float) -> None:
        """Synchronous recount retry of an abandoned/crashed round
        (idempotent: recounts are pure writes of per-segment outputs).
        The whole recovery blocks the foreground, so it lands in BOTH
        ``wait_s`` and ``recount_s`` — a recovered round hides
        nothing — and the abandoned worker's clock is ignored."""
        stats = self._fault_stats()
        if stats is not None:
            stats.watchdog_recoveries += 1
        t0 = time.perf_counter()
        _recount_run(self.fleet, rnd.work)
        blocked = waited + (time.perf_counter() - t0)
        self.wait_s += blocked
        self.recount_s += blocked

    def close(self) -> None:
        """Release every queued round without surfacing results or
        errors: cancel each in-flight recount, join each worker briefly
        (daemon threads are abandoned if truly hung), and drop pending
        work and stored exceptions. Idempotent; never raises — the
        teardown path for exceptional exits, so no live thread outlives
        the fleet even with multiple rounds in flight."""
        rounds, self._queue = list(self._queue), deque()
        for rnd in rounds:
            rnd.cancel.set()
        for rnd in rounds:
            if rnd.thread is not None and rnd.thread.is_alive():
                rnd.thread.join(
                    self.watchdog_s if self.watchdog_s is not None else 5.0)

    def __enter__(self) -> "GroundSegment":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            self.sync()
        else:
            self.close()
        return False

    @property
    def hidden_fraction(self) -> float:
        """Share of deferred-recount wall time hidden behind foreground
        work (0.0 when nothing was deferred)."""
        if not self.rounds_deferred or self.recount_s <= 0.0:
            return 0.0
        return max(1.0 - self.wait_s / self.recount_s, 0.0)
