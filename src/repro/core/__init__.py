"""TargetFuse core: the paper's contribution as composable JAX modules.

NOTE: submodules `dedup` and `throttle` contain same-named functions;
import from the submodules directly (`from repro.core.dedup import
dedup`) — this package intentionally re-exports only non-colliding
names.
"""
from repro.core.tiling import optimal_tile_size, tile_image, resize_tiles
from repro.core.energy import RPI4, ATLAS, EnergyLedger, max_tiles_within_budget
from repro.core.metrics import cmae, ap50
from repro.core.pipeline import (PipelineConfig, PipelineResult, budgets_for,
                                 run_pipeline)
from repro.core.policies import (SelectionPolicy, Selection, PolicyContext,
                                 available_policies, get_policy,
                                 register_policy)
from repro.core.mission import (Mission, Stage, Segment, IngestReport,
                                WindowReport, default_contact_stages,
                                default_ingest_stages)
from repro.core.energy import ByteLedger, FleetLedger
from repro.core.fleet import Fleet, run_scenario
from repro.core.fleet_sharding import FleetSharding, sats_mesh
