"""Pluggable selection policies for the Mission stage graph (§III-D).

Each of the paper's five baselines (§IV-A7) is a ``SelectionPolicy``
plugin registered under its method name; the Mission executor
(:mod:`repro.core.mission`) dispatches through the registry and contains
zero per-method branching. A policy declares which optional ingest
stages apply to it (``wants_roi`` / ``wants_dedup`` / ``wants_onboard``)
and implements :meth:`SelectionPolicy.select`, which maps the onboard
state of one ingested segment plus a contact-window byte budget to a
:class:`Selection` — which tiles keep their onboard count, which are
transmitted, and which are credited with a ground recount.

Registering a new policy requires no core changes:

    from repro.core.policies import SelectionPolicy, register_policy

    @register_policy("always_space")
    class AlwaysSpace(SelectionPolicy):
        def select(self, ctx, budget_bytes):
            import numpy as np
            return Selection(ctx.processed.copy(),
                             np.zeros(0, np.int64),
                             np.zeros(ctx.n, bool), 0.0)

    PipelineConfig(method="always_space")   # now a valid method

Note on naming: ``PipelineConfig.method`` picks the *selection policy*
plugin; ``PipelineConfig.policy`` remains the throttle fill order
(``low_conf_first`` / ``fixed_conf`` / ``dynamic_conf``, Fig. 6) used
inside the two-threshold policies.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, Type

import jax.numpy as jnp
import numpy as np

import repro.core.dedup as dd
from repro.core.throttle import throttle, throttle_padded

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.pipeline import PipelineConfig


@dataclass
class PolicyContext:
    """Read-only view of one ingested segment at selection time."""
    n: int                  # tile count
    active: np.ndarray      # (n,) bool  ROI-surviving tiles
    rep_of: np.ndarray      # (n,) int   dedup representative of each tile
    conf: np.ndarray        # (n,) f64   onboard confidence (-1 = unprocessed)
    counts_sp: np.ndarray   # (n,) f64   onboard counts, rep-expanded
    processed: np.ndarray   # (n,) bool  counted onboard within the energy cap
    tile_bytes: float       # downlink cost of one tile (full counter scale)
    pcfg: "PipelineConfig"


@dataclass
class Selection:
    """Select-stage output, consumed by Downlink/GroundRecount/Aggregate."""
    accept_space: np.ndarray   # (n,) bool: pred <- onboard count
    downlink: np.ndarray       # (k,) int64: tile indices to transmit
    ground_credit: np.ndarray  # (n,) bool: pred <- ground count of the rep
    bytes_requested: float     # bytes the policy asks to transmit (kodan
    #                            is bandwidth-oblivious and may exceed the
    #                            window budget; the ledger charges capped)


class SelectionPolicy:
    """Base plugin: stage wants + the selection decision."""

    name = "?"
    wants_roi = False       # run the ROI variance filter for this policy
    wants_dedup = False     # run clustering dedup for this policy
    wants_onboard = True    # run energy-capped onboard counting

    def select(self, ctx: PolicyContext, budget_bytes: float) -> Selection:
        raise NotImplementedError


_REGISTRY: Dict[str, Type[SelectionPolicy]] = {}


def register_policy(name: str):
    """Class decorator: register a :class:`SelectionPolicy` under ``name``."""
    def deco(cls: Type[SelectionPolicy]) -> Type[SelectionPolicy]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_policy(name: str) -> SelectionPolicy:
    """Instantiate the policy registered under ``name``."""
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise ValueError(
            f"unknown selection policy {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def available_policies() -> tuple:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# the paper's five baselines
# ---------------------------------------------------------------------------

@register_policy("space_only")
class SpaceOnlyPolicy(SelectionPolicy):
    """Onboard counts only; nothing is transmitted."""

    def select(self, ctx, budget_bytes):
        return Selection(ctx.processed.copy(), np.zeros(0, np.int64),
                         np.zeros(ctx.n, bool), 0.0)


@register_policy("ground_only")
class GroundOnlyPolicy(SelectionPolicy):
    """Bent-pipe: raw tiles downlinked in index order within bandwidth;
    the rest contribute 0. No onboard compute at all."""

    wants_onboard = False

    def select(self, ctx, budget_bytes):
        k = int(budget_bytes // ctx.tile_bytes)
        sel = np.arange(min(k, ctx.n))
        credit = np.zeros(ctx.n, bool)
        credit[sel] = True
        return Selection(np.zeros(ctx.n, bool), sel.astype(np.int64),
                         credit, len(sel) * ctx.tile_bytes)


@register_policy("tiansuan")
class TiansuanPolicy(SelectionPolicy):
    """Fixed confidence threshold: results above it are accepted onboard,
    the rest are downlinked indiscriminately within bandwidth; leftovers
    are lost.

    Ground-credit note (audited): energy-capped *unprocessed* tiles join
    the indiscriminate downlink queue (conf = -1 never clears the
    threshold) and spend bytes, but the PR-1 pipeline only credited the
    ground recount to tiles with ``processed`` set — an arriving tile the
    satellite never counted kept pred = 0 even though its ground count
    was computed and its bytes were spent. That behaviour is preserved by
    default for bit-parity with published numbers;
    ``PipelineConfig.tiansuan_credit_unprocessed=True`` credits every
    downlinked tile (see tests/test_mission.py regression).
    """

    def select(self, ctx, budget_bytes):
        pcfg = ctx.pcfg
        accept = ctx.processed & (ctx.conf > pcfg.tiansuan_thresh)
        cand = np.where(ctx.active & ~accept)[0]
        cand_reps = np.unique(ctx.rep_of[cand])
        k = int(budget_bytes // ctx.tile_bytes)
        sel_reps = cand_reps[:k]
        credit = np.isin(ctx.rep_of, sel_reps) & ~accept
        if not pcfg.tiansuan_credit_unprocessed:
            credit &= ctx.processed
        return Selection(accept, sel_reps.astype(np.int64), credit,
                         len(sel_reps) * ctx.tile_bytes)


class TwoThresholdPolicy(SelectionPolicy):
    """Shared kodan/targetfuse logic: two-threshold selection over dedup
    representatives (Algorithm 2) + leftover-bandwidth raw downlink of
    representatives the energy budget never let us process onboard (an
    unprocessed tile earns a ground count instead of counting 0)."""

    wants_roi = True
    wants_dedup = True
    bandwidth_oblivious = False  # kodan: selects as if bandwidth were infinite

    def select(self, ctx, budget_bytes):
        pcfg = ctx.pcfg
        n = ctx.n
        rep_self = ctx.rep_of == np.arange(n)
        rep_idx = np.where(ctx.processed & rep_self)[0]
        n_rep = len(rep_idx)
        budget = (np.float64(1e18) if self.bandwidth_oblivious
                  else np.float64(budget_bytes))
        if pcfg.use_engine:
            # shape-stable: pad the rep set to a bucket; pad slots are
            # inactive so they sort last and take no budget
            space_m, down_m = throttle_padded(
                ctx.conf[rep_idx], ctx.tile_bytes, budget,
                pcfg.conf_p, pcfg.conf_q, pcfg.policy,
                n_pad=dd.bucket_size(max(n_rep, 1)))
        else:
            tr = throttle(jnp.asarray(ctx.conf[rep_idx]),
                          jnp.full(n_rep, ctx.tile_bytes),
                          budget, pcfg.conf_p, pcfg.conf_q, pcfg.policy)
            space_m = np.asarray(tr.space)
            down_m = np.asarray(tr.downlink)
        down_reps = rep_idx[down_m]

        unproc_reps = np.where(ctx.active & rep_self & ~ctx.processed)[0]
        k_extra = int(max(budget - len(down_reps) * ctx.tile_bytes, 0.0)
                      // ctx.tile_bytes)
        down_all = np.concatenate([down_reps,
                                   unproc_reps[:k_extra]]).astype(np.int64)

        rep_space = np.zeros(n, bool)
        rep_space[rep_idx[space_m]] = True
        rep_down = np.zeros(n, bool)
        rep_down[down_all] = True
        use_ground = rep_down[ctx.rep_of] & ctx.active
        use_space = rep_space[ctx.rep_of] & ctx.processed & ~use_ground
        return Selection(use_space, down_all, use_ground,
                         len(down_all) * ctx.tile_bytes)


@register_policy("targetfuse")
class TargetFusePolicy(TwoThresholdPolicy):
    """Full system: tiling + dedup + dynamic-conf throttling."""


@register_policy("kodan")
class KodanPolicy(TwoThresholdPolicy):
    """Value-ranked downlink with dedup/ROI but bandwidth-oblivious —
    the paper treats it as an upper bound."""

    bandwidth_oblivious = True
