"""Pluggable selection policies for the Mission stage graph (§III-D).

Each of the paper's five baselines (§IV-A7) is a ``SelectionPolicy``
plugin registered under its method name; the Mission executor
(:mod:`repro.core.mission`) dispatches through the registry and contains
zero per-method branching. A policy declares which optional ingest
stages apply to it (``wants_roi`` / ``wants_dedup`` / ``wants_onboard``)
and implements :meth:`SelectionPolicy.select`, which maps the onboard
state of one ingested segment plus a contact-window byte budget to a
:class:`Selection` — which tiles keep their onboard count, which are
transmitted, and which are credited with a ground recount.

Registering a new policy requires no core changes:

    from repro.core.policies import SelectionPolicy, register_policy

    @register_policy("always_space")
    class AlwaysSpace(SelectionPolicy):
        def select(self, ctx, budget_bytes):
            import numpy as np
            return Selection(ctx.processed.copy(),
                             np.zeros(0, np.int64),
                             np.zeros(ctx.n, bool), 0.0)

    PipelineConfig(method="always_space")   # now a valid method

Note on naming: ``PipelineConfig.method`` picks the *selection policy*
plugin; ``PipelineConfig.policy`` remains the throttle fill order
(``low_conf_first`` / ``fixed_conf`` / ``dynamic_conf``, Fig. 6) used
inside the two-threshold policies.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Sequence, Tuple, Type

import jax.numpy as jnp
import numpy as np

import repro.core.dedup as dd
from repro.core.throttle import throttle, throttle_padded, throttle_padded_batch

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.pipeline import PipelineConfig


@dataclass
class PolicyContext:
    """Read-only view of one ingested segment at selection time."""
    n: int                  # tile count
    active: np.ndarray      # (n,) bool  ROI-surviving tiles
    rep_of: np.ndarray      # (n,) int   dedup representative of each tile
    conf: np.ndarray        # (n,) f64   onboard confidence (-1 = unprocessed)
    counts_sp: np.ndarray   # (n,) f64   onboard counts, rep-expanded
    processed: np.ndarray   # (n,) bool  counted onboard within the energy cap
    tile_bytes: float       # downlink cost of one tile (full counter scale)
    pcfg: "PipelineConfig"


@dataclass
class Selection:
    """Select-stage output, consumed by Downlink/GroundRecount/Aggregate."""
    accept_space: np.ndarray   # (n,) bool: pred <- onboard count
    downlink: np.ndarray       # (k,) int64: tile indices to transmit
    ground_credit: np.ndarray  # (n,) bool: pred <- ground count of the rep
    bytes_requested: float     # bytes the policy asks to transmit (kodan
    #                            is bandwidth-oblivious and may exceed the
    #                            window budget; the ledger charges capped)


@dataclass
class PolicyContextBatch:
    """Lane-stacked :class:`PolicyContext`: L contact-window lanes' worth
    of segment state as (L, n_max) padded arrays.

    This is the batched ground-segment core's view of one drain step —
    one lane per window currently serving a segment. Pad slots (columns
    past each lane's ``n``) are inert: ``active``/``processed`` False,
    ``conf`` -1, ``rep_of`` -1. ``lane(i)`` recovers the exact scalar
    :class:`PolicyContext` of lane ``i`` (row slices of the stack are
    bit-equal copies of the segment arrays), which is what keeps the
    batched planner's selections bit-identical to the scalar FIFO path.

    ``policies`` carries each lane's own policy *instance*: the default
    :meth:`SelectionPolicy.select_batch` adapter dispatches through it,
    so stateful third-party plugins keep per-mission state even when
    lanes of the same class are grouped into one batch.
    """

    n: np.ndarray            # (L,) int64 per-lane tile counts
    active: np.ndarray       # (L, n_max) bool
    rep_of: np.ndarray       # (L, n_max) int64 (pad slots -1)
    conf: np.ndarray         # (L, n_max) f64   (pad slots -1)
    counts_sp: np.ndarray    # (L, n_max) f64
    processed: np.ndarray    # (L, n_max) bool
    tile_bytes: np.ndarray   # (L,) f64
    pcfgs: Tuple             # per-lane PipelineConfig
    policies: Tuple          # per-lane SelectionPolicy instances
    sharding: object = None  # optional FleetSharding for the jax stages

    @property
    def n_lanes(self) -> int:
        return len(self.pcfgs)

    @classmethod
    def stack(cls, ctxs: Sequence[PolicyContext],
              policies: Sequence["SelectionPolicy"],
              sharding=None) -> "PolicyContextBatch":
        L = len(ctxs)
        n = np.array([c.n for c in ctxs], np.int64)
        n_max = max(int(n.max()) if L else 0, 1)

        def pack(fld, dtype, fill):
            out = np.full((L, n_max), fill, dtype)
            for i, c in enumerate(ctxs):
                out[i, :c.n] = getattr(c, fld)
            return out

        return cls(
            n=n,
            active=pack("active", bool, False),
            rep_of=pack("rep_of", np.int64, -1),
            conf=pack("conf", np.float64, -1.0),
            counts_sp=pack("counts_sp", np.float64, 0.0),
            processed=pack("processed", bool, False),
            tile_bytes=np.array([c.tile_bytes for c in ctxs], np.float64),
            pcfgs=tuple(c.pcfg for c in ctxs),
            policies=tuple(policies),
            sharding=sharding)

    def lane(self, i: int) -> PolicyContext:
        """Scalar view of lane ``i`` (unpadded row slices)."""
        n = int(self.n[i])
        return PolicyContext(
            n=n, active=self.active[i, :n], rep_of=self.rep_of[i, :n],
            conf=self.conf[i, :n], counts_sp=self.counts_sp[i, :n],
            processed=self.processed[i, :n],
            tile_bytes=float(self.tile_bytes[i]), pcfg=self.pcfgs[i])


@dataclass
class SelectionBatch:
    """Lane-aligned select_batch output: per-lane :class:`Selection`
    objects plus the stacked byte-request vector the vectorized Downlink
    charge consumes."""

    selections: List[Selection]
    bytes_requested: np.ndarray = field(default=None)  # (L,) f64

    def __post_init__(self):
        if self.bytes_requested is None:
            self.bytes_requested = np.array(
                [s.bytes_requested for s in self.selections], np.float64)


class SelectionPolicy:
    """Base plugin: stage wants + the selection decision.

    :meth:`select` is the scalar contract (one segment, one budget).
    :meth:`select_batch` is the lane-stacked contract the batched
    ground-segment core drives; the base implementation adapts any
    scalar policy by draining the lanes through each lane's own
    ``select`` — third-party plugins keep working unmodified — while
    the built-ins override it with native lane-stacked programs
    (bit-identical to the scalar path, differentially gated by
    tests/test_contact.py).
    """

    name = "?"
    wants_roi = False       # run the ROI variance filter for this policy
    wants_dedup = False     # run clustering dedup for this policy
    wants_onboard = True    # run energy-capped onboard counting

    def select(self, ctx: PolicyContext, budget_bytes: float) -> Selection:
        raise NotImplementedError

    def select_batch(self, batch: PolicyContextBatch,
                     budgets: np.ndarray) -> SelectionBatch:
        """Default adapter: scalar ``select`` per lane, dispatched
        through each lane's own policy instance (stateful third-party
        policies see exactly the calls the FIFO loop would make)."""
        return SelectionBatch([
            batch.policies[i].select(batch.lane(i), float(budgets[i]))
            for i in range(batch.n_lanes)])


_REGISTRY: Dict[str, Type[SelectionPolicy]] = {}


def register_policy(name: str):
    """Class decorator: register a :class:`SelectionPolicy` under ``name``."""
    def deco(cls: Type[SelectionPolicy]) -> Type[SelectionPolicy]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_policy(name: str) -> SelectionPolicy:
    """Instantiate the policy registered under ``name``."""
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise ValueError(
            f"unknown selection policy {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def available_policies() -> tuple:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# the paper's five baselines
# ---------------------------------------------------------------------------

@register_policy("space_only")
class SpaceOnlyPolicy(SelectionPolicy):
    """Onboard counts only; nothing is transmitted."""

    @staticmethod
    def _lane(processed, n):
        """One lane's selection — the single body shared by the scalar
        and lane-stacked entry points (no hand-synced duplicates)."""
        return Selection(processed.copy(), np.zeros(0, np.int64),
                         np.zeros(n, bool), 0.0)

    def select(self, ctx, budget_bytes):
        return self._lane(ctx.processed, ctx.n)

    def select_batch(self, batch, budgets):
        """Native: the accept masks are rows of the stacked
        ``processed`` plane."""
        return SelectionBatch(
            [self._lane(batch.processed[i, :n], n)
             for i, n in enumerate(map(int, batch.n))],
            np.zeros(batch.n_lanes, np.float64))


@register_policy("ground_only")
class GroundOnlyPolicy(SelectionPolicy):
    """Bent-pipe: raw tiles downlinked in index order within bandwidth;
    the rest contribute 0. No onboard compute at all."""

    wants_onboard = False

    @staticmethod
    def _lane(n, tile_bytes, budget_bytes):
        """One lane's budget-bounded index-prefix fill (shared body)."""
        k = int(budget_bytes // tile_bytes)
        sel = np.arange(min(k, n))
        credit = np.zeros(n, bool)
        credit[sel] = True
        return Selection(np.zeros(n, bool), sel.astype(np.int64),
                         credit, len(sel) * tile_bytes)

    def select(self, ctx, budget_bytes):
        return self._lane(ctx.n, ctx.tile_bytes, budget_bytes)

    def select_batch(self, batch, budgets):
        """Native: pure prefix fills over the stacked lane scalars."""
        return SelectionBatch(
            [self._lane(n, float(batch.tile_bytes[i]), float(budgets[i]))
             for i, n in enumerate(map(int, batch.n))])


@register_policy("tiansuan")
class TiansuanPolicy(SelectionPolicy):
    """Fixed confidence threshold: results above it are accepted onboard,
    the rest are downlinked indiscriminately within bandwidth; leftovers
    are lost.

    Ground-credit note (audited): energy-capped *unprocessed* tiles join
    the indiscriminate downlink queue (conf = -1 never clears the
    threshold) and spend bytes, but the PR-1 pipeline only credited the
    ground recount to tiles with ``processed`` set — an arriving tile the
    satellite never counted kept pred = 0 even though its ground count
    was computed and its bytes were spent. That behaviour is preserved by
    default for bit-parity with published numbers;
    ``PipelineConfig.tiansuan_credit_unprocessed=True`` credits every
    downlinked tile (see tests/test_mission.py regression).
    """

    def select(self, ctx, budget_bytes):
        accept = ctx.processed & (ctx.conf > ctx.pcfg.tiansuan_thresh)
        return self._finish(ctx, accept, budget_bytes)

    @staticmethod
    def _finish(ctx, accept, budget_bytes):
        """Shared scalar/batched tail: the candidate queue, budget cut,
        and credit masks of one lane (pure numpy, per-lane exact)."""
        cand = np.where(ctx.active & ~accept)[0]
        cand_reps = np.unique(ctx.rep_of[cand])
        k = int(budget_bytes // ctx.tile_bytes)
        sel_reps = cand_reps[:k]
        credit = np.isin(ctx.rep_of, sel_reps) & ~accept
        if not ctx.pcfg.tiansuan_credit_unprocessed:
            credit &= ctx.processed
        return Selection(accept, sel_reps.astype(np.int64), credit,
                         len(sel_reps) * ctx.tile_bytes)

    def select_batch(self, batch, budgets):
        """Native: the fixed-threshold accept masks for ALL lanes come
        from one stacked compare (pad slots: ``processed`` False keeps
        them out); the ragged candidate queues stay per-lane numpy."""
        thresh = np.array([p.tiansuan_thresh for p in batch.pcfgs],
                          np.float64)
        accept2d = batch.processed & (batch.conf > thresh[:, None])
        return SelectionBatch(
            [self._finish(batch.lane(i), accept2d[i, :int(batch.n[i])],
                          float(budgets[i]))
             for i in range(batch.n_lanes)])


class TwoThresholdPolicy(SelectionPolicy):
    """Shared kodan/targetfuse logic: two-threshold selection over dedup
    representatives (Algorithm 2) + leftover-bandwidth raw downlink of
    representatives the energy budget never let us process onboard (an
    unprocessed tile earns a ground count instead of counting 0)."""

    wants_roi = True
    wants_dedup = True
    bandwidth_oblivious = False  # kodan: selects as if bandwidth were infinite

    @staticmethod
    def _reps(ctx) -> np.ndarray:
        """Processed dedup representatives — the throttle's candidates."""
        rep_self = ctx.rep_of == np.arange(ctx.n)
        return np.where(ctx.processed & rep_self)[0]

    def _budget(self, budget_bytes) -> np.float64:
        return (np.float64(1e18) if self.bandwidth_oblivious
                else np.float64(budget_bytes))

    def select(self, ctx, budget_bytes):
        pcfg = ctx.pcfg
        rep_idx = self._reps(ctx)
        n_rep = len(rep_idx)
        budget = self._budget(budget_bytes)
        if pcfg.use_engine:
            # shape-stable: pad the rep set to a bucket; pad slots are
            # inactive so they sort last and take no budget
            space_m, down_m = throttle_padded(
                ctx.conf[rep_idx], ctx.tile_bytes, budget,
                pcfg.conf_p, pcfg.conf_q, pcfg.policy,
                n_pad=dd.bucket_size(max(n_rep, 1)))
        else:
            tr = throttle(jnp.asarray(ctx.conf[rep_idx]),
                          jnp.full(n_rep, ctx.tile_bytes),
                          budget, pcfg.conf_p, pcfg.conf_q, pcfg.policy)
            space_m = np.asarray(tr.space)
            down_m = np.asarray(tr.downlink)
        return self._finish(ctx, rep_idx, budget, space_m, down_m)

    @staticmethod
    def _finish(ctx, rep_idx, budget, space_m, down_m):
        """Shared scalar/batched tail: leftover-bandwidth raw downlink of
        unprocessed reps + rep-expanded space/ground masks of one lane."""
        n = ctx.n
        rep_self = ctx.rep_of == np.arange(n)
        down_reps = rep_idx[down_m]

        unproc_reps = np.where(ctx.active & rep_self & ~ctx.processed)[0]
        k_extra = int(max(budget - len(down_reps) * ctx.tile_bytes, 0.0)
                      // ctx.tile_bytes)
        down_all = np.concatenate([down_reps,
                                   unproc_reps[:k_extra]]).astype(np.int64)

        rep_space = np.zeros(n, bool)
        rep_space[rep_idx[space_m]] = True
        rep_down = np.zeros(n, bool)
        rep_down[down_all] = True
        use_ground = rep_down[ctx.rep_of] & ctx.active
        use_space = rep_space[ctx.rep_of] & ctx.processed & ~use_ground
        return Selection(use_space, down_all, use_ground,
                         len(down_all) * ctx.tile_bytes)

    def select_batch(self, batch, budgets):
        """Native lane-stacked selection: every lane's candidate set
        joins ONE vmapped padded-throttle program per fill order
        (:func:`repro.core.throttle.throttle_padded_batch`) instead of L
        jitted dispatches — the hot win of the batched planner. Per-lane
        masks are bit-equal to the scalar bucketed call (padding
        invariance + per-row vmap independence, differentially gated).
        Reference-path lanes (``use_engine=False``) fall back to the
        scalar adapter, whose eager unpadded throttle they are specified
        against.
        """
        if not all(p.use_engine for p in batch.pcfgs):
            return SelectionPolicy.select_batch(self, batch, budgets)
        L = batch.n_lanes
        ctxs = [batch.lane(i) for i in range(L)]
        rep_idxs = [self._reps(c) for c in ctxs]
        budget_eff = np.array([self._budget(float(budgets[i]))
                               for i in range(L)], np.float64)
        masks: list = [None] * L
        by_fill: Dict[str, list] = {}
        for i, c in enumerate(ctxs):
            by_fill.setdefault(c.pcfg.policy, []).append(i)
        for fill, ids in by_fill.items():
            n_pad = dd.bucket_size(max(max(len(rep_idxs[i]) for i in ids), 1))
            res = throttle_padded_batch(
                [ctxs[i].conf[rep_idxs[i]] for i in ids],
                [ctxs[i].tile_bytes for i in ids], budget_eff[ids],
                [ctxs[i].pcfg.conf_p for i in ids],
                [ctxs[i].pcfg.conf_q for i in ids],
                fill, n_pad=n_pad, sharding=batch.sharding)
            for i, m in zip(ids, res):
                masks[i] = m
        return SelectionBatch(
            [self._finish(ctxs[i], rep_idxs[i], np.float64(budget_eff[i]),
                          *masks[i]) for i in range(L)])


@register_policy("targetfuse")
class TargetFusePolicy(TwoThresholdPolicy):
    """Full system: tiling + dedup + dynamic-conf throttling."""


@register_policy("kodan")
class KodanPolicy(TwoThresholdPolicy):
    """Value-ranked downlink with dedup/ROI but bandwidth-oblivious —
    the paper treats it as an upper bound."""

    bandwidth_oblivious = True
