"""Adaptive image tiling (paper §III-B, Algorithm 1).

Large EO frames are cut into tiles and resized to the DNN counter's
input size. Tile size trades mAP against per-frame execution overhead
(more tiles = more forward passes); Algorithm 1 ternary-searches the
interior optimum of the (unimodal) accuracy curve.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def tile_image(img: jnp.ndarray, tile_size: int) -> jnp.ndarray:
    """img (H, W, C) -> (N, tile_size, tile_size, C); pads to a multiple."""
    h, w, c = img.shape
    ph, pw = -h % tile_size, -w % tile_size
    img = jnp.pad(img, ((0, ph), (0, pw), (0, 0)))
    gh, gw = (h + ph) // tile_size, (w + pw) // tile_size
    t = img.reshape(gh, tile_size, gw, tile_size, c).transpose(0, 2, 1, 3, 4)
    return t.reshape(gh * gw, tile_size, tile_size, c)


def untile_counts(counts: jnp.ndarray):
    """Aggregate per-tile counts back to a per-frame total."""
    return jnp.sum(counts)


def resize_tiles(tiles: jnp.ndarray, out_size: int) -> jnp.ndarray:
    """(N, S, S, C) -> (N, out_size, out_size, C), bilinear."""
    n, _, _, c = tiles.shape
    return jax.image.resize(tiles.astype(jnp.float32),
                            (n, out_size, out_size, c), "bilinear")


def n_tiles(img_hw: Tuple[int, int], tile_size: int) -> int:
    h, w = img_hw
    return ((h + tile_size - 1) // tile_size) * ((w + tile_size - 1) // tile_size)


def optimal_tile_size(map_fn: Callable[[int], float], s_min: int, s_max: int,
                      eps: int = 32) -> Tuple[int, Dict[int, float]]:
    """Algorithm 1: ternary search for the mAP-optimal tile size.

    ``map_fn(size) -> mAP``. Returns (s_best, evaluated sizes cache).

    The paper's listing narrows [s_left, s_right] by thirds, comparing
    mAP at the one-third points, until the interval is below ``eps``;
    the midpoint of the final interval is returned.
    """
    cache: Dict[int, float] = {}

    def f(s: int) -> float:
        s = int(s)
        if s not in cache:
            cache[s] = float(map_fn(s))
        return cache[s]

    s_left, s_right = s_min, s_max
    while s_right - s_left > eps:
        s_midl = s_left + (s_right - s_left) / 3.0
        s_midr = s_right - (s_right - s_left) / 3.0
        if f(int(s_midl)) < f(int(s_midr)):
            s_left = s_midl
        else:
            s_right = s_midr
    s_best = int((s_left + s_right) / 2)
    f(s_best)
    return s_best, cache
