"""Deterministic fault injection + degradation machinery for the fleet
runtime.

The paper's premise is operating under *scarce* resources (§III budgets
energy and bandwidth per pass); real LEO operations add *unreliable*
ones — links drop mid-window, ground stations go dark, satellites brown
out, and ground workers crash. This module injects exactly those fault
classes into the contact/ingest tiers, fully deterministically, and
owns the degradation rules that absorb them:

**Fault classes** (a :class:`FaultPlan` describes all of them):

* **window drop** — a contact window never happens. Plan repair
  (:meth:`FaultPlan.repair`) removes it before execution and *folds its
  explicit byte budget into the satellite's next surviving window* of
  the same round (entitlement windows have no explicit budget to fold;
  a drop with no later window for that satellite loses the budget).
* **station outage** — every window a station offers over a span of
  rounds drops (same repair path, keyed by station label).
* **mid-window truncation** — the link dies partway through a window:
  from pending-segment position ``t`` on, the window's remaining byte
  budget is cut to 0.0 (segment granularity — segments before ``t``
  drain normally, later ones see a zero budget).
* **corrupted downlink segment** — a served segment's transmitted bytes
  arrive corrupted and the ground discards them: no ground recount, no
  ground credit (never a double credit — the segment either retries
  cleanly later or is lost). The byte/radio charges follow the
  configurable refund policy: ``"refund"`` reconciles the ledger with a
  vectorized inverse charge
  (:meth:`~repro.core.energy.FleetLedger.refund_downlink_windows`);
  ``"charge"`` keeps them spent (the airtime was used either way).
* **satellite blackout** — a (round, sat) brownout: the pass is skipped
  entirely (no frames, zero harvest, no capture charge — see
  ``Mission.ingest(blackout=True)``).
* **ground-worker crash / stall** — one queued round's worker of the
  :class:`~repro.core.contact.GroundSegment` recount pipeline raises
  before recounting, or sleeps past the watchdog timeout. Worker-fault
  draws key on the contact-round counter, so each round queued in a
  depth-``k`` pipeline carries its own independent draw. The watchdog
  (``Fleet(watchdog_s=...)``) cancels that round's worker at
  retirement (a cancelled worker writes nothing — the cancel event is
  checked before every write-back) and retries the round's recount
  synchronously — recounts charge nothing and only overwrite
  per-segment outputs, so the retry is idempotent and bit-equal to the
  synchronous arm, at every pipeline depth.

**Degradation machinery**:

* **bounded retry with backoff** — a corrupted segment re-queues at the
  FRONT of its mission's pending FIFO (it is the oldest data) and
  becomes eligible again after a linear backoff of ``retries`` rounds;
  after ``max_retries`` failed transmissions it is permanently lost
  downlink-side (onboard-accepted counts still land at Aggregate; the
  ground-credited tiles predict 0).
* **budget reconciliation** — refunds are single vectorized
  :class:`~repro.core.energy.FleetLedger` ops with the exact inverse
  arithmetic of the charge (per-lane float64 sequences), so ledgers
  never go negative and are never double-credited.
* **plan repair** — see window drop above.

**Determinism**: every stochastic decision is a pure function of
``(seed, fault-class, round, window/sat, segment)`` through counter-based
``SeedSequence`` hashing — no RNG state is carried, so the batched
ContactPlan executor and the scalar FIFO reference see byte-identical
fault schedules regardless of execution order, and a re-run of the same
seed replays the same faults.

**The parity gate**: ``FaultPlan.none()`` (or ``faults=None``) is
bit-equal — per-tile predictions, summaries, and every ledger lane — to
the fault-free runtime for all five policies on both the engine and
reference execution paths and both the batched and FIFO-reference
contact paths (tests/test_faults.py), with disabled-path overhead gated
< 2% in benchmarks/fleet_bench.py.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field, replace
from typing import Dict, Mapping, Optional, Tuple

import numpy as np

__all__ = ["FaultPlan", "FaultStats", "FaultContext", "RepairedPlan",
           "WorkerCrash", "REFUND_POLICIES", "scenario_faults"]

REFUND_POLICIES = ("refund", "charge")

# fault-class codes for the counter-based hash (never reuse/renumber:
# a seed's fault schedule is part of the reproducibility contract)
_KIND_DROP = 0
_KIND_TRUNCATE = 1
_KIND_TRUNCATE_POS = 2
_KIND_CORRUPT = 3
_KIND_BLACKOUT = 4
_KIND_WORKER = 5


class WorkerCrash(RuntimeError):
    """Injected ground-worker crash (recoverable: the watchdog retries
    the recount synchronously instead of surfacing it)."""


@dataclass
class FaultStats:
    """Mutable fault/degradation counters one Fleet accumulates
    (mirrored into ``Fleet.summary()``)."""

    windows_dropped: int = 0
    windows_truncated: int = 0
    segments_corrupted: int = 0
    segments_requeued: int = 0
    segments_lost: int = 0
    blackout_passes: int = 0
    bytes_refunded: float = 0.0
    bytes_wasted: float = 0.0      # spent on attempts the ground discarded
    bytes_delivered: float = 0.0   # spent on attempts the ground kept
    budget_folded: float = 0.0     # dead-window budget folded forward
    budget_lost: float = 0.0       # dead-window budget with no heir
    worker_crashes: int = 0
    worker_stalls: int = 0
    watchdog_recoveries: int = 0

    def as_dict(self) -> dict:
        return {f"fault_{k}": v for k, v in vars(self).items()}


@dataclass
class FaultContext:
    """Mutable state of ONE faulty contact round, threaded through the
    batched and scalar-reference executors so both consume the identical
    fault schedule and report into the same counters.

    ``orig_windows[w]`` maps surviving window ``w`` back to its index in
    the pre-repair plan (fault draws stay keyed by the original
    schedule). ``held`` carries the backoff-ineligible re-queued
    segments the fleet parked for this round; ``requeue`` collects the
    segments this round's corruptions send back to the pending FIFO;
    ``events`` records ``(orig_window, pos, kind, bytes)`` byte-flow
    facts that the fleet folds into :class:`FaultStats` in canonical
    ``(window, pos)`` order at round end — so the float accumulation
    order (and thus the summary) is identical no matter which executor
    ran the round.
    """

    faults: "FaultPlan"
    rnd: int
    orig_windows: np.ndarray
    stats: FaultStats
    worker: Optional[str] = None
    held: list = field(default_factory=list)
    requeue: list = field(default_factory=list)
    events: list = field(default_factory=list)


@dataclass(frozen=True)
class RepairedPlan:
    """The surviving plan plus each surviving window's index in the
    ORIGINAL plan (fault addressing stays keyed by the original window
    index, so repair never shifts a later window's fault schedule)."""

    plan: object                # ContactPlan
    orig_windows: np.ndarray    # (n_surviving,) int64


@dataclass(frozen=True)
class FaultPlan:
    """Seeded, fully deterministic fault schedule (see module docstring).

    Rates draw faults via counter-based hashing; the explicit
    ``window_drops`` / ``window_truncations`` / ``segment_corruptions``
    / ``sat_blackouts`` / ``worker_faults`` containers pin individual
    faults for tests and reproductions. Both forms compose (explicit
    entries are unioned with rate draws).
    """

    seed: int = 0
    # stochastic rates (0.0 = class disabled)
    drop_rate: float = 0.0          # per contact window
    truncate_rate: float = 0.0      # per contact window
    corrupt_rate: float = 0.0       # per served segment transmission
    blackout_rate: float = 0.0      # per (round, sat) pass
    worker_crash_rate: float = 0.0  # per async contact round
    worker_stall_rate: float = 0.0  # per async contact round
    # explicit injections
    window_drops: frozenset = frozenset()          # {(round, window)}
    window_truncations: Mapping[Tuple[int, int], int] = \
        field(default_factory=dict)                # (round, window) -> pos
    segment_corruptions: frozenset = frozenset()   # {(round, window, pos)}
    sat_blackouts: frozenset = frozenset()         # {(round, sat)}
    worker_faults: Mapping[int, str] = \
        field(default_factory=dict)                # round -> crash|stall
    station_outages: Tuple[Tuple[str, int, int], ...] = ()
    #   (station_name, first_round, last_round) inclusive spans
    # degradation knobs
    max_retries: int = 2            # transmissions per segment = 1 + this
    refund_policy: str = "refund"   # "refund" | "charge"
    stall_s: float = 0.2            # injected worker-stall sleep

    def __post_init__(self):
        if self.refund_policy not in REFUND_POLICIES:
            raise ValueError(
                f"FaultPlan: refund_policy {self.refund_policy!r} not in "
                f"{REFUND_POLICIES}")
        if self.max_retries < 0:
            raise ValueError("FaultPlan: max_retries must be >= 0")
        for rate_name in ("drop_rate", "truncate_rate", "corrupt_rate",
                          "blackout_rate", "worker_crash_rate",
                          "worker_stall_rate"):
            r = getattr(self, rate_name)
            if not 0.0 <= r <= 1.0:
                raise ValueError(f"FaultPlan: {rate_name}={r} not in [0, 1]")
        for span in self.station_outages:
            if len(span) != 3 or span[1] > span[2]:
                raise ValueError(
                    f"FaultPlan: station outage {span!r} must be "
                    f"(name, first_round, last_round) with first <= last")
        object.__setattr__(self, "window_drops",
                           frozenset(self.window_drops))
        object.__setattr__(self, "segment_corruptions",
                           frozenset(self.segment_corruptions))
        object.__setattr__(self, "sat_blackouts",
                           frozenset(self.sat_blackouts))
        object.__setattr__(self, "window_truncations",
                           dict(self.window_truncations))
        object.__setattr__(self, "worker_faults", dict(self.worker_faults))
        object.__setattr__(self, "station_outages",
                           tuple(tuple(s) for s in self.station_outages))

    # -- constructors -------------------------------------------------------

    @staticmethod
    def none() -> "FaultPlan":
        """The empty plan: injects nothing, and the runtime is bit-equal
        to passing ``faults=None`` (the no-fault-subsystem path)."""
        return FaultPlan()

    @property
    def empty(self) -> bool:
        """True when no fault class can ever fire (the executors use
        this single check to keep the disabled path allocation-free)."""
        return (self.drop_rate == self.truncate_rate == self.corrupt_rate
                == self.blackout_rate == self.worker_crash_rate
                == self.worker_stall_rate == 0.0
                and not self.window_drops and not self.window_truncations
                and not self.segment_corruptions and not self.sat_blackouts
                and not self.worker_faults and not self.station_outages)

    def with_retries(self, max_retries: int) -> "FaultPlan":
        """Same schedule, different retry bound (the bench's retry vs
        no-retry arms must see IDENTICAL fault draws)."""
        return replace(self, max_retries=max_retries)

    # -- deterministic draws ------------------------------------------------

    def _unit(self, kind: int, *key: int) -> float:
        """Uniform [0,1) as a pure function of (seed, kind, key) — no RNG
        state carried, so draw order can never perturb the schedule."""
        ss = np.random.SeedSequence(
            entropy=(int(self.seed) & 0xFFFFFFFF, kind)
            + tuple(int(k) & 0xFFFFFFFF for k in key))
        # one 32-bit word is plenty for a rate compare
        return float(ss.generate_state(1, np.uint32)[0]) / 2.0 ** 32

    def window_dropped(self, rnd: int, window: int, station: str = "") -> bool:
        if (rnd, window) in self.window_drops:
            return True
        if station and self.station_out(station, rnd):
            return True
        return (self.drop_rate > 0.0
                and self._unit(_KIND_DROP, rnd, window) < self.drop_rate)

    def station_out(self, station: str, rnd: int) -> bool:
        for name, first, last in self.station_outages:
            if name == station and first <= rnd <= last:
                return True
        return False

    def truncated_at(self, rnd: int, window: int,
                     n_segments: int) -> Optional[int]:
        """Pending-segment position the window's budget dies at, or
        None. Position is drawn uniformly over the segments actually
        served, so both contact paths (same pending depth) agree."""
        if (rnd, window) in self.window_truncations:
            return int(self.window_truncations[(rnd, window)])
        if n_segments <= 0 or self.truncate_rate <= 0.0:
            return None
        if self._unit(_KIND_TRUNCATE, rnd, window) >= self.truncate_rate:
            return None
        return int(self._unit(_KIND_TRUNCATE_POS, rnd, window) * n_segments)

    def segment_corrupted(self, rnd: int, window: int, pos: int) -> bool:
        if (rnd, window, pos) in self.segment_corruptions:
            return True
        return (self.corrupt_rate > 0.0
                and self._unit(_KIND_CORRUPT, rnd, window, pos)
                < self.corrupt_rate)

    def blackout(self, rnd: int, sat: int) -> bool:
        if (rnd, sat) in self.sat_blackouts:
            return True
        return (self.blackout_rate > 0.0
                and self._unit(_KIND_BLACKOUT, rnd, sat) < self.blackout_rate)

    def worker_fault(self, rnd: int) -> Optional[str]:
        """"crash" | "stall" | None for the async ground worker of one
        contact round."""
        explicit = self.worker_faults.get(rnd)
        if explicit is not None:
            if explicit not in ("crash", "stall"):
                raise ValueError(
                    f"FaultPlan: worker fault {explicit!r} for round {rnd} "
                    f"must be 'crash' or 'stall'")
            return explicit
        if (self.worker_crash_rate > 0.0
                and self._unit(_KIND_WORKER, rnd, 0) < self.worker_crash_rate):
            return "crash"
        if (self.worker_stall_rate > 0.0
                and self._unit(_KIND_WORKER, rnd, 1) < self.worker_stall_rate):
            return "stall"
        return None

    # -- plan repair --------------------------------------------------------

    def repair(self, plan, rnd: int,
               stats: Optional[FaultStats] = None) -> RepairedPlan:
        """Remove this round's dead windows (drops + station outages)
        from a :class:`~repro.core.contact.ContactPlan` and fold each
        dead window's explicit byte budget into the same satellite's
        next surviving window. Returns the surviving plan plus each
        surviving window's ORIGINAL index (fault addressing for
        truncation/corruption stays keyed by the original schedule, so
        repair never shifts later windows' faults)."""
        from repro.core.contact import ContactPlan

        n = plan.n_windows
        dead = np.array([self.window_dropped(rnd, w, plan.stations[w])
                         for w in range(n)], bool)
        if not dead.any():
            return RepairedPlan(plan, np.arange(n))
        budgets = plan.budgets.copy()
        for w in np.flatnonzero(dead):
            if plan.entitlement[w]:
                continue  # nothing explicit to fold
            heirs = np.flatnonzero(~dead[w + 1:]
                                   & (plan.sats[w + 1:] == plan.sats[w]))
            if heirs.size:
                budgets[w + 1 + heirs[0]] += budgets[w]
                if stats is not None:
                    stats.budget_folded += float(budgets[w])
            elif stats is not None:
                stats.budget_lost += float(budgets[w])
        keep = np.flatnonzero(~dead)
        if stats is not None:
            stats.windows_dropped += int(dead.sum())
        repaired = ContactPlan(
            sats=plan.sats[keep], budgets=budgets[keep],
            entitlement=plan.entitlement[keep],
            stations=tuple(plan.stations[int(w)] for w in keep),
            n_sats=plan.n_sats)
        return RepairedPlan(repaired, keep)


def scenario_faults(spec, seed: Optional[int] = None, *,
                    drop_rate: float = 0.0, truncate_rate: float = 0.0,
                    corrupt_rate: float = 0.0, blackout_rate: float = 0.0,
                    outage_rate: float = 0.0, max_retries: int = 2,
                    refund_policy: str = "refund",
                    worker_faults: Optional[Dict[int, str]] = None
                    ) -> FaultPlan:
    """Fault-bearing rounds for a :class:`~repro.data.scenarios.
    FleetScenarioSpec`: a :class:`FaultPlan` sized to the scenario, with
    station outages drawn as round spans over the spec's real station
    names (``outage_rate`` = probability a station suffers one outage
    across the scenario; span ~ up to half the rounds). The per-event
    classes stay lazy rate draws — they need no scenario shape."""
    seed = spec.seed if seed is None else seed
    outages = []
    if outage_rate > 0.0 and spec.n_rounds > 0:
        rng = np.random.default_rng(
            np.random.SeedSequence((int(seed) & 0xFFFFFFFF, 0x5747)))
        for st in spec.stations:
            # station identity enters the draw, not tuple order
            u = rng.random(3)
            h = zlib.crc32(st.name.encode()) / 2.0 ** 32
            if (u[0] + h) % 1.0 < outage_rate:
                first = int(u[1] * spec.n_rounds)
                span = max(int(u[2] * (spec.n_rounds / 2)), 1)
                outages.append((st.name, first,
                                min(first + span - 1, spec.n_rounds - 1)))
    return FaultPlan(seed=seed, drop_rate=drop_rate,
                     truncate_rate=truncate_rate, corrupt_rate=corrupt_rate,
                     blackout_rate=blackout_rate,
                     station_outages=tuple(outages), max_retries=max_retries,
                     refund_policy=refund_policy,
                     worker_faults=worker_faults or {})
