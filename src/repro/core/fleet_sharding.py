"""Device-mesh fleet runtime: shard constellation work along a ``sats`` axis.

The fleet engine batches every satellite's work into stacked device
arrays (shared fused-capture frame buckets, fleet-wide counting batches,
the vmapped multi-satellite dedup core, (n_lanes,) budget-ledger lanes).
All of those arrays are *independent per lane/chunk*, so placing their
leading axis along a one-axis device mesh turns the fleet round into an
SPMD program: each device runs the identical per-sample arithmetic on
its shard of the constellation, and XLA inserts no cross-device
collectives because nothing couples lanes.

The batched ground segment rides the same axis: a ContactPlan drain
step's lane-stacked throttle call
(:func:`repro.core.throttle.throttle_padded_batch`) and the shared
ground-recount batches place their leading *window-lane* axis along the
mesh too — contact lanes, like satellite lanes, never couple, so the
placement is pure SPMD and per-lane masks are unchanged.

:class:`FleetSharding` is the placement context threaded through
``fleet.py`` / ``engine.py`` / ``cascade.py`` / ``energy.py``. It
follows the off-mesh no-op pattern of :mod:`repro.sharding.ctx`: built
without a mesh, every helper degrades to identity, so the single-device
fleet path (and every existing test) runs through the exact same code
unchanged.

Parity story: on the CPU backend the sharded fleet is *bit-equal* to
the single-device fleet (enforced by ``tests/test_fleet.py`` under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` and by the
``benchmarks/fleet_bench.py`` multi-device sweep) — every batched
program is per-sample and sharding only changes which device computes a
lane. Backends whose batched clustering reductions may reassociate can
force the sequential per-satellite dedup core with
``Fleet(strict_parity=True)``.

Uneven fleets (``n_sats % n_devices != 0``) are handled by *lane
padding*: leading axes are zero-padded up to a device multiple before
placement (:meth:`FleetSharding.pad` / :meth:`FleetSharding.shard`),
and pad lanes are sliced off before any result is read — they never
perturb real lanes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

SATS_AXIS = "sats"


def sats_mesh(n_devices: Optional[int] = None) -> Optional[Mesh]:
    """One-axis ``sats`` mesh over the first ``n_devices`` devices.

    ``None`` uses every visible device. Returns ``None`` (= off-mesh,
    single-device fleet path) when only one device would participate —
    callers never special-case device counts. On CPU, multiple host
    devices come from ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    (set before the first jax import).
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if n > len(devs):
        raise ValueError(
            f"sats_mesh: {n} devices requested but only {len(devs)} visible "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"before jax initializes for forced host devices)")
    if n <= 1:
        return None
    return Mesh(np.asarray(devs[:n]), (SATS_AXIS,))


class FleetSharding:
    """Placement context for the ``sats`` axis (no-op when ``mesh`` is None).

    The two primitives every sharded call site composes:

    * :meth:`pad` — round a lane/chunk count up to a device multiple.
    * :meth:`shard` — zero-pad the leading axis to that multiple and
      ``device_put`` with ``NamedSharding(P("sats", None, ...))``.

    Off-mesh both are identity (``pad(n) == n``; ``shard`` returns its
    input as-is), which is what keeps the single-device fleet byte-for-
    byte on its pre-sharding code path.
    """

    __slots__ = ("mesh", "_placements")

    def __init__(self, mesh: Optional[Mesh] = None):
        self.mesh = mesh
        # NamedSharding cache, one entry per ndim: the placement spec is
        # a pure function of (mesh, ndim), but building it per call made
        # every round's device_put re-derive sharding metadata — real
        # churn at fleet scale (hundreds of placements per round)
        self._placements: dict = {}

    @property
    def on_mesh(self) -> bool:
        return self.mesh is not None

    @property
    def n_devices(self) -> int:
        return 1 if self.mesh is None else int(self.mesh.size)

    def pad(self, n: int) -> int:
        """Smallest device multiple >= n (lane padding; identity off-mesh)."""
        nd = self.n_devices
        return -(-int(n) // nd) * nd

    def spec(self, ndim: int) -> P:
        return P(*((SATS_AXIS,) + (None,) * (ndim - 1)))

    def placement(self, ndim: int) -> NamedSharding:
        """The cached ``NamedSharding`` for an ndim-dimensional array
        (built once per ndim per mesh, reused every round)."""
        s = self._placements.get(ndim)
        if s is None:
            s = NamedSharding(self.mesh, self.spec(ndim))
            self._placements[ndim] = s
        return s

    def device_put(self, arr):
        """Place ``arr`` with its (device-multiple) leading axis split
        along ``sats``; identity off-mesh. Every real placement is
        counted in :mod:`repro.core.xfer`'s transfer ledger (the
        count-based churn gate in the fleet bench)."""
        if self.mesh is None:
            return arr
        from repro.core import xfer
        xfer.record_transfer()
        return jax.device_put(arr, self.placement(arr.ndim))

    def shard(self, arr):
        """Zero-pad the leading axis to a device multiple and place it.

        Pad rows hold zeros — every sharded fleet program is per-sample,
        so they produce garbage *in their own rows only*; callers slice
        results back to the real count. Off-mesh: identity.
        """
        if self.mesh is None:
            return arr
        n = arr.shape[0]
        n_pad = self.pad(n)
        if n_pad != n:
            arr = jnp.concatenate(
                [jnp.asarray(arr),
                 jnp.zeros((n_pad - n, *arr.shape[1:]),
                           jnp.asarray(arr).dtype)])
        return self.device_put(jnp.asarray(arr))


# the shared off-mesh singleton: call sites take `sharding=None` and
# normalize through this so `None` and "no mesh" behave identically
OFF_MESH = FleetSharding(None)


def ctx(sharding: Optional[FleetSharding]) -> FleetSharding:
    """Normalize an optional sharding argument (None -> off-mesh no-op)."""
    return OFF_MESH if sharding is None else sharding
