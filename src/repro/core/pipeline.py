"""Pipeline config/result types + the ``run_pipeline`` compatibility
wrapper over the Mission stage-graph executor.

MIGRATION NOTES (Mission API redesign)
--------------------------------------
The end-to-end pipeline used to live here as one ~200-line
``run_pipeline`` monolith with the five baselines as inline
``pcfg.method`` branches. It is now an explicit stage graph executed by
:class:`repro.core.mission.Mission`:

    ingest(frames):          Capture -> RoiFilter -> Dedup -> OnboardCount
    contact_window(bytes):   Select -> Downlink -> GroundRecount -> Aggregate

* The five baselines are registered
  :class:`~repro.core.policies.SelectionPolicy` plugins
  (``@register_policy("targetfuse")`` etc.); new policies and stages can
  be added without touching core. ``PipelineConfig.method`` names the
  plugin; ``PipelineConfig.policy`` is still the throttle fill order.
* A ``Mission`` owns persistent budget state (``EnergyLedger`` + byte
  ledger) across multiple ingests and contact windows — multi-pass /
  multi-window / constellation scenarios compose from the streaming API
  (see examples/constellation_sim.py).
* ``run_pipeline(frames, space, ground, pcfg)`` remains and is
  bit-identical to the pre-refactor monolith on both the engine and
  reference paths (``pcfg.use_engine``), enforced by
  tests/test_mission.py against the frozen oracle in
  :mod:`repro.core._legacy`.

Budget model (calibrated to the paper's published satellite numbers):
the simulated tile set stands for a ``day_fraction`` = n_tiles /
``tiles_per_day`` slice of one operational day. The energy budget
(default 150 KJ/day of the 260 KJ harvest) and the downlink byte budget
(bandwidth x contact windows) are prorated by that fraction. Energy and
byte costs are priced at FULL counter scale (416-px tiles, full-width
models from Table II) even when a reduced numerical proxy executes —
so the resource regime matches the paper (onboard compute covers ~22%
of captured tiles at 150 KJ; downlink covers ~15-20%), independent of
the proxy's size.

Baselines (paper §IV-A7), each a registered selection policy:
  space_only  — onboard counts only, no tile downlink
  ground_only — bent-pipe: raw tiles downlinked (index order) within
                bandwidth; ground counts those; the rest contribute 0
  tiansuan    — fixed confidence threshold; results above it accepted,
                the rest downlinked indiscriminately within bandwidth,
                leftovers lost
  kodan       — value-ranked downlink with dedup/ROI but bandwidth-
                oblivious (counts as if every wanted tile arrives) —
                the paper treats it as an upper bound
  targetfuse  — full system (tiling + dedup + dynamic-conf throttling)
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields
from typing import Optional, Tuple

import numpy as np

from repro.core.energy import DeviceProfile, RPI4


@dataclass
class PipelineConfig:
    method: str = "targetfuse"           # a registered SelectionPolicy name
    tile_size: int = 128
    conf_p: float = 0.10
    conf_q: float = 0.55
    policy: str = "dynamic_conf"         # throttle fill order (Fig. 6)
    bandwidth_mbps: float = 50.0
    contact_s: float = 360.0
    contacts_per_day: float = 4.0
    energy_budget_j: float = 150_000.0
    hardware: DeviceProfile = RPI4
    use_dedup: bool = True
    k_clusters: Optional[int] = None     # default: n_active // 2
    use_roi: bool = True
    roi_std_thresh: float = 0.02
    score_thresh: float = 0.15
    tiansuan_thresh: float = 0.5
    # credit ground recounts to downlinked-but-unprocessed tiles in the
    # tiansuan baseline (False reproduces the PR-1/paper behaviour where
    # such tiles spend bytes yet keep pred = 0; see TiansuanPolicy)
    tiansuan_credit_unprocessed: bool = False
    # --- day-fraction calibration (see module docstring) ---
    tiles_per_day: float = 100_000.0
    real_tile_px: int = 416              # byte/energy pricing scale
    seed: int = 0
    # device-resident engine (False = seed host-orchestrated reference path)
    use_engine: bool = True


@dataclass
class PipelineResult:
    cmae: float
    total_true: float
    total_pred: float
    bytes_downlinked: float
    bytes_budget: float
    tiles_processed_space: int
    tiles_downlinked: int
    tiles_total: int
    energy_spent_j: float
    energy_budget_j: float
    per_tile_pred: Optional[np.ndarray] = field(repr=False, default=None)
    per_tile_true: Optional[np.ndarray] = field(repr=False, default=None)

    def summary(self) -> dict:
        """Scalar fields only (no per-tile arrays) — the dict that
        benchmarks/examples print or serialize."""
        return {f.name: getattr(self, f.name) for f in fields(self)
                if not f.name.startswith("per_tile")}


def budgets_for(pcfg: PipelineConfig, n_tiles: int) -> Tuple[float, float, float]:
    """-> (energy_budget_j, byte_budget, bytes_per_tile) for the sim slice.

    Degenerate slices (``n_tiles <= 0`` or a non-positive
    ``tiles_per_day`` calibration) get zero budgets instead of dividing
    by zero — downstream selection then comes back empty.
    """
    tile_bytes = float(pcfg.real_tile_px ** 2 * 3)
    if n_tiles <= 0 or pcfg.tiles_per_day <= 0:
        return 0.0, 0.0, tile_bytes
    day_fraction = n_tiles / pcfg.tiles_per_day
    energy = pcfg.energy_budget_j * day_fraction
    byte_budget = (pcfg.bandwidth_mbps * 1e6 / 8.0 * pcfg.contact_s
                   * pcfg.contacts_per_day * day_fraction)
    return energy, byte_budget, tile_bytes


def run_pipeline(frames, space, ground, pcfg: PipelineConfig = None,
                 energy_cfgs=None) -> PipelineResult:
    """Compatibility wrapper: one-window Mission.

    frames: list of (image, boxes, classes). space/ground: (params, cfg).
    ``energy_cfgs``: (space_cfg_full, ground_cfg_full) used to PRICE
    compute; defaults to the paper's full-scale Table II counters.

    Equivalent to ``Mission(space, ground, pcfg).run(frames)`` —
    bit-identical to the pre-refactor monolith (see module docstring).
    """
    from repro.core.mission import Mission
    return Mission(space, ground, pcfg, energy_cfgs=energy_cfgs).run(frames)
