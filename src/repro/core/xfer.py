"""Content-keyed host->device transfer cache for control-plane arrays.

The fleet's batched round re-uploads the same *little* host arrays every
``ingest()``: pow2-padded gather indices (dedup moment gathers, counting
subset gathers), per-group lane counts / cluster counts, and stacked PRNG
keys (the dedup seed is per-config, so the key stack is literally
identical round over round). Each upload is cheap alone, but the count
scales with fleet size x rounds, and on a mesh every one also builds
placement metadata. Since these arrays are pure *values* (no aliasing,
never donated, never mutated), they can be cached by content —
``(mesh, dtype, shape, bytes)`` — and a repeated-shape scenario then
issues ZERO transfers for them after its first round.

The counters are the honest ledger the bench gates on (count-based, not
timing-based): ``transfer_stats()['device_puts']`` counts real
host->device placements issued through this module and through
:meth:`repro.core.fleet_sharding.FleetSharding.device_put`;
``cache_reuses`` counts uploads avoided. ``tests/test_fleet.py`` and
``benchmarks/fleet_bench.py`` assert that a repeated-shape round issues
strictly fewer transfers than the cold round that preceded it.

Thread safety: recount workers (:mod:`repro.core.contact`) call
:func:`repro.core.cascade.count_tiles_multi` off the foreground thread,
so cache and counters are lock-protected.
"""
from __future__ import annotations

import threading

import numpy as np

# cache only small control-plane arrays: index vectors, lane counts, key
# stacks. Data arrays (tiles, frames, moments) are content-unique per
# round and would only churn the dict.
_MAX_ITEM_BYTES = 1 << 16
_MAX_ENTRIES = 4096

_lock = threading.Lock()
_cache: dict = {}
_stats = {"device_puts": 0, "cache_reuses": 0}


def record_transfer(n: int = 1) -> None:
    """Count ``n`` real host->device placements (called by every path
    that issues one: this module's misses and
    :meth:`FleetSharding.device_put`)."""
    with _lock:
        _stats["device_puts"] += n


def transfer_stats() -> dict:
    """Snapshot of the transfer counters (copies; safe to diff)."""
    with _lock:
        return dict(_stats)


def reset_transfer_stats() -> None:
    with _lock:
        _stats["device_puts"] = 0
        _stats["cache_reuses"] = 0


def clear_cache() -> None:
    """Drop every cached resident (test isolation; counters unchanged)."""
    with _lock:
        _cache.clear()


def cache_size() -> int:
    with _lock:
        return len(_cache)


def _put(arr, sharding, on_mesh):
    import jax.numpy as jnp

    dev = jnp.asarray(arr)
    if on_mesh:
        return sharding.device_put(dev)  # device_put records the transfer
    record_transfer()
    return dev


def device_constant(arr, sharding=None):
    """Return ``arr`` as a device-resident constant, cached by content.

    ``arr`` is a small host ndarray whose value tends to repeat across
    rounds. With an on-mesh
    :class:`~repro.core.fleet_sharding.FleetSharding`, the cached
    resident is placed along the ``sats`` axis (the cache key includes
    the mesh, so meshes never share residents); off-mesh it is a plain
    device array. Arrays above the size cap bypass the cache but are
    still counted as transfers. The returned array must be treated as
    immutable — every caller only gathers/consumes it.
    """
    arr = np.asarray(arr)
    on_mesh = sharding is not None and getattr(sharding, "on_mesh", False)
    if arr.nbytes > _MAX_ITEM_BYTES:
        return _put(arr, sharding, on_mesh)
    key = (id(sharding.mesh) if on_mesh else None,
           arr.dtype.str, arr.shape, arr.tobytes())
    with _lock:
        hit = _cache.get(key)
    if hit is not None:
        with _lock:
            _stats["cache_reuses"] += 1
        return hit
    dev = _put(arr, sharding, on_mesh)
    with _lock:
        if len(_cache) >= _MAX_ENTRIES:
            _cache.clear()
        _cache[key] = dev
    return dev
