"""Fleet engine: vectorized constellation-scale Mission execution.

A :class:`Fleet` owns the persistent budget state of N satellites as
STACKED arrays (one :class:`~repro.core.energy.FleetLedger` instead of N
scalar ledgers) and executes the Mission ingest stages for the whole
constellation through shared compiled programs:

* **Capture** — frames from all satellites flow through the same fused
  frame-program buckets (:func:`repro.core.engine.prepare_frames_multi`),
  so 8 satellites with 2 frames each run 4 full buckets instead of 8
  half-empty ones.
* **Admission** — day-fraction energy grants and capture charges are one
  vectorized ledger op across the fleet.
* **OnboardCount** — every satellite's representative set is counted in
  shared fixed-shape forward batches
  (:func:`repro.core.cascade.count_tiles_multi`): the 64-slot padding of
  the counting program is paid once per fleet-round, not once per
  satellite.

* **Dedup** — clustering couples tiles only within one satellite, but
  the k-means cores all run in ONE vmapped call per shape bucket
  (:func:`repro.core.dedup.dedup_multi`) — ingest has no per-satellite
  Python loop left (``strict_parity=True`` restores the sequential
  per-sat core).

RoiFilter / Select stay per-satellite host bookkeeping (cheap masks over
the fused statistics) and reuse the bucketed compiled programs, which
are shared across the fleet by construction.

Contact rounds run the batched ContactPlan core
(:mod:`repro.core.contact`): a round's windows become a declarative,
validated :class:`~repro.core.contact.ContactPlan`; Select executes as
lane-stacked ``select_batch`` programs across the round's windows (the
two-threshold throttles collapse into one vmapped call per drain
step), Downlink charges through vectorized :class:`FleetLedger` window
ops, and the ground recounts of every window share counting batches —
optionally deferred to the bounded recount pipeline
(``async_ground=True`` for the single-slot overlap, ``async_depth=k``
for up to *k* rounds in flight) so round *k*'s recount overlaps later
rounds' ingest dispatch.
FIFO-within-window byte semantics are preserved exactly (a window's
remaining budget is its plan budget minus the prefix sum of its
earlier segments' spends), so the batched planner is bit-equal to
draining every window through the scalar stage loop
(:meth:`Fleet.contact_round_reference`; tests/test_contact.py gates
all five policies at 0.0 deviation).

The executed arithmetic is IDENTICAL to running N independent
:class:`~repro.core.mission.Mission` objects: every batched program is
per-sample, ledger lanes are independent float64 sequences, and the
per-satellite stages are literally Mission's. ``tests/test_fleet.py``
enforces exact equality of per-tile predictions and summaries against
the looped-Mission oracle (:func:`run_scenario` with ``fleet=False``)
for all registered policies.

Contact windows rotate: :meth:`Fleet.contact_round` serves the next
``stations`` satellites round-robin, or takes an explicit ``windows``
list, or — preferred — a :class:`~repro.core.contact.ContactPlan`
(e.g. a scenario round's contact events via
``Round.contact_plan(n)``); each window drains its satellite's pending
passes FIFO through its policy's selection.

Scaling past one accelerator: ``Fleet(..., mesh=...)`` threads a
:class:`~repro.core.fleet_sharding.FleetSharding` context through the
batched stages — shared frame buckets, fleet counting batches, the
vmapped dedup core, and the padded ledger lanes are then placed along a
``sats`` device mesh axis (see :mod:`repro.core.fleet_sharding` for the
parity story and the lane-padding rule for uneven fleets).
``strict_parity=True`` trades the batched multi-satellite dedup core
back for the sequential per-satellite one — construction-guaranteed
bit-parity with looped Missions on any backend.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

import repro.core.dedup as dd
from repro.core import engine, xfer
from repro.core.cascade import count_tiles_multi
from repro.core.contact import ContactPlan, GroundSegment
from repro.core.energy import (FleetLedger, max_tiles_within_budget,
                               max_tiles_within_budget_vec)
from repro.core.faults import FaultContext, FaultPlan, FaultStats
from repro.core.fleet_sharding import FleetSharding
from repro.core.mission import (Aggregate, Capture, Dedup, Downlink,
                                GroundRecount, IngestReport, Mission,
                                OnboardCount, RoiFilter, Segment, Select,
                                WindowReport)
from repro.core.pipeline import PipelineConfig, PipelineResult

_DEFAULT_INGEST_GRAPH = (Capture, RoiFilter, Dedup, OnboardCount)
_DEFAULT_CONTACT_GRAPH = (Select, Downlink, GroundRecount, Aggregate)


class Fleet:
    """N-satellite constellation over one counter pair.

    Parameters
    ----------
    space, ground : (params, cfg) counter pairs shared by the fleet.
    pcfg : one :class:`PipelineConfig` (replicated) or a sequence of N
        per-satellite configs — policies/methods may differ per
        satellite; ``use_engine`` or a custom stage graph falls that
        satellite back to its Mission's sequential ingest.
    n_sats : fleet size when ``pcfg`` is a single config.
    energy_cfgs : as for :class:`Mission` (compute pricing), shared.
    mesh : optional ``sats``-axis device mesh
        (:func:`~repro.core.fleet_sharding.sats_mesh`); the batched
        stages then place their stacked arrays along it. ``None`` =
        single-device execution, byte-for-byte the pre-sharding path.
    strict_parity : ``True`` runs dedup per-satellite through the
        sequential core — bit-parity with looped Missions by
        construction on every backend. ``False`` (default) runs the
        vmapped multi-satellite dedup core (no per-sat Python loop);
        bit-equal on CPU (test-enforced; documented tolerance 0.0), may
        reassociate on other backends.
    async_ground : ``True`` defers each contact round's batched ground
        recount to a worker thread so it overlaps the next round's
        ingest dispatch (:class:`~repro.core.contact.GroundSegment`;
        ``results()``/``finalize()`` sync first). ``False`` (default)
        recounts inline — same arithmetic, synchronous. Shorthand for
        ``async_depth=1``.
    async_depth : bounded recount-pipeline depth — up to this many
        rounds' deferred recounts stay in flight at once, with
        backpressure (the oldest retires before a new round enters).
        ``0`` = synchronous inline recount, ``1`` = the single-slot
        overlap of ``async_ground``, ``2``-``3`` = deep pipelining of
        ingest-dispatch / device-compute / ground-recount stages.
        Bit-equal output at EVERY depth (test-enforced at 0.0 deviation
        for all five policies). ``None`` (default) derives the depth
        from ``async_ground``; passing both ``async_ground=True`` and
        ``async_depth=0`` is a conflict and raises; negative depths
        raise.
    ingest_overlap : ``True`` round-pipelines ingest itself: a round's
        dedup/cap/counting *results* stay on device as a deferred tail
        while the foreground returns, and round k+1's frame prep is
        dispatched BEFORE round k's tail is resolved — so round k's
        device compute runs behind round k+1's dispatch work. All
        device->host syncs (dedup assign/rep gather, the fleet-wide
        ``roi_std`` copy, counting results, the on-mesh window-cap
        round-trip) become deferred fetches resolved at the round's
        Aggregate/recount boundary: the next ``ingest()``, any contact
        round, ``results()``/``finalize()``/``summary()``, or a clean
        ``__exit__``. Ledger interaction is double-buffered exactly like
        the recount pipeline's snapshot-at-dispatch: at most one round's
        ledger tail plus one round's counting fetches are ever pending,
        and a pending tail always resolves before any later ledger op
        on the same lanes — per-lane float64 op order is preserved, so
        output is bit-equal to ``False`` (test-enforced at 0.0 for all
        five policies x engine/reference x recount depths 0-2, incl.
        under fault plans). ``False`` (default) keeps every sync inline.
    contact_reference : ``True`` pins EVERY contact round (including the
        ``finalize`` flush) to the scalar FIFO-loop reference path —
        the parity oracle / bench baseline of the batched planner.
    faults : optional :class:`~repro.core.faults.FaultPlan` — a seeded,
        fully deterministic fault schedule injected at the contact/ingest
        tiers (window drops, truncation, corrupted downlinks with
        retry-with-backoff, blackout passes, station outages, worker
        crash/stall; see :mod:`repro.core.faults`). ``None`` (default)
        and ``FaultPlan.none()`` are bit-equal to the fault-free runtime
        on every path. Blackouts key on the ingest-call counter; contact
        faults on the contact-round counter (the ``finalize`` flush is
        never faulted, so everything not permanently lost drains).
    watchdog_s : optional ground-worker watchdog timeout (seconds) for
        ``async_ground=True``: a recount worker that hasn't finished
        within it is cancelled and the round recounted synchronously
        (bit-equal — recounts are idempotent and charge nothing).
    """

    def __init__(self, space, ground, pcfg=None, n_sats: Optional[int] = None,
                 energy_cfgs=None, mesh=None, strict_parity: bool = False,
                 async_ground: bool = False, contact_reference: bool = False,
                 faults: Optional[FaultPlan] = None,
                 watchdog_s: Optional[float] = None,
                 async_depth: Optional[int] = None,
                 ingest_overlap: bool = False):
        if isinstance(pcfg, (list, tuple)):
            pcfgs = list(pcfg)
            if n_sats is not None and n_sats != len(pcfgs):
                raise ValueError(
                    f"n_sats={n_sats} conflicts with {len(pcfgs)} "
                    f"per-satellite configs")
            n_sats = len(pcfgs)
        else:
            n_sats = 1 if n_sats is None else n_sats
            pcfgs = [pcfg if pcfg is not None else PipelineConfig()
                     for _ in range(n_sats)]
        if n_sats < 1:
            raise ValueError("a fleet needs at least one satellite")
        self.n_sats = n_sats
        self.space = space
        self.ground = ground
        self.sharding = FleetSharding(mesh)
        self.strict_parity = bool(strict_parity)
        self.missions = [Mission(space, ground, p, energy_cfgs=energy_cfgs)
                         for p in pcfgs]
        # swap every Mission's scalar ledgers for lanes of ONE stacked
        # fleet ledger: budget state lives in (n_lanes,) arrays — lane-
        # padded to the device mesh for uneven fleets — and the ground-
        # side Mission stages keep working unmodified via views
        self.ledger = FleetLedger(n_sats,
                                  n_lanes=self.sharding.pad(n_sats))
        for i, m in enumerate(self.missions):
            m.ledger = self.ledger.energy_view(i)
            m.bytes_ledger = self.ledger.bytes_view(i)
        self._station = 0  # rotating contact-window pointer
        self._batchable = [self._can_batch(m) for m in self.missions]
        self._contact_batchable = [self._can_batch_contact(m)
                                   for m in self.missions]
        if async_depth is not None and int(async_depth) < 0:
            raise ValueError(
                f"Fleet: async_depth must be >= 0 (0 = synchronous "
                f"recount, k = up to k rounds' recounts in flight), "
                f"got {async_depth}")
        if not isinstance(ingest_overlap, bool) and ingest_overlap < 0:
            raise ValueError(
                f"Fleet: ingest_overlap must be a bool (True = defer "
                f"each round's device->host fetches behind the next "
                f"round's dispatch), got {ingest_overlap}")
        if async_depth is not None and async_ground and int(async_depth) == 0:
            raise ValueError(
                "async_ground=True conflicts with async_depth=0 "
                "(a synchronous pipeline cannot overlap)")
        self.ground_segment = GroundSegment(self, overlap=async_ground,
                                            watchdog_s=watchdog_s,
                                            depth=async_depth)
        self.contact_reference = bool(contact_reference)
        self.ingest_overlap = bool(ingest_overlap)
        # ingest pipeline state: at most ONE round's ledger tail (dedup
        # fetch + aggregation/compute charges + count dispatch) and one
        # round's counting fetches are pending at any time — the
        # double-buffered round snapshot mirroring the recount pipeline
        self._ingest_tail = None          # (finish_fn, dispatch_time)
        self._pending_counts: List[Tuple] = []  # [(fetch_fn, dispatch_time)]
        self._ingest_rounds_deferred = 0
        # per-stage ingest timing (summary() S-invariant:
        # host_fetch_s <= device_compute_s, both 0.0 when synchronous)
        self._ingest_dispatch_s = 0.0  # foreground dispatch wall
        self._device_compute_s = 0.0   # cumulative deferred in-flight wall
        self._host_fetch_s = 0.0       # foreground wall blocked resolving
        self._ingest_s = 0.0       # cumulative ingest wall time
        self._tiles_ingested = 0   # for summary() throughput
        self._contact_s = 0.0      # cumulative contact-round wall time
        self._windows_served = 0   # across all contact rounds
        # fault subsystem: the empty-plan check happens ONCE here so the
        # disabled path costs a single cached-bool test per round
        self.faults = faults
        self.fault_stats = FaultStats()
        self._faults_active = faults is not None and not faults.empty
        self._ingest_round = 0     # blackout draws key on this counter
        self._fault_round = 0      # contact-tier draws key on this one
        self._suppress_faults = False  # the finalize flush is never faulted

    @staticmethod
    def _can_batch(m: Mission) -> bool:
        return (m.pcfg.use_engine
                and tuple(type(s) for s in m.ingest_stages)
                == _DEFAULT_INGEST_GRAPH)

    @staticmethod
    def _can_batch_contact(m: Mission) -> bool:
        return (m.pcfg.use_engine
                and tuple(type(s) for s in m.contact_stages)
                == _DEFAULT_CONTACT_GRAPH)

    # -- streaming API ------------------------------------------------------

    def ingest(self, frames_per_sat: Sequence,
               energy_budgets_j: Optional[Sequence] = None
               ) -> List[IngestReport]:
        """One orbital pass for every satellite, constellation-batched.

        ``frames_per_sat[i]`` is satellite *i*'s frame list for this
        round (may be empty); ``energy_budgets_j[i]`` optionally
        overrides its harvest grant (eclipse/sunlit profiles). Returns
        per-satellite :class:`IngestReport`\\ s identical to calling
        ``Mission.ingest`` satellite by satellite.

        With ``ingest_overlap=True`` the returned reports' deferred
        fields (``tiles_processed_space``, ``energy_remaining_j``) are
        finalized at the round's resolution boundary — the next
        ``ingest``/contact/``results`` call — while the eager fields
        (``n_tiles``, grants, entitlements) are always final on return.
        """
        t0 = time.perf_counter()
        fetch0 = self._host_fetch_s
        if len(frames_per_sat) != self.n_sats:
            raise ValueError(
                f"expected {self.n_sats} frame lists, got {len(frames_per_sat)}")
        if energy_budgets_j is None:
            energy_budgets_j = [None] * self.n_sats
        elif len(energy_budgets_j) != self.n_sats:
            raise ValueError(
                f"expected {self.n_sats} energy budgets, "
                f"got {len(energy_budgets_j)}")
        reports: List[Optional[IngestReport]] = [None] * self.n_sats

        blackouts = frozenset()
        if self._faults_active:
            blackouts = frozenset(
                i for i in range(self.n_sats)
                if self.faults.blackout(self._ingest_round, i))
            self.fault_stats.blackout_passes += len(blackouts)
        batched = [i for i in range(self.n_sats)
                   if self._batchable[i] and frames_per_sat[i]
                   and i not in blackouts]
        if self._ingest_tail is not None and len(batched) < self.n_sats:
            # some satellite takes the sequential Mission path this
            # round (empty pass, custom graph, blackout): its ledger ops
            # must come AFTER the pending round's deferred charges on
            # the same lanes, so the tail resolves before the loop —
            # frame prep of fully-batched rounds still overlaps it
            self._resolve_ingest_tail()
        for i in range(self.n_sats):
            if i in blackouts:
                # satellite brownout: the pass is skipped entirely (zero
                # harvest, no segment, no capture charge)
                reports[i] = self.missions[i].ingest(
                    frames_per_sat[i], energy_budget_j=energy_budgets_j[i],
                    blackout=True)
            elif i not in batched:
                # empty passes and non-default graphs take the exact
                # sequential Mission path
                reports[i] = self.missions[i].ingest(
                    frames_per_sat[i], energy_budget_j=energy_budgets_j[i])
        if batched:
            self._ingest_batched(batched, frames_per_sat, energy_budgets_j,
                                 reports)
        self._ingest_round += 1
        dt = time.perf_counter() - t0
        self._ingest_s += dt
        # dispatch time = this call's wall minus whatever it spent
        # blocked resolving deferred fetches (0 in synchronous mode)
        self._ingest_dispatch_s += max(
            dt - (self._host_fetch_s - fetch0), 0.0)
        self._tiles_ingested += sum(r.n_tiles for r in reports
                                    if r is not None)
        return reports  # type: ignore[return-value]

    def _ingest_batched(self, sats, frames_per_sat, energy_budgets_j,
                        reports):
        sp_size = self.space[1].input_size
        gd_size = self.ground[1].input_size
        overlap = self.ingest_overlap
        t_dispatch = time.perf_counter()

        # --- Capture.prepare: shared frame buckets across the fleet ---
        segs: Dict[int, Segment] = {}
        by_tile: Dict[int, List[int]] = {}
        for i in sats:
            by_tile.setdefault(self.missions[i].pcfg.tile_size, []).append(i)
        for tile_size, ids in by_tile.items():
            # the shared buckets compute moments/ROI stats only if some
            # satellite in the group consumes them (tiles are identical
            # either way, so bucket sharing stays exact)
            stats = any(
                (self.missions[i].pcfg.use_roi
                 and self.missions[i].policy.wants_roi)
                or (self.missions[i].pcfg.use_dedup
                    and self.missions[i].policy.wants_dedup) for i in ids)
            preps = engine.prepare_frames_multi(
                [frames_per_sat[i] for i in ids], tile_size, sp_size, gd_size,
                sharding=self.sharding, with_stats=stats,
                defer_stats=overlap)
            for i, prep in zip(ids, preps):
                seg = Segment(frames=list(frames_per_sat[i]),
                              energy_grant_override=energy_budgets_j[i])
                seg.prep = prep
                seg.tiles_sp, seg.tiles_gd = prep.tiles_sp, prep.tiles_gd
                seg.true, seg.n = prep.true, prep.n
                segs[i] = seg

        if overlap:
            # double-buffered round boundary: the PREVIOUS round's
            # deferred tail resolves only now — with this round's frame
            # buckets already enqueued behind its programs on the device
            # — and strictly before this round's grants, so every lane's
            # float64 ledger sequence is the synchronous one. Counting
            # fetches dispatched by that tail's predecessor drain first
            # (they touch no ledger; draining bounds pending work at one
            # round of counts + one tail).
            self._drain_count_fetches()
            self._resolve_ingest_tail()

        # --- Capture.admit, with the ledger ops lifted out: the fleet
        # grants every satellite's entitlement in one vectorized op ---
        evec = np.zeros(self.ledger.n_lanes, np.float64)
        fvec = np.zeros(self.ledger.n_lanes, np.float64)
        for i in sats:
            m, seg = self.missions[i], segs[i]
            evec[i] = Capture.entitle(m, seg)
            fvec[i] = len(seg.frames)
            Capture.init_state(m, seg)
        self.ledger.grant(evec)
        self.ledger.charge_capture(fvec)

        # --- RoiFilter: per-satellite host masks over the fused stats
        # (under overlap, roi_std is a lazy device slice — materialized
        # here only for satellites whose policy actually reads it) ---
        for i in sats:
            m, seg = self.missions[i], segs[i]
            if overlap:
                self._materialize_roi(m, seg)
            m.ingest_stages[1].run(m, seg)  # RoiFilter
        # --- Dedup: one vmapped multi-sat core call per shape bucket
        # (strict_parity falls back to the sequential per-sat core) ---
        dedup_fetch = nops = None
        if self.strict_parity:
            for i in sats:
                m, seg = self.missions[i], segs[i]
                m.ingest_stages[2].run(m, seg)  # Dedup (charges aggregate)
        elif overlap:
            dedup_fetch, nops = self._dedup_batched(sats, segs, defer=True)
        else:
            self._dedup_batched(sats, segs)

        # --- OnboardCount: fleet-shared fixed-shape counting batches ---
        count_sats = [i for i in sats
                      if self.missions[i].policy.wants_onboard]
        if overlap:
            def finish():
                # the deferred round tail — runs at the next resolution
                # boundary. Ledger op order per lane matches the
                # synchronous path exactly: charge_aggregate lands
                # before the cap read + charge_compute, and the whole
                # tail lands before any LATER round's grant.
                if nops is not None:
                    self.ledger.charge_aggregate(nops)
                # dispatch the on-mesh cap program now so its round-trip
                # rides behind the dedup-result wait (remaining is final
                # for this round: charge_aggregate just landed)
                caps_resolver = self._dispatch_caps(count_sats)
                if dedup_fetch is not None:
                    dedup_fetch()  # seg.rep_of writes (no ledger)
                self._onboard_count_batched(count_sats, segs, defer=True,
                                            caps_resolver=caps_resolver)
                for i in sats:
                    m, seg = self.missions[i], segs[i]
                    reports[i].tiles_processed_space = seg.n_processed
                    reports[i].energy_remaining_j = m.ledger.remaining
            self._ingest_tail = (finish, t_dispatch)
            self._ingest_rounds_deferred += 1
        else:
            self._onboard_count_batched(count_sats, segs)

        for i in sats:
            m, seg = self.missions[i], segs[i]
            m._segments.append(seg)
            m._pending.append(seg)
            m._finalized = False
            reports[i] = IngestReport(
                n_frames=len(seg.frames), n_tiles=seg.n,
                tiles_processed_space=seg.n_processed,
                energy_granted_j=seg.energy_granted_j,
                energy_remaining_j=m.ledger.remaining,
                byte_entitlement=seg.byte_entitlement)

    # -- ingest-overlap resolution boundaries ------------------------------

    def _resolve_ingest_tail(self):
        """Run the previous round's deferred ledger/fetch tail (no-op
        when nothing is pending). Cleared before running so a raising
        tail can never re-fire at the next boundary."""
        tail = self._ingest_tail
        if tail is None:
            return
        self._ingest_tail = None
        fn, t_disp = tail
        t1 = time.perf_counter()
        fn()
        t2 = time.perf_counter()
        self._host_fetch_s += t2 - t1
        self._device_compute_s += t2 - t_disp

    def _drain_count_fetches(self):
        """Resolve every parked counting-batch fetch. These touch no
        ledger lanes, so drain order is free — but draining before the
        tail resolves bounds pending work at one round's counts plus
        one round's tail."""
        pend, self._pending_counts = self._pending_counts, []
        for fn, t_disp in pend:
            t1 = time.perf_counter()
            fn()
            t2 = time.perf_counter()
            self._host_fetch_s += t2 - t1
            self._device_compute_s += t2 - t_disp

    def _resolve_ingest_pending(self):
        """Full resolution boundary: tail first (it dispatches this
        round's counting batches), then all parked count fetches.
        Called by GroundSegment entry points, results(), and summary()
        so no reader ever observes a half-finished round."""
        self._resolve_ingest_tail()
        self._drain_count_fetches()

    def _materialize_roi(self, m, seg):
        """Fetch a satellite's deferred ``roi_std`` device slice to host
        (overlap mode hands out lazy slices from the fused stats
        program). Only satellites whose policy actually reads ROI pay
        the copy; the blocked time counts as both host-fetch and
        device-compute wall (the fetch IS the in-flight window here)."""
        prep = getattr(seg, "prep", None)
        if prep is None or prep.roi_std is None:
            return
        if isinstance(prep.roi_std, np.ndarray):
            return
        if not (m.pcfg.use_roi and m.policy.wants_roi) or not seg.n:
            return
        t1 = time.perf_counter()
        prep.roi_std = np.asarray(prep.roi_std)
        t2 = time.perf_counter()
        self._host_fetch_s += t2 - t1
        self._device_compute_s += t2 - t1

    def _dedup_batched(self, sats, segs, defer=False):
        """Mission.Dedup semantics with the per-satellite k-means loop
        lifted into :func:`repro.core.dedup.dedup_multi`: every
        satellite's padded moment gather joins ONE vmapped core call per
        shape bucket (placed along the ``sats`` mesh axis when sharded).
        Skip conditions, cluster counts, gathers, keys, and the
        aggregation charge are exactly the sequential stage's.

        With ``defer=True`` the core call is dispatched but the
        device->host fetch and ``seg.rep_of`` writes move into a
        returned closure, and the aggregation charge is NOT applied here
        — the caller charges ``nops`` (second return value) itself so
        the ledger op can land before the fetch blocks. Returns
        ``(fetch_fn, nops)``, both ``None`` when no satellite deduped.
        """
        parts, ids = [], []
        nops = np.zeros(self.ledger.n_lanes, np.float64)
        for i in sats:
            m, seg = self.missions[i], segs[i]
            pcfg = m.pcfg
            if (not (pcfg.use_dedup and m.policy.wants_dedup)
                    or seg.active.sum() <= 4):
                continue
            k = pcfg.k_clusters or max(2, int(seg.active.sum()) // 2)
            idx_active = np.where(seg.active)[0]
            n_act = len(idx_active)
            idx_pad = np.zeros(dd.dedup_pad_size(n_act), np.int64)
            idx_pad[:n_act] = idx_active
            parts.append((seg.prep.moments[xfer.device_constant(idx_pad)], k,
                          jax.random.PRNGKey(pcfg.seed), n_act))
            ids.append((i, idx_active))
            nops[i] = n_act
        if not parts:
            return (None, None) if defer else None
        results = dd.dedup_multi(parts, sharding=self.sharding)

        def fetch():
            for (i, idx_active), res in zip(ids, results):
                seg = segs[i]
                assign = np.asarray(res.assign)
                rep_local = np.asarray(res.rep_idx)
                seg.rep_of[idx_active] = idx_active[rep_local[assign]]
        if defer:
            return fetch, nops
        fetch()
        self.ledger.charge_aggregate(nops)
        return None

    def _dispatch_caps(self, sats):
        """Enqueue the uniform-profile on-mesh energy-cap program and
        return its deferred resolver (``None`` when the fleet has
        heterogeneous pricing, or nothing to count — the per-satellite
        fallback in :meth:`_onboard_count_batched` covers those)."""
        if not sats:
            return None
        profiles = {(self.missions[i].gflops_space,
                     self.missions[i].pcfg.hardware) for i in sats}
        if len(profiles) != 1:
            return None
        (gflops, hw), = profiles
        return max_tiles_within_budget_vec(self.ledger.remaining * 0.95,
                                           gflops, hw,
                                           sharding=self.sharding, defer=True)

    def _onboard_count_batched(self, sats, segs, defer=False,
                               caps_resolver=None):
        """Mission.OnboardCount semantics, with every satellite's
        energy-capped representative set counted in shared batches.

        ``caps_resolver`` (from :meth:`_dispatch_caps`) supplies the
        uniform energy caps from an already-in-flight device program.
        With ``defer=True`` the rep selection and compute charge still
        happen eagerly (they feed the ledger and reports), but each
        counting batch's device->host fetch is parked on
        ``self._pending_counts`` for a later resolution boundary."""
        if not sats:
            return
        # energy caps and compute spends are vectorized over the stacked
        # ledger when the fleet shares one pricing profile (lanes are
        # independent, so reading all caps before charging is exact);
        # heterogeneous hardware falls back to identical per-lane floats
        profiles = {(self.missions[i].gflops_space,
                     self.missions[i].pcfg.hardware) for i in sats}
        uniform = len(profiles) == 1
        caps = None
        if uniform:
            (gflops, hw), = profiles
            caps = (caps_resolver() if caps_resolver is not None else
                    max_tiles_within_budget_vec(self.ledger.remaining * 0.95,
                                                gflops, hw,
                                                sharding=self.sharding))
        process: Dict[int, np.ndarray] = {}
        nproc = np.zeros(self.ledger.n_lanes, np.float64)
        for i in sats:
            m, seg = self.missions[i], segs[i]
            reps = np.unique(seg.rep_of[seg.active])
            cap = (int(caps[i]) if caps is not None else
                   max_tiles_within_budget(m.ledger.remaining * 0.95,
                                           m.gflops_space, m.pcfg.hardware))
            process[i] = reps[:cap] if len(reps) > cap else reps
            seg.n_processed = len(process[i])
            nproc[i] = seg.n_processed
        if uniform:
            self.ledger.charge_compute(nproc, gflops, hw)
        else:
            for i in sats:
                m = self.missions[i]
                m.ledger.charge_compute(segs[i].n_processed, m.gflops_space,
                                        m.pcfg.hardware)

        # shared-batch forward per distinct (score_thresh,) group
        by_thresh: Dict[float, List[int]] = {}
        for i in sats:
            by_thresh.setdefault(self.missions[i].pcfg.score_thresh,
                                 []).append(i)
        params, cfg = self.space
        for thresh, ids in by_thresh.items():
            parts = [(segs[i].tiles_sp, process[i]) for i in ids]
            out = count_tiles_multi(params, cfg, parts, score_thresh=thresh,
                                    sharding=self.sharding, defer=defer)
            if defer:
                # `out` is the resolve closure: the batch is enqueued on
                # the device; the single host fetch + write-back parks
                # until a resolution boundary (no ledger ops inside)
                self._pending_counts.append((
                    lambda resolve=out, ids=ids, process=process:
                        self._apply_counts(ids, segs, process, resolve()),
                    time.perf_counter()))
            else:
                self._apply_counts(ids, segs, process, out)

    def _apply_counts(self, ids, segs, process, results):
        """Write one counting batch's (counts, conf) back onto its
        segments — identical to the sequential stage's scatter."""
        for i, (c, f) in zip(ids, results):
            seg = segs[i]
            counts_sp = np.zeros(seg.n)
            conf = np.full(seg.n, -1.0)
            if seg.n_processed:
                counts_sp[process[i]] = c
                conf[process[i]] = f
            seg.counts_sp = counts_sp[seg.rep_of]
            seg.conf = conf[seg.rep_of]
            seg.processed = np.isin(seg.rep_of, process[i]) & seg.active

    def _resolve_plan(self, windows, stations, budget_bytes, plan
                      ) -> ContactPlan:
        """Normalize the three contact-round input shapes into ONE
        validated :class:`~repro.core.contact.ContactPlan` (malformed
        windows fail here, at plan-build time, not deep in the drain)."""
        if plan is not None:
            if windows is not None:
                raise ValueError("pass either plan= or windows=, not both")
            if plan.n_sats != self.n_sats:
                raise ValueError(
                    f"plan is for a {plan.n_sats}-satellite fleet; this "
                    f"fleet has {self.n_sats}")
            return plan
        if windows is not None:
            return ContactPlan.build(windows, self.n_sats)
        plan, self._station = ContactPlan.rotating(
            self.n_sats, stations, start=self._station,
            budget_bytes=budget_bytes)
        return plan

    # -- fault-round lifecycle ---------------------------------------------

    def _begin_fault_round(self, plan: ContactPlan):
        """Open one faulty contact round: repair the plan (drop dead
        windows, fold their budgets forward), park re-queued segments
        whose retry backoff hasn't elapsed, and build the
        :class:`~repro.core.faults.FaultContext` both executors consume.
        Returns ``(plan, None)`` untouched when faults are off (a single
        cached-bool test — the <2% disabled-path overhead gate)."""
        if not self._faults_active or self._suppress_faults:
            return plan, None
        rnd = self._fault_round
        repaired = self.faults.repair(plan, rnd, self.fault_stats)
        ctx = FaultContext(
            faults=self.faults, rnd=rnd,
            orig_windows=repaired.orig_windows, stats=self.fault_stats,
            worker=(self.faults.worker_fault(rnd)
                    if self.ground_segment.overlap else None))
        for m in self.missions:
            if not m._pending:
                continue
            hold = [s for s in m._pending
                    if s.requeued and s.eligible_round > rnd]
            if hold:
                m._pending = [s for s in m._pending if not
                              (s.requeued and s.eligible_round > rnd)]
                ctx.held.append((m, hold))
        return repaired.plan, ctx

    def _end_fault_round(self, ctx: Optional[FaultContext]) -> None:
        """Close a faulty round: re-queue held + newly-failed segments at
        the FRONT of their mission's pending FIFO (they are the oldest
        data, ordered by ingest), and fold the round's byte-flow events
        into the fault counters in canonical ``(window, pos)`` order so
        summaries are executor-order independent. Runs in a ``finally``:
        a mid-round exception can never strand a parked segment, so
        ``finalize()`` stays safe afterwards."""
        if ctx is None:
            return
        per_m: Dict[int, Tuple[Mission, list]] = {}
        for m, hold in ctx.held:
            per_m.setdefault(id(m), (m, []))[1].extend(hold)
        for m, seg in ctx.requeue:
            per_m.setdefault(id(m), (m, []))[1].append(seg)
        for m, group in per_m.values():
            order = {id(s): k for k, s in enumerate(m._segments)}
            group.sort(key=lambda s: order[id(s)])
            m._pending[:0] = group
        stats = self.fault_stats
        for _, _, kind, amt in sorted(ctx.events,
                                      key=lambda e: (e[0], e[1], e[2])):
            if kind == "delivered":
                stats.bytes_delivered += amt
            elif kind == "refunded":
                stats.bytes_refunded += amt
            elif kind == "wasted":
                stats.bytes_wasted += amt
        self._fault_round += 1

    def contact_round(self, windows: Optional[Sequence[Tuple[int, float]]]
                      = None, stations: int = 1,
                      budget_bytes: Optional[float] = None, *,
                      plan: Optional[ContactPlan] = None
                      ) -> List[Tuple[int, WindowReport]]:
        """One ground-contact round, executed by the batched ContactPlan
        core (:mod:`repro.core.contact`).

        Pass a declarative ``plan`` (explicit windows, a scenario
        round's contact events via
        :meth:`ContactPlan.from_contacts`, or any builder output); or
        the legacy shapes — explicit ``windows`` as
        ``[(sat, budget_bytes), ...]``, or the rotating default: the
        next ``stations`` satellites (round-robin from the rotating
        pointer) each get a window of ``budget_bytes`` (None = their
        pending entitlement; with more stations than satellites the
        rotation wraps, so a satellite can get several windows in one
        round). Each window drains that satellite's pending passes FIFO
        through its selection policy — Select runs as lane-stacked
        ``select_batch`` calls across the round's windows, Downlink
        charges through vectorized ledger ops, and the ground recounts
        share fixed-shape counting batches (deferred to overlap the
        next round's ingest when the fleet was built with
        ``async_ground=True``). Bit-equal to draining each window
        through the scalar stage loop (:meth:`contact_round_reference`).
        Returns ``[(sat, WindowReport), ...]`` in window order.
        """
        if self.contact_reference:  # constructor-pinned reference mode
            return self.contact_round_reference(
                windows, stations, budget_bytes, plan=plan)
        plan = self._resolve_plan(windows, stations, budget_bytes, plan)
        plan, ctx = self._begin_fault_round(plan)
        t0 = time.perf_counter()
        try:
            out = self.ground_segment.execute(plan, fault_ctx=ctx)
        finally:
            self._end_fault_round(ctx)
        self._contact_s += time.perf_counter() - t0
        self._windows_served += plan.n_windows
        return out

    def contact_round_reference(
            self, windows: Optional[Sequence[Tuple[int, float]]] = None,
            stations: int = 1, budget_bytes: Optional[float] = None, *,
            plan: Optional[ContactPlan] = None
            ) -> List[Tuple[int, WindowReport]]:
        """:meth:`contact_round` through the FIFO-loop reference path:
        every window drains sequentially through the scalar Mission
        stage loop. The parity oracle (and bench baseline) the batched
        planner is gated against at 0.0 deviation."""
        plan = self._resolve_plan(windows, stations, budget_bytes, plan)
        plan, ctx = self._begin_fault_round(plan)
        t0 = time.perf_counter()
        try:
            out = self.ground_segment.execute_reference(plan, fault_ctx=ctx)
        finally:
            self._end_fault_round(ctx)
        self._contact_s += time.perf_counter() - t0
        self._windows_served += plan.n_windows
        return out

    def finalize(self) -> List[PipelineResult]:
        """Flush every satellite's pending passes through zero-byte
        windows (onboard results land, nothing transmits) in one batched
        contact round, then aggregate per satellite.

        The flush is NEVER faulted: re-queued segments still waiting out
        their retry backoff (and everything else pending) drain here, so
        only permanently-lost transmissions end without ground credit."""
        pend = [i for i in range(self.n_sats) if self.missions[i]._pending]
        if pend:
            self._suppress_faults = True
            try:
                self.contact_round(windows=[(i, 0.0) for i in pend])
            finally:
                self._suppress_faults = False
        for m in self.missions:
            m._finalized = True
        return self.results()

    def close(self) -> None:
        """Tear down without surfacing deferred-recount results or
        errors (delegates to :meth:`GroundSegment.close`): idempotent,
        never raises, never leaks a worker thread. Any ingest-overlap
        tail or parked count fetches are DROPPED, not resolved —
        teardown never runs deferred work that could raise."""
        self._ingest_tail = None
        self._pending_counts = []
        self.ground_segment.close()

    def __enter__(self) -> "Fleet":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            self.ground_segment.sync()
        else:
            self.close()
        return False

    def results(self) -> List[PipelineResult]:
        self.ground_segment.sync()  # deferred recounts land before reads
        return [m.result() for m in self.missions]

    @property
    def pending_segments(self) -> List[int]:
        return [m.pending_segments for m in self.missions]

    def summary(self) -> dict:
        """Fleet-aggregate scalars (per-satellite results summed) plus
        the runtime facts benches and examples used to recompute ad hoc:
        the device-mesh width, whether ingest ran the batched
        (vmapped/no-per-sat-loop) dedup core, ingest throughput
        (cumulative wall time of :meth:`ingest` calls), and the
        contact-tier mirror — cumulative :meth:`contact_round` wall
        time, window/byte throughput, and the overlapped-recount
        accounting of the :class:`~repro.core.contact.GroundSegment`.

        Ingest-pipeline stage timings mirror the recount tier's:
        ``ingest_dispatch_s`` is foreground wall spent enqueuing device
        work, ``host_fetch_s`` is foreground wall blocked on deferred
        device->host copies, ``device_compute_s`` is the cumulative
        dispatch->resolution in-flight window those copies rode in, and
        ``ingest_hidden_frac = 1 - host_fetch_s/device_compute_s`` is
        the fraction of deferred-work wall hidden behind later
        dispatch. Side-effect-free: resolving pending work is the same
        resolution every reader forces, so two consecutive calls return
        equal dicts."""
        rs = self.results()
        tps = (self._tiles_ingested / self._ingest_s
               if self._ingest_s > 0 else 0.0)
        gseg = self.ground_segment
        assert gseg.wait_s <= gseg.recount_s, (
            f"recount accounting invariant broken: wait_s={gseg.wait_s} "
            f"> recount_s={gseg.recount_s}")
        assert self._host_fetch_s <= self._device_compute_s, (
            f"ingest accounting invariant broken: host_fetch_s="
            f"{self._host_fetch_s} > device_compute_s="
            f"{self._device_compute_s}")
        hidden = (max(1.0 - self._host_fetch_s / self._device_compute_s, 0.0)
                  if self._device_compute_s > 0 else 0.0)
        bytes_spent = float(self.ledger.bytes_spent[:self.n_sats].sum())
        return {
            "n_sats": self.n_sats,
            "n_devices": self.sharding.n_devices,
            "dedup_batched": not self.strict_parity,
            "ingest_s": self._ingest_s,
            "tiles_per_s": tps,
            "tiles_per_s_per_sat": tps / self.n_sats,
            "ingest_overlap": self.ingest_overlap,
            "ingest_rounds_deferred": self._ingest_rounds_deferred,
            "ingest_dispatch_s": self._ingest_dispatch_s,
            "device_compute_s": self._device_compute_s,
            "host_fetch_s": self._host_fetch_s,
            "ingest_hidden_frac": hidden,
            "contact_s": self._contact_s,
            "windows_served": self._windows_served,
            "windows_per_s": (self._windows_served / self._contact_s
                              if self._contact_s > 0 else 0.0),
            "bytes_downlinked_per_s": (bytes_spent / self._contact_s
                                       if self._contact_s > 0 else 0.0),
            "async_ground": gseg.overlap,
            "async_depth": gseg.depth,
            "recount_rounds_deferred": gseg.rounds_deferred,
            "recount_max_in_flight": gseg.max_in_flight,
            "recount_s": gseg.recount_s,
            "recount_wait_s": gseg.wait_s,
            "recount_hidden_frac": gseg.hidden_fraction,
            "total_true": sum(r.total_true for r in rs),
            "total_pred": sum(r.total_pred for r in rs),
            "tiles_total": sum(r.tiles_total for r in rs),
            "tiles_processed_space": sum(r.tiles_processed_space for r in rs),
            "tiles_downlinked": sum(r.tiles_downlinked for r in rs),
            # sum REAL lanes only: pad lanes hold zeros, but including
            # them changes numpy's pairwise-summation tree and shifts
            # the aggregate by an ulp vs the unpadded fleet
            "bytes_spent": bytes_spent,
            "bytes_budget": float(self.ledger.bytes_budget[:self.n_sats].sum()),
            "energy_spent_j": float(self.ledger.spent[:self.n_sats].sum()),
            "energy_budget_j": float(self.ledger.budget_j[:self.n_sats].sum()),
            "faults_active": self._faults_active,
            **self.fault_stats.as_dict(),
        }


def run_scenario(space, ground, pcfg, scenario, *, fleet: bool = True,
                 energy_cfgs=None, mesh=None, strict_parity: bool = False,
                 async_ground: bool = False, contact_reference: bool = False,
                 faults: Optional[FaultPlan] = None,
                 watchdog_s: Optional[float] = None,
                 async_depth: Optional[int] = None,
                 ingest_overlap: bool = False):
    """Execute a :class:`~repro.data.scenarios.FleetScenario`.

    ``fleet=True`` runs the constellation-batched :class:`Fleet` path
    (optionally sharded along a ``sats`` device ``mesh``), driving each
    round's contact events as a declarative
    :class:`~repro.core.contact.ContactPlan`; ``async_ground=True``
    additionally overlaps every round's ground recount with the next
    round's ingest (``async_depth=k`` generalizes that to a bounded
    pipeline holding up to ``k`` rounds' recounts in flight — bit-equal
    at every depth), ``ingest_overlap=True`` round-pipelines ingest
    itself (each round's device->host fetches defer behind the next
    round's dispatch — bit-equal to the synchronous path), and
    ``contact_reference=True`` swaps the batched planner for the scalar
    FIFO-loop reference (the bench baseline).
    ``fleet=False`` runs the looped-Mission parity oracle — one
    sequential ``Mission`` per satellite fed the identical event order.
    Returns ``(per_sat_results, driver)`` where ``driver`` is the Fleet
    or the Mission list.

    ``faults`` injects a deterministic fault schedule
    (:mod:`repro.core.faults`). The Fleet path supports every fault
    class; the looped-Mission oracle supports the plan/ingest-tier
    classes (blackouts, window drops, station outages) with identical
    draws — segment-granular faults (truncation, corruption/retry,
    worker crash/stall) need the Fleet executors and raise here on the
    oracle path.
    """
    n = scenario.spec.n_sats
    faults_active = faults is not None and not faults.empty
    if fleet:
        fl = Fleet(space, ground, pcfg, n_sats=n, energy_cfgs=energy_cfgs,
                   mesh=mesh, strict_parity=strict_parity,
                   async_ground=async_ground,
                   contact_reference=contact_reference, faults=faults,
                   watchdog_s=watchdog_s, async_depth=async_depth,
                   ingest_overlap=ingest_overlap)
        for rnd in scenario.rounds:
            fl.ingest(rnd.frames_per_sat(n), rnd.harvest_per_sat(n))
            if rnd.contacts:
                fl.contact_round(plan=rnd.contact_plan(n))
        return fl.finalize(), fl
    if faults_active and (
            faults.truncate_rate or faults.corrupt_rate
            or faults.worker_crash_rate or faults.worker_stall_rate
            or faults.window_truncations or faults.segment_corruptions
            or faults.worker_faults):
        raise ValueError(
            "the looped-Mission oracle supports blackout/window-drop/"
            "station-outage faults only; segment-granular fault classes "
            "need the Fleet path (fleet=True)")
    pcfgs = (list(pcfg) if isinstance(pcfg, (list, tuple))
             else [pcfg] * n)
    if len(pcfgs) != n:
        raise ValueError(f"{len(pcfgs)} per-satellite configs for an "
                         f"{n}-satellite scenario")
    missions = [Mission(space, ground, p, energy_cfgs=energy_cfgs)
                for p in pcfgs]
    contact_idx = 0  # mirrors Fleet._fault_round (rounds with contacts)
    for r_i, rnd in enumerate(scenario.rounds):
        frames = rnd.frames_per_sat(n)
        harvest = rnd.harvest_per_sat(n)
        for i in range(n):
            missions[i].ingest(
                frames[i], energy_budget_j=harvest[i],
                blackout=faults_active and faults.blackout(r_i, i))
        if not rnd.contacts:
            continue
        if faults_active:
            rp = faults.repair(rnd.contact_plan(n), contact_idx)
            for w in range(rp.plan.n_windows):
                missions[int(rp.plan.sats[w])].contact_window(
                    rp.plan.window_budget(w))
            contact_idx += 1
        else:
            for c in rnd.contacts:
                missions[c.sat].contact_window(c.budget_bytes)
    return [m.finalize() for m in missions], missions
