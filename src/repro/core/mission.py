"""Mission sessions: composable stage-graph pipeline with streaming
contact windows.

A :class:`Mission` owns the persistent budget state of one satellite —
an :class:`~repro.core.energy.EnergyLedger` plus a downlink byte ledger
— and executes an explicit stage graph over ingested frame segments:

    ingest(frames):          Capture -> RoiFilter -> Dedup -> OnboardCount
    contact_window(bytes):   Select -> Downlink -> GroundRecount -> Aggregate

``ingest`` may be called any number of times (orbital passes); each call
grants the day-fraction energy/byte entitlement for its tile slice and
runs the onboard stages under whatever energy remains, so budgets carry
across passes. ``contact_window`` drains pending segments FIFO through
the ground-side stages within one window's byte budget (default: the
accumulated entitlement of the pending segments). ``result()``
aggregates everything windowed so far into a
:class:`~repro.core.pipeline.PipelineResult`; ``finalize()`` first
flushes pending segments through a zero-byte window (onboard-accepted
counts still land — nothing is transmitted).

Selection logic is pluggable: ``PipelineConfig.method`` names a
registered :class:`~repro.core.policies.SelectionPolicy`; the executor
itself has no per-method branching. Stages are objects too — pass custom
``ingest_stages`` / ``contact_stages`` lists to compose new graphs
without touching this module.

``run_pipeline(frames, space, ground, pcfg)`` remains as a compatibility
wrapper over a one-window Mission and is bit-identical to the
pre-refactor monolith on both the engine and reference paths (enforced
by tests/test_mission.py against the frozen oracle in
:mod:`repro.core._legacy`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.dedup as dd
from repro.core import engine
from repro.core.cascade import count_tiles_batched, count_tiles_batched_ref
from repro.core.energy import (ByteLedger, EnergyLedger, detector_gflops,
                               max_tiles_within_budget)
from repro.core.metrics import cmae
from repro.core.pipeline import PipelineConfig, PipelineResult, budgets_for
from repro.core.policies import PolicyContext, Selection, get_policy
from repro.core.throttle import clamp_budget_bytes


@dataclass
class Segment:
    """One ingested frame batch (an orbital pass's tile slice) and the
    per-tile state the stages accumulate over it."""
    frames: list
    energy_grant_override: Optional[float] = None
    # Capture
    n: int = 0
    prep: Optional[engine.PreparedFrames] = None
    tiles_sp: object = None          # device (engine) or host (reference)
    tiles_gd: object = None
    true: Optional[np.ndarray] = None
    energy_granted_j: float = 0.0
    byte_entitlement: float = 0.0
    # RoiFilter / Dedup
    active: Optional[np.ndarray] = None
    rep_of: Optional[np.ndarray] = None
    # OnboardCount
    conf: Optional[np.ndarray] = None
    counts_sp: Optional[np.ndarray] = None
    processed: Optional[np.ndarray] = None
    n_processed: int = 0
    # contact-window stages
    selection: Optional[Selection] = None
    counts_gd: Optional[np.ndarray] = None
    bytes_requested: float = 0.0
    bytes_spent: float = 0.0
    pred: Optional[np.ndarray] = None
    # fault/degradation state (repro.core.faults): transmission attempts
    # that failed, the retry-with-backoff bookkeeping, and whether the
    # last attempt's downlink was discarded by the ground
    retries: int = 0
    eligible_round: int = 0
    requeued: bool = False
    corrupted: bool = False


@dataclass
class ContactWindow:
    """Mutable byte budget shared by the segments of one window."""
    budget: float
    remaining: float


@dataclass
class IngestReport:
    n_frames: int
    n_tiles: int
    tiles_processed_space: int
    energy_granted_j: float
    energy_remaining_j: float
    byte_entitlement: float


@dataclass
class WindowReport:
    budget_bytes: float
    bytes_requested: float
    bytes_spent: float
    tiles_downlinked: int
    segments: int


class Stage:
    """One node of the Mission stage graph.

    Ingest stages are called as ``run(mission, seg)``; contact stages as
    ``run(mission, seg, window)``. Subclass and insert into
    ``Mission(ingest_stages=..., contact_stages=...)`` to extend the
    graph without touching core.
    """

    name = "stage"

    def run(self, mission: "Mission", seg: Segment,
            window: Optional[ContactWindow] = None) -> None:
        raise NotImplementedError


class Capture(Stage):
    """Tile + resize + moments (engine path: one fused device program),
    collect ground truth, and grant this slice's day-fraction budgets.

    Split into :meth:`prepare` (tiles/truth) and :meth:`admit` (budget
    grant + per-tile state init) so the fleet engine can substitute a
    constellation-batched prepare (shared frame buckets across
    satellites) and still run the exact admission arithmetic.
    """

    name = "capture"

    def run(self, mission, seg, window=None):
        self.prepare(mission, seg)
        self.admit(mission, seg)

    def prepare(self, mission, seg):
        pcfg = mission.pcfg
        sp_cfg = mission.space[1]
        gd_cfg = mission.ground[1]
        if not seg.frames:
            seg.n = 0
            seg.true = np.zeros(0, np.float64)
            seg.tiles_sp = np.zeros(
                (0, sp_cfg.input_size, sp_cfg.input_size, 3), np.float32)
            seg.tiles_gd = np.zeros(
                (0, gd_cfg.input_size, gd_cfg.input_size, 3), np.float32)
        elif pcfg.use_engine:
            # skip the fused program's moments/ROI tail when this
            # policy consumes neither statistic (tiles are identical)
            with_stats = ((pcfg.use_roi and mission.policy.wants_roi)
                          or (pcfg.use_dedup and mission.policy.wants_dedup))
            prep = engine.prepare_frames(seg.frames, pcfg.tile_size,
                                         sp_cfg.input_size, gd_cfg.input_size,
                                         with_stats=with_stats)
            seg.prep = prep
            seg.tiles_sp, seg.tiles_gd = prep.tiles_sp, prep.tiles_gd
            seg.true, seg.n = prep.true, prep.n
        else:
            from repro.core import tiling
            from repro.data.synthetic import tile_counts

            def prep_tiles(img, input_size):
                t = tiling.tile_image(jnp.asarray(img), pcfg.tile_size)
                return np.asarray(tiling.resize_tiles(t, input_size))

            sp, gd, true = [], [], []
            for img, boxes, _classes in seg.frames:
                true.append(tile_counts(boxes, img.shape[0], pcfg.tile_size))
                sp.append(prep_tiles(img, sp_cfg.input_size))
                gd.append(prep_tiles(img, gd_cfg.input_size))
            seg.tiles_sp = np.concatenate(sp)
            seg.tiles_gd = np.concatenate(gd)
            seg.true = np.concatenate(true).astype(np.float64)
            seg.n = seg.tiles_sp.shape[0]

    def admit(self, mission, seg):
        energy = self.entitle(mission, seg)
        mission.ledger.grant(energy)
        mission.ledger.charge_capture(len(seg.frames))
        self.init_state(mission, seg)

    @staticmethod
    def entitle(mission, seg) -> float:
        """Record the slice's day-fraction entitlements on the segment;
        returns the energy grant (the fleet engine grants a whole
        constellation's entitlements in one vectorized ledger op)."""
        energy, byte_budget, _ = budgets_for(mission.pcfg, seg.n)
        if seg.energy_grant_override is not None:
            energy = float(seg.energy_grant_override)
        seg.energy_granted_j = energy
        seg.byte_entitlement = byte_budget
        return energy

    @staticmethod
    def init_state(mission, seg):
        mission.frames_seen += len(seg.frames)
        seg.active = np.ones(seg.n, bool)
        seg.rep_of = np.arange(seg.n)
        seg.conf = np.full(seg.n, -1.0)
        seg.counts_sp = np.zeros(seg.n)
        seg.processed = np.zeros(seg.n, bool)


class RoiFilter(Stage):
    """Drop low-variance tiles (background/cloud) when the policy uses ROI."""

    name = "roi_filter"

    def run(self, mission, seg, window=None):
        pcfg = mission.pcfg
        if not (pcfg.use_roi and mission.policy.wants_roi) or seg.n == 0:
            return
        if seg.prep is not None:
            # stddev moment from the fused program; np.asarray is free
            # for the host copy and materializes a still-deferred device
            # slice (engine defer_stats) exactly here
            raw_sd = np.asarray(seg.prep.roi_std)
        else:
            raw_sd = np.asarray(jnp.mean(jnp.std(jnp.asarray(seg.tiles_sp),
                                                 axis=(1, 2)), axis=-1))
        seg.active &= raw_sd > pcfg.roi_std_thresh


class Dedup(Stage):
    """Cluster active tiles into geographic contexts; representatives
    stand for their cluster downstream."""

    name = "dedup"

    def run(self, mission, seg, window=None):
        pcfg = mission.pcfg
        if (not (pcfg.use_dedup and mission.policy.wants_dedup)
                or seg.active.sum() <= 4):
            return
        k = pcfg.k_clusters or max(2, int(seg.active.sum()) // 2)
        idx_active = np.where(seg.active)[0]
        if seg.prep is not None:
            # bucketed gather of the fused program's moments: pad the index
            # vector so the gather (and the whole dedup) is shape-stable
            n_act = len(idx_active)
            idx_pad = np.zeros(dd.dedup_pad_size(n_act), np.int64)
            idx_pad[:n_act] = idx_active
            res = dd.dedup_from_moments(seg.prep.moments[jnp.asarray(idx_pad)],
                                        k, jax.random.PRNGKey(pcfg.seed),
                                        n=n_act)
        else:
            res = dd.dedup(jnp.asarray(seg.tiles_sp[idx_active]), k,
                           jax.random.PRNGKey(pcfg.seed))
        assign = np.asarray(res.assign)
        rep_local = np.asarray(res.rep_idx)
        seg.rep_of[idx_active] = idx_active[rep_local[assign]]
        mission.ledger.charge_aggregate(len(idx_active))


class OnboardCount(Stage):
    """Energy-capped onboard counting of representatives (the paper's
    '22% of observable images' bottleneck), charged to the ledger."""

    name = "onboard_count"

    def run(self, mission, seg, window=None):
        if not mission.policy.wants_onboard:
            return
        pcfg = mission.pcfg
        reps = np.unique(seg.rep_of[seg.active])
        cap = max_tiles_within_budget(mission.ledger.remaining * 0.95,
                                      mission.gflops_space, pcfg.hardware)
        process = reps[:cap] if len(reps) > cap else reps
        seg.n_processed = len(process)
        mission.ledger.charge_compute(seg.n_processed, mission.gflops_space,
                                      pcfg.hardware)
        counts_sp = np.zeros(seg.n)
        conf = np.full(seg.n, -1.0)
        if seg.n_processed:
            c, f = mission._count(mission.space, seg.tiles_sp, process)
            counts_sp[process] = c
            conf[process] = f
        seg.counts_sp = counts_sp[seg.rep_of]
        seg.conf = conf[seg.rep_of]
        seg.processed = np.isin(seg.rep_of, process) & seg.active


def policy_context(mission: "Mission", seg: Segment) -> PolicyContext:
    """Selection-time view of one segment — shared by the scalar Select
    stage and the batched ContactPlan executor's lane stacking, so both
    paths hand the policy bit-identical inputs."""
    return PolicyContext(n=seg.n, active=seg.active, rep_of=seg.rep_of,
                         conf=seg.conf, counts_sp=seg.counts_sp,
                         processed=seg.processed,
                         tile_bytes=mission.tile_bytes, pcfg=mission.pcfg)


class Select(Stage):
    """Delegate the accept/transmit/credit decision to the registered
    :class:`~repro.core.policies.SelectionPolicy`."""

    name = "select"

    def run(self, mission, seg, window=None):
        budget = window.remaining if window is not None else 0.0
        seg.selection = mission.policy.select(policy_context(mission, seg),
                                              budget)


class Downlink(Stage):
    """Charge the byte/radio ledgers; actual spend is capped by the
    window budget even when the policy is bandwidth-oblivious."""

    name = "downlink"

    def run(self, mission, seg, window=None):
        sel = seg.selection
        remaining = window.remaining if window is not None else 0.0
        spend = min(sel.bytes_requested, remaining)
        mission.ledger.charge_downlink(spend, mission.pcfg.bandwidth_mbps)
        if window is not None:
            # prefix-drain with the denormal/negative underflow clamp:
            # a remainder below one normal float of bytes is exact 0.0
            # (bit-exact no-op on any real budget — see throttle)
            window.remaining = clamp_budget_bytes(window.remaining - spend)
        seg.bytes_requested = sel.bytes_requested
        seg.bytes_spent = spend
        mission.bytes_ledger.requested += sel.bytes_requested
        mission.bytes_ledger.spent += spend


class GroundRecount(Stage):
    """Recount transmitted tiles with the deeper ground-tier counter."""

    name = "ground_recount"

    def run(self, mission, seg, window=None):
        counts_gd = np.zeros(seg.n)
        down = seg.selection.downlink
        if len(down):
            c, _ = mission._count(mission.ground, seg.tiles_gd, down)
            counts_gd[down] = c
        seg.counts_gd = counts_gd[seg.rep_of]


class Aggregate(Stage):
    """Fuse onboard and ground counts into per-tile predictions."""

    name = "aggregate"

    def run(self, mission, seg, window=None):
        sel = seg.selection
        pred = np.zeros(seg.n, np.float64)
        pred[sel.accept_space] = seg.counts_sp[sel.accept_space]
        pred[sel.ground_credit] = seg.counts_gd[sel.ground_credit]
        seg.pred = pred


def default_ingest_stages() -> List[Stage]:
    return [Capture(), RoiFilter(), Dedup(), OnboardCount()]


def default_contact_stages() -> List[Stage]:
    return [Select(), Downlink(), GroundRecount(), Aggregate()]


class Mission:
    """One satellite's pipeline session (see module docstring).

    Parameters
    ----------
    space, ground : (params, cfg) counter pairs (see ``get_counters``).
    pcfg : PipelineConfig — ``method`` names the registered selection
        policy; ``use_engine`` picks the device-resident vs reference
        execution of the counting stages.
    energy_cfgs : optional (space_cfg_full, ground_cfg_full) used to
        PRICE compute; defaults to the paper's full-scale Table II
        counters.
    ingest_stages, contact_stages : optional custom stage lists.
    """

    def __init__(self, space, ground, pcfg: PipelineConfig = None,
                 energy_cfgs=None, ingest_stages: List[Stage] = None,
                 contact_stages: List[Stage] = None):
        self.pcfg = pcfg if pcfg is not None else PipelineConfig()
        self.space = space
        self.ground = ground
        if energy_cfgs is None:
            from repro.configs import get_config
            energy_cfgs = (get_config("targetfuse-space"),
                           get_config("targetfuse-ground"))
        self.gflops_space = detector_gflops(energy_cfgs[0])
        self.policy = get_policy(self.pcfg.method)
        self.tile_bytes = float(self.pcfg.real_tile_px ** 2 * 3)
        self.ledger = EnergyLedger(budget_j=0.0)
        self.bytes_ledger = ByteLedger()
        self.frames_seen = 0
        self._finalized = False
        self.ingest_stages = (list(ingest_stages) if ingest_stages is not None
                              else default_ingest_stages())
        self.contact_stages = (list(contact_stages)
                               if contact_stages is not None
                               else default_contact_stages())
        self._segments: List[Segment] = []  # ingest order
        self._pending: List[Segment] = []   # awaiting a contact window

    # byte-ledger views (the stacked fleet ledger swaps in its own
    # bytes_ledger; these names stay stable for drivers/examples)
    @property
    def bytes_budget(self) -> float:
        """Bytes offered across contact windows."""
        return self.bytes_ledger.budget

    @property
    def bytes_requested(self) -> float:
        """Bytes the policies asked to transmit."""
        return self.bytes_ledger.requested

    @property
    def bytes_spent(self) -> float:
        """Bytes actually charged (<= budget)."""
        return self.bytes_ledger.spent

    # -- streaming API ------------------------------------------------------

    def ingest(self, frames, energy_budget_j: float = None, *,
               blackout: bool = False) -> IngestReport:
        """Run the onboard stages over one frame batch (an orbital pass).

        Grants the slice's day-fraction energy budget (or an explicit
        ``energy_budget_j``) to the persistent ledger first; onboard
        counting then runs under whatever energy remains mission-wide.

        ``blackout=True`` skips the pass entirely (a satellite brownout
        round injected by :mod:`repro.core.faults`): no segment is
        created, nothing is granted or charged — zero harvest, zero
        capture — and the mission's stream state is untouched.
        """
        if blackout:
            return IngestReport(
                n_frames=0, n_tiles=0, tiles_processed_space=0,
                energy_granted_j=0.0,
                energy_remaining_j=self.ledger.remaining,
                byte_entitlement=0.0)
        self._finalized = False
        seg = Segment(frames=list(frames),
                      energy_grant_override=energy_budget_j)
        for stage in self.ingest_stages:
            stage.run(self, seg)
        self._segments.append(seg)
        self._pending.append(seg)
        return IngestReport(
            n_frames=len(seg.frames), n_tiles=seg.n,
            tiles_processed_space=seg.n_processed,
            energy_granted_j=seg.energy_granted_j,
            energy_remaining_j=self.ledger.remaining,
            byte_entitlement=seg.byte_entitlement)

    def contact_window(self, budget_bytes: float = None) -> WindowReport:
        """Drain pending segments through the ground-side stages within
        one window's byte budget (default: the pending segments'
        accumulated entitlement). Segments are served FIFO; unspent
        budget flows to later segments in the same window.

        After :meth:`finalize` (and before any new ingest) this is a
        no-op: the mission is drained, so an offered window neither
        flushes anything nor inflates the byte-budget accounting."""
        if self._window_is_noop():
            return self._drained_window_report()
        segs, window = self._open_window(budget_bytes)
        for seg in segs:
            for stage in self.contact_stages:
                stage.run(self, seg, window)
        return self._window_report(window, segs)

    # window protocol pieces, shared with the fleet engine's batched
    # contact rounds so the drain/accounting rules live in ONE place

    def _window_is_noop(self) -> bool:
        return self._finalized and not self._pending

    @staticmethod
    def _drained_window_report() -> WindowReport:
        return WindowReport(budget_bytes=0.0, bytes_requested=0.0,
                            bytes_spent=0.0, tiles_downlinked=0, segments=0)

    def _open_window(self, budget_bytes, accrue: bool = True):
        """Pop the pending segments and accrue one window's byte budget
        (default: the pending segments' accumulated entitlement).

        ``accrue=False`` skips the byte-ledger accrual: the batched
        ContactPlan executor opens a whole round's windows first and
        accrues every lane in one vectorized
        :meth:`~repro.core.energy.FleetLedger.accrue_window_budgets` op
        (per-lane addition order unchanged — see that method)."""
        segs, self._pending = self._pending, []
        if budget_bytes is None:
            # re-queued segments (failed transmissions awaiting retry)
            # accrued their entitlement in their FIRST window; offering
            # it again would double-credit the byte budget
            budget_bytes = sum(s.byte_entitlement for s in segs
                               if not s.requeued)
        # denormal/negative budgets clamp to exact 0.0 before they can
        # accrue to the ledger or leak into the drain
        budget_bytes = clamp_budget_bytes(budget_bytes)
        window = ContactWindow(budget=budget_bytes,
                               remaining=budget_bytes)
        if accrue:
            self.bytes_ledger.budget += window.budget
        return segs, window

    @staticmethod
    def _window_report(window: ContactWindow, segs) -> WindowReport:
        return WindowReport(
            budget_bytes=window.budget,
            bytes_requested=sum(s.bytes_requested for s in segs),
            bytes_spent=sum(s.bytes_spent for s in segs),
            tiles_downlinked=sum(len(s.selection.downlink) for s in segs),
            segments=len(segs))

    # -- one-shot API -------------------------------------------------------

    def run(self, frames) -> PipelineResult:
        """Single ingest + one full-entitlement contact window — the
        ``run_pipeline`` compatibility semantics."""
        self.ingest(frames)
        self.contact_window()
        return self.result()

    def finalize(self) -> PipelineResult:
        """Flush pending segments through a zero-byte window (onboard
        results land, nothing transmits), then aggregate.

        Idempotent: repeated calls (and :meth:`contact_window` calls in
        between) are no-ops until a new :meth:`ingest` resumes the
        stream."""
        if self._pending:
            self.contact_window(0.0)
        self._finalized = True
        return self.result()

    def result(self) -> PipelineResult:
        """Aggregate over every segment that has been through a contact
        window. Call :meth:`finalize` to include un-windowed segments."""
        done = [s for s in self._segments if s.pred is not None]
        if done:
            pred = np.concatenate([s.pred for s in done])
            true = np.concatenate([s.true for s in done])
        else:
            pred = np.zeros(0, np.float64)
            true = np.zeros(0, np.float64)
        return PipelineResult(
            cmae=cmae(pred, true),
            total_true=float(true.sum()),
            total_pred=float(pred.sum()),
            bytes_downlinked=float(self.bytes_requested),
            bytes_budget=float(self.bytes_budget),
            tiles_processed_space=int(sum(s.n_processed for s in done)),
            tiles_downlinked=int(sum(len(s.selection.downlink) for s in done
                                     if s.selection is not None)),
            tiles_total=int(sum(s.n for s in done)),
            energy_spent_j=float(self.ledger.spent),
            energy_budget_j=float(self.ledger.budget_j),
            per_tile_pred=pred,
            per_tile_true=true,
        )

    @property
    def pending_segments(self) -> int:
        return len(self._pending)

    # -- shared helpers -----------------------------------------------------

    def _count(self, counter, tiles, idx):
        """Count ``tiles[idx]``: device gather + fixed-shape batches on
        the engine path, host slice + seed batching on the reference
        path."""
        params, cfg = counter
        if self.pcfg.use_engine:
            return count_tiles_batched(params, cfg, tiles, idx=idx,
                                       score_thresh=self.pcfg.score_thresh)
        return count_tiles_batched_ref(params, cfg, tiles[idx],
                                       score_thresh=self.pcfg.score_thresh)
