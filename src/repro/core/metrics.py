"""Counting metrics: CMAE (the paper's headline metric) + a simplified
mAP@0.5 used by the tile-size study (Fig. 4).
"""
from __future__ import annotations

import numpy as np

from repro.kernels import ops as kops


def cmae(pred_counts, true_counts) -> float:
    """Count Mean Absolute Error: sum|y_i - g_i| / sum g_i (paper §IV-A6)."""
    y = np.asarray(pred_counts, dtype=np.float64)
    g = np.asarray(true_counts, dtype=np.float64)
    denom = max(g.sum(), 1e-9)
    return float(np.abs(y - g).sum() / denom)


def ap50(pred_boxes, pred_scores, gt_boxes, iou_thresh: float = 0.5) -> float:
    """Average precision at IoU 0.5 for one class over a list of images.

    pred_boxes: list of (Ni,4); pred_scores: list of (Ni,); gt_boxes: list
    of (Mi,4). Greedy score-ordered matching, 101-point interpolation.
    """
    rows = []  # (score, is_tp)
    n_gt = 0
    for pb, ps, gb in zip(pred_boxes, pred_scores, gt_boxes):
        pb, ps, gb = np.asarray(pb), np.asarray(ps), np.asarray(gb)
        n_gt += len(gb)
        if len(pb) == 0:
            continue
        order = np.argsort(-ps)
        pb, ps = pb[order], ps[order]
        matched = np.zeros(len(gb), bool)
        if len(gb):
            iou = np.asarray(kops.iou_matrix(pb, gb))
        for i in range(len(pb)):
            tp = False
            if len(gb):
                j = int(np.argmax(iou[i] * ~matched))
                if iou[i, j] >= iou_thresh and not matched[j]:
                    matched[j] = True
                    tp = True
            rows.append((ps[i], tp))
    if not rows or n_gt == 0:
        return 0.0
    rows.sort(key=lambda r: -r[0])
    tps = np.array([r[1] for r in rows], dtype=np.float64)
    cum_tp = np.cumsum(tps)
    precision = cum_tp / (np.arange(len(rows)) + 1)
    recall = cum_tp / n_gt
    # 101-point interpolated AP
    ap = 0.0
    for r in np.linspace(0, 1, 101):
        p = precision[recall >= r]
        ap += (p.max() if len(p) else 0.0) / 101
    return float(ap)
