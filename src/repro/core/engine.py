"""Device-resident batched pipeline engine (run_pipeline stages 0-2).

The seed pipeline orchestrated its hot path from the host: a Python
loop tiled and resized each frame separately, the ROI filter launched an
ad-hoc ``jnp.std`` round-trip over all tiles, dedup re-read every tile
to recompute the color moments, and every distinct counting batch shape
triggered a fresh XLA compile. This module replaces all of that with a
small number of shape-stable jit programs:

* ``_frame_program`` — one fused compiled call that tiles a fixed-size
  bucket of frames, resizes to BOTH counter input sizes, and computes
  ``tile_moments`` once. The moments feed the ROI variance filter (the
  stddev moment IS the ROI statistic) and are reused by dedup
  (:func:`repro.core.dedup.dedup_from_moments`) — the tiles are read
  exactly once.
* frame batches are padded to ``frame_bucket`` so the program compiles
  per distinct frame *resolution*, never per frame *count*.
* tile arrays stay on device (`jnp`): downstream gathers
  (``tiles[process]``) and the fixed-shape ``count_tiles_batched``
  consume them without host round-trips; results transfer once.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiling
from repro.core.dedup import bucket_size
from repro.kernels import ops as kops

FRAME_BUCKET = 4  # frames per fused-program invocation (padded up)


@dataclass
class PreparedFrames:
    """Stage-0/1 output: device-resident tiles + per-tile statistics.

    Device arrays are zero-padded to a power-of-two tile bucket
    (rows past ``n`` are zero tiles), so every downstream gather and
    counting program compiles once per bucket instead of once per
    workload size. Host arrays (`roi_std`, `true`) hold the ``n`` real
    tiles only.
    """
    tiles_sp: jnp.ndarray   # (N_pad, s_sp, s_sp, C) space-tier input, device
    tiles_gd: jnp.ndarray   # (N_pad, s_gd, s_gd, C) ground-tier input, device
    moments: jnp.ndarray    # (N_pad, 3C) raw color moments, device
    roi_std: np.ndarray     # (n,) mean per-channel stddev (host, for masking)
    true: np.ndarray        # (n,) ground-truth per-tile counts
    n: int                  # real tile count (rows [n:] are padding)


@partial(jax.jit, static_argnames=("tile_size", "sp_size", "gd_size"))
def _frame_program(imgs, tile_size: int, sp_size: int, gd_size: int):
    """(B, H, W, C) frames -> (tiles_sp, tiles_gd, moments, roi_std).

    Fused tile -> resize(space) -> resize(ground) -> tile_moments in one
    compiled program; ``tiling.tile_image`` (vmapped over the frame
    batch) stays the single definition of tile order — row-major within
    each frame, frames in batch order.
    """
    b, _, _, c = imgs.shape
    t = jax.vmap(lambda im: tiling.tile_image(im, tile_size))(imgs)
    t = t.reshape(b * t.shape[1], tile_size, tile_size, c)
    tiles_sp = tiling.resize_tiles(t, sp_size)
    tiles_gd = tiling.resize_tiles(t, gd_size)
    moments = kops.tile_moments(tiles_sp)
    roi_std = jnp.mean(moments[:, c:2 * c], axis=-1)
    return tiles_sp, tiles_gd, moments, roi_std


def _bucketed_chunks(imgs, shape, tile_size: int, sp_size: int, gd_size: int,
                     frame_bucket: int):
    """Zero-pad a same-resolution image list to whole ``frame_bucket``s
    and run the fused program chunk by chunk (the single definition of
    bucket rounding/fill, shared by every capture entry point)."""
    nb = -(-len(imgs) // frame_bucket) * frame_bucket
    arr = np.zeros((nb, *shape), np.float32)
    for j, img in enumerate(imgs):
        arr[j] = img
    return [_frame_program(jnp.asarray(arr[c0:c0 + frame_bucket]),
                           tile_size, sp_size, gd_size)
            for c0 in range(0, nb, frame_bucket)]


def _per_frame_pieces(frames, tile_size: int, sp_size: int, gd_size: int,
                      frame_bucket: int):
    """Run the fused frame program grouped by resolution; return the
    (tiles_sp, tiles_gd, moments, roi_std) piece of EVERY frame, in
    input order. Each frame's piece is a pure function of that frame
    alone (the program is per-sample), so any regrouping of frames into
    buckets yields bit-identical pieces."""
    groups: dict = {}
    for i, (img, _, _) in enumerate(frames):
        groups.setdefault(np.asarray(img).shape, []).append(i)
    per_frame = [None] * len(frames)
    for shape, idxs in groups.items():
        chunks = _bucketed_chunks([frames[i][0] for i in idxs], shape,
                                  tile_size, sp_size, gd_size, frame_bucket)
        ntile = chunks[0][0].shape[0] // frame_bucket
        for j, i in enumerate(idxs):
            ck, off = chunks[j // frame_bucket], (j % frame_bucket) * ntile
            per_frame[i] = tuple(a[off:off + ntile] for a in ck)
    return per_frame


def _assemble(parts, frames, tile_size: int, roi_std: np.ndarray = None,
              n: int = None) -> PreparedFrames:
    """Per-frame pieces (input order) -> one bucket-padded PreparedFrames.

    ``roi_std``: optional precomputed host copy of the (n,) ROI stddev
    rows (the multi-workload path transfers the fleet's roi_std in one
    device->host copy and hands out slices). ``n``: explicit real tile
    count when the pieces carry trailing pad-frame rows (the
    single-resolution fast paths pass whole program chunks)."""
    from repro.data.synthetic import tile_counts

    if n is None:
        n = sum(p[0].shape[0] for p in parts)

    def cat(j):
        return parts[0][j] if len(parts) == 1 else jnp.concatenate(
            [p[j] for p in parts])

    n_pad = bucket_size(n)

    def pad(a):
        if a.shape[0] == n_pad:
            return a
        if a.shape[0] > n_pad:
            return a[:n_pad]
        return jnp.concatenate(
            [a, jnp.zeros((n_pad - a.shape[0], *a.shape[1:]), a.dtype)])

    tiles_sp = pad(cat(0))
    tiles_gd = pad(cat(1))
    moments = pad(cat(2))
    if roi_std is None:
        roi_std = np.asarray(pad(cat(3)))[:n]
    true = np.concatenate([
        tile_counts(boxes, np.asarray(img).shape[0], tile_size)
        for img, boxes, _ in frames
    ]).astype(np.float64)
    return PreparedFrames(tiles_sp, tiles_gd, moments, roi_std, true, n)


def _empty_prepared(sp_size: int, gd_size: int) -> PreparedFrames:
    n_pad = bucket_size(0)
    return PreparedFrames(
        tiles_sp=jnp.zeros((n_pad, sp_size, sp_size, 3), jnp.float32),
        tiles_gd=jnp.zeros((n_pad, gd_size, gd_size, 3), jnp.float32),
        moments=jnp.zeros((n_pad, 9), jnp.float32),
        roi_std=np.zeros(0), true=np.zeros(0, np.float64), n=0)


def prepare_frames_multi(workloads, tile_size: int, sp_size: int,
                         gd_size: int,
                         frame_bucket: int = FRAME_BUCKET):
    """Constellation-batched capture: N independent frame workloads (one
    per satellite) flow through SHARED frame buckets of the fused
    program, then split back into one :class:`PreparedFrames` per
    workload.

    Per-workload outputs are bit-identical (real rows) to calling
    :func:`prepare_frames` on each workload alone — the fused program is
    per-sample, so bucket composition never perturbs a frame's tiles —
    but the padded-bucket cost is paid once across the fleet instead of
    once per satellite: 8 satellites with 2 frames each run 4 full
    buckets instead of 8 half-empty ones.
    """
    flat = [f for w in workloads for f in w]
    if not flat:
        return [_empty_prepared(sp_size, gd_size) for _ in workloads]

    shapes = {np.asarray(img).shape for img, _, _ in flat}
    if len(shapes) == 1:
        # common case (one frame resolution fleet-wide): run the shared
        # buckets once and hand each workload a contiguous slice of the
        # chunk outputs — no per-frame device slicing
        (shape,) = shapes
        chunks = _bucketed_chunks([img for img, _, _ in flat], shape,
                                  tile_size, sp_size, gd_size, frame_bucket)
        ntile = chunks[0][0].shape[0] // frame_bucket
        if len(chunks) == 1:
            cat = list(chunks[0])
        else:
            cat = [jnp.concatenate([ck[j] for ck in chunks])
                   for j in range(len(chunks[0]))]
        roi_all = np.asarray(cat[3])  # ONE device->host copy for the fleet
        out, pos = [], 0
        for w in workloads:
            if not w:
                out.append(_empty_prepared(sp_size, gd_size))
                continue
            parts = [tuple(a[pos * ntile:(pos + len(w)) * ntile] for a in cat)]
            roi = roi_all[pos * ntile:(pos + len(w)) * ntile]
            pos += len(w)
            out.append(_assemble(parts, w, tile_size, roi_std=roi))
        return out

    per_frame = _per_frame_pieces(flat, tile_size, sp_size, gd_size,
                                  frame_bucket)
    out, pos = [], 0
    for w in workloads:
        if not w:
            out.append(_empty_prepared(sp_size, gd_size))
            continue
        parts = per_frame[pos:pos + len(w)]
        pos += len(w)
        out.append(_assemble(parts, w, tile_size))
    return out


def prepare_frames(frames, tile_size: int, sp_size: int, gd_size: int,
                   frame_bucket: int = FRAME_BUCKET) -> PreparedFrames:
    """Run the fused frame program over a workload of (img, boxes, classes).

    Frames are grouped by resolution and processed in fixed-size buckets
    (zero-padded), so the number of compiled programs is bounded by the
    number of distinct frame shapes — not by workload size. Ground-truth
    counts are collected host-side alongside.
    """
    if not frames:
        return _empty_prepared(sp_size, gd_size)

    groups: dict = {}
    for i, (img, _, _) in enumerate(frames):
        groups.setdefault(np.asarray(img).shape, []).append(i)

    if len(groups) == 1:
        # common case (one frame resolution): chunk outputs are already in
        # frame order — pad frames land at the tail and fold into
        # _assemble's tile padding, so no per-frame reassembly is needed
        (shape, idxs), = groups.items()
        parts = _bucketed_chunks([frames[i][0] for i in idxs], shape,
                                 tile_size, sp_size, gd_size, frame_bucket)
        ntile = parts[0][0].shape[0] // frame_bucket
        return _assemble(parts, frames, tile_size, n=ntile * len(idxs))

    parts = _per_frame_pieces(frames, tile_size, sp_size, gd_size,
                              frame_bucket)
    return _assemble(parts, frames, tile_size)
