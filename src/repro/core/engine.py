"""Device-resident batched pipeline engine (run_pipeline stages 0-2).

The seed pipeline orchestrated its hot path from the host: a Python
loop tiled and resized each frame separately, the ROI filter launched an
ad-hoc ``jnp.std`` round-trip over all tiles, dedup re-read every tile
to recompute the color moments, and every distinct counting batch shape
triggered a fresh XLA compile. This module replaces all of that with a
small number of shape-stable jit programs:

* ``_frame_program`` — one fused compiled call that tiles a fixed-size
  bucket of frames, resizes to BOTH counter input sizes, and computes
  ``tile_moments`` once. The moments feed the ROI variance filter (the
  stddev moment IS the ROI statistic) and are reused by dedup
  (:func:`repro.core.dedup.dedup_from_moments`) — the tiles are read
  exactly once.
* frame batches are padded to ``frame_bucket`` so the program compiles
  per distinct frame *resolution*, never per frame *count*.
* tile arrays stay on device (`jnp`): downstream gathers
  (``tiles[process]``) and the fixed-shape ``count_tiles_batched``
  consume them without host round-trips; results transfer once.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiling
from repro.core.dedup import bucket_size
from repro.kernels import ops as kops

FRAME_BUCKET = 4  # frames per fused-program invocation (padded up)


@dataclass
class PreparedFrames:
    """Stage-0/1 output: device-resident tiles + per-tile statistics.

    Device arrays are zero-padded to a power-of-two tile bucket
    (rows past ``n`` are zero tiles), so every downstream gather and
    counting program compiles once per bucket instead of once per
    workload size. Host arrays (`roi_std`, `true`) hold the ``n`` real
    tiles only. ``moments``/``roi_std`` are ``None`` when prepared with
    ``with_stats=False`` (the policy uses neither ROI nor dedup, so the
    fused program skips the statistics entirely).
    """
    tiles_sp: jnp.ndarray   # (N_pad, s_sp, s_sp, C) space-tier input, device
    tiles_gd: jnp.ndarray   # (N_pad, s_gd, s_gd, C) ground-tier input, device
    moments: object         # (N_pad, 3C) raw color moments, device (or None)
    roi_std: object         # (n,) mean per-channel stddev, host (or None)
    true: np.ndarray        # (n,) ground-truth per-tile counts
    n: int                  # real tile count (rows [n:] are padding)


def _frame_program_body(imgs, tile_size: int, sp_size: int, gd_size: int,
                        with_stats: bool = True):
    """(B, H, W, C) frames -> (tiles_sp, tiles_gd[, moments, roi_std]).

    Fused tile -> resize(space) -> resize(ground) -> tile_moments in one
    compiled program; ``tiling.tile_image`` (vmapped over the frame
    batch) stays the single definition of tile order — row-major within
    each frame, frames in batch order. ``with_stats=False`` (policies
    that use neither the ROI filter nor dedup) compiles a variant
    without the statistics tail — the tile values are identical, the
    moments pass simply never runs.
    """
    b, _, _, c = imgs.shape
    t = jax.vmap(lambda im: tiling.tile_image(im, tile_size))(imgs)
    t = t.reshape(b * t.shape[1], tile_size, tile_size, c)
    tiles_sp = tiling.resize_tiles(t, sp_size)
    tiles_gd = tiling.resize_tiles(t, gd_size)
    if not with_stats:
        return tiles_sp, tiles_gd
    moments = kops.tile_moments(tiles_sp)
    roi_std = jnp.mean(moments[:, c:2 * c], axis=-1)
    return tiles_sp, tiles_gd, moments, roi_std


_frame_program = partial(jax.jit, static_argnames=(
    "tile_size", "sp_size", "gd_size", "with_stats"))(_frame_program_body)


@partial(jax.jit, static_argnames=("tile_size", "sp_size", "gd_size",
                                   "with_stats"))
def _frame_program_multi(chunks, tile_size: int, sp_size: int, gd_size: int,
                         with_stats: bool = True):
    """The fused frame program vmapped over a stacked chunk axis.

    ``chunks`` is (n_chunks, frame_bucket, H, W, C); with the chunk axis
    placed along a ``sats`` device mesh, each device captures its share
    of the fleet's frame buckets in parallel. The body is per-sample, so
    per-chunk outputs are bit-equal to looping :func:`_frame_program`.
    """
    return jax.vmap(lambda imgs: _frame_program_body(
        imgs, tile_size, sp_size, gd_size, with_stats))(chunks)


def _bucketed_chunks(imgs, shape, tile_size: int, sp_size: int, gd_size: int,
                     frame_bucket: int, sharding=None,
                     with_stats: bool = True):
    """Zero-pad a same-resolution image list to whole ``frame_bucket``s
    and run the fused program chunk by chunk (the single definition of
    bucket rounding/fill, shared by every capture entry point).

    With an on-mesh :class:`~repro.core.fleet_sharding.FleetSharding`,
    the chunks are stacked, lane-padded to a device multiple, and run as
    ONE sharded :func:`_frame_program_multi` call — capture parallelizes
    across the mesh instead of queueing per-chunk on one device.
    """
    from repro.core.fleet_sharding import ctx
    sh = ctx(sharding)
    nb = -(-len(imgs) // frame_bucket) * frame_bucket
    arr = np.zeros((nb, *shape), np.float32)
    for j, img in enumerate(imgs):
        arr[j] = img
    n_chunks = nb // frame_bucket
    if sh.on_mesh and n_chunks > 1:
        # pad the chunk axis to a power-of-two bucket x device multiple:
        # chunk counts vary per round, and the stacked program compiles
        # per chunk count — bucketing bounds the program count
        n_stack = sh.pad(bucket_size(n_chunks, 1))
        chunks_arr = np.zeros((n_stack, frame_bucket, *shape), np.float32)
        chunks_arr[:n_chunks] = arr.reshape(n_chunks, frame_bucket, *shape)
        stacked = sh.device_put(jnp.asarray(chunks_arr))
        outs = _frame_program_multi(stacked, tile_size, sp_size, gd_size,
                                    with_stats)
        return [tuple(o[i] for o in outs) for i in range(n_chunks)]
    return [_frame_program(jnp.asarray(arr[c0:c0 + frame_bucket]),
                           tile_size, sp_size, gd_size, with_stats)
            for c0 in range(0, nb, frame_bucket)]


def _per_frame_pieces(frames, tile_size: int, sp_size: int, gd_size: int,
                      frame_bucket: int, sharding=None,
                      with_stats: bool = True):
    """Run the fused frame program grouped by resolution; return the
    (tiles_sp, tiles_gd[, moments, roi_std]) piece of EVERY frame, in
    input order. Each frame's piece is a pure function of that frame
    alone (the program is per-sample), so any regrouping of frames into
    buckets yields bit-identical pieces."""
    groups: dict = {}
    for i, (img, _, _) in enumerate(frames):
        # np.shape reads the .shape attribute — np.asarray(img).shape
        # would materialize a full host copy of a device-resident frame
        # just to group it
        groups.setdefault(np.shape(img), []).append(i)
    per_frame = [None] * len(frames)
    for shape, idxs in groups.items():
        chunks = _bucketed_chunks([frames[i][0] for i in idxs], shape,
                                  tile_size, sp_size, gd_size, frame_bucket,
                                  sharding=sharding, with_stats=with_stats)
        ntile = chunks[0][0].shape[0] // frame_bucket
        for j, i in enumerate(idxs):
            ck, off = chunks[j // frame_bucket], (j % frame_bucket) * ntile
            per_frame[i] = tuple(a[off:off + ntile] for a in ck)
    return per_frame


def _assemble(parts, frames, tile_size: int, roi_std=None,
              n: int = None, defer_stats: bool = False) -> PreparedFrames:
    """Per-frame pieces (input order) -> one bucket-padded PreparedFrames.

    ``roi_std``: optional precomputed (n,) ROI stddev rows (the
    multi-workload path transfers the fleet's roi_std in one
    device->host copy and hands out slices — or device slices under
    ``defer_stats``). ``n``: explicit real tile count when the pieces
    carry trailing pad-frame rows (the single-resolution fast paths pass
    whole program chunks). ``defer_stats=True`` leaves ``roi_std`` a
    device array (a lazy slice of the fused program's output) instead of
    forcing the device->host sync here — the caller fetches it at its
    own round boundary, or never (policies that don't use ROI)."""
    from repro.data.synthetic import tile_counts

    if n is None:
        n = sum(p[0].shape[0] for p in parts)

    def cat(j):
        return parts[0][j] if len(parts) == 1 else jnp.concatenate(
            [p[j] for p in parts])

    n_pad = bucket_size(n)

    def pad(a):
        if a.shape[0] == n_pad:
            return a
        if a.shape[0] > n_pad:
            return a[:n_pad]
        return jnp.concatenate(
            [a, jnp.zeros((n_pad - a.shape[0], *a.shape[1:]), a.dtype)])

    with_stats = len(parts[0]) == 4
    tiles_sp = pad(cat(0))
    tiles_gd = pad(cat(1))
    moments = pad(cat(2)) if with_stats else None
    if roi_std is None and with_stats:
        rs = pad(cat(3))[:n]
        # analysis: waive(host-sync): the per-workload roi_std copy is the
        # designed transfer point; defer_stats keeps it lazy on device
        roi_std = rs if defer_stats else np.asarray(rs)
    true = np.concatenate([
        tile_counts(boxes, np.shape(img)[0], tile_size)
        for img, boxes, _ in frames
    ]).astype(np.float64)
    return PreparedFrames(tiles_sp, tiles_gd, moments, roi_std, true, n)


def _empty_prepared(sp_size: int, gd_size: int,
                    with_stats: bool = True) -> PreparedFrames:
    n_pad = bucket_size(0)
    return PreparedFrames(
        tiles_sp=jnp.zeros((n_pad, sp_size, sp_size, 3), jnp.float32),
        tiles_gd=jnp.zeros((n_pad, gd_size, gd_size, 3), jnp.float32),
        moments=jnp.zeros((n_pad, 9), jnp.float32) if with_stats else None,
        roi_std=np.zeros(0) if with_stats else None,
        true=np.zeros(0, np.float64), n=0)


def prepare_frames_multi(workloads, tile_size: int, sp_size: int,
                         gd_size: int,
                         frame_bucket: int = FRAME_BUCKET, sharding=None,
                         with_stats: bool = True,
                         defer_stats: bool = False):
    """Constellation-batched capture: N independent frame workloads (one
    per satellite) flow through SHARED frame buckets of the fused
    program, then split back into one :class:`PreparedFrames` per
    workload.

    Per-workload outputs are bit-identical (real rows) to calling
    :func:`prepare_frames` on each workload alone — the fused program is
    per-sample, so bucket composition never perturbs a frame's tiles —
    but the padded-bucket cost is paid once across the fleet instead of
    once per satellite: 8 satellites with 2 frames each run 4 full
    buckets instead of 8 half-empty ones. ``sharding``: optional
    :class:`~repro.core.fleet_sharding.FleetSharding`; on-mesh, the
    shared frame buckets are placed along the ``sats`` mesh axis and
    captured in one sharded program call per resolution.

    ``defer_stats=True`` (the fleet's ``ingest_overlap`` path) skips the
    fleet-wide ``roi_std`` device->host copy: each workload's
    ``PreparedFrames.roi_std`` is then a *device* slice of the fused
    program's output (values bit-identical), and the caller materializes
    it lazily — only for satellites whose policy reads it, and only when
    it reaches its round's resolution boundary.
    """
    flat = [f for w in workloads for f in w]
    if not flat:
        return [_empty_prepared(sp_size, gd_size, with_stats)
                for _ in workloads]

    shapes = {np.shape(img) for img, _, _ in flat}
    if len(shapes) == 1:
        # common case (one frame resolution fleet-wide): run the shared
        # buckets once and hand each workload a contiguous slice of the
        # chunk outputs — no per-frame device slicing
        (shape,) = shapes
        chunks = _bucketed_chunks([img for img, _, _ in flat], shape,
                                  tile_size, sp_size, gd_size, frame_bucket,
                                  sharding=sharding, with_stats=with_stats)
        ntile = chunks[0][0].shape[0] // frame_bucket
        if len(chunks) == 1:
            cat = list(chunks[0])
        else:
            cat = [jnp.concatenate([ck[j] for ck in chunks])
                   for j in range(len(chunks[0]))]
        # ONE device->host copy of the fleet's ROI stats — or, under
        # defer_stats, no copy at all: workloads get lazy device slices
        roi_all = cat[3] if with_stats else None
        if with_stats and not defer_stats:
            # analysis: waive(host-sync): ONE fleet-wide ROI-stat copy per
            # ingest (see comment above); defer_stats elides it entirely
            roi_all = np.asarray(roi_all)
        out, pos = [], 0
        for w in workloads:
            if not w:
                out.append(_empty_prepared(sp_size, gd_size, with_stats))
                continue
            parts = [tuple(a[pos * ntile:(pos + len(w)) * ntile] for a in cat)]
            roi = (roi_all[pos * ntile:(pos + len(w)) * ntile]
                   if with_stats else None)
            pos += len(w)
            out.append(_assemble(parts, w, tile_size, roi_std=roi))
        return out

    per_frame = _per_frame_pieces(flat, tile_size, sp_size, gd_size,
                                  frame_bucket, sharding=sharding,
                                  with_stats=with_stats)
    out, pos = [], 0
    for w in workloads:
        if not w:
            out.append(_empty_prepared(sp_size, gd_size, with_stats))
            continue
        parts = per_frame[pos:pos + len(w)]
        pos += len(w)
        out.append(_assemble(parts, w, tile_size, defer_stats=defer_stats))
    return out


def prepare_frames(frames, tile_size: int, sp_size: int, gd_size: int,
                   frame_bucket: int = FRAME_BUCKET,
                   with_stats: bool = True) -> PreparedFrames:
    """Run the fused frame program over a workload of (img, boxes, classes).

    Frames are grouped by resolution and processed in fixed-size buckets
    (zero-padded), so the number of compiled programs is bounded by the
    number of distinct frame shapes — not by workload size. Ground-truth
    counts are collected host-side alongside. ``with_stats=False`` skips
    the moments/ROI statistics (policies that use neither); tiles are
    bit-identical either way.
    """
    if not frames:
        return _empty_prepared(sp_size, gd_size, with_stats)

    groups: dict = {}
    for i, (img, _, _) in enumerate(frames):
        groups.setdefault(np.shape(img), []).append(i)

    if len(groups) == 1:
        # common case (one frame resolution): chunk outputs are already in
        # frame order — pad frames land at the tail and fold into
        # _assemble's tile padding, so no per-frame reassembly is needed
        (shape, idxs), = groups.items()
        parts = _bucketed_chunks([frames[i][0] for i in idxs], shape,
                                 tile_size, sp_size, gd_size, frame_bucket,
                                 with_stats=with_stats)
        ntile = parts[0][0].shape[0] // frame_bucket
        return _assemble(parts, frames, tile_size, n=ntile * len(idxs))

    parts = _per_frame_pieces(frames, tile_size, sp_size, gd_size,
                              frame_bucket, with_stats=with_stats)
    return _assemble(parts, frames, tile_size)
