"""Energy budget model (paper §III-A-1), calibrated to published numbers.

Real-world anchors from the paper / Baoyun satellite:
  - daily solar harvest <= 260 KJ; ~150 KJ allocable to computing
  - compute ~50% of in-operation energy; E_com + E_down > 60% of total
  - COTS tiers: Raspberry Pi 4B (6 W) and Atlas 200 DK (13 W);
    RPi processes ~2x more tiles per joule (Fig. 8: '~50% energy saved')
  - measured downlink 30-50 Mbps; contact window <= ~6 min
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class DeviceProfile:
    name: str
    power_w: float
    effective_gflops: float  # sustained DNN throughput

    @property
    def joules_per_gflop(self) -> float:
        return self.power_w / self.effective_gflops


# Calibrated so RPI4 ~ 0.83 GFLOPS/W vs Atlas ~ 0.42 GFLOPS/W (the paper's
# observed ~2x J/tile gap), with absolute rates in the RPi4-for-CNN range.
RPI4 = DeviceProfile("rpi4", power_w=6.0, effective_gflops=5.0)
ATLAS = DeviceProfile("atlas", power_w=13.0, effective_gflops=5.4)
PROFILES = {p.name: p for p in (RPI4, ATLAS)}

DAILY_HARVEST_J = 260_000.0
DEFAULT_COMPUTE_BUDGET_J = 150_000.0
RADIO_POWER_W = 8.0


@dataclass
class EnergyLedger:
    """Tracks the four activity classes of §III-A-1."""

    budget_j: float
    e_cap: float = 0.0
    e_com: float = 0.0
    e_agg: float = 0.0
    e_down: float = 0.0

    @property
    def spent(self) -> float:
        return self.e_cap + self.e_com + self.e_agg + self.e_down

    @property
    def remaining(self) -> float:
        return max(self.budget_j - self.spent, 0.0)

    def grant(self, j: float):
        """Add harvested energy to the budget (streaming Missions grant
        each ingested slice's day-fraction entitlement incrementally)."""
        self.budget_j += j

    def charge_capture(self, n_images: int, j_per_image: float = 0.05):
        self.e_cap += n_images * j_per_image

    def charge_compute(self, n_tiles: int, gflops_per_tile: float,
                       profile: DeviceProfile):
        self.e_com += n_tiles * gflops_per_tile * profile.joules_per_gflop

    def charge_aggregate(self, n_ops: int = 1000, j_per_op: float = 1e-6):
        self.e_agg += n_ops * j_per_op

    def charge_downlink(self, n_bytes: float, bandwidth_mbps: float):
        seconds = n_bytes * 8.0 / (bandwidth_mbps * 1e6)
        self.e_down += seconds * RADIO_POWER_W


def max_tiles_within_budget(budget_j: float, gflops_per_tile: float,
                            profile: DeviceProfile) -> int:
    """How many tiles the onboard counter may process (computational
    bottleneck: the paper's '22% of observable images' phenomenon)."""
    if gflops_per_tile <= 0:
        return 0
    return int(budget_j / (gflops_per_tile * profile.joules_per_gflop))


def detector_gflops(cfg, tile_px: int = None) -> float:
    """Rough fwd FLOPs of a detector counter on one tile (GFLOP).

    Conv stages at stride-2: sum over stages of H*W*K*K*Cin*Cout*2.
    """
    px = tile_px or cfg.input_size
    total = 0.0
    h = px
    c_in = 3
    total += h * h * 9 * c_in * cfg.widths[0] * 2
    c_in = cfg.widths[0]
    for w in cfg.widths[1:]:
        h = h // 2
        total += h * h * 9 * c_in * w * 2
        total += (cfg.n_blocks_per_stage - 1) * h * h * 9 * w * w * 2
        c_in = w
    total += h * h * c_in * cfg.n_anchors * (5 + cfg.n_classes) * 2
    return total / 1e9
