"""Energy budget model (paper §III-A-1), calibrated to published numbers.

Real-world anchors from the paper / Baoyun satellite:
  - daily solar harvest <= 260 KJ; ~150 KJ allocable to computing
  - compute ~50% of in-operation energy; E_com + E_down > 60% of total
  - COTS tiers: Raspberry Pi 4B (6 W) and Atlas 200 DK (13 W);
    RPi processes ~2x more tiles per joule (Fig. 8: '~50% energy saved')
  - measured downlink 30-50 Mbps; contact window <= ~6 min
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class DeviceProfile:
    name: str
    power_w: float
    effective_gflops: float  # sustained DNN throughput

    @property
    def joules_per_gflop(self) -> float:
        return self.power_w / self.effective_gflops


# Calibrated so RPI4 ~ 0.83 GFLOPS/W vs Atlas ~ 0.42 GFLOPS/W (the paper's
# observed ~2x J/tile gap), with absolute rates in the RPi4-for-CNN range.
RPI4 = DeviceProfile("rpi4", power_w=6.0, effective_gflops=5.0)
ATLAS = DeviceProfile("atlas", power_w=13.0, effective_gflops=5.4)
PROFILES = {p.name: p for p in (RPI4, ATLAS)}

DAILY_HARVEST_J = 260_000.0
DEFAULT_COMPUTE_BUDGET_J = 150_000.0
RADIO_POWER_W = 8.0


@dataclass
class EnergyLedger:
    """Tracks the four activity classes of §III-A-1."""

    budget_j: float
    e_cap: float = 0.0
    e_com: float = 0.0
    e_agg: float = 0.0
    e_down: float = 0.0

    @property
    def spent(self) -> float:
        return self.e_cap + self.e_com + self.e_agg + self.e_down

    @property
    def remaining(self) -> float:
        return max(self.budget_j - self.spent, 0.0)

    def grant(self, j: float):
        """Add harvested energy to the budget (streaming Missions grant
        each ingested slice's day-fraction entitlement incrementally)."""
        self.budget_j += j

    def charge_capture(self, n_images: int, j_per_image: float = 0.05):
        self.e_cap += n_images * j_per_image

    def charge_compute(self, n_tiles: int, gflops_per_tile: float,
                       profile: DeviceProfile):
        self.e_com += n_tiles * gflops_per_tile * profile.joules_per_gflop

    def charge_aggregate(self, n_ops: int = 1000, j_per_op: float = 1e-6):
        self.e_agg += n_ops * j_per_op

    def charge_downlink(self, n_bytes: float, bandwidth_mbps: float):
        seconds = n_bytes * 8.0 / (bandwidth_mbps * 1e6)
        self.e_down += seconds * RADIO_POWER_W

    def refund_downlink(self, n_bytes: float, bandwidth_mbps: float):
        """Reverse a downlink radio charge (fault reconciliation: a
        corrupted transmission under the ``refund`` policy). Computes the
        EXACT joule value :meth:`charge_downlink` added and subtracts it,
        so a charge/refund pair can never drive ``e_down`` negative
        (``fl(fl(a+x)-x) >= 0`` for ``a, x >= 0``)."""
        seconds = n_bytes * 8.0 / (bandwidth_mbps * 1e6)
        self.e_down -= seconds * RADIO_POWER_W


@dataclass
class ByteLedger:
    """Downlink byte accounting of one satellite: bytes offered across
    contact windows, bytes the policies asked to transmit, and bytes
    actually charged (capped by each window's budget)."""

    budget: float = 0.0
    requested: float = 0.0
    spent: float = 0.0


def _energy_lane(field):
    def fget(self):
        return float(getattr(self._ledger, field)[self._sat])
    return property(fget)


def _byte_lane(field):
    def fget(self):
        return float(getattr(self._ledger, field)[self._sat])

    def fset(self, v):
        getattr(self._ledger, field)[self._sat] = v
    return property(fget, fset)


class SatEnergyView:
    """EnergyLedger-compatible view of one lane of a :class:`FleetLedger`.

    Scalar charges write into the stacked arrays with the exact same
    float64 arithmetic as :class:`EnergyLedger`, so a Mission running on
    a view is bit-identical to one running on its own ledger.
    """

    __slots__ = ("_ledger", "_sat")

    def __init__(self, ledger: "FleetLedger", sat: int):
        self._ledger = ledger
        self._sat = sat

    budget_j = _energy_lane("budget_j")
    e_cap = _energy_lane("e_cap")
    e_com = _energy_lane("e_com")
    e_agg = _energy_lane("e_agg")
    e_down = _energy_lane("e_down")

    @property
    def spent(self) -> float:
        return self.e_cap + self.e_com + self.e_agg + self.e_down

    @property
    def remaining(self) -> float:
        return max(self.budget_j - self.spent, 0.0)

    def grant(self, j: float):
        self._ledger.budget_j[self._sat] += j

    def charge_capture(self, n_images: int, j_per_image: float = 0.05):
        self._ledger.e_cap[self._sat] += n_images * j_per_image

    def charge_compute(self, n_tiles: int, gflops_per_tile: float,
                       profile: DeviceProfile):
        self._ledger.e_com[self._sat] += (
            n_tiles * gflops_per_tile * profile.joules_per_gflop)

    def charge_aggregate(self, n_ops: int = 1000, j_per_op: float = 1e-6):
        self._ledger.e_agg[self._sat] += n_ops * j_per_op

    def charge_downlink(self, n_bytes: float, bandwidth_mbps: float):
        seconds = n_bytes * 8.0 / (bandwidth_mbps * 1e6)
        self._ledger.e_down[self._sat] += seconds * RADIO_POWER_W

    def refund_downlink(self, n_bytes: float, bandwidth_mbps: float):
        seconds = n_bytes * 8.0 / (bandwidth_mbps * 1e6)
        self._ledger.e_down[self._sat] -= seconds * RADIO_POWER_W


class SatBytesView:
    """ByteLedger-compatible view of one lane of a :class:`FleetLedger`."""

    __slots__ = ("_ledger", "_sat")

    def __init__(self, ledger: "FleetLedger", sat: int):
        self._ledger = ledger
        self._sat = sat

    budget = _byte_lane("bytes_budget")
    requested = _byte_lane("bytes_requested")
    spent = _byte_lane("bytes_spent")


class FleetLedger:
    """Stacked per-satellite budget state of a constellation.

    One (n_lanes,) float64 array per activity class instead of N scalar
    :class:`EnergyLedger` objects — fleet-wide grants and charges are
    single vectorized ops, and per-lane IEEE arithmetic is identical to
    the scalar ledger (each lane sees the same sequence of float64
    operations), so fleet execution stays bit-equal to looped Missions.
    Byte ledgers (offered / requested / spent downlink bytes) ride in
    the same object. ``energy_view(i)`` / ``bytes_view(i)`` expose
    Mission-compatible scalar views of lane ``i``.

    ``n_lanes`` (>= ``n_sats``, default equal) pads the stacked arrays
    up to a device multiple so the lane axis aligns with a ``sats``
    device mesh when ``n_sats`` doesn't divide evenly. Pad lanes start
    at zero and no view ever points at them, so every grant/charge the
    fleet issues writes zeros there — real lanes are never perturbed and
    fleet-wide sums are unchanged.
    """

    def __init__(self, n_sats: int, n_lanes: Optional[int] = None):
        self.n_sats = int(n_sats)
        self.n_lanes = self.n_sats if n_lanes is None else int(n_lanes)
        if self.n_lanes < self.n_sats:
            raise ValueError(
                f"n_lanes={self.n_lanes} < n_sats={self.n_sats}")
        z = lambda: np.zeros(self.n_lanes, np.float64)  # noqa: E731
        self.budget_j = z()
        self.e_cap = z()
        self.e_com = z()
        self.e_agg = z()
        self.e_down = z()
        self.bytes_budget = z()
        self.bytes_requested = z()
        self.bytes_spent = z()

    # -- vectorized grants/spends (fleet-batched stages) --------------------

    @property
    def spent(self) -> np.ndarray:
        return self.e_cap + self.e_com + self.e_agg + self.e_down

    @property
    def remaining(self) -> np.ndarray:
        return np.maximum(self.budget_j - self.spent, 0.0)

    def grant(self, j):
        """Add per-satellite harvested energy (``j``: scalar or (n_sats,))."""
        self.budget_j += j

    def charge_capture(self, n_images, j_per_image: float = 0.05):
        self.e_cap += np.asarray(n_images, np.float64) * j_per_image

    def charge_compute(self, n_tiles, gflops_per_tile: float,
                       profile: DeviceProfile):
        self.e_com += (np.asarray(n_tiles, np.float64) * gflops_per_tile
                       * profile.joules_per_gflop)

    def charge_aggregate(self, n_ops, j_per_op: float = 1e-6):
        self.e_agg += np.asarray(n_ops, np.float64) * j_per_op

    def charge_downlink(self, n_bytes, bandwidth_mbps: float):
        seconds = np.asarray(n_bytes, np.float64) * 8.0 / (bandwidth_mbps * 1e6)
        self.e_down += seconds * RADIO_POWER_W

    # -- vectorized contact-window ops (batched ContactPlan execution) ------
    #
    # These index by WINDOW, not by lane: ``sats`` may repeat a lane when
    # one satellite gets several windows in a round. ``np.add.at`` is
    # unbuffered and applies in index order, so a repeated lane sees the
    # exact float64 addition sequence the scalar per-window accrual
    # produces — vectorization never reassociates a lane's ledger.

    def accrue_window_budgets(self, sats, budgets):
        """Offer one round's window byte budgets (plan order)."""
        np.add.at(self.bytes_budget, np.asarray(sats, np.int64),
                  np.asarray(budgets, np.float64))

    def charge_downlink_windows(self, sats, requested, spends,
                                bandwidth_mbps):
        """One drain step's Downlink charges for every serving lane:
        requested/spent byte accounting plus the radio-energy spend, all
        with the per-lane IEEE arithmetic of the scalar
        :meth:`EnergyLedger.charge_downlink`."""
        sats = np.asarray(sats, np.int64)
        spends = np.asarray(spends, np.float64)
        np.add.at(self.bytes_requested, sats,
                  np.asarray(requested, np.float64))
        np.add.at(self.bytes_spent, sats, spends)
        seconds = spends * 8.0 / (np.asarray(bandwidth_mbps, np.float64)
                                  * 1e6)
        np.add.at(self.e_down, sats, seconds * RADIO_POWER_W)

    def refund_downlink_windows(self, sats, spends, bandwidth_mbps):
        """Reverse one drain step's Downlink charges for the lanes whose
        transmission the ground discarded (fault reconciliation under the
        ``refund`` policy) — byte spend and radio energy. Subtracts the
        EXACT per-lane float64 values :meth:`charge_downlink_windows`
        added (same ``seconds * RADIO_POWER_W`` arithmetic, negated,
        ``np.add.at`` in lane order), so lanes can never go negative and
        a refund is bit-equal to the scalar
        :meth:`EnergyLedger.refund_downlink` sequence. Requested bytes
        are NOT refunded: the policy did ask for the transmission."""
        sats = np.asarray(sats, np.int64)
        spends = np.asarray(spends, np.float64)
        np.add.at(self.bytes_spent, sats, -spends)
        seconds = spends * 8.0 / (np.asarray(bandwidth_mbps, np.float64)
                                  * 1e6)
        np.add.at(self.e_down, sats, -(seconds * RADIO_POWER_W))

    # -- per-satellite Mission-compatible views -----------------------------

    def energy_view(self, sat: int) -> SatEnergyView:
        if not 0 <= sat < self.n_sats:
            raise IndexError(f"sat {sat} out of range (pad lanes have no view)")
        return SatEnergyView(self, sat)

    def bytes_view(self, sat: int) -> SatBytesView:
        if not 0 <= sat < self.n_sats:
            raise IndexError(f"sat {sat} out of range (pad lanes have no view)")
        return SatBytesView(self, sat)


def max_tiles_within_budget_vec(budget_j, gflops_per_tile: float,
                                profile: DeviceProfile,
                                sharding=None, defer: bool = False):
    """Vectorized :func:`max_tiles_within_budget` over stacked budgets.

    Quotients are clamped below 2**62 before the integer cast — unlike
    Python's arbitrary-precision ``int()``, ``astype(int64)`` would wrap
    an astronomical grant to a NEGATIVE cap and silently process zero
    tiles. The clamp exceeds any real tile count, so caps stay
    effectively unbounded (and fleet/oracle-identical) either way.

    ``sharding``: optional on-mesh
    :class:`~repro.core.fleet_sharding.FleetSharding` — the stacked
    budget lanes are then placed along the ``sats`` mesh axis and the
    quotient clamp computed on-device in float64 (IEEE division and the
    truncating int64 cast are exactly specified, so on-mesh caps are
    bit-equal to the host computation).

    ``defer=True`` returns a zero-argument resolver instead of the caps
    array: on-mesh, the cap program is dispatched immediately but the
    device->host round-trip happens only when the resolver is called —
    the fleet's ingest-overlap tail dispatches caps right after the
    aggregation charge and fetches them after the dedup results land, so
    the round-trip rides behind the dedup wait. Off-mesh the computation
    is host-side anyway; the resolver just hands back the result.
    """
    budget_j = np.asarray(budget_j, np.float64)
    if gflops_per_tile <= 0:
        caps = np.zeros(budget_j.shape, np.int64)
        return (lambda: caps) if defer else caps
    if sharding is not None and sharding.on_mesh and budget_j.ndim == 1:
        return _lane_caps_on_mesh(budget_j, gflops_per_tile, profile,
                                  sharding, defer=defer)
    q = budget_j / (gflops_per_tile * profile.joules_per_gflop)
    caps = np.minimum(q, np.float64(2 ** 62)).astype(np.int64)
    return (lambda: caps) if defer else caps


def _lane_caps_on_mesh(budget_j: np.ndarray, gflops_per_tile: float,
                       profile: DeviceProfile, sharding,
                       defer: bool = False):
    """Compute per-lane compute caps with the ledger lanes device-placed
    along the ``sats`` mesh axis (f64 via a local x64 scope — jax's
    default f32 downcast would break cap parity with the host op).
    ``defer=True`` dispatches the program and returns a resolver for the
    device->host round-trip (the array carries its own int64 dtype, so
    the fetch needs no x64 scope)."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    n = budget_j.shape[0]
    with enable_x64():
        lanes = sharding.shard(jnp.asarray(budget_j, jnp.float64))
        q = lanes / (gflops_per_tile * profile.joules_per_gflop)
        caps = jnp.minimum(q, jnp.float64(2 ** 62)).astype(jnp.int64)
    if defer:
        return lambda: np.asarray(caps)[:n]
    return np.asarray(caps)[:n]


def max_tiles_within_budget(budget_j: float, gflops_per_tile: float,
                            profile: DeviceProfile) -> int:
    """How many tiles the onboard counter may process (computational
    bottleneck: the paper's '22% of observable images' phenomenon)."""
    if gflops_per_tile <= 0:
        return 0
    return int(budget_j / (gflops_per_tile * profile.joules_per_gflop))


def detector_gflops(cfg, tile_px: int = None) -> float:
    """Rough fwd FLOPs of a detector counter on one tile (GFLOP).

    Conv stages at stride-2: sum over stages of H*W*K*K*Cin*Cout*2.
    """
    px = tile_px or cfg.input_size
    total = 0.0
    h = px
    c_in = 3
    total += h * h * 9 * c_in * cfg.widths[0] * 2
    c_in = cfg.widths[0]
    for w in cfg.widths[1:]:
        h = h // 2
        total += h * h * 9 * c_in * w * 2
        total += (cfg.n_blocks_per_stage - 1) * h * h * 9 * w * w * 2
        c_in = w
    total += h * h * c_in * cfg.n_anchors * (5 + cfg.n_classes) * 2
    return total / 1e9
