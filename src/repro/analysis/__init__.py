"""repro.analysis — repo-specific static analysis + runtime sanitizers.

Static rules (AST-based, run via ``python -m repro.analysis``):
thread-ownership race checking for the ground-segment worker pipeline,
host-sync-in-hot-path lints protecting PR 9's churn elimination, and
determinism lints guarding the seeded-fault replay contract.  Runtime:
:class:`~repro.analysis.jitguard.JitGuard` counts XLA compilations so
benches/tests can assert steady-state rounds compile nothing.
"""
from repro.analysis.engine import (Finding, analyze, load_rules,  # noqa: F401
                                   register)
from repro.analysis.jitguard import JitGuard  # noqa: F401
