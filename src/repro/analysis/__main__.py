"""CLI: ``python -m repro.analysis [paths...] [options]``.

Exit codes: 0 — no findings beyond the baseline; 1 — new findings (or
malformed waivers).  Default target is ``src/repro``; the default
baseline is ``analysis_baseline.json`` at the repo root (missing file =
empty baseline).

    python -m repro.analysis                     # gate the tree
    python -m repro.analysis src/repro/core      # subset
    python -m repro.analysis --update-baseline   # accept current findings
    python -m repro.analysis --verbose           # show waived/baselined
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis import engine


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis: thread-ownership, "
                    "host-sync, and determinism rules")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files/dirs to analyze (default: src/repro)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="suppression baseline JSON (default: "
                         "analysis_baseline.json at the repo root)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write current findings to the baseline and "
                         "exit 0 (ratchet: run after fixing/waiving)")
    ap.add_argument("--verbose", action="store_true",
                    help="also list waived and baselined findings")
    args = ap.parse_args(argv)

    paths = args.paths or [engine.DEFAULT_TARGET]
    baseline_path = args.baseline or engine.DEFAULT_BASELINE

    findings, waived = engine.analyze(paths)
    baseline = engine.load_baseline(baseline_path)
    new, old, stale = engine.apply_baseline(findings, baseline)

    if args.update_baseline:
        engine.write_baseline(findings, baseline_path)
        print(f"analysis: baseline updated ({len(findings)} finding(s) "
              f"recorded) -> {baseline_path}")
        return 0

    for f in new:
        print(f.format())
    if args.verbose:
        for f in old:
            print(f"{f.format()}  [baselined]")
        for f in waived:
            print(f"{f.format()}  [waived]")
    if stale:
        print(f"analysis: {len(stale)} baseline entr"
              f"{'y is' if len(stale) == 1 else 'ies are'} stale "
              f"(fixed findings) — ratchet down with --update-baseline:")
        for k in stale:
            print(f"  - {k}")
    print(f"analysis: {len(new)} new, {len(old)} baselined, "
          f"{len(waived)} waived finding(s) over "
          f"{', '.join(str(p) for p in paths)}")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
