"""Runtime jit-recompilation sanitizer.

The fleet engine's whole shape discipline — pow2 frame buckets,
size-tiered counting batches, padded dedup cores — exists so that
steady-state rounds re-dispatch *already-compiled* XLA programs.  A
regression that lets a data-dependent shape reach a jit boundary shows
up as a recompile per round: silent, correct, and catastrophically slow.
:class:`JitGuard` counts XLA compilations inside a ``with`` block so
benches and tests can assert the steady state compiles nothing:

    with JitGuard() as g:
        fleet.ingest(frames, harvest)          # round >= 2, fixed sizes
    g.assert_steady_state("fleet round 3")     # raises if g.compilations

Primary signal: ``jax.monitoring`` duration events — jax emits
``/jax/core/compile/backend_compile_duration`` once per backend
compilation (verified: cache hits emit nothing).  Fallback when the
monitoring listener API is unavailable: the miss counter of jax's
parameter-inference lru cache (``_infer_params_cached``), which grows
exactly when a jitted call sees a novel (function, shapes) key.  The
fallback over-approximates compilations (tracing-cache misses), which is
safe for a zero-gate; ``mode`` records which signal counted.
"""
from __future__ import annotations

import threading

_COMPILE_EVENT_PREFIX = "/jax/core/compile/backend_compile"


def _lru_misses() -> int:
    from jax._src import pjit as _pjit
    return int(_pjit._infer_params_cached.cache_info().misses)


class JitGuard:
    """Context manager counting XLA compilations in its dynamic extent.

    Thread-safe: compilations from worker threads (the GroundSegment
    recount pipeline) are counted too — the monitoring listener is
    process-global and guarded by a lock.
    """

    def __init__(self, label: str = ""):
        self.label = label
        self.compilations = 0
        self.mode: str = "inactive"
        self._lock = threading.Lock()
        self._active = False
        self._cb = None
        self._base = 0

    def __enter__(self) -> "JitGuard":
        self.compilations = 0
        try:
            import jax.monitoring as mon

            def _on_duration(name: str, secs: float, **kw) -> None:
                if self._active and name.startswith(_COMPILE_EVENT_PREFIX):
                    with self._lock:
                        self.compilations += 1

            mon.register_event_duration_secs_listener(_on_duration)
            self._cb = _on_duration
            self.mode = "monitoring"
        except Exception:
            try:
                self._base = _lru_misses()
                self.mode = "lru-fallback"
            except Exception:
                self.mode = "unsupported"
        self._active = True
        return self

    def __exit__(self, *exc) -> bool:
        self._active = False
        if self.mode == "monitoring":
            try:
                from jax._src import monitoring as _impl
                _impl._unregister_event_duration_listener_by_callback(
                    self._cb)
            except Exception:
                pass  # listener stays registered but inert (_active False)
            self._cb = None
        elif self.mode == "lru-fallback":
            self.compilations = max(0, _lru_misses() - self._base)
        return False

    @property
    def supported(self) -> bool:
        return self.mode in ("monitoring", "lru-fallback")

    def assert_steady_state(self, what: str = "") -> None:
        """Raise if the guarded block compiled any new XLA program."""
        if not self.supported:
            return
        if self.compilations:
            label = what or self.label or "guarded block"
            raise AssertionError(
                f"jitguard: {label} compiled {self.compilations} new XLA "
                f"program(s); steady-state rounds must re-dispatch "
                f"already-compiled programs only (shape churn reached a "
                f"jit boundary — check pow2 bucketing / tier floors)")
