"""AST-based static-analysis engine for the repro codebase.

The concurrency and churn invariants this repo runs on — worker threads
that only touch worker-owned state, hot paths that never block on
device→host syncs, fault draws that replay bit-exactly — were each paid
for by a debugging PR (PR 8's watchdog races, PR 9's transfer churn).
``repro.analysis`` makes those invariants *mechanically checked*: rules
walk each module's AST and emit :class:`Finding` records, a waiver
comment with a mandatory reason string silences a deliberate exception
in place, and a baseline file lets pre-existing findings ratchet down
instead of blocking.

Rule modules self-register via :func:`register`; :func:`load_rules`
imports them all.  Run the whole thing with ``python -m repro.analysis``
(see ``__main__.py`` for the CLI contract).

Waiver syntax (trailing comment on the flagged line)::

    x = np.asarray(dev)  # analysis: waive(host-sync): the one designed copy

The rule id may be a family prefix (``host-sync`` waives
``host-sync/asarray``).  A waiver with an empty reason is itself a
finding (``waiver/missing-reason``) — exceptions must say why.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_TARGET = REPO_ROOT / "src" / "repro"
DEFAULT_BASELINE = REPO_ROOT / "analysis_baseline.json"


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str       # e.g. "thread-ownership/foreground"
    path: str       # repo-relative posix path
    line: int
    message: str

    @property
    def key(self) -> str:
        # line numbers drift under unrelated edits, so baseline keys are
        # (rule, file, message) with an occurrence count — see baseline()
        return f"{self.rule}::{self.path}::{self.message}"

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class ModuleContext:
    """Everything a rule needs about one parsed module."""

    path: Path                  # absolute
    rel: str                    # repo-relative posix path
    tree: ast.Module
    source: str

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule, self.rel, getattr(node, "lineno", 0), message)


Rule = Callable[[ModuleContext], List[Finding]]
_REGISTRY: List[Rule] = []


def register(rule: Rule) -> Rule:
    _REGISTRY.append(rule)
    return rule


def load_rules() -> List[Rule]:
    """Import every rule module (idempotent) and return the registry."""
    from repro.analysis import rules_determinism  # noqa: F401
    from repro.analysis import rules_sync  # noqa: F401
    from repro.analysis import rules_threads  # noqa: F401
    return list(_REGISTRY)


# -- AST helpers shared by the rule modules -------------------------------

def annotate_parents(tree: ast.Module) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._repro_parent = node  # type: ignore[attr-defined]


def enclosing_function(node: ast.AST) -> Optional[ast.AST]:
    cur = getattr(node, "_repro_parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = getattr(cur, "_repro_parent", None)
    return None


def enclosing_class(node: ast.AST) -> Optional[ast.ClassDef]:
    cur = getattr(node, "_repro_parent", None)
    while cur is not None:
        if isinstance(cur, ast.ClassDef):
            return cur
        cur = getattr(cur, "_repro_parent", None)
    return None


def call_name(node: ast.Call) -> str:
    """Dotted name of a call target ('' when not a plain name/attr)."""
    try:
        return ast.unparse(node.func)
    except Exception:
        return ""


# -- waivers --------------------------------------------------------------

_WAIVER_RE = re.compile(r"#\s*analysis:\s*waive\(([^)]*)\)\s*:?\s*(.*)")


def collect_waivers(source: str, rel: str
                    ) -> Tuple[Dict[int, List[Tuple[str, str]]],
                               List[Finding]]:
    """Per-line waivers plus findings for malformed ones.

    A trailing waiver covers its own line; a waiver on a comment-only
    line covers the next code line (for sites too long to annotate
    inline)."""
    lines = source.splitlines()
    waivers: Dict[int, List[Tuple[str, str]]] = {}
    bad: List[Finding] = []
    for lineno, text in enumerate(lines, start=1):
        m = _WAIVER_RE.search(text)
        if not m:
            continue
        rule = m.group(1).strip()
        reason = m.group(2).strip()
        if not rule or not reason:
            bad.append(Finding(
                "waiver/missing-reason", rel, lineno,
                "waiver must name a rule and give a non-empty reason: "
                "# analysis: waive(<rule>): <why this exception is safe>"))
            continue
        target = lineno
        if text[:m.start()].strip() == "":  # standalone comment line
            for nxt in range(lineno, len(lines)):
                code = lines[nxt].strip()
                if code and not code.startswith("#"):
                    target = nxt + 1
                    break
        waivers.setdefault(target, []).append((rule, reason))
    return waivers, bad


def _waived(finding: Finding,
            waivers: Dict[int, List[Tuple[str, str]]]) -> bool:
    for rule, _reason in waivers.get(finding.line, ()):
        if finding.rule == rule or finding.rule.startswith(rule + "/"):
            return True
    return False


# -- driver ---------------------------------------------------------------

def iter_py_files(paths: Sequence[Path]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(q for q in p.rglob("*.py")
                              if "__pycache__" not in q.parts))
        elif p.suffix == ".py":
            out.append(p)
    return out


def _relpath(path: Path, repo_root: Path) -> str:
    try:
        return path.resolve().relative_to(repo_root).as_posix()
    except ValueError:
        return path.as_posix()


def analyze(paths: Sequence[Path],
            repo_root: Path = REPO_ROOT,
            rules: Optional[Sequence[Rule]] = None,
            ) -> Tuple[List[Finding], List[Finding]]:
    """Run every rule over every file.

    Returns ``(findings, waived)``: unwaived findings (including
    malformed-waiver findings, which are never suppressible) and the
    list a waiver comment silenced (for ``--verbose`` reporting).
    """
    rules = list(rules) if rules is not None else load_rules()
    findings: List[Finding] = []
    waived: List[Finding] = []
    for path in iter_py_files(paths):
        source = path.read_text()
        rel = _relpath(path, repo_root)
        try:
            tree = ast.parse(source, filename=str(path))
        except SyntaxError as e:
            findings.append(Finding("parse/error", rel, e.lineno or 0,
                                    f"syntax error: {e.msg}"))
            continue
        annotate_parents(tree)
        waivers, bad_waivers = collect_waivers(source, rel)
        findings.extend(bad_waivers)
        ctx = ModuleContext(path=path, rel=rel, tree=tree, source=source)
        for rule in rules:
            for f in rule(ctx):
                (waived if _waived(f, waivers) else findings).append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, waived


# -- baseline (ratchet) ---------------------------------------------------

def baseline_counts(findings: Sequence[Finding]) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.key] = counts.get(f.key, 0) + 1
    return counts


def load_baseline(path: Path) -> Dict[str, int]:
    if not Path(path).exists():
        return {}
    text = Path(path).read_text()
    if not text.strip():
        return {}
    data = json.loads(text)
    raw = data.get("findings", data) if isinstance(data, dict) else {}
    return {str(k): int(v) for k, v in raw.items()
            if not str(k).startswith("_")}


def write_baseline(findings: Sequence[Finding], path: Path) -> None:
    payload = {
        "_comment": (
            "repro.analysis suppression baseline: known findings, keyed "
            "rule::path::message -> count. The CLI fails only on findings "
            "NOT covered here, so this file may only shrink (ratchet): "
            "fix or waive a finding, then `python -m repro.analysis "
            "--update-baseline` to drop its entry."),
        "findings": dict(sorted(baseline_counts(findings).items())),
    }
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")


def apply_baseline(findings: Sequence[Finding], baseline: Dict[str, int]
                   ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Split findings into (new, baselined); also return stale keys —
    baseline entries no longer matched, i.e. ratchet progress."""
    remaining = dict(baseline)
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        if remaining.get(f.key, 0) > 0:
            remaining[f.key] -= 1
            old.append(f)
        else:
            new.append(f)
    stale = sorted(k for k, v in remaining.items() if v > 0)
    return new, old, stale
