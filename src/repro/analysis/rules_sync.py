"""Host-sync-in-hot-path lint.

PR 9's churn elimination hinges on one discipline: inside the hot
modules, device values stay on device until the *designated* transfer
point.  A stray ``np.asarray`` / ``.item()`` / ``float()`` /
``block_until_ready`` on a JAX value re-introduces a blocking
device→host round-trip per call — exactly the per-round churn that
erased the 32-sat batching margin before PR 9.

The rule runs only over the designated hot scopes (``engine.py``, the
``cascade`` count paths, ``dedup.py``, ``orbits/propagation.py``) and
only flags syncs whose operand is *device-tainted*: produced by a
``jnp.*``/``jax.*`` call, a ``jax.jit``-wrapped program, or a function
that returns such a value (a module-level fixpoint infers those).
Host-side ``np.asarray`` on parameters/python data is fine.  The
designated single-copy transfer points carry explicit
``# analysis: waive(host-sync): <reason>`` comments; everything else is
a finding:

- ``host-sync/asarray``  — ``np.asarray``/``np.array`` on a device value
- ``host-sync/float``    — ``float()`` on a device value
- ``host-sync/item``     — ``.item()`` on a device value
- ``host-sync/block``    — any ``block_until_ready`` in a hot scope

``repro.core.xfer`` is the sanctioned host→device direction and is
never flagged (its *results* are device values like any other).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.engine import (Finding, ModuleContext, call_name,
                                   register)

# hot scopes: path suffix -> top-level function allowlist (None = whole
# module). cascade is scoped to its count paths: the training/data-prep
# helpers and the seed reference (count_tiles_batched_ref) are host code
# by design.
HOT_SCOPES: Dict[str, Optional[frozenset]] = {
    "repro/core/engine.py": None,
    "repro/core/cascade.py": frozenset({
        "count_tiles", "_count_tiles_body", "_count_tiles_chunks",
        "_count_forward", "count_tiles_batched", "count_tiles_multi",
        "_tier_batch"}),
    "repro/core/dedup.py": None,
    "repro/orbits/propagation.py": None,
}

# cross-module device producers: jit-wrapped entry points a hot module
# may call without seeing their jax.jit assignment
EXTERNAL_PRODUCERS = frozenset({
    "count_tiles", "count_tiles_batched", "count_tiles_multi",
    "_count_forward", "_count_tiles_chunks", "propagate_jit",
    "device_constant",
})
_DEVICE_ROOTS = ("jnp.", "jax.")


def _scope_functions(rel: str) -> Optional[frozenset]:
    for suffix, fns in HOT_SCOPES.items():
        if rel.endswith(suffix):
            return fns if fns is not None else frozenset({"*"})
    return None


def _rhs_mentions_jit(node: ast.AST) -> bool:
    try:
        return "jax.jit" in ast.unparse(node)
    except Exception:
        return False


def _module_producers(tree: ast.Module) -> Set[str]:
    """Names bound to jit programs plus (fixpoint) functions returning
    device-tainted values."""
    producers: Set[str] = set(EXTERNAL_PRODUCERS)
    for node in tree.body:
        if isinstance(node, ast.Assign) and _rhs_mentions_jit(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    producers.add(t.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_rhs_mentions_jit(d) for d in node.decorator_list):
                producers.add(node.name)
    fns = [n for n in tree.body
           if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for _ in range(4):  # fixpoint over return-taint
        grew = False
        for fn in fns:
            if fn.name in producers:
                continue
            taint = _function_taint(fn, producers)
            for n in ast.walk(fn):
                if (isinstance(n, ast.Return) and n.value is not None
                        and _tainted(n.value, taint, producers)):
                    producers.add(fn.name)
                    grew = True
                    break
        if not grew:
            break
    return producers


def _tainted(expr: ast.AST, taint: Set[str], producers: Set[str]) -> bool:
    """Conservative device-value test for an expression."""
    if isinstance(expr, ast.Name):
        return expr.id in taint
    if isinstance(expr, ast.Call):
        name = call_name(expr)
        if name.startswith(_DEVICE_ROOTS):
            return True
        if name.rsplit(".", 1)[-1] in producers:
            return True
        # method chains on tainted receivers: x.at[i].set(v), x.astype(...)
        if isinstance(expr.func, ast.Attribute):
            return _tainted(expr.func.value, taint, producers)
        return False
    if isinstance(expr, ast.Attribute):
        return _tainted(expr.value, taint, producers)
    if isinstance(expr, ast.Subscript):
        return _tainted(expr.value, taint, producers)
    if isinstance(expr, ast.BinOp):
        return (_tainted(expr.left, taint, producers)
                or _tainted(expr.right, taint, producers))
    if isinstance(expr, ast.UnaryOp):
        return _tainted(expr.operand, taint, producers)
    if isinstance(expr, ast.Compare):
        return (_tainted(expr.left, taint, producers)
                or any(_tainted(c, taint, producers)
                       for c in expr.comparators))
    if isinstance(expr, ast.IfExp):
        return (_tainted(expr.body, taint, producers)
                or _tainted(expr.orelse, taint, producers))
    if isinstance(expr, (ast.Tuple, ast.List)):
        return any(_tainted(e, taint, producers) for e in expr.elts)
    if isinstance(expr, (ast.ListComp, ast.GeneratorExp, ast.SetComp)):
        return (_tainted(expr.elt, taint, producers)
                or any(_tainted(g.iter, taint, producers)
                       for g in expr.generators))
    if isinstance(expr, ast.Starred):
        return _tainted(expr.value, taint, producers)
    return False


def _bound_names(target: ast.AST) -> List[str]:
    return [n.id for n in ast.walk(target) if isinstance(n, ast.Name)]


def _propagate(stmts, taint: Set[str], producers: Set[str]) -> None:
    """One in-order pass growing the tainted-name set."""
    for stmt in stmts:
        if isinstance(stmt, ast.Assign):
            if _tainted(stmt.value, taint, producers):
                for t in stmt.targets:
                    taint.update(_bound_names(t))
        elif isinstance(stmt, ast.AugAssign):
            if (_tainted(stmt.value, taint, producers)
                    or _tainted(stmt.target, taint, producers)):
                taint.update(_bound_names(stmt.target))
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None and _tainted(stmt.value, taint,
                                                   producers):
                taint.update(_bound_names(stmt.target))
        elif isinstance(stmt, ast.For):
            if _tainted(stmt.iter, taint, producers):
                taint.update(_bound_names(stmt.target))
            _propagate(stmt.body, taint, producers)
            _propagate(stmt.orelse, taint, producers)
        elif isinstance(stmt, (ast.While, ast.If)):
            _propagate(stmt.body, taint, producers)
            _propagate(stmt.orelse, taint, producers)
        elif isinstance(stmt, ast.With):
            _propagate(stmt.body, taint, producers)
        elif isinstance(stmt, ast.Try):
            _propagate(stmt.body, taint, producers)
            for h in stmt.handlers:
                _propagate(h.body, taint, producers)
            _propagate(stmt.orelse, taint, producers)
            _propagate(stmt.finalbody, taint, producers)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # closures see the enclosing taint minus their own params;
            # their locals do not leak back out
            inner = taint - {a.arg for a in stmt.args.args}
            _propagate(stmt.body, inner, producers)


def _function_taint(fn, producers: Set[str],
                    seed: Optional[Set[str]] = None) -> Set[str]:
    """Two propagation passes ≈ fixpoint for straight-line hot code."""
    taint: Set[str] = set(seed or ()) - {a.arg for a in fn.args.args}
    _propagate(fn.body, taint, producers)
    _propagate(fn.body, taint, producers)
    return taint


def _local_producers(fn, producers: Set[str]) -> Set[str]:
    """Nested defs whose returns are tainted count as producers too."""
    out = set(producers)
    for node in ast.walk(fn):
        if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node is not fn and node.name not in out):
            inner_taint = _function_taint(node, out)
            for n in ast.walk(node):
                if (isinstance(n, ast.Return) and n.value is not None
                        and _tainted(n.value, inner_taint, out)):
                    out.add(node.name)
                    break
    return out


def _check_scope(ctx: ModuleContext, body, taint: Set[str],
                 producers: Set[str], findings: List[Finding]) -> None:
    for node in (n for s in body for n in ast.walk(s)):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        short = name.rsplit(".", 1)[-1]
        if short == "block_until_ready":
            findings.append(ctx.finding(
                "host-sync/block", node,
                "block_until_ready in a hot scope: route the fetch "
                "through the designated transfer point or waive with a "
                "reason"))
        elif (name in ("np.asarray", "np.array", "numpy.asarray",
                       "numpy.array") and node.args
              and _tainted(node.args[0], taint, producers)):
            findings.append(ctx.finding(
                "host-sync/asarray", node,
                f"{name} on a device value blocks on a device->host "
                f"copy in a hot scope (PR 9 churn class): defer it to "
                f"the designated transfer point or waive with a reason"))
        elif (name == "float" and node.args
              and _tainted(node.args[0], taint, producers)):
            findings.append(ctx.finding(
                "host-sync/float", node,
                "float() on a device value forces a blocking host sync "
                "in a hot scope"))
        elif (short == "item" and isinstance(node.func, ast.Attribute)
              and _tainted(node.func.value, taint, producers)):
            findings.append(ctx.finding(
                "host-sync/item", node,
                ".item() on a device value forces a blocking host sync "
                "in a hot scope"))


@register
def host_sync_rule(ctx: ModuleContext) -> List[Finding]:
    scope = _scope_functions(ctx.rel)
    if scope is None:
        return []
    findings: List[Finding] = []
    producers = _module_producers(ctx.tree)
    whole_module = "*" in scope
    # module-level taint accumulates across the whole module body
    module_taint: Set[str] = set()
    module_stmts = [n for n in ctx.tree.body
                    if not isinstance(n, (ast.FunctionDef,
                                          ast.AsyncFunctionDef,
                                          ast.ClassDef))]
    _propagate(module_stmts, module_taint, producers)
    for node in ctx.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not whole_module and node.name not in scope:
                continue
            local = _local_producers(node, producers)
            taint = _function_taint(node, local, seed=module_taint)
            _check_scope(ctx, node.body, taint, local, findings)
        elif whole_module and not isinstance(node, ast.ClassDef):
            _check_scope(ctx, [node], module_taint, producers, findings)
    return findings
