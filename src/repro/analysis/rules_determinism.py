"""Determinism lints for ``repro.core`` + ``repro.orbits``.

The fault subsystem's replayability contract (PR 6) is that every
random draw routes through a counter-hashed ``np.random.SeedSequence``
keyed by (seed, kind, site) — order-independent and bit-replayable —
and every other source of nondeterminism (process-global RNG state,
wall-clock reads) stays out of the core.  These lints ban the footguns:

- ``determinism/global-rng``   — ``np.random.seed(...)`` (process-global
  state: one call anywhere silently reorders every later draw)
- ``determinism/unseeded-rng`` — argless ``np.random.default_rng()`` /
  ``np.random.SeedSequence()`` (fresh OS entropy per call)
- ``determinism/random-module`` — the stdlib ``random`` module (global
  Mersenne state; use a seeded numpy Generator)
- ``determinism/wall-clock``   — ``time.time()`` (timing accumulators
  use ``time.perf_counter``; wall-clock reads leak host time into
  results — waive with a reason if one is genuinely wanted)
- ``determinism/frozen-setattr`` — ``object.__setattr__`` on frozen
  dataclasses outside ``__post_init__`` (mutating a "frozen" plan after
  construction invalidates every validation it ran)

Seeded constructors (``default_rng(0)``, ``default_rng(SeedSequence(
(seed, kind) + site))``, ``jax.random.PRNGKey(seed)``) are fine — the
ban is on *unseeded or global* state, not on randomness.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.engine import (Finding, ModuleContext, call_name,
                                   enclosing_function, register)

_SCOPES = ("repro/core/", "repro/orbits/")


def _in_scope(rel: str) -> bool:
    return any(s in rel for s in _SCOPES)


@register
def determinism_rule(ctx: ModuleContext) -> List[Finding]:
    findings: List[Finding] = []
    in_scope = _in_scope(ctx.rel)
    for node in ast.walk(ctx.tree):
        if in_scope and isinstance(node, (ast.Import, ast.ImportFrom)):
            mod = (node.module if isinstance(node, ast.ImportFrom)
                   else None)
            names = [a.name for a in node.names]
            if mod == "random" or "random" in names:
                findings.append(ctx.finding(
                    "determinism/random-module", node,
                    "stdlib `random` uses process-global Mersenne state; "
                    "use the counter-hashed SeedSequence discipline "
                    "(repro.core.faults) or a seeded np.random Generator"))
            continue
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if not in_scope:
            # the frozen-setattr lint applies tree-wide: a frozen plan
            # is frozen no matter which package mutates it
            if name == "object.__setattr__":
                findings.extend(_check_setattr(ctx, node))
            continue
        if name in ("np.random.seed", "numpy.random.seed"):
            findings.append(ctx.finding(
                "determinism/global-rng", node,
                "np.random.seed mutates process-global RNG state and "
                "silently reorders every later draw; use a seeded "
                "Generator or the faults.py SeedSequence discipline"))
        elif (name in ("np.random.default_rng", "numpy.random.default_rng",
                       "default_rng", "np.random.SeedSequence",
                       "numpy.random.SeedSequence", "SeedSequence")
              and not node.args and not node.keywords):
            findings.append(ctx.finding(
                "determinism/unseeded-rng", node,
                f"argless {name}() draws fresh OS entropy per call — "
                f"unreplayable; pass an explicit seed/entropy"))
        elif name == "time.time":
            findings.append(ctx.finding(
                "determinism/wall-clock", node,
                "time.time() leaks wall-clock into core results; timing "
                "accumulators use time.perf_counter() — waive with a "
                "reason if wall-clock is genuinely required"))
        elif name == "object.__setattr__":
            findings.extend(_check_setattr(ctx, node))
    return findings


def _check_setattr(ctx: ModuleContext, node: ast.Call) -> List[Finding]:
    fn = enclosing_function(node)
    if fn is not None and fn.name == "__post_init__":
        return []
    where = f"in `{fn.name}`" if fn is not None else "at module level"
    return [ctx.finding(
        "determinism/frozen-setattr", node,
        f"object.__setattr__ {where}: frozen dataclasses may only be "
        f"written during __post_init__ — post-construction mutation "
        f"bypasses build-time validation")]
