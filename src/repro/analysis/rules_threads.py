"""Thread-ownership race checker.

The ground segment's recount pipeline (PR 5/8) runs worker threads
spawned via ``threading.Thread(target=...)``.  The contract that keeps
depth-k pipelining bit-exact is ownership: a worker may read immutable
config and its own per-round snapshot, write only its round's slots and
per-segment outputs, and must check its round's ``cancel`` event before
every write-back group — a worker abandoned by the watchdog writes
NOTHING.  PR 8 exists because that contract was once only prose; this
rule makes it a build failure.

The ownership map below is *declarative* and name-based: ``self`` in a
mapped class resolves by class name, other receivers resolve by the
repo's parameter-naming conventions (``fleet``/``work``/``rnd``/``seg``/
``stats``/``m``).  Every function reachable from a thread entry point is
checked; foreground-only functions (``execute``/``_retire``/``sync``)
are deliberately out of scope — they run under foreground ownership.

Findings:

- ``thread-ownership/foreground`` — worker code reads or writes a
  foreground-owned attribute (e.g. the ``recount_s``/``wait_s``
  accumulators, the pipeline deque).
- ``thread-ownership/cancel`` — a write-back (guarded attribute write or
  Aggregate-stage call) not covered by a ``cancel.is_set()`` check since
  the last compute barrier / loop round.
- ``thread-ownership/undeclared`` — worker code writes an attribute of a
  mapped role that the ownership map does not permit.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.engine import (Finding, ModuleContext, call_name,
                                   enclosing_class, register)

ANY = "*"


@dataclass(frozen=True)
class Role:
    """Worker-visible ownership contract for one object kind."""

    read: object = frozenset()        # attrs the worker may read (or ANY)
    write: object = frozenset()       # attrs the worker may write (or ANY)
    guarded: frozenset = frozenset()  # writes permitted only under cancel
    foreground: frozenset = frozenset()  # attrs the worker must not touch


OWNERSHIP: Dict[str, Role] = {
    # the dispatcher: workers may read its config, never its accounting
    "GroundSegment": Role(
        read=frozenset({"fleet", "watchdog_s", "depth"}),
        foreground=frozenset({"recount_s", "wait_s", "rounds_deferred",
                              "max_in_flight", "_queue"})),
    # shared engine/config handles are read-only; every ingest/contact
    # accumulator is foreground-owned (the worker charges nothing)
    "Fleet": Role(
        read=frozenset({"ground", "space", "pcfg", "sharding", "missions",
                        "fault_stats", "n_sats"}),
        foreground=frozenset({"ledger", "_ingest_s", "_contact_s",
                              "_ingest_tail", "_pending_counts",
                              "_ingest_dispatch_s", "_host_fetch_s",
                              "_device_compute_s", "_windows_served",
                              "_bytes_downlinked"})),
    # the worker's own per-round object: result/err/clock slots are its
    # to write; the foreground reads them only after join()
    "_InFlightRound": Role(read=frozenset({"work", "cancel", "thread"}),
                           write=frozenset({"err", "worker_s"})),
    # the dispatch-time snapshot is frozen: read-only
    "_RecountWork": Role(read=frozenset({"by_thresh", "agg"})),
    # per-segment recount output: pure write of this round's own
    # segments, legal only behind a fresh cancel check
    "Segment": Role(read=ANY, guarded=frozenset({"counts_gd"})),
    # GIL-atomic int event counters, incremented from either side
    "FaultStats": Role(read=ANY, write=ANY),
    # stage graph handle: the Aggregate write-back routes through it
    "Mission": Role(read=frozenset({"contact_stages"}),
                    foreground=frozenset({"ledger", "_pending"})),
}

# receiver-name -> role, the repo's parameter naming convention
PARAM_ROLES: Dict[str, str] = {
    "fleet": "Fleet", "work": "_RecountWork", "rnd": "_InFlightRound",
    "seg": "Segment", "stats": "FaultStats", "m": "Mission",
}
# attribute-chain hops: self.fleet on GroundSegment is a Fleet
ATTR_ROLES: Dict[Tuple[str, str], str] = {("GroundSegment", "fleet"): "Fleet"}

# device-compute calls: a cancel check goes stale once one runs (the
# watchdog may fire during the batch)
BARRIER_CALLS = frozenset({"count_tiles_multi", "count_tiles",
                           "count_tiles_batched", "_recount_plan"})
# calls that ARE a write-back group (Aggregate stage dispatch)
GUARDED_CALL_MARKER = "contact_stages"
CANCEL_NAMES = frozenset({"cancel"})


def _collect_functions(tree: ast.Module):
    """All defs: by bare name (module level preferred) and (class, name)."""
    by_name: Dict[str, ast.AST] = {}
    methods: Dict[Tuple[str, str], ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            cls = enclosing_class(node)
            if cls is not None:
                methods[(cls.name, node.name)] = node
            else:
                by_name.setdefault(node.name, node)
    return by_name, methods


def _thread_entries(tree, by_name, methods) -> List[Tuple[str, ast.AST]]:
    """(owner_class_or_None, fn) for each Thread(target=...) expression."""
    entries = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and call_name(node).endswith("Thread")):
            continue
        for kw in node.keywords:
            if kw.arg != "target":
                continue
            t = kw.value
            if (isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"):
                cls = enclosing_class(node)
                fn = methods.get((cls.name, t.attr)) if cls else None
                if fn is not None:
                    entries.append((cls.name, fn))
            elif isinstance(t, ast.Name) and t.id in by_name:
                entries.append((None, by_name[t.id]))
    return entries


def _reachable(entries, by_name, methods):
    """Closure over same-module calls: f(), self.m() with static names."""
    seen: List[Tuple[Optional[str], ast.AST]] = []
    work = list(entries)
    while work:
        cls, fn = work.pop()
        if any(f is fn for _, f in seen):
            continue
        seen.append((cls, fn))
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Name) and f.id in by_name:
                work.append((None, by_name[f.id]))
            elif (isinstance(f, ast.Attribute)
                  and isinstance(f.value, ast.Name) and f.value.id == "self"
                  and cls is not None and (cls, f.attr) in methods):
                work.append((cls, methods[(cls, f.attr)]))
    return seen


def _resolve_role(expr: ast.AST, self_class: Optional[str]) -> Optional[str]:
    """Role name for a receiver expression, else None."""
    if isinstance(expr, ast.Name):
        if expr.id == "self":
            return self_class if self_class in OWNERSHIP else None
        return PARAM_ROLES.get(expr.id)
    if isinstance(expr, ast.Attribute):
        base = _resolve_role(expr.value, self_class)
        if base is not None:
            return ATTR_ROLES.get((base, expr.attr))
    return None


def _is_cancel_guard(stmt: ast.If) -> bool:
    """`if cancel is not None and cancel.is_set(): return/continue/...`"""
    has_check = any(
        isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
        and n.func.attr == "is_set"
        and _mentions_cancel(n.func.value)
        for n in ast.walk(stmt.test))
    exits = any(isinstance(s, (ast.Return, ast.Raise, ast.Continue,
                               ast.Break)) for s in stmt.body)
    return has_check and exits


def _mentions_cancel(expr: ast.AST) -> bool:
    for n in ast.walk(expr):
        if isinstance(n, ast.Name) and n.id in CANCEL_NAMES:
            return True
        if isinstance(n, ast.Attribute) and n.attr in CANCEL_NAMES:
            return True
    return False


def _has_barrier_or_guarded_call(stmts) -> bool:
    for s in stmts:
        for n in ast.walk(s):
            if isinstance(n, ast.Call):
                name = call_name(n)
                if name.rsplit(".", 1)[-1] in BARRIER_CALLS:
                    return True
                if GUARDED_CALL_MARKER in name:
                    return True
    return False


@dataclass
class _FnChecker:
    ctx: ModuleContext
    self_class: Optional[str]
    fn: ast.AST
    findings: List[Finding] = field(default_factory=list)
    cancel_ok: bool = False

    def run(self) -> List[Finding]:
        self.cancel_ok = False
        self._stmts(self.fn.body)
        return self.findings

    # -- statement walk with cancel-freshness state -------------------

    def _stmts(self, stmts) -> None:
        for stmt in stmts:
            if isinstance(stmt, ast.If):
                if _is_cancel_guard(stmt):
                    self._stmts(stmt.orelse)
                    self.cancel_ok = True
                    continue
                before = self.cancel_ok
                self._stmts(stmt.body)
                after_body = self.cancel_ok
                self.cancel_ok = before
                self._stmts(stmt.orelse)
                self.cancel_ok = self.cancel_ok and after_body
            elif isinstance(stmt, (ast.For, ast.While)):
                head = stmt.iter if isinstance(stmt, ast.For) else stmt.test
                self._scan(head)
                round_loop = _has_barrier_or_guarded_call(stmt.body)
                if round_loop:
                    # iterations 2..n re-enter after a barrier/group: a
                    # pre-loop check does not cover them
                    self.cancel_ok = False
                self._stmts(stmt.body)
                self._stmts(stmt.orelse)
                if round_loop:
                    self.cancel_ok = False
            elif isinstance(stmt, ast.Try):
                self._stmts(stmt.body)
                for h in stmt.handlers:
                    self._stmts(h.body)
                self._stmts(stmt.orelse)
                self._stmts(stmt.finalbody)
            elif isinstance(stmt, ast.With):
                for item in stmt.items:
                    self._scan(item.context_expr)
                self._stmts(stmt.body)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._stmts(stmt.body)  # nested def runs on this thread
            else:
                self._scan(stmt)

    # -- per-statement attribute/call checks --------------------------

    def _scan(self, node: ast.AST) -> None:
        if node is None:
            return
        stale = False
        for n in ast.walk(node):
            if isinstance(n, ast.Attribute):
                self._attr(n)
            elif isinstance(n, ast.Call):
                name = call_name(n)
                if name.rsplit(".", 1)[-1] in BARRIER_CALLS:
                    stale = True
                if GUARDED_CALL_MARKER in name:
                    if not self.cancel_ok:
                        self.findings.append(self.ctx.finding(
                            "thread-ownership/cancel", n,
                            f"worker write-back `{name}(...)` without a "
                            f"cancel check since the last barrier/group — "
                            f"an abandoned worker must write nothing"))
                    stale = True
        if stale:
            self.cancel_ok = False

    def _attr(self, n: ast.Attribute) -> None:
        role_name = _resolve_role(n.value, self.self_class)
        if role_name is None:
            return
        role = OWNERSHIP[role_name]
        recv = ast.unparse(n.value)
        is_write = isinstance(n.ctx, (ast.Store, ast.Del))
        if n.attr in role.foreground:
            self.findings.append(self.ctx.finding(
                "thread-ownership/foreground", n,
                f"worker thread {'writes' if is_write else 'reads'} "
                f"foreground-owned attribute `{recv}.{n.attr}` "
                f"({role_name} ownership map)"))
            return
        if not is_write:
            return
        if role.write == ANY or n.attr in role.write:
            return
        if n.attr in role.guarded:
            if not self.cancel_ok:
                self.findings.append(self.ctx.finding(
                    "thread-ownership/cancel", n,
                    f"worker write-back `{recv}.{n.attr}` without a cancel "
                    f"check since the last barrier — an abandoned worker "
                    f"must write nothing"))
            return
        self.findings.append(self.ctx.finding(
            "thread-ownership/undeclared", n,
            f"worker thread writes `{recv}.{n.attr}`, which the "
            f"{role_name} ownership map does not declare worker-writable"))


@register
def thread_ownership_rule(ctx: ModuleContext) -> List[Finding]:
    if "threading" not in ctx.source:
        return []
    by_name, methods = _collect_functions(ctx.tree)
    entries = _thread_entries(ctx.tree, by_name, methods)
    if not entries:
        return []
    findings: List[Finding] = []
    seen_fns: Set[int] = set()
    for cls, fn in _reachable(entries, by_name, methods):
        if id(fn) in seen_fns:
            continue
        seen_fns.add(id(fn))
        findings.extend(_FnChecker(ctx, cls, fn).run())
    return findings
