"""Render §Dry-run / §Roofline tables for EXPERIMENTS.md from the
artifacts emitted by launch.dryrun.

  PYTHONPATH=src python -m repro.launch.report [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def load(mesh: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(ART_DIR, mesh, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    # keep the latest record per (arch, shape)
    best = {}
    for r in recs:
        best[(r["arch"], r["shape"])] = r
    return [best[k] for k in sorted(best)]


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b / 1e12:.2f}T"
    if b >= 1e9:
        return f"{b / 1e9:.2f}G"
    if b >= 1e6:
        return f"{b / 1e6:.1f}M"
    return f"{b / 1e3:.0f}K"


def roofline_table(mesh: str = "single") -> str:
    """model_flops/useful/fraction are recomputed live from
    launch.steps.model_flops so estimator fixes apply without
    re-compiling the artifacts."""
    from repro.launch.roofline import Roofline

    rows = [
        "| arch | shape | FLOPs/dev | HBM B/dev | coll B/dev | compute s | "
        "memory s | coll s | bound | MODEL_FLOPS | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        rl = dict(r["roofline"])
        try:
            from repro.launch.steps import model_flops
            mf = model_flops(r["arch"], r["shape"])
        except Exception:
            mf = rl["model_flops"]
        raw = r.get("cost_raw", {})
        conv = r.get("convert_artifact", {})
        ma = r.get("memory_analysis", {})
        rr = Roofline(flops=raw.get("flops", rl["flops_per_dev"]),
                      bytes_hbm=raw.get("bytes_accessed", rl["hbm_bytes_per_dev"]),
                      bytes_coll=rl["coll_bytes_per_dev"],
                      n_chips=r["n_chips"], model_flops_total=mf,
                      convert_elems=conv.get("elems", 0.0),
                      convert_bytes=conv.get("bytes", 0.0),
                      min_bytes=float(ma.get("argument_bytes", 0)
                                      + ma.get("output_bytes", 0)))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rr.flops:.2e} | "
            f"{fmt_bytes(rr.bytes_hbm)} | {fmt_bytes(rr.bytes_coll)} | "
            f"{rr.compute_s:.3f} | {rr.memory_s:.3f} | "
            f"{rr.collective_s:.3f} | **{rr.dominant}** | "
            f"{mf:.2e} | {rr.useful_ratio:.2f} | "
            f"{rr.roofline_fraction:.3f} |")
    return "\n".join(rows)


def dryrun_table(mesh: str) -> str:
    rows = [
        "| arch | shape | compile s | arg B/dev | temp B/dev | "
        "ag | ar | rs | a2a | cp |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        m = r["memory_analysis"]
        c = r["collective_counts"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f} | "
            f"{fmt_bytes(m.get('argument_bytes', 0))} | "
            f"{fmt_bytes(m.get('temp_bytes', 0))} | "
            f"{c['all-gather']} | {c['all-reduce']} | {c['reduce-scatter']} | "
            f"{c['all-to-all']} | {c['collective-permute']} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--kind", default="roofline", choices=["roofline", "dryrun"])
    args = ap.parse_args()
    if args.kind == "roofline":
        print(roofline_table(args.mesh))
    else:
        print(dryrun_table(args.mesh))


if __name__ == "__main__":
    main()
