"""Production mesh builders.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure data parallelism across pods (gradient all-reduce
crosses the inter-pod links only once per step).

Functions, not module constants: importing this module never touches
jax device state (device count is locked at first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int = None, model: int = 2):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = n_devices or len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
