import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# -- the two lines above MUST run before any jax import (device count is
#    locked at first init). Tests may override the count via env:
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])
# the dry-run never EXECUTES the compiled module -> skip expensive LLVM
# codegen passes (measured 1.7x faster compiles, identical cost analysis)
os.environ["XLA_FLAGS"] += " --xla_backend_optimization_level=0"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on
the production meshes, record memory/cost/collective analyses.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Artifacts land in artifacts/dryrun/<mesh>/<arch>__<shape>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import all_cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline, parse_collectives
from repro.launch.steps import build_cell, model_flops

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def _mesh_for(name: str):
    if name == "single":
        return make_production_mesh(multi_pod=False)
    if name == "multi":
        return make_production_mesh(multi_pod=True)
    if name == "tiny":  # CI-scale stand-in
        return jax.make_mesh((2, 4), ("data", "model"))
    if name == "tinymulti":
        return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    raise KeyError(name)


def run_cell(arch: str, shape: str, mesh_name: str, opts=None,
             save: bool = True, hlo_out: str = None) -> dict:
    # unrolled layers by default: exact per-layer cost accounting
    opts = {"unroll": True, **(opts or {})}
    mesh = _mesh_for(mesh_name)
    n_chips = mesh.devices.size
    t0 = time.time()
    plan = build_cell(arch, shape, mesh, **opts)
    # set_mesh so in-model with_sharding_constraint(PartitionSpec) calls
    # resolve; older jax spells it use_mesh, and older still only has the
    # `with mesh:` context manager (same ambient-mesh semantics there)
    set_mesh = (getattr(jax.sharding, "set_mesh", None)
                or getattr(jax.sharding, "use_mesh", None))
    with (set_mesh(mesh) if set_mesh else mesh):
        jfn = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                      out_shardings=plan.out_shardings,
                      donate_argnums=plan.donate_argnums)
        lowered = jfn.lower(*plan.args_sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        }
        mem_d["total_bytes"] = (mem_d["argument_bytes"] + mem_d["output_bytes"]
                                + mem_d["temp_bytes"])
    except Exception as e:  # CPU backend may not implement it
        mem_d = {"error": str(e)}

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    if hlo_out:
        with open(hlo_out, "w") as f:
            f.write(hlo)

    mf = model_flops(arch, shape)
    min_bytes = float(mem_d.get("argument_bytes", 0) + mem_d.get("output_bytes", 0))
    rl = Roofline(flops=flops, bytes_hbm=bytes_hbm, bytes_coll=coll["total"],
                  n_chips=n_chips, model_flops_total=mf,
                  convert_elems=coll.get("convert_elems", 0.0),
                  convert_bytes=coll.get("convert_bytes", 0.0),
                  min_bytes=min_bytes)

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "mesh_shape": list(mesh.devices.shape),
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_d,
        "cost_analysis": {"flops": flops, "bytes_accessed": bytes_hbm},
        "collectives": {k: v for k, v in coll.items() if k != "counts"},
        "convert_artifact": {"elems": coll.get("convert_elems", 0.0),
                             "bytes": coll.get("convert_bytes", 0.0)},
        "cost_raw": {"flops": flops, "bytes_accessed": bytes_hbm},
        "collective_counts": coll["counts"],
        "roofline": rl.as_dict(),
        "opts": {k: str(v) for k, v in opts.items()},
        "hlo_lines": hlo.count("\n"),
    }
    if save:
        d = os.path.join(ART_DIR, mesh_name)
        os.makedirs(d, exist_ok=True)
        tag = "" if not opts else "__" + "_".join(f"{k}-{v}" for k, v in opts.items())
        with open(os.path.join(d, f"{arch}__{shape}{tag}.json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both", "tiny", "tinymulti"])
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--no-mla-absorb", action="store_true")
    ap.add_argument("--hlo-out", default=None)
    ap.add_argument("--continue-on-error", action="store_true")
    ap.add_argument("--scan", action="store_true",
                    help="keep scan-over-layers (fast compile; use for the "
                         "multi-pod compile-proof pass — cost accounting "
                         "then undercounts loop bodies)")
    args = ap.parse_args()

    opts = {}
    if args.scan:
        opts["unroll"] = False
    if args.grad_accum:
        opts["grad_accum"] = args.grad_accum
    if args.zero1:
        opts["zero1_axis"] = "data"
    if args.no_mla_absorb:
        opts["mla_absorb"] = False

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    failures = []
    for mesh_name in meshes:
        for arch, shape in cells:
            tag = f"{arch} x {shape} @ {mesh_name}"
            try:
                t0 = time.time()
                rec = run_cell(arch, shape, mesh_name, opts,
                               hlo_out=args.hlo_out)
                r = rec["roofline"]
                print(f"[ok] {tag}: compile={rec['compile_s']:.1f}s "
                      f"flops/dev={r['flops_per_dev']:.3e} "
                      f"dominant={r['dominant']} "
                      f"bound={max(r['compute_s'], r['memory_s'], r['collective_s']):.4f}s "
                      f"useful={r['useful_ratio']:.2f}", flush=True)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag}: {e!r}", flush=True)
                if not args.continue_on_error:
                    traceback.print_exc()
                    raise SystemExit(1)
    if failures:
        print(f"\n{len(failures)} failures:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall cells passed")


if __name__ == "__main__":
    main()
