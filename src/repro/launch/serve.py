"""Collaborative serving driver: batched tile requests through the
TargetFuse cascade (the paper-kind end-to-end path).

  PYTHONPATH=src python -m repro.launch.serve --frames 4 --revisits 3

Trains (or loads cached) reduced counters, then runs a one-window
Mission for every registered selection policy and prints the CMAE
table.
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.cascade import fit_counter
from repro.core.mission import Mission
from repro.core.pipeline import PipelineConfig
from repro.core.policies import available_policies
from repro.data.synthetic import DATASETS, SceneSpec, make_scene, revisit_frames

CACHE = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                     "artifacts", "counters")


def get_counters(train_steps=(800, 2000), scene=None, force=False,
                 cache_dir=CACHE, seed=0):
    """(space (params, cfg), ground (params, cfg)) — cached on disk.

    Trained on a MIX of scene profiles (mini + the three dataset
    analogues) so confidence calibration transfers across benchmarks.
    """
    from repro.checkpoint import ckpt

    sp_cfg = reduced(get_config("targetfuse-space"))
    gd_cfg = reduced(get_config("targetfuse-ground"))
    pair = []
    rng = np.random.default_rng(seed)
    if scene is not None:
        profiles = [scene]
    else:
        from repro.data.synthetic import SceneSpec as SS
        profiles = [
            SceneSpec("mini", 512, (20, 30), (10, 24), cloud_fraction=0.2),
            SS("xview", 768, (30, 60), (8, 20), cloud_fraction=0.3),
            SS("dota", 768, (22, 45), (10, 32), cloud_fraction=0.3),
            SS("uavod", 512, (8, 24), (12, 40), cloud_fraction=0.2),
        ]
    scenes = []
    for p in profiles:
        scenes += [make_scene(rng, p) for _ in range(max(2, 8 // len(profiles)))]
    for name, cfg, steps, k in (("space", sp_cfg, train_steps[0], 0),
                                ("ground", gd_cfg, train_steps[1], 1)):
        d = os.path.join(cache_dir, name)
        from repro.models import detector
        template = detector.init(jax.random.PRNGKey(k), cfg)
        if not force:
            try:
                _, params = ckpt.restore(d, template)
                pair.append((params, cfg))
                continue
            except (FileNotFoundError, ValueError):
                pass
        params, loss = fit_counter(cfg, scenes, 128, steps, jax.random.PRNGKey(k))
        ckpt.save(d, steps, params)
        pair.append((params, cfg))
    return pair[0], pair[1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=3)
    ap.add_argument("--revisits", type=int, default=3)
    ap.add_argument("--dataset", default="mini")
    ap.add_argument("--bandwidth", type=float, default=50.0)
    ap.add_argument("--retrain", action="store_true")
    args = ap.parse_args()

    spec = (DATASETS[args.dataset] if args.dataset in DATASETS
            else SceneSpec("mini", 512, (20, 30), (10, 24), cloud_fraction=0.2))
    space, ground = get_counters(force=args.retrain)

    rng = np.random.default_rng(1)
    frames = []
    for _ in range(args.frames):
        img, b, c = make_scene(rng, spec)
        frames += revisit_frames(rng, img, b, c, args.revisits)
    print(f"{len(frames)} frames, {(spec.scene_px // 128) ** 2} tiles each")

    print(f"{'method':14s} {'CMAE':>7s} {'pred':>6s} {'true':>6s} "
          f"{'down':>5s} {'proc':>5s} {'MB':>7s}")
    for method in available_policies():
        pcfg = PipelineConfig(method=method, bandwidth_mbps=args.bandwidth,
                              score_thresh=0.25)
        s = Mission(space, ground, pcfg).run(frames).summary()
        print(f"{method:14s} {s['cmae']:7.3f} {s['total_pred']:6.0f} "
              f"{s['total_true']:6.0f} {s['tiles_downlinked']:5d} "
              f"{s['tiles_processed_space']:5d} "
              f"{s['bytes_downlinked'] / 1e6:7.2f}")


if __name__ == "__main__":
    main()
