"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bandwidth_per_chip
  collective = collective_bytes_per_device / ICI_link_bandwidth

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` of the
post-SPMD per-device module. Collective bytes are parsed out of the
compiled HLO text (cost_analysis does not expose them): every
all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute result shape is summed with the standard ring-cost
factor (all-reduce moves ~2x its payload; others ~1x).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum bytes of every dtype[dims] literal in `text`."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, float]:
    """Per-device collective bytes by op kind (+ op counts) and
    dtype-convert accounting.

    The CPU backend has no native bf16 compute: every bf16 dot operand
    is first `convert`-ed to f32. XLA's cost analysis counts those
    converts as flops and bytes — pure backend artifact that a TPU
    compile would not contain. We sum convert elements/bytes so the
    roofline can report TPU-representative adjusted terms.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    convert_elems = 0.0
    convert_bytes = 0.0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not ls or ls.startswith("//"):
            continue
        m = re.search(r"=\s*(\w+\[[0-9,]*\])[^\s]*\s+convert\(", ls)
        if m:
            b = _shape_bytes(m.group(1))
            dt = m.group(1).split("[")[0]
            ib = _DTYPE_BYTES.get(dt, 4)
            n = b / ib
            convert_elems += n
            # bytes accessed by a convert: read input + write output; the
            # input dtype is unknown here — assume the bf16<->f32 pair
            convert_bytes += n * (2 + 4)
            continue
        for kind in _COLLECTIVES:
            # match result side of `%x = <shape> kind(` or fused `kind-start(`
            m = re.search(r"=\s*(.+?)\s+" + kind + r"(?:-start|-done)?\(", ls)
            if m:
                if kind + "-done(" in ls:
                    continue  # counted at -start
                b = _shape_bytes(m.group(1))
                out[kind] += b * _FACTOR[kind]
                counts[kind] += 1
                break
    out["counts"] = counts
    out["total"] = float(sum(v for k, v in out.items()
                             if k in _COLLECTIVES))
    out["convert_elems"] = convert_elems
    out["convert_bytes"] = convert_bytes
    return out


@dataclass
class Roofline:
    flops: float               # per device
    bytes_hbm: float           # per device
    bytes_coll: float          # per device
    n_chips: int
    model_flops_total: float = 0.0
    convert_elems: float = 0.0  # CPU-backend bf16-emulation artifact
    convert_bytes: float = 0.0
    min_bytes: float = 0.0      # floor: one pass over args+outputs
    compute_s: float = field(init=False)
    memory_s: float = field(init=False)
    collective_s: float = field(init=False)

    def __post_init__(self):
        # subtract the CPU backend's bf16-emulation converts (a TPU
        # compile performs bf16 dots natively; see parse_collectives),
        # flooring traffic at one pass over the program's arguments and
        # outputs (params/activations must move at least once)
        flops_adj = max(self.flops - self.convert_elems, 0.0)
        bytes_adj = max(self.bytes_hbm - self.convert_bytes, self.min_bytes)
        self.flops = flops_adj
        self.bytes_hbm = bytes_adj
        self.compute_s = flops_adj / PEAK_FLOPS
        self.memory_s = bytes_adj / HBM_BW
        self.collective_s = self.bytes_coll / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (remat/redundancy waste)."""
        total_hlo = self.flops * self.n_chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline actually achieved if the cell
        runs at its bound: (useful FLOP time) / (bound time)."""
        useful_s = (self.model_flops_total / self.n_chips) / PEAK_FLOPS
        return useful_s / self.bound_s if self.bound_s else 0.0

    def as_dict(self):
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.bytes_hbm,
            "coll_bytes_per_dev": self.bytes_coll,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops_total,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "n_chips": self.n_chips,
        }
