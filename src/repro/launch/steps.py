"""Per-cell step builders: (arch x input-shape) -> a lowered-able plan.

``build_cell(arch, shape_name, mesh)`` returns a CellPlan holding the
step function, ShapeDtypeStruct input stand-ins (``input_specs()``), and
in/out shardings — everything ``launch.dryrun`` needs to lower+compile,
and everything ``launch.train/serve`` need to run for real at reduced
scale.

No array is ever allocated here: model/optimizer state shapes come from
``jax.eval_shape`` over the init functions.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import base as cfgs
from repro.configs.base import (DetectorConfig, DiffusionConfig, LMConfig,
                                ShapeSpec, VisionConfig, get_config, get_shape)
from repro.models import convnext, detector, diffusion, dit, lm, resnet, unet, vit
from repro.optim.adamw import adamw
from repro.sharding import policy as pol
from repro.sharding.rules import param_specs
from repro.core.throttle import throttle as throttle_fn
from repro.kernels import ops as kops


@dataclass
class CellPlan:
    arch: str
    shape: str
    fn: Callable                      # positional args match args_sds
    args_sds: Tuple                   # ShapeDtypeStruct pytrees
    in_shardings: Tuple               # matching NamedSharding pytrees
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    meta: Dict[str, Any] = field(default_factory=dict)
    static_argnums: Tuple[int, ...] = ()


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _state_specs(params_sds, cfg, mesh, with_opt=True, zero1_axis=None):
    """PartitionSpec trees for (params, opt_state). ZeRO-1: optionally
    shard optimizer moments over `zero1_axis` on their first shardable
    dim (on top of the param's own TP sharding)."""
    pspec = param_specs(params_sds, cfg, mesh)
    if not with_opt:
        return pspec
    def moment_spec(ps, leaf):
        if zero1_axis is None:
            return ps
        parts = list(ps)
        for i, axis in enumerate(parts):
            if axis is None and leaf.shape[i] % 16 == 0:
                parts[i] = zero1_axis
                break
        return P(*parts)
    mspec = jax.tree_util.tree_map(
        moment_spec, pspec,
        jax.tree_util.tree_map(lambda x: x, params_sds))
    from repro.optim.adamw import AdamWState
    opt_spec = AdamWState(step=P(), mu=mspec, nu=mspec)
    return pspec, opt_spec


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _pure_dp_axes(mesh, batch: int, n_params: int, max_params: float = 1.5e9):
    """Pure data parallelism for small models: when the global batch
    divides the whole mesh and the replicated model+optimizer fits HBM,
    TP buys nothing and costs an all-reduce per layer. Returns the batch
    axes tuple, or None when pure DP doesn't apply."""
    if n_params > max_params:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for axes in (("pod", "data", "model"), ("data", "model")):
        if all(a in sizes for a in axes):
            n = 1
            for a in axes:
                n *= sizes[a]
            if batch % n == 0:
                return axes
    return None


def _replicated_specs(tree):
    return jax.tree_util.tree_map(lambda l: P(*([None] * l.ndim)), tree)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_train(arch, cfg: LMConfig, shape: ShapeSpec, mesh, opts):
    b, s = shape.global_batch, shape.seq_len
    params_sds = jax.eval_shape(functools.partial(lm.init, cfg=cfg),
                                jax.random.PRNGKey(0))
    opt_init, opt_update = adamw(1e-4)
    opt_sds = jax.eval_shape(opt_init, params_sds)
    pspec, opt_spec = _state_specs(params_sds, cfg, mesh,
                                   zero1_axis=opts.get("zero1_axis"))
    tok_specs = pol.lm_specs(mesh, "train", b, s)
    accum = opts.get("grad_accum", 1)

    def step(params, opt_state, tokens, labels):
        if accum > 1:
            mb_tok = tokens.reshape(accum, b // accum, s)
            mb_lab = labels.reshape(accum, b // accum, s)

            def micro(carry, xs):
                g_acc, l_acc = carry
                t, l = xs
                (loss, _), g = jax.value_and_grad(lm.loss_fn, has_aux=True)(
                    params, cfg, t, l)
                g_acc = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32) / accum, g_acc, g)
                return (g_acc, l_acc + loss / accum), None

            zeros = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), (mb_tok, mb_lab))
        else:
            (loss, _), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
                params, cfg, tokens, labels)
        params, opt_state, _ = opt_update(grads, opt_state, params)
        return params, opt_state, loss

    args = (params_sds, opt_sds,
            jax.ShapeDtypeStruct((b, s), jnp.int32),
            jax.ShapeDtypeStruct((b, s), jnp.int32))
    in_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec),
             _ns(mesh, tok_specs["tokens"]), _ns(mesh, tok_specs["labels"]))
    out_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), NamedSharding(mesh, P()))
    return CellPlan(arch, shape.name, step, args, in_sh, out_sh,
                    donate_argnums=(0, 1),
                    meta={"tokens": b * s, "kind": "train"})


def _lm_prefill(arch, cfg: LMConfig, shape: ShapeSpec, mesh, opts):
    b, s = shape.global_batch, shape.seq_len
    params_sds = jax.eval_shape(functools.partial(lm.init, cfg=cfg),
                                jax.random.PRNGKey(0))
    pspec = param_specs(params_sds, cfg, mesh)
    tok_specs = pol.lm_specs(mesh, "prefill", b, s)
    cache_spec_one = pol.lm_cache_spec(mesh, cfg, b,
                                       pol.cache_len_axes(mesh, b, s))

    def step(params, tokens):
        return lm.prefill(params, cfg, tokens)

    # out: (logits (B,V), caches dict-of-stacks)
    cache_sds = jax.eval_shape(
        functools.partial(lm.init_cache, cfg, b, s))
    cache_out_spec = {k: cache_spec_one for k in cache_sds}
    ba = pol.batch_axes(mesh, b)
    out_sh = (NamedSharding(mesh, P(ba if ba else None, "model")),
              _ns(mesh, cache_out_spec))
    args = (params_sds, jax.ShapeDtypeStruct((b, s), jnp.int32))
    in_sh = (_ns(mesh, pspec), _ns(mesh, tok_specs["tokens"]))
    return CellPlan(arch, shape.name, step, args, in_sh, out_sh,
                    meta={"tokens": b * s, "kind": "prefill"})


def _lm_decode(arch, cfg: LMConfig, shape: ShapeSpec, mesh, opts):
    b, s = shape.global_batch, shape.seq_len
    params_sds = jax.eval_shape(functools.partial(lm.init, cfg=cfg),
                                jax.random.PRNGKey(0))
    pspec = param_specs(params_sds, cfg, mesh)
    d = pol.lm_specs(mesh, "decode", b, s)
    cache_spec_one = pol.lm_cache_spec(mesh, cfg, b,
                                       pol.cache_len_axes(mesh, b, s))
    cache_sds = jax.eval_shape(functools.partial(lm.init_cache, cfg, b, s))
    cache_spec = {k: cache_spec_one for k in cache_sds}
    absorb = opts.get("mla_absorb", True)

    def step(params, token, caches, pos):
        return lm.decode_step(params, cfg, token, caches, pos, absorb=absorb)

    ba = pol.batch_axes(mesh, b)
    args = (params_sds, jax.ShapeDtypeStruct((b, 1), jnp.int32), cache_sds,
            jax.ShapeDtypeStruct((), jnp.int32))
    in_sh = (_ns(mesh, pspec), _ns(mesh, d["token"]), _ns(mesh, cache_spec),
             NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, P(ba if ba else None, "model")),
              _ns(mesh, cache_spec))
    return CellPlan(arch, shape.name, step, args, in_sh, out_sh,
                    donate_argnums=(2,),
                    meta={"tokens": b, "kind": "decode", "cache_len": s})


# ---------------------------------------------------------------------------
# vision cells
# ---------------------------------------------------------------------------


def _vision_fwd_fn(cfg):
    if cfg.kind == "vit":
        return vit
    if cfg.kind == "convnext":
        return convnext
    return resnet


def _vision_train(arch, cfg: VisionConfig, shape: ShapeSpec, mesh, opts):
    b, r = shape.global_batch, shape.img_res
    mod = _vision_fwd_fn(cfg)
    is_resnet = cfg.kind == "resnet"
    if is_resnet:
        params_sds, bn_sds = jax.eval_shape(
            functools.partial(resnet.init, cfg=cfg), jax.random.PRNGKey(0))
    else:
        params_sds = jax.eval_shape(functools.partial(mod.init, cfg=cfg),
                                    jax.random.PRNGKey(0))
    opt_init, opt_update = adamw(1e-3)
    opt_sds = jax.eval_shape(opt_init, params_sds)
    dp = None if opts.get("no_pure_dp") else _pure_dp_axes(mesh, b, cfg.n_params)
    if dp is not None:
        pspec = _replicated_specs(params_sds)
        from repro.optim.adamw import AdamWState
        opt_spec = AdamWState(step=P(), mu=pspec, nu=pspec)
        ba = dp
        img_spec = P(dp, None, None, None)
    else:
        pspec, opt_spec = _state_specs(params_sds, cfg, mesh,
                                       zero1_axis=opts.get("zero1_axis"))
        img_spec = pol.image_specs(mesh, b)
        ba = pol.batch_axes(mesh, b)
    lab_spec = P(ba if ba else None)

    def ce(logits, labels):
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))

    if is_resnet:
        bn_spec = jax.tree_util.tree_map(lambda _: P(None), bn_sds)

        def step(params, bn_state, opt_state, images, labels):
            def loss_fn(p):
                logits, new_bn = resnet.forward(p, bn_state, cfg, images, train=True)
                return ce(logits, labels), new_bn
            (loss, new_bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            params, opt_state, _ = opt_update(grads, opt_state, params)
            return params, new_bn, opt_state, loss

        args = (params_sds, bn_sds, opt_sds,
                jax.ShapeDtypeStruct((b, r, r, 3), jnp.float32),
                jax.ShapeDtypeStruct((b,), jnp.int32))
        in_sh = (_ns(mesh, pspec), _ns(mesh, bn_spec), _ns(mesh, opt_spec),
                 _ns(mesh, img_spec), _ns(mesh, lab_spec))
        out_sh = (_ns(mesh, pspec), _ns(mesh, bn_spec), _ns(mesh, opt_spec),
                  NamedSharding(mesh, P()))
        return CellPlan(arch, shape.name, step, args, in_sh, out_sh,
                        donate_argnums=(0, 1, 2),
                        meta={"images": b, "kind": "train"})

    def step(params, opt_state, images, labels):
        def loss_fn(p):
            logits = mod.forward(p, cfg, images, train=True)
            return ce(logits, labels)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = opt_update(grads, opt_state, params)
        return params, opt_state, loss

    args = (params_sds, opt_sds,
            jax.ShapeDtypeStruct((b, r, r, 3), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32))
    in_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), _ns(mesh, img_spec),
             _ns(mesh, lab_spec))
    out_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), NamedSharding(mesh, P()))
    return CellPlan(arch, shape.name, step, args, in_sh, out_sh,
                    donate_argnums=(0, 1), meta={"images": b, "kind": "train"})


def _vision_serve(arch, cfg: VisionConfig, shape: ShapeSpec, mesh, opts):
    b, r = shape.global_batch, shape.img_res
    mod = _vision_fwd_fn(cfg)
    is_resnet = cfg.kind == "resnet"
    if is_resnet:
        params_sds, bn_sds = jax.eval_shape(
            functools.partial(resnet.init, cfg=cfg), jax.random.PRNGKey(0))
    else:
        params_sds = jax.eval_shape(functools.partial(mod.init, cfg=cfg),
                                    jax.random.PRNGKey(0))
    pspec = param_specs(params_sds, cfg, mesh)
    img_spec = pol.image_specs(mesh, b)
    ba = pol.batch_axes(mesh, b)

    if is_resnet:
        bn_spec = jax.tree_util.tree_map(lambda _: P(None), bn_sds)

        def step(params, bn_state, images):
            logits, _ = resnet.forward(params, bn_state, cfg, images, train=False)
            return logits

        args = (params_sds, bn_sds,
                jax.ShapeDtypeStruct((b, r, r, 3), jnp.float32))
        in_sh = (_ns(mesh, pspec), _ns(mesh, bn_spec), _ns(mesh, img_spec))
    else:
        def step(params, images):
            return mod.forward(params, cfg, images, train=False)

        args = (params_sds, jax.ShapeDtypeStruct((b, r, r, 3), jnp.float32))
        in_sh = (_ns(mesh, pspec), _ns(mesh, img_spec))
    out_sh = NamedSharding(mesh, P(ba if ba else None, None))
    return CellPlan(arch, shape.name, step, args, in_sh, out_sh,
                    meta={"images": b, "kind": "serve"})


# ---------------------------------------------------------------------------
# diffusion cells
# ---------------------------------------------------------------------------


def _diff_train(arch, cfg: DiffusionConfig, shape: ShapeSpec, mesh, opts):
    b = shape.global_batch
    lr = shape.img_res // cfg.latent_factor
    is_dit = cfg.kind == "dit"
    mod = dit if is_dit else unet
    params_sds = jax.eval_shape(functools.partial(mod.init, cfg=cfg),
                                jax.random.PRNGKey(0))
    opt_init, opt_update = adamw(1e-4)
    opt_sds = jax.eval_shape(opt_init, params_sds)
    dp = None if opts.get("no_pure_dp") else _pure_dp_axes(mesh, b, cfg.n_params)
    if dp is not None:
        pspec = _replicated_specs(params_sds)
        from repro.optim.adamw import AdamWState
        opt_spec = AdamWState(step=P(), mu=pspec, nu=pspec)
        ba = dp
        lat_spec = P(dp, None, None, None)
    else:
        pspec, opt_spec = _state_specs(params_sds, cfg, mesh,
                                       zero1_axis=opts.get("zero1_axis"))
        lat_spec = pol.image_specs(mesh, b)
        ba = pol.batch_axes(mesh, b)
    bspec = ba if ba else None

    if is_dit:
        def step(params, opt_state, latents, y, key):
            def loss_fn(p):
                def eps_fn(x, t):
                    return mod.forward(p, cfg, x, t, y, train=True)[0]
                return diffusion.train_loss(eps_fn, latents, key)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state, _ = opt_update(grads, opt_state, params)
            return params, opt_state, loss

        args = (params_sds, opt_sds,
                jax.ShapeDtypeStruct((b, lr, lr, cfg.latent_ch), jnp.float32),
                jax.ShapeDtypeStruct((b,), jnp.int32),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
        in_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), _ns(mesh, lat_spec),
                 _ns(mesh, P(bspec)), NamedSharding(mesh, P(None)))
    else:
        def step(params, opt_state, latents, ctx, key):
            def loss_fn(p):
                def eps_fn(x, t):
                    return mod.forward(p, cfg, x, t, ctx, train=True)
                return diffusion.train_loss(eps_fn, latents, key)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state, _ = opt_update(grads, opt_state, params)
            return params, opt_state, loss

        args = (params_sds, opt_sds,
                jax.ShapeDtypeStruct((b, lr, lr, cfg.latent_ch), jnp.float32),
                jax.ShapeDtypeStruct((b, cfg.ctx_len, cfg.ctx_dim), jnp.float32),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
        in_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), _ns(mesh, lat_spec),
                 _ns(mesh, P(bspec, None, None)), NamedSharding(mesh, P(None)))
    out_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), NamedSharding(mesh, P()))
    return CellPlan(arch, shape.name, step, args, in_sh, out_sh,
                    donate_argnums=(0, 1),
                    meta={"images": b, "kind": "train", "steps": shape.steps})


def _diff_gen(arch, cfg: DiffusionConfig, shape: ShapeSpec, mesh, opts):
    b = shape.global_batch
    lr = shape.img_res // cfg.latent_factor
    is_dit = cfg.kind == "dit"
    mod = dit if is_dit else unet
    params_sds = jax.eval_shape(functools.partial(mod.init, cfg=cfg),
                                jax.random.PRNGKey(0))
    pspec = param_specs(params_sds, cfg, mesh)
    lat_spec = pol.image_specs(mesh, b)
    ba = pol.batch_axes(mesh, b)
    bspec = ba if ba else None

    if is_dit:
        def step(params, latents, y, t_cur, t_prev):
            def eps_fn(x, t):
                return mod.forward(params, cfg, x, t, y, train=False)[0]
            return diffusion.ddim_step(eps_fn, latents, t_cur, t_prev)

        args = (params_sds,
                jax.ShapeDtypeStruct((b, lr, lr, cfg.latent_ch), jnp.float32),
                jax.ShapeDtypeStruct((b,), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (_ns(mesh, pspec), _ns(mesh, lat_spec), _ns(mesh, P(bspec)),
                 NamedSharding(mesh, P()), NamedSharding(mesh, P()))
    else:
        def step(params, latents, ctx, t_cur, t_prev):
            def eps_fn(x, t):
                return mod.forward(params, cfg, x, t, ctx, train=False)
            return diffusion.ddim_step(eps_fn, latents, t_cur, t_prev)

        args = (params_sds,
                jax.ShapeDtypeStruct((b, lr, lr, cfg.latent_ch), jnp.float32),
                jax.ShapeDtypeStruct((b, cfg.ctx_len, cfg.ctx_dim), jnp.float32),
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (_ns(mesh, pspec), _ns(mesh, lat_spec),
                 _ns(mesh, P(bspec, None, None)),
                 NamedSharding(mesh, P()), NamedSharding(mesh, P()))
    out_sh = _ns(mesh, lat_spec)
    return CellPlan(arch, shape.name, step, args, in_sh, out_sh,
                    donate_argnums=(1,),
                    meta={"images": b, "kind": "gen", "steps": shape.steps})


# ---------------------------------------------------------------------------
# the paper's own arch: TargetFuse onboard serving cell
# ---------------------------------------------------------------------------


def _targetfuse_serve(arch, cfg: DetectorConfig, shape: ShapeSpec, mesh, opts):
    b, r = shape.global_batch, shape.img_res
    params_sds = jax.eval_shape(functools.partial(detector.init, cfg=cfg),
                                jax.random.PRNGKey(0))
    # The counter is tiny (~5M params): channel-sharding it over "model"
    # buys nothing and costs an all-reduce per conv. When the tile batch
    # divides the whole (non-pod) mesh, run pure DP: batch over
    # ("data","model"), weights replicated, zero per-layer collectives.
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = tuple(a for a in ("data", "model") if a in sizes)
    n_dp = 1
    for a in dp_axes:
        n_dp *= sizes[a]
    pure_dp = opts.get("dp_serve", True) and b % n_dp == 0
    if pure_dp:
        pspec = jax.tree_util.tree_map(
            lambda l: P(*([None] * l.ndim)), params_sds)
        ba = dp_axes
    else:
        pspec = param_specs(params_sds, cfg, mesh)
        ba = pol.batch_axes(mesh, b)
    img_spec = P(ba if ba else None, None, None, None)
    bspec = ba if ba else None
    n_clusters = 64

    def step(params, tiles, centroids):
        """The full onboard pipeline of Fig. 3 as one XLA program."""
        raw = detector.forward(params, cfg, tiles)
        counts, conf = detector.count_and_confidence(raw, cfg, input_size=r)
        feats = kops.tile_moments(tiles)
        assign, d2 = kops.kmeans_assign(feats, centroids)
        sizes = jnp.full((b,), float(r * r * 3))
        tr = throttle_fn(conf, sizes, jnp.float32(b * r * r * 3 * 0.15),
                         0.10, 0.55, "dynamic_conf")
        c_space = jnp.sum(jnp.where(tr.space, counts, 0.0))
        return counts, conf, assign, tr.downlink, c_space

    args = (params_sds,
            jax.ShapeDtypeStruct((b, r, r, 3), jnp.float32),
            jax.ShapeDtypeStruct((n_clusters, 9), jnp.float32))
    in_sh = (_ns(mesh, pspec), NamedSharding(mesh, img_spec),
             NamedSharding(mesh, P(None, None)))
    out_sh = (NamedSharding(mesh, P(bspec)), NamedSharding(mesh, P(bspec)),
              NamedSharding(mesh, P(bspec)), NamedSharding(mesh, P(bspec)),
              NamedSharding(mesh, P()))
    return CellPlan(arch, shape.name, step, args, in_sh, out_sh,
                    meta={"tiles": b, "kind": "serve"})


def _detector_train(arch, cfg: DetectorConfig, shape: ShapeSpec, mesh, opts):
    b, r = shape.global_batch, shape.img_res
    params_sds = jax.eval_shape(functools.partial(detector.init, cfg=cfg),
                                jax.random.PRNGKey(0))
    opt_init, opt_update = adamw(1e-3)
    opt_sds = jax.eval_shape(opt_init, params_sds)
    pspec = param_specs(params_sds, cfg, mesh)
    opt_spec = jax.eval_shape(opt_init, params_sds)  # shapes only
    from repro.optim.adamw import AdamWState
    opt_spec = AdamWState(step=P(), mu=pspec, nu=pspec)
    img_spec = pol.image_specs(mesh, b)
    ba = pol.batch_axes(mesh, b)
    g = detector.grid_size(cfg, r)

    def step(params, opt_state, images, targets):
        (loss, _), grads = jax.value_and_grad(detector.loss_fn, has_aux=True)(
            params, cfg, images, targets)
        params, opt_state, _ = opt_update(grads, opt_state, params)
        return params, opt_state, loss

    args = (params_sds, opt_sds,
            jax.ShapeDtypeStruct((b, r, r, 3), jnp.float32),
            jax.ShapeDtypeStruct((b, g, g, cfg.n_anchors, 5 + cfg.n_classes),
                                 jnp.float32))
    in_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), _ns(mesh, img_spec),
             _ns(mesh, P(ba if ba else None, None, None, None, None)))
    out_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), NamedSharding(mesh, P()))
    return CellPlan(arch, shape.name, step, args, in_sh, out_sh,
                    donate_argnums=(0, 1), meta={"tiles": b, "kind": "train"})


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def build_cell(arch: str, shape_name: str, mesh, **opts) -> CellPlan:
    cfg = get_config(arch)
    if opts.get("unroll"):
        import dataclasses
        if hasattr(cfg, "scan_layers"):
            cfg = dataclasses.replace(cfg, scan_layers=False)
    if opts.get("remat") and hasattr(cfg, "remat"):
        import dataclasses
        cfg = dataclasses.replace(cfg, remat=opts["remat"])
    shape = get_shape(arch, shape_name)
    fam = cfg.family
    if fam == "lm":
        if shape.kind == "train":
            return _lm_train(arch, cfg, shape, mesh, opts)
        if shape.kind == "prefill":
            return _lm_prefill(arch, cfg, shape, mesh, opts)
        return _lm_decode(arch, cfg, shape, mesh, opts)
    if fam == "vision":
        if shape.kind in ("cls",):
            return _vision_train(arch, cfg, shape, mesh, opts)
        return _vision_serve(arch, cfg, shape, mesh, opts)
    if fam == "diffusion":
        if shape.kind == "train":
            return _diff_train(arch, cfg, shape, mesh, opts)
        return _diff_gen(arch, cfg, shape, mesh, opts)
    if fam == "detector":
        if shape.kind == "train":
            return _detector_train(arch, cfg, shape, mesh, opts)
        return _targetfuse_serve(arch, cfg, shape, mesh, opts)
    raise KeyError(fam)


def input_specs(arch: str, shape_name: str, mesh=None) -> Tuple:
    """ShapeDtypeStruct stand-ins for every input of the cell's step
    (the deliverable's ``input_specs()``). No device allocation."""
    if mesh is None:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
    return build_cell(arch, shape_name, mesh).args_sds


def _vit_fwd_flops(cfg, img_res: int) -> float:
    """Exact matmul FLOPs of one ViT forward image."""
    t = (img_res // cfg.patch) ** 2 + 1
    d, f = cfg.d_model, cfg.d_ff
    patch = 2.0 * (img_res // cfg.patch) ** 2 * cfg.patch ** 2 * 3 * d
    blk = 2.0 * t * (4 * d * d + 2 * d * f) + 4.0 * t * t * d
    head = 2.0 * d * cfg.n_classes
    return patch + cfg.n_layers * blk + head


def _convnext_fwd_flops(cfg, img_res: int) -> float:
    r = img_res // 4
    total = 2.0 * r * r * 4 * 4 * 3 * cfg.dims[0]
    for i, (dep, dim) in enumerate(zip(cfg.depths, cfg.dims)):
        hw = r * r
        blk = 2.0 * hw * (49 * dim + 8 * dim * dim)
        total += dep * blk
        if i + 1 < len(cfg.dims):
            total += 2.0 * (r // 2) ** 2 * 4 * dim * cfg.dims[i + 1]
            r //= 2
    return total + 2.0 * cfg.dims[-1] * cfg.n_classes


def _resnet_fwd_flops(cfg, img_res: int) -> float:
    w = cfg.width
    r = img_res // 2
    total = 2.0 * r * r * 49 * 3 * w
    r //= 2  # maxpool
    c_in = w
    for i, dep in enumerate(cfg.depths):
        mid = w * (2 ** i)
        out = mid * 4
        if i > 0:
            r //= 2
        for b in range(dep):
            total += 2.0 * r * r * (c_in * mid + 9 * mid * mid + mid * out)
            if b == 0:
                total += 2.0 * r * r * c_in * out  # projection
            c_in = out
    return total + 2.0 * c_in * cfg.n_classes


def _dit_fwd_flops(cfg, img_res: int) -> float:
    lr = img_res // cfg.latent_factor
    t = (lr // cfg.patch) ** 2
    d = cfg.d_model
    # adaLN conditioning is per-image (B, 6d), not per-token
    blk = 2.0 * t * (4 * d * d + 8 * d * d) + 4.0 * t * t * d + 2.0 * 6 * d * d
    io = 2.0 * t * (cfg.patch ** 2 * cfg.latent_ch * d * 3)
    return cfg.n_layers * blk + io


def _unet_fwd_flops(cfg, img_res: int) -> float:
    """Walks the same structure as models.unet (down+mid+up)."""
    lr = img_res // cfg.latent_factor
    ch = cfg.ch
    chans = [ch * m for m in cfg.ch_mult]

    def res_block(hw, cin, cout):
        f = 2.0 * hw * 9 * (cin * cout + cout * cout) + 2.0 * hw * 4 * ch * cout
        if cin != cout:
            f += 2.0 * hw * cin * cout
        return f

    def attn_block(hw, c):
        heads_proj = 2.0 * hw * c * c * (3 + 1 + 1 + 1 + 2)  # qkv,o,proj_in/out... approx
        sa = 4.0 * hw * hw * c
        ca = 4.0 * hw * cfg.ctx_len * c + 2.0 * cfg.ctx_len * cfg.ctx_dim * c * 2
        ff = 2.0 * hw * (8 * c * c + 4 * c * c)
        return heads_proj + sa + ca + ff

    total = 2.0 * lr * lr * 9 * cfg.latent_ch * ch
    r = lr
    prev = ch
    # down
    for lvl, c in enumerate(chans):
        hw = r * r
        for _ in range(cfg.n_res_blocks):
            total += res_block(hw, prev, c)
            if lvl in cfg.attn_levels:
                total += attn_block(hw, c)
            prev = c
        if lvl + 1 < len(chans):
            r //= 2
            total += 2.0 * r * r * 9 * c * c
    # mid
    hw = r * r
    total += 2 * res_block(hw, chans[-1], chans[-1]) + attn_block(hw, chans[-1])
    # up (skip concats raise cin)
    for lvl in reversed(range(len(chans))):
        c = chans[lvl]
        hw = r * r
        for _ in range(cfg.n_res_blocks + 1):
            total += res_block(hw, c + prev, c)
            if lvl in cfg.attn_levels:
                total += attn_block(hw, c)
            prev = c
        if lvl > 0:
            r *= 2
            total += 2.0 * r * r * 9 * c * c
    return total + 2.0 * lr * lr * 9 * ch * cfg.latent_ch


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic MODEL_FLOPS (useful work) for the roofline ratio.

    Exact matmul/conv accounting per family; train = 3x forward
    (remat recompute is implementation overhead and excluded — that is
    the point of the useful_ratio metric). MoE prices active params
    only; causal attention counts the used (lower-triangle) half.
    """
    cfg = get_config(arch)
    shape = get_shape(arch, shape_name)
    if cfg.family == "lm":
        b, s = shape.global_batch, shape.seq_len
        n_active = cfg.n_active_params
        if cfg.mla is None:
            qk_dim = v_dim = cfg.head_dim
        else:
            qk_dim = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
            v_dim = cfg.mla.v_head_dim
        h = cfg.n_heads
        if shape.kind in ("train", "prefill"):
            base = 2.0 * n_active * b * s
            # causal: half the S^2 pairs are useful
            attn_fwd = cfg.n_layers * b * s * s * h * (qk_dim + v_dim)
            mult = 3.0 if shape.kind == "train" else 1.0
            return (base + attn_fwd) * mult
        # decode: one token per sequence against an s-long cache
        base = 2.0 * n_active * b
        if cfg.mla is not None:  # absorbed-latent decode scores+combine
            attn = 2.0 * cfg.n_layers * b * s * h * (
                cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
                + cfg.mla.kv_lora_rank)
        else:
            attn = 2.0 * cfg.n_layers * b * s * h * (qk_dim + v_dim)
        return base + attn
    if cfg.family == "vision":
        b, r = shape.global_batch, shape.img_res
        per = {"vit": _vit_fwd_flops, "convnext": _convnext_fwd_flops,
               "resnet": _resnet_fwd_flops}[cfg.kind](cfg, r)
        mult = 3.0 if shape.kind == "cls" else 1.0
        return per * b * mult
    if cfg.family == "diffusion":
        b, r = shape.global_batch, shape.img_res
        per = (_dit_fwd_flops if cfg.kind == "dit" else _unet_fwd_flops)(cfg, r)
        mult = 3.0 if shape.kind == "train" else 1.0
        return per * b * mult
    # detector
    b = shape.global_batch
    from repro.core.energy import detector_gflops
    per = detector_gflops(cfg, shape.img_res) * 1e9
    mult = 3.0 if shape.kind == "train" else 1.0
    return per * b * mult
