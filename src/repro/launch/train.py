"""Distributed training driver (runs for real at reduced scale on CPU;
the same code path lowers at production scale via launch.dryrun).

  PYTHONPATH=src python -m repro.launch.train --arch vit-l16 --steps 20 \
      --reduced --batch 8

Features: pjit with the same sharding rules as the dry-run, the
fault-tolerant supervisor (checkpoint/restart, bad-step rejection),
optional int8 gradient compression for the DP all-reduce.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.models import lm as lm_mod
from repro.models import vit as vit_mod
from repro.optim.adamw import adamw
from repro.optim.compress import int8_roundtrip_tree
from repro.optim.schedule import cosine_with_warmup
from repro.runtime.supervisor import SupervisorConfig, run_training
from repro.sharding.rules import param_shardings


def build_lm_trainer(cfg, mesh, lr=3e-4, total_steps=100, compress=False):
    opt_init, opt_update = adamw(cosine_with_warmup(lr, 10, total_steps))

    def step_fn(state, batch):
        params, opt_state, key = state
        tokens, labels = batch

        def step(params, opt_state, key, tokens, labels):
            (loss, _), grads = jax.value_and_grad(lm_mod.loss_fn, has_aux=True)(
                params, cfg, tokens, labels)
            if compress:
                key, sub = jax.random.split(key)
                grads = int8_roundtrip_tree(grads, sub)
            params, opt_state, _ = opt_update(grads, opt_state, params)
            return params, opt_state, key, loss

        jstep = jax.jit(step, donate_argnums=(0, 1))
        params, opt_state, key, loss = jstep(params, opt_state, key,
                                             jnp.asarray(tokens), jnp.asarray(labels))
        return (params, opt_state, key), loss

    return step_fn, opt_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--compress", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.family != "lm":
        raise SystemExit("train.py drives LM archs; see examples/ for others")

    key = jax.random.PRNGKey(0)
    params = lm_mod.init(key, cfg)
    step_fn, opt_init = build_lm_trainer(cfg, None, total_steps=args.steps,
                                         compress=args.compress)
    opt_state = opt_init(params)
    state = (params, opt_state, key)

    rng = np.random.default_rng(0)

    def data_fn(step):
        tokens = rng.integers(0, cfg.vocab_size, (args.batch, args.seq), dtype=np.int32)
        labels = np.roll(tokens, -1, axis=1)
        return tokens, labels

    sup = SupervisorConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                           max_steps=args.steps)
    state, report = run_training(state, step_fn, data_fn, sup)
    print(f"steps={report.steps_run} resumed_from={report.resumed_from} "
          f"first_loss={report.losses[0]:.4f} last_loss={report.losses[-1]:.4f} "
          f"rejected={report.rejected_steps}")


if __name__ == "__main__":
    main()
