"""Batched Keplerian propagation: element arrays x a time grid -> ECI
positions in ONE fused jitted program.

The throughput bar (ROADMAP / OrbVeil's VALIDATION.md) is the full
~14k-object CelesTrak catalog per batch in tens of milliseconds. The
whole propagation — mean anomaly advance, a fixed-iteration Newton
solve of Kepler's equation, perifocal coordinates, and the
RAAN/inclination/argument-of-perigee rotation — is elementwise over the
``(n_sats, n_times)`` grid, so it compiles to one XLA program with no
host round-trips and no per-satellite dispatch;
``benchmarks/orbits_bench.py`` gates sats x steps throughput on it.

Two deliberate modeling choices, shared with the rest of the subsystem:

* **Two-body only** — no J2/drag. Scenario horizons here are hours, over
  which two-body error is far below the scenario generator's time-grid
  quantization; secular perturbations matter for weeks-long screening,
  not for contact-window synthesis.
* **Fixed-iteration Kepler** — ``KEPLER_ITERS`` Newton steps instead of
  a convergence loop, so the program is shape-stable and branch-free
  (vmappable, shardable). For the eccentricity cap enforced by
  :mod:`repro.orbits.elements` (< 0.25), 8 Newton steps land at
  round-off of whatever dtype jax runs in — float32 by default in this
  repo, i.e. meter-level LEO positions, far below the scenario
  generator's time-grid quantization (with ``jax_enable_x64`` the same
  program is float64 end to end).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["MU_EARTH_M3_S2", "R_EARTH_M", "OMEGA_EARTH_RAD_S",
           "KEPLER_ITERS", "orbital_period_s", "propagate",
           "propagate_jit", "gmst_rad", "eci_to_ecef"]

MU_EARTH_M3_S2 = 3.986004418e14   # standard gravitational parameter
R_EARTH_M = 6_371_000.0           # mean (spherical-model) Earth radius
OMEGA_EARTH_RAD_S = 7.2921159e-5  # sidereal rotation rate
KEPLER_ITERS = 8                  # fixed Newton steps (see module docstring)


def orbital_period_s(a_m) -> np.ndarray:
    """Keplerian period T = 2 pi sqrt(a^3 / mu)."""
    a = np.asarray(a_m, np.float64)
    return 2.0 * np.pi * np.sqrt(a ** 3 / MU_EARTH_M3_S2)


def _kepler(mean_anom, ecc):
    """Eccentric anomaly from mean anomaly: ``KEPLER_ITERS`` Newton
    steps on ``E - e sin E = M`` (branch-free; exact pass-through at
    e = 0 where E = M after the first step)."""
    E = mean_anom
    for _ in range(KEPLER_ITERS):
        E = E - (E - ecc * jnp.sin(E) - mean_anom) / (1.0 - ecc * jnp.cos(E))
    return E


def _propagate(a, ecc, inc, raan, argp, m0, times_s):
    """(n_sats,) elements x (n_times,) seconds -> (n_sats, n_times, 3)
    ECI positions in meters. Pure jnp; jit/vmap/shard-safe."""
    n = jnp.sqrt(MU_EARTH_M3_S2 / a ** 3)                  # (S,)
    M = m0[:, None] + n[:, None] * times_s[None, :]        # (S, T)
    e = ecc[:, None]
    E = _kepler(M, e)
    cosE, sinE = jnp.cos(E), jnp.sin(E)
    # perifocal coordinates (z = 0)
    b_over_a = jnp.sqrt(1.0 - e * e)
    xp = a[:, None] * (cosE - e)
    yp = a[:, None] * b_over_a * sinE
    # perifocal -> ECI: R3(-raan) R1(-inc) R3(-argp); expanded to the
    # two basis columns so the whole rotation is 6 fused multiplies
    cO, sO = jnp.cos(raan)[:, None], jnp.sin(raan)[:, None]
    ci, si = jnp.cos(inc)[:, None], jnp.sin(inc)[:, None]
    cw, sw = jnp.cos(argp)[:, None], jnp.sin(argp)[:, None]
    px = cO * cw - sO * sw * ci
    py = sO * cw + cO * sw * ci
    pz = sw * si
    qx = -cO * sw - sO * cw * ci
    qy = -sO * sw + cO * cw * ci
    qz = cw * si
    return jnp.stack([xp * px + yp * qx,
                      xp * py + yp * qy,
                      xp * pz + yp * qz], axis=-1)         # (S, T, 3)


propagate_jit = jax.jit(_propagate)


def propagate(elements, times_s):
    """Batch-propagate a catalog over a time grid.

    ``elements``: :class:`~repro.orbits.elements.OrbitalElements`
    (``n_sats`` stacked element arrays); ``times_s``: ``(n_times,)``
    seconds past epoch. Returns ``(n_sats, n_times, 3)`` ECI positions
    (meters) as a device array from one jitted program — the compiled
    program is reused across catalogs of the same ``(n_sats, n_times)``
    shape.
    """
    times = jnp.asarray(np.asarray(times_s, np.float64))
    return propagate_jit(*[jnp.asarray(v) for v in elements.arrays()],
                         times)


def gmst_rad(times_s, gmst0_rad: float = 0.0):
    """Greenwich mean sidereal angle over the grid (linear model —
    scenario epochs are arbitrary, so a rate-accurate angle is all the
    geometry needs)."""
    return gmst0_rad + OMEGA_EARTH_RAD_S * jnp.asarray(times_s)


@partial(jax.jit, static_argnames=())
def eci_to_ecef(pos_eci, times_s, gmst0_rad: float = 0.0):
    """Rotate ``(..., n_times, 3)`` ECI positions into the rotating
    Earth-fixed frame (R3 by the sidereal angle)."""
    g = gmst_rad(times_s, gmst0_rad)
    cg, sg = jnp.cos(g), jnp.sin(g)
    x, y, z = pos_eci[..., 0], pos_eci[..., 1], pos_eci[..., 2]
    return jnp.stack([cg * x + sg * y, -sg * x + cg * y, z], axis=-1)
