"""Catalog-scale orbital geometry engine.

JAX-native batched orbital mechanics for constellation scenarios: stacked
Keplerian element arrays (:mod:`repro.orbits.elements`), a jitted/vmapped
propagator mapping ``(n_sats,)`` elements x ``(n_times,)`` time grids to
ECI position batches in one fused program
(:mod:`repro.orbits.propagation`), ground-station elevation masks with
vectorized pass extraction and cylindrical Earth-shadow eclipse modeling
(:mod:`repro.orbits.visibility`), and the scenario bridge turning passes
and eclipse fractions into :class:`~repro.data.scenarios.ContactEvent`
streams and harvest energy grants (:mod:`repro.orbits.schedule`).

``FleetScenarioSpec(geometry="orbital")`` routes
:func:`repro.data.scenarios.generate_scenario` through this subsystem;
``geometry="toy"`` (the default) keeps the bit-equal phase-offset model.
"""
from repro.orbits.elements import OrbitalElements, shell, walker_delta
from repro.orbits.propagation import (MU_EARTH_M3_S2, OMEGA_EARTH_RAD_S,
                                      R_EARTH_M, orbital_period_s,
                                      propagate)
from repro.orbits.schedule import (default_sites, generate_orbital_scenario,
                                   pass_contacts)
from repro.orbits.visibility import (PassSet, eclipse_fractions, eclipse_mask,
                                     elevation_deg, extract_passes,
                                     station_ecef, sun_direction)

__all__ = [
    "OrbitalElements", "walker_delta", "shell",
    "propagate", "orbital_period_s",
    "MU_EARTH_M3_S2", "R_EARTH_M", "OMEGA_EARTH_RAD_S",
    "station_ecef", "elevation_deg", "extract_passes", "PassSet",
    "sun_direction", "eclipse_mask", "eclipse_fractions",
    "pass_contacts", "generate_orbital_scenario", "default_sites",
]
