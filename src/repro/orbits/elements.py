"""Stacked orbital-element arrays and constellation constructors.

One :class:`OrbitalElements` instance holds a WHOLE catalog as aligned
``(n_sats,)`` float64 arrays — the stacked-array layout the batched
propagator consumes directly (no per-satellite objects, no Python loop
between the catalog and the compiled program). Construct through
:func:`walker_delta` (the Walker-delta pattern behind Starlink-style
shells), :func:`shell` (a seeded scattered single-altitude shell), or
the validating constructor itself; malformed catalogs — a perigee below
the atmosphere floor, an inclination outside ``[0, pi]``, misaligned
arrays — raise ``ValueError`` at build time, the same fail-at-build
contract as :class:`~repro.core.contact.ContactPlan`.

Angles are radians internally (constructors take degrees where noted);
lengths are meters. Eccentricity is capped well below parabolic so the
fixed-iteration Kepler solve in :mod:`repro.orbits.propagation` is
uniformly convergent over any valid catalog.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.orbits.propagation import R_EARTH_M

__all__ = ["OrbitalElements", "walker_delta", "shell", "ECC_MAX",
           "MIN_PERIGEE_ALT_M"]

ECC_MAX = 0.25            # Newton-on-Kepler converges in 8 steps below this
MIN_PERIGEE_ALT_M = 80e3  # below ~80 km an orbit is re-entry, not a catalog


@dataclass(frozen=True)
class OrbitalElements:
    """A catalog of ``n_sats`` Keplerian element sets as stacked arrays.

    ``a_m`` semi-major axis (m), ``ecc`` eccentricity, ``inc_rad``
    inclination, ``raan_rad`` right ascension of the ascending node,
    ``argp_rad`` argument of perigee, ``m0_rad`` mean anomaly at epoch.
    All ``(n_sats,)`` float64, validated and stored contiguous.
    """

    a_m: np.ndarray
    ecc: np.ndarray
    inc_rad: np.ndarray
    raan_rad: np.ndarray
    argp_rad: np.ndarray
    m0_rad: np.ndarray

    _FIELDS = ("a_m", "ecc", "inc_rad", "raan_rad", "argp_rad", "m0_rad")

    def __post_init__(self):
        arrays = {}
        shape = None
        for f in self._FIELDS:
            v = np.ascontiguousarray(getattr(self, f), np.float64)
            if v.ndim != 1:
                raise ValueError(
                    f"OrbitalElements: {f} must be 1-D (n_sats,), got "
                    f"shape {v.shape}")
            if shape is None:
                shape = v.shape
            elif v.shape != shape:
                raise ValueError(
                    f"OrbitalElements: {f} has shape {v.shape}, expected "
                    f"{shape} (all element arrays must be aligned)")
            if not np.isfinite(v).all():
                raise ValueError(f"OrbitalElements: {f} contains non-finite "
                                 f"entries")
            arrays[f] = v
        if shape[0] < 1:
            raise ValueError("OrbitalElements: a catalog needs at least one "
                             "satellite")
        bad = arrays["ecc"] < 0.0
        bad |= arrays["ecc"] >= ECC_MAX
        if bad.any():
            i = int(np.flatnonzero(bad)[0])
            raise ValueError(
                f"OrbitalElements: satellite {i} has eccentricity "
                f"{arrays['ecc'][i]}, outside [0, {ECC_MAX}) (the fixed-"
                f"iteration Kepler solve's convergence envelope)")
        perigee = arrays["a_m"] * (1.0 - arrays["ecc"])
        bad = perigee < R_EARTH_M + MIN_PERIGEE_ALT_M
        if bad.any():
            i = int(np.flatnonzero(bad)[0])
            raise ValueError(
                f"OrbitalElements: satellite {i} has perigee altitude "
                f"{(perigee[i] - R_EARTH_M) / 1e3:.1f} km, below the "
                f"{MIN_PERIGEE_ALT_M / 1e3:.0f} km floor")
        bad = (arrays["inc_rad"] < 0.0) | (arrays["inc_rad"] > np.pi)
        if bad.any():
            i = int(np.flatnonzero(bad)[0])
            raise ValueError(
                f"OrbitalElements: satellite {i} has inclination "
                f"{arrays['inc_rad'][i]} rad, outside [0, pi]")
        for f, v in arrays.items():
            object.__setattr__(self, f, v)

    @property
    def n_sats(self) -> int:
        return int(self.a_m.shape[0])

    def arrays(self):
        """The stacked arrays in propagator order."""
        return (self.a_m, self.ecc, self.inc_rad, self.raan_rad,
                self.argp_rad, self.m0_rad)


def walker_delta(n_sats: int, n_planes: int, inc_deg: float, alt_km: float,
                 phasing: int = 1, raan0_deg: float = 0.0,
                 ecc: float = 0.0) -> OrbitalElements:
    """Walker-delta pattern ``i: n_sats / n_planes / phasing``.

    ``n_planes`` equally-spaced RAAN planes of ``n_sats / n_planes``
    satellites each (``n_sats`` must divide evenly); the relative
    in-plane phase between adjacent planes advances by
    ``phasing * 360 / n_sats`` degrees — the standard Walker phasing
    parameter ``f in [0, n_planes)``.
    """
    n_sats, n_planes = int(n_sats), int(n_planes)
    if n_sats < 1 or n_planes < 1:
        raise ValueError(f"walker_delta: need n_sats >= 1 and n_planes >= 1, "
                         f"got {n_sats}/{n_planes}")
    if n_sats % n_planes:
        raise ValueError(f"walker_delta: {n_planes} planes do not divide "
                         f"{n_sats} satellites evenly")
    if not 0 <= int(phasing) < n_planes:
        raise ValueError(f"walker_delta: phasing {phasing} outside "
                         f"[0, {n_planes})")
    per_plane = n_sats // n_planes
    plane = np.repeat(np.arange(n_planes), per_plane)
    slot = np.tile(np.arange(per_plane), n_planes)
    raan = np.radians(raan0_deg) + 2.0 * np.pi * plane / n_planes
    m0 = (2.0 * np.pi * slot / per_plane
          + 2.0 * np.pi * int(phasing) * plane / n_sats)
    n = np.full(n_sats, np.nan)
    return OrbitalElements(
        a_m=np.full_like(n, R_EARTH_M + float(alt_km) * 1e3),
        ecc=np.full_like(n, float(ecc)),
        inc_rad=np.full_like(n, np.radians(float(inc_deg))),
        raan_rad=raan % (2.0 * np.pi),
        argp_rad=np.zeros_like(n),
        m0_rad=m0 % (2.0 * np.pi))


def shell(n_sats: int, inc_deg: float, alt_km: float, seed: int = 0,
          ecc_max: float = 0.02) -> OrbitalElements:
    """A seeded scattered shell: one altitude/inclination, RAAN and
    anomaly drawn uniformly (small random eccentricities below
    ``ecc_max``) — the catalog shape of a debris belt or a mixed
    operator shell, for stress-testing at sizes with no Walker
    structure."""
    n_sats = int(n_sats)
    if n_sats < 1:
        raise ValueError(f"shell: need n_sats >= 1, got {n_sats}")
    if not 0.0 <= float(ecc_max) < ECC_MAX:
        raise ValueError(f"shell: ecc_max {ecc_max} outside [0, {ECC_MAX})")
    rng = np.random.default_rng(seed)
    return OrbitalElements(
        a_m=np.full(n_sats, R_EARTH_M + float(alt_km) * 1e3),
        ecc=rng.uniform(0.0, float(ecc_max), n_sats),
        inc_rad=np.full(n_sats, np.radians(float(inc_deg))),
        raan_rad=rng.uniform(0.0, 2.0 * np.pi, n_sats),
        argp_rad=rng.uniform(0.0, 2.0 * np.pi, n_sats),
        m0_rad=rng.uniform(0.0, 2.0 * np.pi, n_sats))
