"""Ground-station visibility and eclipse geometry over propagated
position batches.

Three layers, matching how the scenario bridge consumes them:

* **Elevation series** — ground stations become ECEF vectors
  (:func:`station_ecef`, spherical Earth — consistent with the
  propagator's mean-radius shadow model), get rotated through the
  sidereal angle into ECI per time step, and the whole
  ``(n_stations, n_sats, n_times)`` elevation grid comes out of one
  jitted program (:func:`elevation_deg`).

* **Pass extraction** — thresholding the elevation grid at a minimum
  elevation gives visibility masks; :func:`extract_passes` turns every
  row's mask into contact passes via SEGMENT SCANS (padded diff for
  rise/set edges, cumulative pass ids, ``ufunc.at`` reductions for
  per-pass max elevation and culmination) — no Python loop over rows or
  passes, so a full catalog x station-network grid extracts in one
  shot. Each pass is a maximal contiguous above-mask run: start/end
  indices, rise/set/culmination times, duration, max elevation.

* **Eclipse** — the cylindrical Earth-shadow test of the
  energy-harvest literature (arXiv 2111.09045): a satellite is
  eclipsed iff it sits behind the terminator plane (anti-sun side) AND
  inside the shadow cylinder of radius ``R_EARTH``
  (:func:`eclipse_mask`, with a circular-ecliptic sun from
  :func:`sun_direction`); :func:`eclipse_fractions` folds the mask
  into per-window shadow fractions that the scenario bridge turns into
  harvest energy grants.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.orbits.propagation import OMEGA_EARTH_RAD_S, R_EARTH_M

__all__ = ["station_ecef", "elevation_deg", "extract_passes", "PassSet",
           "sun_direction", "eclipse_mask", "eclipse_fractions",
           "YEAR_S", "OBLIQUITY_RAD"]

YEAR_S = 365.25 * 86_400.0
OBLIQUITY_RAD = float(np.radians(23.439))


def station_ecef(lat_deg: float, lon_deg: float,
                 alt_m: float = 0.0) -> np.ndarray:
    """Geodetic site -> ECEF vector (m), spherical Earth model."""
    lat, lon = np.radians(float(lat_deg)), np.radians(float(lon_deg))
    r = R_EARTH_M + float(alt_m)
    return np.array([r * np.cos(lat) * np.cos(lon),
                     r * np.cos(lat) * np.sin(lon),
                     r * np.sin(lat)], np.float64)


def _elevation(pos_eci, times_s, stations_ecef, gmst0, omega):
    """(S, T, 3) positions x (N, 3) stations -> (N, S, T) elevation
    (degrees). Stations rotate into ECI by the sidereal angle (R3 of
    -theta applied to the ECEF site), which avoids rotating the much
    larger satellite batch."""
    g = gmst0 + omega * times_s                            # (T,)
    cg, sg = jnp.cos(g), jnp.sin(g)
    sx, sy, sz = (stations_ecef[:, 0][:, None],
                  stations_ecef[:, 1][:, None],
                  stations_ecef[:, 2][:, None])            # (N, 1)
    st = jnp.stack([cg[None, :] * sx - sg[None, :] * sy,
                    sg[None, :] * sx + cg[None, :] * sy,
                    jnp.broadcast_to(sz, sx.shape[:1] + g.shape)],
                   axis=-1)                                # (N, T, 3)
    up = st / jnp.linalg.norm(st, axis=-1, keepdims=True)
    d = pos_eci[None, :, :, :] - st[:, None, :, :]         # (N, S, T, 3)
    sin_el = (jnp.sum(d * up[:, None, :, :], axis=-1)
              / jnp.linalg.norm(d, axis=-1))
    return jnp.degrees(jnp.arcsin(jnp.clip(sin_el, -1.0, 1.0)))


_elevation_jit = jax.jit(_elevation)


def elevation_deg(pos_eci, times_s, stations_ecef, gmst0_rad: float = 0.0,
                  omega_rad_s: float = OMEGA_EARTH_RAD_S):
    """Elevation grid ``(n_stations, n_sats, n_times)`` in degrees, one
    jitted program. ``omega_rad_s=0.0`` freezes Earth rotation (the
    symmetry oracle used by the property tests)."""
    return _elevation_jit(
        jnp.asarray(pos_eci), jnp.asarray(np.asarray(times_s, np.float64)),
        jnp.asarray(np.atleast_2d(np.asarray(stations_ecef, np.float64))),
        float(gmst0_rad), float(omega_rad_s))


@dataclass(frozen=True)
class PassSet:
    """Extracted contact passes over flattened elevation rows.

    ``row[p]`` indexes the flattened leading axes of the elevation grid
    the passes came from (unravel with ``np.unravel_index(row,
    grid.shape[:-1])`` to recover (station, sat)); ``start``/``stop``
    are the [inclusive, exclusive) time-grid indices of the maximal
    above-mask run. Times are seconds on the caller's grid;
    ``duration_s`` counts each above-mask sample at its grid step, so a
    single-sample grazing pass still carries one step of contact time.
    """

    row: np.ndarray          # (n_passes,) int64
    start: np.ndarray        # (n_passes,) int64, inclusive
    stop: np.ndarray         # (n_passes,) int64, exclusive
    t_rise: np.ndarray       # (n_passes,) f64 seconds
    t_set: np.ndarray        # (n_passes,) f64 seconds (last sample)
    duration_s: np.ndarray   # (n_passes,) f64
    max_elev_deg: np.ndarray  # (n_passes,) f64
    t_culminate: np.ndarray  # (n_passes,) f64 seconds (first max sample)

    @property
    def n_passes(self) -> int:
        return int(self.row.shape[0])


def extract_passes(elev_deg, times_s, min_elev_deg: float) -> PassSet:
    """Vectorized pass extraction over ``(..., n_times)`` elevation rows.

    Pure segment scans — a zero-padded ``diff`` finds every rise/set
    edge at once, a cumulative count of rise edges labels each
    above-mask sample with its pass id, and ``np.maximum.at`` /
    ``np.minimum.at`` reduce per-pass max elevation and culmination —
    so the cost is O(rows x times) regardless of how many passes there
    are, with no Python loop over either.
    """
    elev = np.asarray(elev_deg, np.float64)
    times = np.asarray(times_s, np.float64)
    T = elev.shape[-1]
    if times.shape != (T,):
        raise ValueError(f"extract_passes: {times.shape[0] if times.ndim else 0}"
                         f"-point time grid for {T}-sample elevation rows")
    rows = elev.reshape(-1, T)
    mask = rows >= float(min_elev_deg)

    padded = np.zeros((rows.shape[0], T + 2), np.int8)
    padded[:, 1:-1] = mask
    edges = np.diff(padded, axis=1)            # (R, T+1): +1 rise, -1 set
    r_rise, t_rise_i = np.nonzero(edges == 1)  # row-major -> passes pair up
    r_set, t_set_i = np.nonzero(edges == -1)   # t_set_i is EXCLUSIVE stop
    n = r_rise.shape[0]
    assert r_set.shape[0] == n and (r_rise == r_set).all()

    # per-sample pass ids: cumulative rise count over the flat grid
    marks = np.zeros((rows.shape[0], T), bool)
    marks[r_rise, t_rise_i] = True
    pid = np.cumsum(marks.ravel()) - 1
    fm = mask.ravel()
    pid_m, val_m = pid[fm], rows.ravel()[fm]

    max_elev = np.full(n, -np.inf)
    np.maximum.at(max_elev, pid_m, val_m)
    # culmination = FIRST sample attaining the pass max
    flat_idx = np.flatnonzero(fm)
    at_max = val_m == max_elev[pid_m]
    culm_flat = np.full(n, rows.size, np.int64)
    np.minimum.at(culm_flat, pid_m[at_max], flat_idx[at_max])
    culm_t = culm_flat % T

    # duration: each sample counts one grid step (last step extrapolated)
    if T > 1:
        steps = np.append(np.diff(times), times[-1] - times[-2])
    else:
        steps = np.zeros(1)
    edges_t = np.append(times, times[-1] + steps[-1])
    return PassSet(
        row=r_rise.astype(np.int64),
        start=t_rise_i.astype(np.int64),
        stop=t_set_i.astype(np.int64),
        t_rise=times[t_rise_i],
        t_set=times[t_set_i - 1],
        duration_s=edges_t[t_set_i] - times[t_rise_i],
        max_elev_deg=max_elev,
        t_culminate=times[culm_t] if n else np.zeros(0))


def sun_direction(times_s, sun_lon0_rad: float = 0.0):
    """(n_times, 3) unit sun direction: circular ecliptic model (mean
    motion over :data:`YEAR_S`, obliquity tilt) — plenty for shadow
    geometry whose epoch is arbitrary anyway."""
    t = jnp.asarray(np.asarray(times_s, np.float64))
    lam = sun_lon0_rad + 2.0 * jnp.pi * t / YEAR_S
    ce, se = np.cos(OBLIQUITY_RAD), np.sin(OBLIQUITY_RAD)
    return jnp.stack([jnp.cos(lam), jnp.sin(lam) * ce, jnp.sin(lam) * se],
                     axis=-1)


def _eclipse(pos_eci, sun_dir):
    proj = jnp.sum(pos_eci * sun_dir[None, :, :], axis=-1)   # (S, T)
    rho2 = jnp.sum(pos_eci * pos_eci, axis=-1) - proj * proj
    return (proj < 0.0) & (rho2 < R_EARTH_M * R_EARTH_M)


_eclipse_jit = jax.jit(_eclipse)


def eclipse_mask(pos_eci, sun_dir):
    """Cylindrical Earth-shadow test: ``(n_sats, n_times)`` True where
    the satellite is behind the terminator plane AND inside the shadow
    cylinder of radius ``R_EARTH`` around the anti-sun axis."""
    return _eclipse_jit(jnp.asarray(pos_eci), jnp.asarray(sun_dir))


def eclipse_fractions(mask, bounds) -> np.ndarray:
    """Fold an eclipse mask into per-window shadow fractions.

    ``bounds``: ``(n_windows + 1,)`` time-grid indices (window ``w`` is
    ``[bounds[w], bounds[w+1])``). Returns ``(n_sats, n_windows)``
    fractions in [0, 1]; an empty window is fully sunlit (0.0).
    """
    m = np.asarray(mask, np.float64)
    bounds = np.asarray(bounds, np.int64)
    sums = np.concatenate([np.zeros((m.shape[0], 1)), np.cumsum(m, axis=1)],
                          axis=1)
    width = np.maximum(np.diff(bounds), 1)[None, :]
    return (sums[:, bounds[1:]] - sums[:, bounds[:-1]]) / width
