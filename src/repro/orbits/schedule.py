"""Scenario bridge: propagated geometry -> fleet rounds.

This is where the orbital subsystem meets the fleet/contact tiers, and
the contract is that NOTHING downstream changes: a
``FleetScenarioSpec(geometry="orbital")`` still expands into the same
:class:`~repro.data.scenarios.FleetScenario` of
:class:`~repro.data.scenarios.Round` objects — frames + harvest grants
for ``Mission.ingest`` and :class:`~repro.data.scenarios.ContactEvent`
lists that ``Round.contact_plan`` folds into a validated
``ContactPlan.from_contacts`` — so ``Fleet.run_scenario`` and the
looped-Mission oracle consume it unmodified.

What changes is where the numbers come from:

* **Contacts** are real extracted passes (elevation grid -> segment-scan
  pass extraction), not a round-robin rotation. Bandwidth scales with
  each pass's max elevation through the SAME
  :func:`~repro.data.scenarios.elevation_bandwidth` rule as the toy
  path, and the byte budget integrates that bandwidth over the actual
  pass duration. Real geometry makes the pass mix heavy-tailed — many
  short low-elevation grazes, few long overhead passes — which is the
  skew the `fleet_bench` stations sweep exercises.
* **Harvest grants** come from the cylindrical Earth-shadow eclipse
  fractions per round window: ``harvest_w x sunlit-seconds`` replaces
  the toy phase-offset profile.

Frame content is drawn from the same per-satellite seeded generators as
the toy path, so switching geometry re-times contacts and re-prices
energy without changing what the cameras see.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.throttle import contact_budget_bytes
from repro.data.scenarios import (ContactEvent, FleetScenario,
                                  FleetScenarioSpec, PassEvent, Round,
                                  elevation_bandwidth)
from repro.data.synthetic import make_scene, revisit_frames
from repro.orbits.elements import OrbitalElements, walker_delta
from repro.orbits.propagation import propagate
from repro.orbits.visibility import (PassSet, eclipse_fractions, eclipse_mask,
                                     elevation_deg, extract_passes,
                                     station_ecef, sun_direction)

__all__ = ["default_sites", "spec_elements", "pass_contacts",
           "generate_orbital_scenario"]

# Mid/high-latitude mix typical of commercial ground networks; longitudes
# spread by the golden angle so any prefix of sites is globally dispersed.
_SITE_LATS = (5.0, 40.0, -33.0, 64.0, -12.0, 52.0, -45.0, 21.0)


def default_sites(n: int) -> Tuple[Tuple[float, float], ...]:
    """``n`` deterministic, globally dispersed ``(lat_deg, lon_deg)``
    sites for examples/benchmarks that don't care where their stations
    are, only that they are spread out."""
    return tuple((_SITE_LATS[k % len(_SITE_LATS)],
                  ((137.50776 * k + 10.0) % 360.0) - 180.0)
                 for k in range(int(n)))


def spec_elements(spec: FleetScenarioSpec) -> OrbitalElements:
    """The spec's constellation as a Walker-delta catalog.

    ``n_planes=0`` auto-picks the largest divisor of ``n_sats`` at most
    ``sqrt(n_sats)`` — a near-square Walker grid that degrades cleanly
    to a single plane for primes and tiny fleets.
    """
    planes = int(spec.n_planes)
    if planes == 0:
        planes = max(d for d in range(1, int(np.sqrt(spec.n_sats)) + 1)
                     if spec.n_sats % d == 0)
    return walker_delta(spec.n_sats, planes, spec.inc_deg, spec.alt_km,
                        phasing=1 if planes > 1 else 0)


def pass_contacts(spec: FleetScenarioSpec, passes: PassSet,
                  n_stations: int) -> List[List[ContactEvent]]:
    """Price extracted passes into per-round :class:`ContactEvent` lists.

    Each pass becomes one window: bandwidth from its max elevation via
    the shared :func:`elevation_bandwidth` rule, byte budget from that
    bandwidth over the pass duration (scaled by ``window_budget_scale``
    like the toy path). A pass lands in the round containing its rise
    time (clamped to the horizon); within a round, windows execute in
    rise-time order.
    """
    per_round: List[List[ContactEvent]] = [[] for _ in range(spec.n_rounds)]
    if passes.n_passes == 0:
        return per_round
    sta_i, sat_i = np.unravel_index(passes.row, (n_stations, spec.n_sats))
    for p in np.argsort(passes.t_rise, kind="stable"):
        station = spec.stations[int(sta_i[p])]
        bw = elevation_bandwidth(float(passes.max_elev_deg[p]), station)
        budget = (contact_budget_bytes(bw, float(passes.duration_s[p]))
                  * spec.window_budget_scale)
        rnd = min(int(passes.t_rise[p] // spec.pass_s), spec.n_rounds - 1)
        per_round[rnd].append(ContactEvent(sat=int(sat_i[p]), station=station,
                                           bandwidth_mbps=bw,
                                           budget_bytes=budget))
    return per_round


def generate_orbital_scenario(spec: FleetScenarioSpec) -> FleetScenario:
    """Expand a ``geometry="orbital"`` spec into concrete rounds.

    One batched propagation covers the whole horizon (``n_rounds x
    pass_s`` at ``time_step_s`` resolution); visibility, pass
    extraction, and eclipse fractions all derive from that single
    position batch. Deterministic for a given spec — same seed, same
    scenario, byte for byte.
    """
    missing = [st.name for st in spec.stations if st.site is None]
    if missing:
        raise ValueError(
            f"generate_orbital_scenario: stations {missing} have no site "
            f"(lat_deg, lon_deg); geometry='orbital' needs real locations — "
            f"see repro.orbits.default_sites")
    dt = spec.time_step_s
    n_steps = max(int(round(spec.n_rounds * spec.pass_s / dt)), spec.n_rounds)
    times = np.arange(n_steps, dtype=np.float64) * dt

    pos = propagate(spec_elements(spec), times)
    sites = np.stack([station_ecef(*st.site) for st in spec.stations])
    elev = np.asarray(elevation_deg(pos, times, sites))
    passes = extract_passes(elev, times, spec.min_elev_deg)
    shadow = np.asarray(eclipse_mask(pos, sun_direction(times)))
    bounds = np.clip(np.round(np.arange(spec.n_rounds + 1) * spec.pass_s / dt)
                     .astype(np.int64), 0, n_steps)
    frac = eclipse_fractions(shadow, bounds)              # (S, n_rounds)
    contacts = pass_contacts(spec, passes, len(spec.stations))

    rngs = [np.random.default_rng(10_000 * spec.seed + s)
            for s in range(spec.n_sats)]
    rounds = []
    for r in range(spec.n_rounds):
        rnd = Round(index=r, contacts=contacts[r])
        for s in range(spec.n_sats):
            scene = spec.scene_mix[s % len(spec.scene_mix)]
            img, b, c = make_scene(rngs[s], scene)
            frames = revisit_frames(rngs[s], img, b, c, spec.frames_per_pass)
            f = float(frac[s, r])
            rnd.passes.append(PassEvent(
                sat=s, frames=frames,
                harvest_j=spec.harvest_w * (1.0 - f) * spec.pass_s,
                sunlit=f < 0.5))
        rounds.append(rnd)
    return FleetScenario(spec=spec, rounds=rounds)
