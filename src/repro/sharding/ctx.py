"""Activation sharding constraints that degrade to no-ops off-mesh.

Models call ``constrain(x, ..axes..)`` at layout-critical points (MoE
dispatch buffers, attention outputs). Under a mesh context (pjit/dry-run)
it emits ``with_sharding_constraint``; in plain CPU tests (no mesh) it
is a no-op, so model code stays mesh-agnostic.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _active_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is None or not m.axis_names:
            return None
        return m
    except Exception:
        return None


def axis_size(name: str):
    """Size of a mesh axis in the active mesh (None when off-mesh)."""
    m = _active_mesh()
    if m is None:
        return None
    sizes = dict(zip(m.axis_names, m.axis_sizes))
    return sizes.get(name)


def constrain(x, *spec):
    """spec entries: axis name(s) or None, one per dim of x."""
    m = _active_mesh()
    if m is None:
        return x
    sizes = dict(zip(m.axis_names, m.axis_sizes))
    parts = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            parts.append(None)
            continue
        axs = (ax,) if isinstance(ax, str) else tuple(ax)
        # keep the subset of axes this mesh actually has (e.g. "pod"
        # only exists on the multi-pod mesh)
        axs = tuple(a for a in axs if a in sizes)
        if not axs:
            parts.append(None)
            continue
        n = 1
        for a in axs:
            n *= sizes[a]
        parts.append((axs if len(axs) > 1 else axs[0]) if dim % n == 0 else None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*parts))
    except Exception:
        return x
