"""Parameter sharding rules: param-tree path patterns -> PartitionSpec.

TP over the "model" axis (column/row-parallel matmuls, expert
parallelism for MoE, channel parallelism for convs); everything small
(norms, routers, biases) replicated. Rules are suffix-regexes over the
'/'-joined tree path; first match wins, default replicate.
"""
from __future__ import annotations

import re
from typing import List, Tuple

import jax
from jax.sharding import PartitionSpec as P

Rules = List[Tuple[str, P]]

# stacked LM blocks carry a leading layer dim -> specs below include it
LM_RULES: Rules = [
    (r"embed$", P("model", None)),
    (r"lm_head$", P(None, "model")),
    # GQA attention
    (r"attn/w[qkv]$", P(None, None, "model")),
    (r"attn/wo$", P(None, "model", None)),
    # MLA
    (r"attn/w_dkv$", P(None, None, None)),
    (r"attn/w_u[kv]$", P(None, None, "model")),
    # dense FFN
    (r"mlp/w_(gate|up)$", P(None, None, "model")),
    (r"mlp/w_down$", P(None, "model", None)),
    # MoE: experts sharded over the model axis (EP)
    (r"moe/w_(gate|up)$", P(None, "model", None, None)),
    (r"moe/w_down$", P(None, "model", None, None)),
    (r"moe/shared/w_(gate|up)$", P(None, None, "model")),
    (r"moe/shared/w_down$", P(None, "model", None)),
]

VIT_RULES: Rules = [
    (r"patch_w$", P(None, None, None, "model")),
    (r"blocks/wqkv$", P(None, None, "model")),
    (r"blocks/wo$", P(None, "model", None)),
    (r"blocks/w_in$", P(None, None, "model")),
    (r"blocks/w_out$", P(None, "model", None)),
    (r"head$", P(None, "model")),
]

CONVNEXT_RULES: Rules = [
    (r"stem_w$", P(None, None, None, "model")),
    (r"stages/\d+/pw1$", P(None, None, "model")),
    (r"stages/\d+/pw2$", P(None, "model", None)),
    (r"downs/\d+/w$", P(None, None, None, "model")),
    (r"head$", P(None, "model")),
]

RESNET_RULES: Rules = [
    (r"w[123]$", P(None, None, None, "model")),
    (r"proj_w$", P(None, None, None, "model")),
    (r"stem_w$", P(None, None, None, "model")),
    (r"head$", P("model", None)),
]

DIT_RULES: Rules = [
    (r"blocks/wqkv$", P(None, None, "model")),
    (r"blocks/wo$", P(None, "model", None)),
    (r"blocks/w_in$", P(None, None, "model")),
    (r"blocks/w_out$", P(None, "model", None)),
    (r"blocks/ada_w$", P(None, None, "model")),
    (r"y_emb$", P("model", None)),
]

UNET_RULES: Rules = [
    (r"/w[12]$", P(None, None, None, "model")),
    (r"skip_w$", P(None, None, None, "model")),
    (r"(down|up)_w$", P(None, None, None, "model")),
    (r"sa_qkv$", P(None, "model")),
    (r"sa_o$", P("model", None)),
    (r"ca_[qkv]$", P(None, "model")),
    (r"ca_o$", P("model", None)),
    (r"ff_in$", P(None, "model")),
    (r"ff_out$", P("model", None)),
    (r"proj_(in|out)$", P(None, "model")),
    (r"temb_w$", P(None, "model")),
]

DETECTOR_RULES: Rules = [
    (r"stem$", P(None, None, None, "model")),
    (r"stages/\d+/\d+/w$", P(None, None, None, "model")),
    (r"head_w$", P(None, None, "model", None)),
]


def rules_for(cfg) -> Rules:
    fam = cfg.family
    if fam == "lm":
        return LM_RULES
    if fam == "vision":
        return {"vit": VIT_RULES, "convnext": CONVNEXT_RULES,
                "resnet": RESNET_RULES}[cfg.kind]
    if fam == "diffusion":
        return DIT_RULES if cfg.kind == "dit" else UNET_RULES
    if fam == "detector":
        return DETECTOR_RULES
    raise KeyError(fam)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_for_path(path_str: str, ndim: int, rules: Rules) -> P:
    for pat, spec in rules:
        if re.search(pat, path_str):
            if len(spec) == ndim:
                return spec
            # rank mismatch (e.g. un-stacked vs stacked): right-align
            if len(spec) < ndim:
                return P(*([None] * (ndim - len(spec)) + list(spec)))
            return P(*spec[len(spec) - ndim:])
    return P(*([None] * ndim))


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop spec axes whose mesh size doesn't divide the dim (pjit input
    shardings must divide evenly; falls back to replication per-dim)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    parts = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            parts.append(None)
            continue
        axs = (ax,) if isinstance(ax, str) else tuple(ax)
        n = 1
        for a in axs:
            n *= sizes.get(a, 1)
        parts.append(ax if dim % n == 0 else None)
    return P(*parts)


def param_specs(params, cfg, mesh=None):
    """Pytree of PartitionSpec matching `params` (mesh-sanitized if a
    mesh is given)."""
    rules = rules_for(cfg)

    def f(path, leaf):
        s = spec_for_path(_path_str(path), leaf.ndim, rules)
        return sanitize_spec(s, leaf.shape, mesh) if mesh is not None else s

    return jax.tree_util.tree_map_with_path(f, params)


def param_shardings(params, mesh, cfg):
    from jax.sharding import NamedSharding
    specs = param_specs(params, cfg, mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)
