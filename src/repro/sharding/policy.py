"""Input/activation sharding policies per (family, shape, mesh).

One place to audit how every dry-run cell is laid out:

- batch shards over ("pod","data") when divisible, over a prefix of
  those axes when partially divisible, else falls back to
  sequence/spatial sharding (gen_1024 B=4, serve_b1/long_500k B=1).
- decode KV caches shard their *length* dim over "data" when the batch
  can't use it (long_500k: 512k-token cache, B=1) — flash-decode style.
- spatial dims shard over "data" for big-image diffusion cells.
"""
from __future__ import annotations

from typing import Optional, Tuple

from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def _mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh, batch: int) -> Tuple:
    """Largest prefix of ("pod","data") whose product divides `batch`."""
    sizes = _mesh_axis_sizes(mesh)
    axes = [a for a in ("pod", "data") if a in sizes]
    chosen = []
    prod = 1
    for a in axes:
        if batch % (prod * sizes[a]) == 0:
            chosen.append(a)
            prod *= sizes[a]
    return tuple(chosen)


def free_data_axis(mesh, batch_ax: Tuple) -> Optional[str]:
    """The 'data' axis if the batch didn't consume it (for seq/spatial)."""
    return "data" if "data" not in batch_ax else None


def lm_specs(mesh, kind: str, batch: int, seq: int):
    """Returns dict of PartitionSpec for LM step inputs."""
    ba = batch_axes(mesh, batch)
    bspec = ba if ba else None
    if kind == "train":
        return {"tokens": P(bspec, None), "labels": P(bspec, None)}
    if kind == "prefill":
        return {"tokens": P(bspec, None)}
    return {"token": P(bspec, None)}


def cache_len_axes(mesh, batch: int, seq: int):
    """KV-cache *length* sharding (flash-decode layout): the model axis
    always (heads rarely divide 16; length does), plus the data axis
    when the batch leaves it free. Attention flops/bytes then spread
    over every chip; per-step softmax stats are the only cross-shard
    traffic (KB, not the GB-scale head all-gathers of head sharding)."""
    ba = batch_axes(mesh, batch)
    sizes = _mesh_axis_sizes(mesh)
    axes = []
    if "data" not in ba and "data" in sizes:
        axes.append("data")
    if "model" in sizes:
        axes.append("model")
    n = 1
    for a in axes:
        n *= sizes[a]
    if axes and seq % n == 0:
        return tuple(axes)
    return None


def lm_cache_spec(mesh, cfg, batch: int, len_axes):
    """PartitionSpec pytree for the stacked KV cache."""
    ba = batch_axes(mesh, batch)
    bspec = ba if ba else None
    la = len_axes if len_axes else None
    if cfg.mla is not None:
        return {
            "c_kv": P(None, bspec, la, None),
            "k_rope": P(None, bspec, la, None),
        }
    return {
        "k": P(None, bspec, la, None, None),
        "v": P(None, bspec, la, None, None),
    }


def image_specs(mesh, batch: int, spatial_dims: int = 2):
    """(B, H, W, C)-style inputs: batch over pod/data, else H over data."""
    ba = batch_axes(mesh, batch)
    bspec = ba if ba else None
    fd = free_data_axis(mesh, ba)
    return P(bspec, fd, None, None)


def token_image_specs(mesh, batch: int):
    ba = batch_axes(mesh, batch)
    return P(ba if ba else None, None, None, None)
