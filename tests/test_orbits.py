"""Orbital geometry engine tests (repro.orbits).

Build-time validation of element catalogs and scenario specs, the
propagator's geometric invariants (radius, period round-trip), known-
geometry visibility/eclipse cases, the segment-scan pass extractor
against hand-built masks, the shared elevation->bandwidth rule (with
the toy path's bit-equality identity), and the acceptance gate: a
``geometry="orbital"`` scenario executes through the UNCHANGED
fleet/contact tiers exact-equal to the looped-Mission oracle — even
with the empty contact rounds a short horizon naturally produces.

Property tests (marked ``slow``; hypothesis or the fallback mini
runner): pass contiguity/coverage, elevation symmetry about the
culmination time for circular orbits with Earth rotation frozen,
eclipse fractions bounded in [0, 1], and the propagator's orbital-
period round-trip over random catalogs.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests use the deterministic mini runner
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.contact import ContactPlan
from repro.data.scenarios import (FleetScenarioSpec, GroundStation,
                                  elevation_bandwidth, generate_scenario)
from repro.data.synthetic import SceneSpec
from repro.orbits import (OrbitalElements, default_sites, eclipse_fractions,
                          eclipse_mask, elevation_deg, extract_passes,
                          orbital_period_s, propagate, shell, station_ecef,
                          sun_direction, walker_delta)
from repro.orbits.propagation import R_EARTH_M

SCENE = SceneSpec("orbtest", 384, (10, 18), (10, 24), cloud_fraction=0.25)


def _orbital_spec(**kw):
    n_st = kw.pop("n_stations", 4)
    sites = default_sites(n_st)
    stations = tuple(GroundStation(f"gs{k}", site=sites[k])
                     for k in range(n_st))
    base = dict(n_sats=4, n_rounds=3, stations=stations, geometry="orbital",
                seed=5, min_elev_deg=5.0, frames_per_pass=1,
                scene_mix=(SCENE,))
    base.update(kw)
    return FleetScenarioSpec(**base)


# ---------------------------------------------------------------------------
# build-time validation
# ---------------------------------------------------------------------------

def _circ(n=1, alt_km=550.0, **kw):
    base = dict(a_m=np.full(n, R_EARTH_M + alt_km * 1e3),
                ecc=np.zeros(n), inc_rad=np.zeros(n), raan_rad=np.zeros(n),
                argp_rad=np.zeros(n), m0_rad=np.zeros(n))
    base.update(kw)
    return OrbitalElements(**base)


def test_elements_validation():
    _circ()  # valid
    with pytest.raises(ValueError, match="eccentricity"):
        _circ(ecc=np.array([0.3]))
    with pytest.raises(ValueError, match="eccentricity"):
        _circ(ecc=np.array([-0.01]))
    with pytest.raises(ValueError, match="perigee"):
        _circ(alt_km=50.0)
    with pytest.raises(ValueError, match="inclination"):
        _circ(inc_rad=np.array([3.5]))
    with pytest.raises(ValueError, match="aligned"):
        _circ(m0_rad=np.zeros(2))
    with pytest.raises(ValueError, match="1-D"):
        _circ(m0_rad=np.zeros((1, 1)))
    with pytest.raises(ValueError, match="at least one"):
        _circ(n=0)
    with pytest.raises(ValueError, match="non-finite"):
        _circ(raan_rad=np.array([np.nan]))


def test_walker_structure():
    els = walker_delta(12, 3, 53.0, 550.0)
    assert els.n_sats == 12
    raans = np.unique(np.round(els.raan_rad, 12))
    assert raans.shape[0] == 3
    np.testing.assert_allclose(np.diff(raans), 2 * np.pi / 3, rtol=1e-9)
    # 4 slots per plane, uniformly phased
    plane0 = np.sort(els.m0_rad[:4])
    np.testing.assert_allclose(np.diff(plane0), 2 * np.pi / 4, rtol=1e-9)
    with pytest.raises(ValueError, match="divide"):
        walker_delta(10, 3, 53.0, 550.0)
    with pytest.raises(ValueError, match="phasing"):
        walker_delta(12, 3, 53.0, 550.0, phasing=3)


def test_spec_validation():
    FleetScenarioSpec()  # the default spec stays valid
    with pytest.raises(ValueError, match="eclipse_fraction"):
        FleetScenarioSpec(eclipse_fraction=1.0)
    with pytest.raises(ValueError, match="eclipse_fraction"):
        FleetScenarioSpec(eclipse_fraction=-0.1)
    with pytest.raises(ValueError, match="orbit_rounds"):
        FleetScenarioSpec(orbit_rounds=0)
    with pytest.raises(ValueError, match="pass_s"):
        FleetScenarioSpec(pass_s=0.0)
    with pytest.raises(ValueError, match="harvest_w"):
        FleetScenarioSpec(harvest_w=-1.0)
    with pytest.raises(ValueError, match="stations"):
        FleetScenarioSpec(stations=())
    with pytest.raises(ValueError, match="geometry"):
        FleetScenarioSpec(geometry="kepler")
    with pytest.raises(ValueError, match="elevation_range"):
        FleetScenarioSpec(elevation_range=(0.5, 1.5))
    with pytest.raises(ValueError, match="elevation_range"):
        FleetScenarioSpec(elevation_range=(0.9, 0.5))
    with pytest.raises(ValueError, match="min_elev_deg"):
        FleetScenarioSpec(min_elev_deg=90.0)
    with pytest.raises(ValueError, match="time_step_s"):
        FleetScenarioSpec(time_step_s=0.0)
    with pytest.raises(ValueError, match="n_planes"):
        FleetScenarioSpec(n_planes=-1)


def test_orbital_requires_sites():
    with pytest.raises(ValueError, match="site"):
        generate_scenario(FleetScenarioSpec(geometry="orbital"))


# ---------------------------------------------------------------------------
# propagation invariants
# ---------------------------------------------------------------------------

def test_propagation_radius_and_period():
    els = walker_delta(8, 2, 53.0, 550.0)
    T = float(orbital_period_s(els.a_m[0]))
    times = np.linspace(0.0, T, 257)
    pos = np.asarray(propagate(els, times))
    assert pos.shape == (8, 257, 3)
    r = np.linalg.norm(pos, axis=-1)
    np.testing.assert_allclose(r, els.a_m[0], rtol=1e-5)  # circular orbit
    # one full period returns every satellite to its epoch position
    # (float32 device math: meter-level round-off on a ~7000 km radius)
    assert np.abs(pos[:, -1] - pos[:, 0]).max() < 50.0


def test_overhead_pass_geometry():
    # sat at (a, 0, 0) at t=0; station at lat 0, lon 0 with gmst0=0 sits
    # directly below -> 90 deg elevation
    els = _circ()
    pos = propagate(els, np.array([0.0]))
    site = station_ecef(0.0, 0.0)
    elev = np.asarray(elevation_deg(pos, np.array([0.0]), site))
    assert elev.shape == (1, 1, 1)
    assert elev[0, 0, 0] > 89.9
    # the antipodal station never sees it
    far = np.asarray(elevation_deg(pos, np.array([0.0]),
                                   station_ecef(0.0, 180.0)))
    assert far[0, 0, 0] < -80.0


def test_eclipse_known_geometry():
    a = R_EARTH_M + 550e3
    pos = np.array([[[a, 0.0, 0.0]],      # sun side: sunlit
                    [[-a, 0.0, 0.0]],     # anti-sun, inside cylinder
                    [[0.0, a, 0.0]]])     # terminator: not behind plane
    sun = np.array([[1.0, 0.0, 0.0]])
    m = np.asarray(eclipse_mask(pos, sun))
    assert m.tolist() == [[False], [True], [False]]
    # anti-sun but OUTSIDE the shadow cylinder stays sunlit
    out = np.array([[[-a, 1.1 * R_EARTH_M, 0.0]]])
    assert not np.asarray(eclipse_mask(out, sun))[0, 0]


def test_eclipse_fractions_windows():
    mask = np.array([[True, True, False, False, False, True]])
    fr = eclipse_fractions(mask, [0, 2, 4, 6])
    np.testing.assert_allclose(fr, [[1.0, 0.0, 0.5]])
    assert eclipse_fractions(mask, [0, 0, 6]).shape == (1, 2)  # empty window


# ---------------------------------------------------------------------------
# pass extraction against hand-built masks
# ---------------------------------------------------------------------------

def test_extract_passes_known_runs():
    times = np.arange(8.0) * 10.0
    #         runs: [1,2] and [5,7] in row 0; [0] in row 1; none in row 2
    elev = np.array([[-5.0, 12.0, 30.0, 3.0, -2.0, 15.0, 25.0, 5.0],
                     [20.0, -1.0, -1.0, -1.0, -1.0, -1.0, -1.0, -1.0],
                     [-9.0, -9.0, -9.0, -9.0, -9.0, -9.0, -9.0, -9.0]])
    ps = extract_passes(elev, times, 10.0)
    assert ps.n_passes == 3
    assert ps.row.tolist() == [0, 0, 1]
    assert ps.start.tolist() == [1, 5, 0]
    assert ps.stop.tolist() == [3, 7, 1]
    np.testing.assert_allclose(ps.t_rise, [10.0, 50.0, 0.0])
    np.testing.assert_allclose(ps.t_set, [20.0, 60.0, 0.0])
    np.testing.assert_allclose(ps.duration_s, [20.0, 20.0, 10.0])
    np.testing.assert_allclose(ps.max_elev_deg, [30.0, 25.0, 20.0])
    np.testing.assert_allclose(ps.t_culminate, [20.0, 60.0, 0.0])


def test_extract_passes_boundary_run():
    # a pass covering the whole grid (rise at 0, never sets)
    times = np.arange(4.0)
    ps = extract_passes(np.full((1, 4), 45.0), times, 10.0)
    assert ps.n_passes == 1
    assert (ps.start[0], ps.stop[0]) == (0, 4)
    assert ps.duration_s[0] == 4.0  # every sample counts one (extrapolated) step
    # ties on max elevation resolve to the FIRST sample
    assert ps.t_culminate[0] == 0.0
    # no passes at all
    assert extract_passes(np.full((2, 4), -5.0), times, 10.0).n_passes == 0


# ---------------------------------------------------------------------------
# the shared elevation -> bandwidth rule
# ---------------------------------------------------------------------------

def test_elevation_bandwidth_toy_identity():
    gs = GroundStation("gs0", bandwidth_mbps=50.0)
    # the toy path passes its drawn factor through `factor`; for any
    # factor already in [0, 1] the clamp must be a bit-exact identity
    for f in (0.0, 0.5, 0.700000000000001, 0.9999999, 1.0):
        assert elevation_bandwidth(0.0, gs, factor=f) == gs.bandwidth_mbps * f
    # out-of-range factors clamp
    assert elevation_bandwidth(0.0, gs, factor=1.5) == 50.0
    assert elevation_bandwidth(0.0, gs, factor=-0.2) == 0.0


def test_elevation_bandwidth_degrees():
    gs = GroundStation("gs0", bandwidth_mbps=50.0)
    assert elevation_bandwidth(90.0, gs) == pytest.approx(50.0)
    assert elevation_bandwidth(0.0, gs) == pytest.approx(0.0)
    assert elevation_bandwidth(-5.0, gs) == pytest.approx(0.0)   # clamped
    assert elevation_bandwidth(120.0, gs) == pytest.approx(50.0)
    elevs = [5.0, 15.0, 30.0, 60.0, 90.0]
    bws = [elevation_bandwidth(e, gs) for e in elevs]
    assert bws == sorted(bws)  # monotone in elevation


def test_from_contacts_plain_string_station():
    class Ev:
        def __init__(self, sat, station, budget):
            self.sat, self.station, self.budget_bytes = sat, station, budget
    plan = ContactPlan.from_contacts(
        [Ev(0, "gsA", 1e6), Ev(1, GroundStation("gsB"), 2e6)], n_sats=2)
    assert plan.stations == ("gsA", "gsB")


# ---------------------------------------------------------------------------
# orbital scenario: determinism, skew, fleet/oracle parity
# ---------------------------------------------------------------------------

def test_orbital_scenario_deterministic_and_bounded():
    a = generate_scenario(_orbital_spec())
    b = generate_scenario(_orbital_spec())
    for ra, rb in zip(a.rounds, b.rounds):
        assert [p.harvest_j for p in ra.passes] == \
               [p.harvest_j for p in rb.passes]
        assert [(c.sat, c.station.name, c.bandwidth_mbps, c.budget_bytes)
                for c in ra.contacts] == \
               [(c.sat, c.station.name, c.bandwidth_mbps, c.budget_bytes)
                for c in rb.contacts]
    spec = a.spec
    for r in a.rounds:
        for p in r.passes:  # harvest bounded by a fully sunlit round
            assert 0.0 <= p.harvest_j <= spec.harvest_w * spec.pass_s
        for c in r.contacts:
            assert 0.0 < c.bandwidth_mbps <= c.station.bandwidth_mbps
            assert c.budget_bytes > 0.0
    assert sum(len(r.contacts) for r in a.rounds) > 0


def test_orbital_fleet_parity(counters):
    """The acceptance gate: an orbital-geometry scenario (including
    rounds with NO contact windows — short horizons make passes bursty)
    runs through the unchanged fleet path exact-equal to the
    looped-Mission oracle."""
    from repro.core.fleet import run_scenario
    from repro.core.pipeline import PipelineConfig
    sc = generate_scenario(_orbital_spec())
    per_round = [len(r.contacts) for r in sc.rounds]
    assert 0 in per_round and sum(per_round) > 0  # exercises the edge
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25, seed=0)
    got, fleet = run_scenario(space, ground, pcfg, sc, fleet=True)
    want, _ = run_scenario(space, ground, pcfg, sc, fleet=False)
    for i, (g, w) in enumerate(zip(got, want)):
        np.testing.assert_array_equal(g.per_tile_pred, w.per_tile_pred,
                                      err_msg=f"sat{i} preds differ")
        assert g.summary() == w.summary(), f"sat{i} summary mismatch"


# ---------------------------------------------------------------------------
# property tests (slow): geometry invariants
# ---------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_pass_contiguity_property(seed):
    """Every extracted pass is a maximal single above-mask run, and the
    passes exactly tile the above-mask samples (nothing dropped or
    merged)."""
    rng = np.random.default_rng(seed)
    elev = rng.normal(0.0, 25.0, size=(rng.integers(1, 5), 64))
    times = np.arange(64.0)
    ps = extract_passes(elev, times, 10.0)
    mask = elev >= 10.0
    assert sum(ps.stop[i] - ps.start[i]
               for i in range(ps.n_passes)) == mask.sum()
    for i in range(ps.n_passes):
        row, s, e = ps.row[i], ps.start[i], ps.stop[i]
        assert mask[row, s:e].all()            # contiguous above-mask run
        assert s == 0 or not mask[row, s - 1]  # maximal on both sides
        assert e == mask.shape[1] or not mask[row, e]
        assert ps.max_elev_deg[i] == elev[row, s:e].max()


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=400, max_value=1200),
       st.floats(min_value=0.0, max_value=80.0),
       st.floats(min_value=-60.0, max_value=60.0),
       st.floats(min_value=-180.0, max_value=180.0))
def test_elevation_symmetry_property(alt_km, inc_deg, lat, lon):
    """With Earth rotation frozen (omega=0), a circular orbit's
    elevation from ANY fixed station is symmetric about the culmination
    time — closest approach to a fixed point along uniform circular
    motion is a mirror axis."""
    els = walker_delta(1, 1, inc_deg, float(alt_km), phasing=0)
    T = float(orbital_period_s(els.a_m[0]))
    dt = 2.0
    times = np.arange(0.0, T, dt)
    pos = propagate(els, times)
    site = station_ecef(lat, lon)
    elev = np.asarray(elevation_deg(pos, times, site,
                                    omega_rad_s=0.0))[0, 0]
    k = int(np.argmax(elev))
    half = min(k, elev.shape[0] - 1 - k, 60)
    if half < 5:  # culmination at the grid edge: skip this draw
        return
    j = np.arange(1, half + 1)
    # grid culmination sits within dt/2 of the true axis -> allow the
    # slope x dt asymmetry plus float32 elevation round-off
    assert np.abs(elev[k - j] - elev[k + j]).max() < 0.75


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=2, max_value=6))
def test_eclipse_fraction_bounds_property(seed, n_windows):
    els = shell(8, 53.0, 550.0, seed=seed)
    T = float(orbital_period_s(els.a_m[0]))
    times = np.arange(0.0, 2 * T, 30.0)
    mask = np.asarray(eclipse_mask(propagate(els, times),
                                   sun_direction(times)))
    rng = np.random.default_rng(seed)
    cuts = np.sort(rng.integers(0, times.shape[0] + 1, n_windows - 1))
    bounds = np.concatenate([[0], cuts, [times.shape[0]]])
    fr = eclipse_fractions(mask, bounds)
    assert fr.shape == (8, n_windows)
    assert (fr >= 0.0).all() and (fr <= 1.0).all()


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.floats(min_value=450.0, max_value=2000.0),
       st.floats(min_value=0.0, max_value=0.1))
def test_period_roundtrip_property(seed, alt_km, ecc):
    rng = np.random.default_rng(seed)
    n = 4
    # draw the PERIGEE altitude so the catalog always clears the
    # build-time perigee floor regardless of the drawn eccentricity
    els = OrbitalElements(
        a_m=np.full(n, (R_EARTH_M + alt_km * 1e3) / (1.0 - ecc)),
        ecc=np.full(n, ecc),
        inc_rad=rng.uniform(0.0, np.pi, n),
        raan_rad=rng.uniform(0.0, 2 * np.pi, n),
        argp_rad=rng.uniform(0.0, 2 * np.pi, n),
        m0_rad=rng.uniform(0.0, 2 * np.pi, n))
    T = float(orbital_period_s(els.a_m[0]))
    pos = np.asarray(propagate(els, np.array([0.0, T])))
    # float32 device math: ~1e-7 relative anomaly error over one period
    assert np.abs(pos[:, 1] - pos[:, 0]).max() < 100.0
