"""Fixture: hot-module code that passes — the one designated transfer
point carries a reason-annotated waiver, and a worker thread honors the
ownership map (parsed only, never imported)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import count_tiles_multi

_frame_program = jax.jit(lambda x: jnp.square(x) + 1.0)


def prepare_frames(frames):
    dev = _frame_program(jnp.asarray(frames))
    # analysis: waive(host-sync): fixture — the designated single copy
    return np.asarray(dev)


def _recount_run(fleet, work, cancel=None):
    params, cfg = fleet.ground
    for thresh, items in work.by_thresh.items():
        if cancel is not None and cancel.is_set():
            return
        parts = [(seg.tiles_gd, down) for _, seg, down in items]
        results = count_tiles_multi(params, cfg, parts, score_thresh=thresh)
        if cancel is not None and cancel.is_set():
            return
        for (m, seg, down), (c, _) in zip(items, results):
            seg.counts_gd = c
    for m, seg, window in work.agg:
        if cancel is not None and cancel.is_set():
            return
        m.contact_stages[3].run(m, seg, window)


class GroundSegment:
    def execute(self, rnd):
        rnd.thread = threading.Thread(target=self._recount_job, args=(rnd,),
                                      daemon=True)
        rnd.thread.start()

    def _recount_job(self, rnd):
        try:
            _recount_run(self.fleet, rnd.work, cancel=rnd.cancel)
        except BaseException as e:
            rnd.err = e
