"""Fixture: the pre-PR-8 ground-segment watchdog worker, reconstructed.

This is the bug class PR 8 fixed, kept as a regression target for the
thread-ownership rule (never imported at runtime — parsed only). Two
violations the rule must report:

1. ``_recount_run`` writes back ``seg.counts_gd`` (and dispatches the
   Aggregate stage) without ever checking ``cancel`` — a worker
   abandoned by the watchdog keeps writing while the foreground's
   recovery recount runs, racing it.
2. ``_recount_job`` accumulates into ``self.recount_s``, a
   foreground-owned accumulator, from the worker thread — the root of
   the recovery double-count.
"""
import threading
import time

import numpy as np

from repro.core.cascade import count_tiles_multi


def _recount_run(fleet, work):
    params, cfg = fleet.ground
    for thresh, items in work.by_thresh.items():
        parts = [(seg.tiles_gd, down) for _, seg, down in items]
        results = count_tiles_multi(params, cfg, parts, score_thresh=thresh,
                                    sharding=fleet.sharding)
        for (m, seg, down), (c, _) in zip(items, results):
            counts_gd = np.zeros(seg.n)
            if len(down):
                counts_gd[down] = c
            seg.counts_gd = counts_gd[seg.rep_of]
    for m, seg, window in work.agg:
        m.contact_stages[3].run(m, seg, window)


class GroundSegment:
    def execute(self, rnd):
        rnd.thread = threading.Thread(target=self._recount_job, args=(rnd,),
                                      daemon=True)
        rnd.thread.start()

    def _recount_job(self, rnd):
        t0 = time.perf_counter()
        try:
            _recount_run(self.fleet, rnd.work)
        except BaseException as e:
            rnd.err = e
        finally:
            self.recount_s += time.perf_counter() - t0
