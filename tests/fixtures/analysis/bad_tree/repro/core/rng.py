"""Fixture: every determinism-lint violation class (parsed only)."""
import random
import time

import numpy as np


def draw(n):
    np.random.seed(42)
    rng = np.random.default_rng()
    wall = time.time()
    return rng.random(n), wall, random.random()


class Plan:
    def mutate(self):
        object.__setattr__(self, "budget", 0.0)
