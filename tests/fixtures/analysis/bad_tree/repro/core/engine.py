"""Fixture: a banned per-round host sync in the hot engine module
(parsed only, never imported). The ``np.asarray`` on a jit result is
exactly the PR 9 churn class the host-sync rule must flag."""
import jax
import jax.numpy as jnp
import numpy as np

_frame_program = jax.jit(lambda x: jnp.square(x) + 1.0)


def prepare_frames(frames):
    dev = _frame_program(jnp.asarray(frames))
    stats = np.asarray(dev)  # banned: blocking device->host sync per round
    return stats.mean()
