"""Tests for the repro.analysis static-analysis engine and the JitGuard
recompilation sanitizer.

The rule tests run the real engine over fixture trees under
``tests/fixtures/analysis/`` — ``bad_tree`` reconstructs the pre-PR-8
watchdog race plus one representative of every lint class, ``good_tree``
is the same shape of code written correctly (waived designated sync,
cancel-disciplined worker). The fixtures are parsed, never imported.
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import JitGuard, analyze
from repro.analysis import engine as ae
from repro.analysis.__main__ import main as analysis_main
from repro.core.fleet import Fleet
from repro.core.pipeline import PipelineConfig
from repro.data.synthetic import SceneSpec, make_scene, revisit_frames

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"
BAD = FIXTURES / "bad_tree"
GOOD = FIXTURES / "good_tree"
SRC = ae.REPO_ROOT / "src" / "repro"


def _rules(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------------------
# thread-ownership race checker
# ---------------------------------------------------------------------------

def test_thread_rule_flags_pre_pr8_watchdog():
    """The reconstructed pre-PR-8 worker must trip both violation
    classes: cancel-free write-backs and a foreground-owned accumulator
    written from the worker thread."""
    findings, _ = analyze([BAD / "repro" / "core" / "contact_pre_pr8.py"])
    cancel = [f for f in findings if f.rule == "thread-ownership/cancel"]
    fg = [f for f in findings if f.rule == "thread-ownership/foreground"]
    assert len(cancel) >= 2, _rules(findings)
    assert any("counts_gd" in f.message for f in cancel)
    assert any("contact_stages" in f.message for f in cancel)
    assert len(fg) == 1 and "recount_s" in fg[0].message


def test_thread_rule_clean_on_current_contact():
    """The shipped (post-PR-8) ground segment honors the ownership map."""
    findings, _ = analyze([SRC / "core" / "contact.py"])
    assert [f for f in findings if f.rule.startswith("thread-ownership")] == []


def test_thread_rule_clean_on_good_fixture():
    findings, _ = analyze([GOOD / "repro" / "core" / "engine.py"])
    assert [f for f in findings if f.rule.startswith("thread-ownership")] == []


# ---------------------------------------------------------------------------
# host-sync-in-hot-path lint
# ---------------------------------------------------------------------------

def test_host_sync_flags_tainted_asarray_in_hot_module():
    findings, _ = analyze([BAD / "repro" / "core" / "engine.py"])
    sync = [f for f in findings if f.rule.startswith("host-sync")]
    assert len(sync) == 1
    assert sync[0].rule == "host-sync/asarray"
    assert sync[0].line == 13


def test_host_sync_waiver_suppresses_with_reason():
    findings, waived = analyze([GOOD / "repro" / "core" / "engine.py"])
    assert [f for f in findings if f.rule.startswith("host-sync")] == []
    assert any(f.rule == "host-sync/asarray" for f in waived)


def test_waiver_without_reason_is_itself_a_finding(tmp_path):
    mod = tmp_path / "repro" / "core" / "engine.py"
    mod.parent.mkdir(parents=True)
    mod.write_text(
        "import jax\nimport numpy as np\n"
        "f = jax.jit(lambda x: x)\n"
        "# analysis: waive(host-sync):\n"
        "y = np.asarray(f(1.0))\n")
    findings, _ = analyze([mod], repo_root=tmp_path)
    assert any(f.rule == "waiver/missing-reason" for f in findings)


# ---------------------------------------------------------------------------
# determinism lints
# ---------------------------------------------------------------------------

def test_determinism_rules_each_fire_once():
    findings, _ = analyze([BAD / "repro" / "core" / "rng.py"])
    assert _rules(findings) == [
        "determinism/frozen-setattr",
        "determinism/global-rng",
        "determinism/random-module",
        "determinism/unseeded-rng",
        "determinism/wall-clock",
    ]


def test_frozen_setattr_allowed_in_post_init(tmp_path):
    mod = tmp_path / "repro" / "core" / "spec.py"
    mod.parent.mkdir(parents=True)
    mod.write_text(
        "class Spec:\n"
        "    def __post_init__(self):\n"
        "        object.__setattr__(self, 'n', 4)\n")
    findings, _ = analyze([mod], repo_root=tmp_path)
    assert findings == []


# ---------------------------------------------------------------------------
# CLI: exit codes, baseline ratchet
# ---------------------------------------------------------------------------

def test_cli_bad_tree_exits_nonzero(tmp_path):
    rc = analysis_main([str(BAD), "--baseline", str(tmp_path / "bl.json")])
    assert rc == 1


def test_cli_shipped_tree_is_clean():
    """`python -m repro.analysis` on the shipped tree: exit 0 with the
    checked-in (empty) baseline — the acceptance gate for this PR."""
    assert analysis_main([]) == 0


def test_baseline_ratchet(tmp_path):
    bl = tmp_path / "baseline.json"
    # --update-baseline swallows the current findings and exits 0 ...
    assert analysis_main([str(BAD), "--baseline", str(bl),
                          "--update-baseline"]) == 0
    assert analysis_main([str(BAD), "--baseline", str(bl)]) == 0
    data = json.loads(bl.read_text())
    assert len(data["findings"]) > 0
    # ... but a NEW finding is never masked by old entries ...
    extra = tmp_path / "repro" / "core" / "fresh.py"
    extra.parent.mkdir(parents=True)
    extra.write_text("import numpy as np\nnp.random.seed(0)\n")
    assert analysis_main([str(BAD), str(extra.parent),
                          "--baseline", str(bl)]) == 1
    # ... and fixing findings leaves stale keys that --update drops
    assert analysis_main([str(GOOD), "--baseline", str(bl),
                          "--update-baseline"]) == 0
    assert json.loads(bl.read_text())["findings"] == {}


# ---------------------------------------------------------------------------
# JitGuard: jit-recompilation sanitizer
# ---------------------------------------------------------------------------

def test_jitguard_counts_fresh_compile_and_cached_silence():
    fn = jax.jit(lambda x: jnp.sin(x) * 2.0)
    x = jnp.arange(7, dtype=jnp.float32)
    with JitGuard("cold") as cold:
        fn(x).block_until_ready()
    if not cold.supported:
        pytest.skip("no compilation-count source on this jax build")
    assert cold.compilations >= 1
    with JitGuard("warm") as warm:
        fn(x).block_until_ready()
    assert warm.compilations == 0
    warm.assert_steady_state("cached call")


def test_jitguard_assert_raises_on_recompile():
    fn = jax.jit(lambda x: jnp.cos(x) + 1.0)
    fn(jnp.arange(5, dtype=jnp.float32)).block_until_ready()
    with JitGuard("churn") as g:
        # a fresh shape forces a new XLA program
        fn(jnp.arange(6, dtype=jnp.float32)).block_until_ready()
    if not g.supported:
        pytest.skip("no compilation-count source on this jax build")
    with pytest.raises(AssertionError, match="churn"):
        g.assert_steady_state("shape churn")


def test_jitguard_fleet_rounds_reach_steady_state(counters):
    """Steady-state fleet ingest compiles ZERO new programs: identical
    frame shapes round over round must hit every jit cache (the runtime
    analogue of the PR 9 churn gate)."""
    space, ground = counters
    rng = np.random.default_rng(17)
    img, b, c = make_scene(rng, SceneSpec("jg", 256, (6, 12), (10, 20),
                                          cloud_fraction=0.2))
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    fleet = Fleet(space, ground, pcfg, n_sats=2)

    def round_(fl):
        fl.ingest([revisit_frames(rng, img, b, c, 1) for _ in range(2)])

    # warm-up rounds trace and compile the programs
    round_(fleet)
    round_(fleet)
    with JitGuard("fleet steady state") as g:
        round_(fleet)
        round_(fleet)
    if not g.supported:
        pytest.skip("no compilation-count source on this jax build")
    g.assert_steady_state("steady-state ingest rounds")
    fleet.finalize()
