"""End-to-end behaviour tests for the TargetFuse system (paper claims at
test scale: mechanics + orderings, not headline magnitudes)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.cascade import count_tiles_batched, fit_counter
from repro.core.pipeline import PipelineConfig, budgets_for, run_pipeline
from repro.data.synthetic import SceneSpec, make_scene, revisit_frames


@pytest.fixture(scope="module")
def counters():
    """Small counters, trained just enough for pipeline mechanics."""
    spec = SceneSpec("mini", 512, (20, 30), (10, 24), cloud_fraction=0.2)
    rng = np.random.default_rng(0)
    scenes = [make_scene(rng, spec) for _ in range(6)]
    sp_cfg = reduced(get_config("targetfuse-space"))
    gd_cfg = reduced(get_config("targetfuse-ground"))
    sp, _ = fit_counter(sp_cfg, scenes, 128, 250, jax.random.PRNGKey(0))
    gd, _ = fit_counter(gd_cfg, scenes, 128, 600, jax.random.PRNGKey(1))
    return (sp, sp_cfg), (gd, gd_cfg), spec


@pytest.fixture(scope="module")
def frames(counters):
    _, _, spec = counters
    rng = np.random.default_rng(7)
    out = []
    for _ in range(2):
        img, b, c = make_scene(rng, spec)
        out += revisit_frames(rng, img, b, c, 3)
    return out


def _run(frames, counters, **kw):
    space, ground, _ = counters
    pcfg = PipelineConfig(score_thresh=0.25, **kw)
    return run_pipeline(frames, space, ground, pcfg)


def test_ground_tier_more_accurate(counters):
    """The cascade's premise: deeper ground counter beats space counter."""
    space, ground, spec = counters
    rng = np.random.default_rng(3)
    from repro.core import tiling
    from repro.data.synthetic import tile_counts
    import jax.numpy as jnp
    errs_s, errs_g = [], []
    for _ in range(3):
        img, b, c = make_scene(rng, spec)
        true = tile_counts(b, spec.scene_px, 128)
        ts = np.asarray(tiling.resize_tiles(
            tiling.tile_image(jnp.asarray(img), 128), space[1].input_size))
        tg = np.asarray(tiling.resize_tiles(
            tiling.tile_image(jnp.asarray(img), 128), ground[1].input_size))
        cs, _ = count_tiles_batched(*space, ts, score_thresh=0.25)
        cg, _ = count_tiles_batched(*ground, tg, score_thresh=0.25)
        errs_s.append(np.abs(cs - true).sum() / max(true.sum(), 1))
        errs_g.append(np.abs(cg - true).sum() / max(true.sum(), 1))
    assert np.mean(errs_g) < np.mean(errs_s)


def test_targetfuse_beats_space_only(frames, counters):
    r_tf = _run(frames, counters, method="targetfuse")
    r_so = _run(frames, counters, method="space_only")
    assert r_tf.cmae < r_so.cmae


def test_targetfuse_beats_tiansuan(frames, counters):
    r_tf = _run(frames, counters, method="targetfuse")
    r_ti = _run(frames, counters, method="tiansuan")
    assert r_tf.cmae <= r_ti.cmae * 1.05


def test_targetfuse_tracks_kodan_upper_bound(frames, counters):
    """Kodan ignores bandwidth -> its CMAE lower-bounds TargetFuse; when
    bandwidth suffices they coincide (paper Fig. 7/10)."""
    r_tf = _run(frames, counters, method="targetfuse")
    r_ko = _run(frames, counters, method="kodan")
    assert r_ko.cmae <= r_tf.cmae + 1e-9


def test_bandwidth_budget_respected(frames, counters):
    for method in ("targetfuse", "tiansuan", "ground_only"):
        r = _run(frames, counters, method=method)
        assert r.bytes_downlinked <= r.bytes_budget + 1e-6, method


def test_kodan_is_bandwidth_oblivious(frames, counters):
    r = _run(frames, counters, method="kodan", bandwidth_mbps=1.0)
    # with ~no bandwidth, kodan still "downlinks" everything it wants
    assert r.bytes_downlinked > r.bytes_budget


def test_more_bandwidth_never_hurts(frames, counters):
    cmaes = [
        _run(frames, counters, method="targetfuse", bandwidth_mbps=bw).cmae
        for bw in (5, 50, 500)
    ]
    assert cmaes[2] <= cmaes[0] + 0.05


def test_dedup_reduces_onboard_compute(frames, counters):
    r_with = _run(frames, counters, method="targetfuse", use_dedup=True)
    r_without = _run(frames, counters, method="targetfuse", use_dedup=False)
    assert r_with.tiles_processed_space <= r_without.tiles_processed_space


def test_energy_budget_caps_processing(frames, counters):
    r_lo = _run(frames, counters, method="space_only", energy_budget_j=20_000)
    r_hi = _run(frames, counters, method="space_only", energy_budget_j=500_000)
    assert r_lo.tiles_processed_space <= r_hi.tiles_processed_space
    e, _, _ = budgets_for(PipelineConfig(energy_budget_j=20_000),
                          r_lo.tiles_total)
    assert r_lo.energy_spent_j <= e * 1.05


def test_rpi4_beats_atlas_per_joule(frames, counters):
    """Paper Fig. 8/9: the low-power tier processes more tiles within the
    same energy budget."""
    from repro.core.energy import ATLAS, RPI4
    r_rpi = _run(frames, counters, method="space_only", hardware=RPI4,
                 energy_budget_j=40_000)
    r_atl = _run(frames, counters, method="space_only", hardware=ATLAS,
                 energy_budget_j=40_000)
    assert r_rpi.tiles_processed_space >= r_atl.tiles_processed_space
    assert r_rpi.cmae <= r_atl.cmae + 1e-9
