"""Round-pipelined ingest (``Fleet(ingest_overlap=True)``) gates.

The acceptance bar mirrors the contact tier's: overlap ON must be
bit-equal (0.0 deviation) to overlap OFF and to the looped-Mission
oracle — per-tile predictions, per-satellite summaries, and every
stacked-ledger lane — for all registered policies, both ingest paths
(engine and reference), every recount depth 0-2, and under fault
injection. Plus the churn-elimination gate: the content-keyed transfer
cache must make repeated-shape rounds issue strictly fewer host->device
uploads than the first round.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import xfer
from repro.core.faults import FaultPlan
from repro.core.fleet import Fleet, run_scenario
from repro.core.pipeline import PipelineConfig
from repro.data.scenarios import (FleetScenarioSpec, GroundStation,
                                  generate_scenario)
from repro.data.synthetic import SceneSpec

METHODS = ("space_only", "ground_only", "tiansuan", "kodan", "targetfuse")

SCENE_A = SceneSpec("trackA", 384, (10, 18), (10, 24), cloud_fraction=0.25)
SCENE_B = SceneSpec("trackB", 256, (6, 12), (10, 20), cloud_fraction=0.2)

FAULTS = FaultPlan(seed=5, drop_rate=0.25, blackout_rate=0.2,
                   truncate_rate=0.2, corrupt_rate=0.2)


@pytest.fixture(scope="module")
def scenario():
    """3 satellites x 4 rounds with contact gaps (rounds without
    contacts give the deferred ingest tail back-to-back ingest calls to
    hide behind — the interesting pipelining case)."""
    return generate_scenario(FleetScenarioSpec(
        n_sats=3, n_rounds=4, frames_per_pass=2,
        stations=(GroundStation("gs0"),
                  GroundStation("gs1", bandwidth_mbps=30.0, contact_s=240.0)),
        scene_mix=(SCENE_A, SCENE_B),
        eclipse_fraction=0.35, seed=11))


def _assert_results_equal(got, want, ctx=""):
    assert len(got) == len(want)
    for i, (a, b) in enumerate(zip(got, want)):
        np.testing.assert_array_equal(
            a.per_tile_pred, b.per_tile_pred,
            err_msg=f"{ctx} sat{i}: per-tile preds differ")
        np.testing.assert_array_equal(
            a.per_tile_true, b.per_tile_true,
            err_msg=f"{ctx} sat{i}: per-tile truth differs")
        assert a.summary() == b.summary(), f"{ctx} sat{i}: summaries differ"


def _assert_lanes_equal(fa: Fleet, fb: Fleet, ctx=""):
    for lane in ("budget_j", "spent", "e_com", "bytes_budget",
                 "bytes_requested", "bytes_spent"):
        np.testing.assert_array_equal(
            getattr(fa.ledger, lane), getattr(fb.ledger, lane),
            err_msg=f"{ctx}: ledger lane {lane} differs")


# ---------------------------------------------------------------------------
# the acceptance gate: overlap ON == overlap OFF == oracle, everywhere
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_overlap_parity_all_policies(method, scenario, counters):
    space, ground = counters
    pcfg = PipelineConfig(method=method, score_thresh=0.25)
    got, fl_o = run_scenario(space, ground, pcfg, scenario, fleet=True,
                             ingest_overlap=True)
    want, fl_s = run_scenario(space, ground, pcfg, scenario, fleet=True)
    oracle, _ = run_scenario(space, ground, pcfg, scenario, fleet=False)
    _assert_results_equal(got, want, f"{method} overlap-vs-sync")
    _assert_results_equal(got, oracle, f"{method} overlap-vs-oracle")
    _assert_lanes_equal(fl_o, fl_s, method)
    so = fl_o.summary()
    assert so["ingest_overlap"] is True
    assert so["ingest_rounds_deferred"] == len(scenario.rounds)


@pytest.mark.parametrize("use_engine", (True, False))
def test_overlap_parity_engine_and_reference(use_engine, scenario, counters):
    """The reference ingest path (use_engine=False) runs satellites
    through sequential Mission.ingest — the overlap tail must resolve
    BEFORE those per-mission ledger ops (the zombie-ordering hazard)."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25,
                          use_engine=use_engine)
    got, _ = run_scenario(space, ground, pcfg, scenario, fleet=True,
                          ingest_overlap=True)
    want, _ = run_scenario(space, ground, pcfg, scenario, fleet=True)
    _assert_results_equal(got, want, f"use_engine={use_engine}")


@pytest.mark.parametrize("depth", (0, 1, 2))
def test_overlap_parity_recount_depths(depth, scenario, counters):
    """Ingest overlap composes with the bounded recount pipeline at
    every depth: two deferred tiers, one synchronous answer."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    got, fl_o = run_scenario(space, ground, pcfg, scenario, fleet=True,
                             ingest_overlap=True, async_depth=depth,
                             async_ground=depth > 0)
    want, fl_s = run_scenario(space, ground, pcfg, scenario, fleet=True)
    _assert_results_equal(got, want, f"depth={depth}")
    _assert_lanes_equal(fl_o, fl_s, f"depth={depth}")


def test_overlap_parity_strict_parity_mode(scenario, counters):
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    got, _ = run_scenario(space, ground, pcfg, scenario, fleet=True,
                          ingest_overlap=True, strict_parity=True)
    want, _ = run_scenario(space, ground, pcfg, scenario, fleet=True,
                           strict_parity=True)
    _assert_results_equal(got, want, "strict_parity")


def test_overlap_parity_under_faults(scenario, counters):
    """Blackouts force mid-fleet sequential passes and window faults
    force retries — the deferred tail must keep exact lane order
    through all of it."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    got, fl_o = run_scenario(space, ground, pcfg, scenario, fleet=True,
                             ingest_overlap=True, faults=FAULTS)
    want, fl_s = run_scenario(space, ground, pcfg, scenario, fleet=True,
                              faults=FAULTS)
    _assert_results_equal(got, want, "faults")
    _assert_lanes_equal(fl_o, fl_s, "faults")
    assert fl_o.fault_stats.as_dict() == fl_s.fault_stats.as_dict()


def test_overlap_heterogeneous_policies(scenario, counters):
    space, ground = counters
    n = scenario.spec.n_sats
    pcfgs = [PipelineConfig(method=METHODS[i % len(METHODS)],
                            score_thresh=0.25) for i in range(n)]
    got, _ = run_scenario(space, ground, pcfgs, scenario, fleet=True,
                          ingest_overlap=True)
    want, _ = run_scenario(space, ground, pcfgs, scenario, fleet=True)
    _assert_results_equal(got, want, "mixed policies")


# ---------------------------------------------------------------------------
# S4: completion-order property — interleaved deferred tiers
# ---------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(method=st.sampled_from(METHODS),
       depth=st.integers(min_value=0, max_value=2),
       fault=st.booleans(),
       seed=st.integers(min_value=0, max_value=3))
def test_overlap_completion_order_property(counters, method, depth, fault,
                                           seed):
    """Property gate: for any (policy, recount depth, fault plan,
    scenario seed) draw, the ingest-overlap run's ledger lanes and
    per-tile predictions are bit-equal to the synchronous fleet."""
    space, ground = counters
    sc = generate_scenario(FleetScenarioSpec(
        n_sats=3, n_rounds=3, frames_per_pass=2,
        stations=(GroundStation("gs0"),),
        scene_mix=(SCENE_B,), eclipse_fraction=0.3, seed=20 + seed))
    faults = FaultPlan(seed=seed, drop_rate=0.3, blackout_rate=0.25) \
        if fault else None
    pcfg = PipelineConfig(method=method, score_thresh=0.25)
    kw = dict(async_depth=depth, async_ground=depth > 0, faults=faults)
    got, fl_o = run_scenario(space, ground, pcfg, sc, fleet=True,
                             ingest_overlap=True, **kw)
    want, fl_s = run_scenario(space, ground, pcfg, sc, fleet=True, **kw)
    _assert_results_equal(got, want, f"{method} d{depth} f{fault} s{seed}")
    _assert_lanes_equal(fl_o, fl_s, f"{method} d{depth} f{fault} s{seed}")


def test_no_zombie_tail_after_results(scenario, counters):
    """results() is a full resolution boundary: a second read (or a
    summary) must observe identical ledger state — the tail fires
    exactly once, never re-fires, and close() drops (not runs) it."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    fl = Fleet(space, ground, pcfg, n_sats=scenario.spec.n_sats,
               ingest_overlap=True)
    for rnd in scenario.rounds:
        fl.ingest(rnd.frames_per_sat(fl.n_sats),
                  rnd.harvest_per_sat(fl.n_sats))
    fl.results()
    snap1 = {k: getattr(fl.ledger, k).copy()
             for k in ("spent", "e_com", "bytes_spent")}
    assert fl._ingest_tail is None and not fl._pending_counts
    fl.results()
    snap2 = {k: getattr(fl.ledger, k).copy()
             for k in ("spent", "e_com", "bytes_spent")}
    for k in snap1:
        np.testing.assert_array_equal(snap1[k], snap2[k],
                                      err_msg=f"zombie tail mutated {k}")
    # a fresh fleet with a pending tail: close() must drop it unfired
    fl2 = Fleet(space, ground, pcfg, n_sats=scenario.spec.n_sats,
                ingest_overlap=True)
    rnd = scenario.rounds[0]
    fl2.ingest(rnd.frames_per_sat(fl2.n_sats),
               rnd.harvest_per_sat(fl2.n_sats))
    assert fl2._ingest_tail is not None
    spent_before = fl2.ledger.spent.copy()
    fl2.close()
    assert fl2._ingest_tail is None and not fl2._pending_counts
    np.testing.assert_array_equal(fl2.ledger.spent, spent_before,
                                  err_msg="close() ran the dropped tail")


# ---------------------------------------------------------------------------
# S3: constructor validation + side-effect-free summary
# ---------------------------------------------------------------------------

def test_negative_async_depth_rejected(counters):
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse")
    with pytest.raises(ValueError, match="async_depth must be >= 0"):
        Fleet(space, ground, pcfg, n_sats=2, async_depth=-1)


def test_negative_ingest_overlap_rejected(counters):
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse")
    with pytest.raises(ValueError, match="ingest_overlap must be a bool"):
        Fleet(space, ground, pcfg, n_sats=2, ingest_overlap=-2)


def test_summary_side_effect_free(scenario, counters):
    """Two consecutive summary() calls return equal dicts and leave the
    ledger untouched — summarizing is a read, not a step."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    for overlap in (False, True):
        _, fl = run_scenario(space, ground, pcfg, scenario, fleet=True,
                             ingest_overlap=overlap)
        s1 = fl.summary()
        spent = fl.ledger.spent.copy()
        s2 = fl.summary()
        assert s1 == s2, f"summary not idempotent (overlap={overlap})"
        np.testing.assert_array_equal(fl.ledger.spent, spent)


def test_summary_stage_timings(scenario, counters):
    """S2 invariant: every summary carries the ingest pipeline stage
    timings and host_fetch_s <= device_compute_s (per deferred item the
    blocked wall is a sub-interval of its in-flight window)."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    _, fl = run_scenario(space, ground, pcfg, scenario, fleet=True,
                         ingest_overlap=True)
    s = fl.summary()
    for k in ("ingest_dispatch_s", "device_compute_s", "host_fetch_s",
              "ingest_hidden_frac", "ingest_rounds_deferred"):
        assert k in s, f"summary missing {k}"
    assert s["host_fetch_s"] <= s["device_compute_s"]
    assert 0.0 <= s["ingest_hidden_frac"] <= 1.0
    assert s["device_compute_s"] > 0.0  # rounds actually deferred
    # synchronous fleets report an idle pipeline, not garbage
    _, fs = run_scenario(space, ground, pcfg, scenario, fleet=True)
    ss = fs.summary()
    assert ss["ingest_rounds_deferred"] == 0
    assert ss["device_compute_s"] == ss["host_fetch_s"] == 0.0
    assert ss["ingest_hidden_frac"] == 0.0


# ---------------------------------------------------------------------------
# churn elimination: the count-based transfer gate
# ---------------------------------------------------------------------------

def test_repeat_round_transfer_counts_drop(scenario, counters):
    """Steady-state gate: rounds re-presenting already-seen control
    arrays (gather indices, lane/cluster vectors, key stacks) must hit
    the content-keyed cache — strictly fewer uploads than round one,
    i.e. fewer than the pre-cache engine (which paid transfers + reuses
    device_puts for the same work)."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    fl = Fleet(space, ground, pcfg, n_sats=scenario.spec.n_sats)
    rnd = scenario.rounds[0]
    frames = rnd.frames_per_sat(fl.n_sats)
    harvest = rnd.harvest_per_sat(fl.n_sats)
    xfer.clear_cache()
    xfer.reset_transfer_stats()
    fl.ingest(frames, harvest)
    first = xfer.transfer_stats()
    xfer.reset_transfer_stats()
    fl.ingest(frames, harvest)
    second = xfer.transfer_stats()
    assert second["cache_reuses"] > 0, (first, second)
    # the pre-PR engine had no cache: every reuse would have been a
    # device_put, so the old upload count for this round is exactly
    # puts + reuses — the cached path issues strictly fewer
    pre_pr_puts = second["device_puts"] + second["cache_reuses"]
    assert second["device_puts"] < pre_pr_puts, (first, second)
    assert second["device_puts"] < first["device_puts"] + \
        first["cache_reuses"], (first, second)


def test_transfer_cache_bounds():
    """Oversize arrays bypass the cache; the entry count stays bounded
    (clear-on-overflow, not unbounded growth)."""
    xfer.clear_cache()
    big = np.zeros(1 << 15, np.float64)  # 256 KiB > the 64 KiB item cap
    xfer.device_constant(big)
    assert xfer.cache_size() == 0
    small = np.arange(8, dtype=np.int64)
    a = xfer.device_constant(small)
    b = xfer.device_constant(small.copy())
    assert a is b  # content-keyed: equal bytes -> the same device array
    assert xfer.cache_size() == 1
