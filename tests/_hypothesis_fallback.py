"""Deterministic mini property runner standing in for `hypothesis`.

When hypothesis is installed the test modules import the real thing and
this file is inert. When it isn't (the CI image bakes no extra wheels),
the property tests still RUN — each ``@given`` test executes
``max_examples`` deterministic examples drawn from a generator seeded by
the test's name, so failures are reproducible run-to-run and the suite
exercises the same invariants either way. No shrinking: a falsifying
example is reported verbatim.

Usage (in test modules):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st

Supported surface (what the repo's tests use): ``settings(max_examples=,
deadline=)``, ``given(*args, **kwargs)`` — positional strategies match
the test's rightmost parameters (hypothesis's rule) and parameters not
covered by ``given`` stay in the wrapper's signature, so pytest injects
them as fixtures (e.g. ``counters``) — and the strategies
``sampled_from``, ``integers``, ``floats``, ``booleans``, ``lists``.
"""
import inspect
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


class strategies:
    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(
            lambda rng: elements[int(rng.integers(len(elements)))])

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Stashes ``max_examples`` on the test for ``given`` to read (the
    repo applies ``settings`` as the inner decorator)."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*pos_strats, **strats):
    """Run the test once per example with deterministic draws.

    Positional strategies are matched to the test function's RIGHTMOST
    parameters (hypothesis's rule, which is what lets ``self``/fixtures
    sit on the left). The wrapper's signature keeps only the parameters
    *not* covered by ``given``, so pytest resolves those as fixtures
    exactly as real hypothesis does.
    """
    def deco(fn):
        n_examples = getattr(fn, "_fallback_max_examples",
                             _DEFAULT_MAX_EXAMPLES)
        sig = inspect.signature(fn)
        if pos_strats:
            names = list(sig.parameters)[-len(pos_strats):]
            strats.update(zip(names, pos_strats))
        fixture_params = [p for name, p in sig.parameters.items()
                          if name not in strats]

        def runner(**fixtures):
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for i in range(n_examples):
                drawn = {k: s.draw(rng) for k, s in strats.items()}
                try:
                    fn(**drawn, **fixtures)
                except Exception as e:
                    raise AssertionError(
                        f"Falsifying example (#{i + 1} of {n_examples}, "
                        f"fallback runner): {fn.__name__}({drawn!r})"
                    ) from e

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__signature__ = sig.replace(parameters=fixture_params)
        return runner
    return deco


st = strategies
