"""Minimal stand-in for `hypothesis` so the suite still collects when it
isn't installed: property tests skip cleanly, everything else runs.

Usage (in test modules):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st
"""
import pytest


def given(*_args, **_kwargs):
    # NB: the zero-arg replacement must NOT carry the original signature
    # (no functools.wraps) or pytest would try to resolve the property
    # arguments as fixtures and error at setup instead of skipping.
    def deco(fn):
        def skipper():
            pytest.skip("hypothesis not installed (see requirements-dev.txt)")
        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper
    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn
    return deco


class _Strategy:
    """Chainable no-op standing in for any strategy expression."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self


class _Strategies:
    def __getattr__(self, name):
        return _Strategy()


strategies = _Strategies()
