"""Property-based Mission budget-conservation invariants (hypothesis).

Runs under real hypothesis when installed (see requirements-dev.txt);
otherwise the `_hypothesis_fallback` mini runner executes each property
over deterministic seeded examples, so the invariants are exercised
either way. All generative tests are marked ``slow`` so `-m "not slow"`
deselects them.

Invariants (paper §III-A-1 budget model):
  * onboard energy classes (capture/compute/aggregate) never overdraw
    the granted harvest — the energy cap governs them;
  * downlink bytes never exceed the offered window budgets, per window
    and in aggregate;
  * ``pending_segments`` drains to 0 after ``finalize()`` and stays
    drained (idempotence);
  * splitting a frame batch across multiple ``ingest()`` calls conserves
    the aggregate tile/truth/frame counts of a single call, for every
    registered policy;
  * the batched ContactPlan executor preserves per-window byte caps and
    FIFO-within-window prefix-drain semantics (each pending segment's
    spend is exactly ``min(requested, budget - earlier spends)``), and
    stays result-equal to the scalar FIFO reference under randomly
    drawn window schedules;
  * the depth-k ground-recount pipeline is completion-order
    independent: random stall patterns over queued rounds (with
    corruption/retry in play) never change results vs the synchronous
    path.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest of the suite runs
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.faults import FaultPlan
from repro.core.fleet import Fleet
from repro.core.mission import Mission
from repro.core.pipeline import PipelineConfig
from repro.data.synthetic import SceneSpec, make_scene, revisit_frames

METHODS = ("space_only", "ground_only", "tiansuan", "kodan", "targetfuse")
# small scenes: 4 tiles/frame at the default 128-px tile size
SPEC = SceneSpec("prop", 256, (4, 10), (8, 20), cloud_fraction=0.2)

pytestmark = pytest.mark.slow


def _frames(seed: int, n_frames: int):
    rng = np.random.default_rng(seed)
    img, b, c = make_scene(rng, SPEC)
    return revisit_frames(rng, img, b, c, n_frames)


def _pcfg(method: str, **kw) -> PipelineConfig:
    kw.setdefault("score_thresh", 0.25)
    kw.setdefault("tiles_per_day", 20_000.0)
    return PipelineConfig(method=method, **kw)


@given(method=st.sampled_from(METHODS), seed=st.integers(0, 2**20),
       n_frames=st.integers(1, 3),
       tiles_per_day=st.floats(2_000.0, 200_000.0))
@settings(max_examples=10, deadline=None)
def test_energy_never_overdraws_grant(method, seed, n_frames, tiles_per_day,
                                      counters):
    """Capture + compute + aggregate spend stays within the granted
    harvest (the onboard classes the energy cap governs)."""
    space, ground = counters
    m = Mission(space, ground, _pcfg(method, tiles_per_day=tiles_per_day))
    m.ingest(_frames(seed, n_frames))
    m.finalize()
    led = m.ledger
    assert led.e_cap + led.e_com + led.e_agg <= led.budget_j + 1e-9
    assert led.e_com <= 0.95 * led.budget_j + 1e-9  # the 5% headroom cap
    assert led.remaining >= 0.0


@given(method=st.sampled_from(METHODS), seed=st.integers(0, 2**20),
       budgets=st.lists(st.floats(0.0, 3.0), min_size=1, max_size=3))
@settings(max_examples=10, deadline=None)
def test_downlink_never_exceeds_window_budget(method, seed, budgets,
                                              counters):
    """Per-window and aggregate byte spends respect the offered budgets
    (budgets drawn in units of one full-scale tile)."""
    space, ground = counters
    m = Mission(space, ground, _pcfg(method))
    reports = []
    for k, b in enumerate(budgets):
        m.ingest(_frames(seed + k, 1))
        reports.append(m.contact_window(b * m.tile_bytes))
    for rep in reports:
        assert rep.bytes_spent <= rep.budget_bytes + 1e-6
    assert m.bytes_spent <= m.bytes_budget + 1e-6
    r = m.result()
    assert r.bytes_budget == pytest.approx(
        sum(rep.budget_bytes for rep in reports))


@given(method=st.sampled_from(METHODS), seed=st.integers(0, 2**20),
       n_passes=st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_finalize_drains_pending_and_stays_drained(method, seed, n_passes,
                                                   counters):
    space, ground = counters
    m = Mission(space, ground, _pcfg(method))
    for k in range(n_passes):
        m.ingest(_frames(seed + k, 1))
    assert m.pending_segments == n_passes
    r1 = m.finalize()
    assert m.pending_segments == 0
    s1 = r1.summary()
    # idempotent: repeated finalize (and interleaved windows) are no-ops
    m.contact_window(1e9)
    r2 = m.finalize()
    assert m.pending_segments == 0
    assert r2.summary() == s1


@given(method=st.sampled_from(METHODS), seed=st.integers(0, 2**20),
       n_frames=st.integers(2, 4), split=st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_split_ingest_conserves_aggregate_counts(method, seed, n_frames,
                                                 split, counters):
    """ingest(A+B) and ingest(A); ingest(B) see the same tiles, truth,
    frames, and (additively) the same day-fraction entitlements."""
    space, ground = counters
    split = min(split, n_frames - 1)
    frames = _frames(seed, n_frames)

    one = Mission(space, ground, _pcfg(method))
    rep_one = one.ingest(frames)
    r_one = one.finalize()

    two = Mission(space, ground, _pcfg(method))
    rep_a = two.ingest(frames[:split])
    rep_b = two.ingest(frames[split:])
    r_two = two.finalize()

    assert rep_a.n_frames + rep_b.n_frames == rep_one.n_frames
    assert rep_a.n_tiles + rep_b.n_tiles == rep_one.n_tiles
    assert two.frames_seen == one.frames_seen
    assert r_two.tiles_total == r_one.tiles_total
    np.testing.assert_array_equal(r_two.per_tile_true, r_one.per_tile_true)
    assert r_two.total_true == r_one.total_true
    # day-fraction budgets prorate linearly over the split
    assert (rep_a.energy_granted_j + rep_b.energy_granted_j
            == pytest.approx(rep_one.energy_granted_j, rel=1e-9))
    assert (rep_a.byte_entitlement + rep_b.byte_entitlement
            == pytest.approx(rep_one.byte_entitlement, rel=1e-9))


# ---------------------------------------------------------------------------
# batched ContactPlan executor properties
# ---------------------------------------------------------------------------

@given(method=st.sampled_from(METHODS), seed=st.integers(0, 2**20),
       budgets=st.lists(st.floats(0.0, 3.0), min_size=1, max_size=3),
       stations=st.integers(1, 3))
@settings(max_examples=8, deadline=None)
def test_batched_plan_respects_window_byte_caps(method, seed, budgets,
                                                stations, counters):
    """Under the batched planner, every window report's spend respects
    its offered budget and the fleet ledger never overdraws in
    aggregate (budgets drawn in units of one full-scale tile; multiple
    windows per round stack lanes)."""
    space, ground = counters
    fleet = Fleet(space, ground, _pcfg(method), n_sats=2)
    tb = fleet.missions[0].tile_bytes
    reports = []
    for k, b in enumerate(budgets):
        fleet.ingest([_frames(seed + k, 1), _frames(seed + 7 * k + 1, 1)])
        reports += fleet.contact_round(stations=stations,
                                       budget_bytes=b * tb)
    for _, rep in reports:
        assert rep.bytes_spent <= rep.budget_bytes + 1e-6
    led = fleet.ledger
    assert (led.bytes_spent <= led.bytes_budget + 1e-6).all()
    assert float(led.bytes_spent.sum()) <= float(led.bytes_budget.sum()) + 1e-6


@given(method=st.sampled_from(METHODS), seed=st.integers(0, 2**20),
       n_passes=st.integers(2, 4), budget_tiles=st.floats(0.0, 6.0))
@settings(max_examples=8, deadline=None)
def test_batched_plan_fifo_prefix_drain(method, seed, n_passes,
                                        budget_tiles, counters):
    """FIFO-within-window: one window draining several pending segments
    gives each segment EXACTLY ``min(requested, budget - earlier
    spends)`` — the prefix-sum drain the batched executor implements
    step-wise (float-exact, not approximate)."""
    space, ground = counters
    fleet = Fleet(space, ground, _pcfg(method), n_sats=1)
    for k in range(n_passes):
        fleet.ingest([_frames(seed + k, 1)])
    budget = budget_tiles * fleet.missions[0].tile_bytes
    [(_, rep)] = fleet.contact_round(windows=[(0, budget)])
    segs = fleet.missions[0]._segments
    assert rep.segments == n_passes == len(segs)
    remaining = float(budget)
    for s in segs:
        assert s.bytes_spent == min(s.bytes_requested, remaining)
        remaining -= s.bytes_spent
    assert remaining >= -1e-9
    assert rep.bytes_spent == pytest.approx(
        sum(s.bytes_spent for s in segs))


@given(method=st.sampled_from(METHODS), seed=st.integers(0, 2**20),
       budgets=st.lists(st.floats(0.0, 4.0), min_size=1, max_size=2),
       stations=st.integers(1, 3))
@settings(max_examples=6, deadline=None)
def test_batched_plan_matches_reference_property(method, seed, budgets,
                                                 stations, counters):
    """Generative differential gate: random window schedules through the
    batched planner and the scalar FIFO reference produce identical
    per-tile predictions, summaries, and ledger lanes."""
    space, ground = counters

    def run(reference):
        fleet = Fleet(space, ground, _pcfg(method), n_sats=2)
        rnd = (fleet.contact_round_reference if reference
               else fleet.contact_round)
        tb = fleet.missions[0].tile_bytes
        for k, b in enumerate(budgets):
            fleet.ingest([_frames(seed + k, 1), _frames(seed + 5 * k + 3, 1)])
            rnd(stations=stations, budget_bytes=b * tb)
        return fleet.finalize(), fleet

    got, fb = run(reference=False)
    want, fr = run(reference=True)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a.per_tile_pred, b.per_tile_pred)
        assert a.summary() == b.summary()
    for f in ("budget_j", "e_down", "bytes_budget", "bytes_requested",
              "bytes_spent"):
        np.testing.assert_array_equal(getattr(fb.ledger, f),
                                      getattr(fr.ledger, f))


# ---------------------------------------------------------------------------
# fault-injection properties (repro.core.faults)
# ---------------------------------------------------------------------------

def _faulty_fleet(space, ground, faults, seed, reference=False):
    fleet = Fleet(space, ground, _pcfg("targetfuse"), n_sats=2,
                  faults=faults, contact_reference=reference)
    tb = fleet.missions[0].tile_bytes
    for k in range(3):
        fleet.ingest([_frames(seed + k, 1), _frames(seed + 11 * k + 5, 1)])
        fleet.contact_round(stations=2, budget_bytes=2.0 * tb)
    return fleet


@given(seed=st.integers(0, 2**20), drop=st.floats(0.0, 0.4),
       corrupt=st.floats(0.0, 0.6), blackout=st.floats(0.0, 0.4),
       retries=st.integers(0, 2),
       policy=st.sampled_from(("refund", "charge")))
@settings(max_examples=6, deadline=None)
def test_fault_ledger_and_retry_invariants(seed, drop, corrupt, blackout,
                                           retries, policy, counters):
    """Under ANY generated FaultPlan: ledgers never go negative, no
    segment is ground-credited twice (every segment contributes exactly
    one prediction block), retries never exceed the bound, refunds never
    exceed waste, and ``finalize()`` drains everything not permanently
    lost."""
    space, ground = counters
    faults = FaultPlan(seed=seed, drop_rate=drop, truncate_rate=0.3,
                       corrupt_rate=corrupt, blackout_rate=blackout,
                       max_retries=retries, refund_policy=policy)
    fleet = _faulty_fleet(space, ground, faults, seed)
    res = fleet.finalize()
    assert fleet.pending_segments == [0, 0]
    for m, r in zip(fleet.missions, res):
        segs = m._segments
        assert all(s.pred is not None for s in segs)
        # one prediction block per segment == never credited twice
        assert len(r.per_tile_pred) == sum(s.n for s in segs)
        assert all(s.retries <= faults.max_retries for s in segs)
    led = fleet.ledger
    for f in ("budget_j", "e_cap", "e_com", "e_agg", "e_down",
              "bytes_budget", "bytes_spent"):
        assert (getattr(led, f)[:2] >= 0.0).all(), f"{f} went negative"
    stats = fleet.fault_stats
    assert stats.bytes_refunded <= stats.bytes_wasted + 1e-9
    if policy == "charge":
        assert stats.bytes_refunded == 0.0
    # net ledger spend reconciles with the byte-flow accounting
    assert float(led.bytes_spent[:2].sum()) == pytest.approx(
        stats.bytes_delivered + stats.bytes_wasted - stats.bytes_refunded,
        rel=1e-9, abs=1e-6)


@given(method=st.sampled_from(METHODS), seed=st.integers(0, 2**20),
       depth=st.integers(1, 3),
       stalls=st.lists(st.booleans(), min_size=3, max_size=3),
       corrupt=st.floats(0.0, 0.5))
@settings(max_examples=6, deadline=None)
def test_queued_round_completion_order_never_affects_results(
        method, seed, depth, stalls, corrupt, counters):
    """Whatever order queued rounds' workers complete in — injected
    stalls make stalled rounds finish AFTER later rounds' workers — the
    depth-k recount pipeline stays bit-equal to the synchronous path,
    including under corruption/retry, where a requeued segment's
    selection is rewritten by a later round's foreground drain while
    earlier rounds are still in flight (the dispatch-time snapshot
    property)."""
    space, ground = counters
    faults = FaultPlan(
        seed=seed, corrupt_rate=corrupt, max_retries=2,
        worker_faults={r: "stall" for r, s in enumerate(stalls) if s},
        stall_s=0.05)

    def run(async_depth):
        fleet = Fleet(space, ground, _pcfg(method), n_sats=2,
                      faults=faults, async_depth=async_depth)
        tb = fleet.missions[0].tile_bytes
        for k in range(3):
            fleet.ingest([_frames(seed + k, 1),
                          _frames(seed + 11 * k + 5, 1)])
            fleet.contact_round(stations=2, budget_bytes=2.0 * tb)
        return fleet.finalize(), fleet

    got, fa = run(depth)
    want, fs = run(0)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a.per_tile_pred, b.per_tile_pred)
        assert a.summary() == b.summary()
    for f in ("budget_j", "e_down", "bytes_budget", "bytes_requested",
              "bytes_spent"):
        np.testing.assert_array_equal(getattr(fa.ledger, f),
                                      getattr(fs.ledger, f))
    assert fa.ground_segment.wait_s <= fa.ground_segment.recount_s


@given(seed=st.integers(0, 2**20), drop=st.floats(0.0, 0.4),
       trunc=st.floats(0.0, 0.5), corrupt=st.floats(0.0, 0.5),
       retries=st.integers(0, 2))
@settings(max_examples=4, deadline=None)
def test_faulty_batched_matches_reference_property(seed, drop, trunc,
                                                   corrupt, retries,
                                                   counters):
    """Generative differential gate: ANY drawn fault schedule produces
    identical predictions, summaries, fault counters, and ledger lanes
    through the batched executor and the scalar FIFO reference."""
    space, ground = counters
    faults = FaultPlan(seed=seed, drop_rate=drop, truncate_rate=trunc,
                       corrupt_rate=corrupt, max_retries=retries)
    fb = _faulty_fleet(space, ground, faults, seed)
    fr = _faulty_fleet(space, ground, faults, seed, reference=True)
    got, want = fb.finalize(), fr.finalize()
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a.per_tile_pred, b.per_tile_pred)
        assert a.summary() == b.summary()
    assert fb.fault_stats == fr.fault_stats
    for f in ("budget_j", "e_down", "bytes_budget", "bytes_requested",
              "bytes_spent"):
        np.testing.assert_array_equal(getattr(fb.ledger, f),
                                      getattr(fr.ledger, f))
