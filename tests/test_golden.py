"""Golden-snapshot regression tests: per-policy Mission summaries from a
fixed-seed scenario, committed under tests/golden/. Silent numeric drift
anywhere in the pipeline (tiling, dedup, counting, selection, budget
arithmetic) fails loudly here.

Regenerate intentionally with:

    PYTHONPATH=src python -m pytest tests/test_golden.py --update-golden

The snapshots pin one software/hardware stack (the repo's CI image):
float32 conv/resize/k-means results can legitimately differ across CPU
architectures or XLA builds, and on such a platform these tests flag
the drift once — regenerate with the flag above after confirming the
difference is environmental, not a pipeline regression.
"""
import json
import os

import numpy as np
import pytest

from repro.core.mission import Mission
from repro.core.pipeline import PipelineConfig
from repro.data.synthetic import SceneSpec, make_scene, revisit_frames

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
METHODS = ("space_only", "ground_only", "tiansuan", "kodan", "targetfuse")
SPEC = SceneSpec("golden", 384, (12, 18), (10, 24), cloud_fraction=0.2)


def _scenario_frames():
    rng = np.random.default_rng(42)
    img, b, c = make_scene(rng, SPEC)
    return revisit_frames(rng, img, b, c, 3)


def _run_summary(method, counters):
    space, ground = counters
    pcfg = PipelineConfig(method=method, score_thresh=0.25, seed=0)
    m = Mission(space, ground, pcfg)
    m.ingest(_scenario_frames())
    m.contact_window(3e6)
    return m.finalize().summary()


@pytest.mark.parametrize("method", METHODS)
def test_golden_summary(method, counters, request):
    path = os.path.join(GOLDEN_DIR, f"{method}.json")
    got = _run_summary(method, counters)
    if request.config.getoption("--update-golden"):
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            json.dump(got, f, indent=2, sort_keys=True)
        pytest.skip(f"updated {path}")
    if not os.path.exists(path):
        pytest.fail(f"golden snapshot missing: {path} — run pytest with "
                    f"--update-golden to create it")
    with open(path) as f:
        want = json.load(f)
    assert set(got) == set(want), "summary keys drifted"
    for k, w in want.items():
        g = got[k]
        if isinstance(w, int) and isinstance(g, int):
            assert g == w, f"{method}.{k}: {g} != golden {w}"
        else:
            assert g == pytest.approx(w, rel=1e-12, abs=1e-12), (
                f"{method}.{k}: {g} != golden {w}")
