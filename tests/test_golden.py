"""Golden-snapshot regression tests: per-policy Mission summaries from a
fixed-seed scenario, committed under tests/golden/. Silent numeric drift
anywhere in the pipeline (tiling, dedup, counting, selection, budget
arithmetic) fails loudly here.

Regenerate intentionally with:

    PYTHONPATH=src python -m pytest tests/test_golden.py --update-golden

The snapshots pin one software/hardware stack (the repo's CI image):
float32 conv/resize/k-means results can legitimately differ across CPU
architectures or XLA builds, and on such a platform these tests flag
the drift once — regenerate with the flag above after confirming the
difference is environmental, not a pipeline regression.
"""
import json
import os

import numpy as np
import pytest

from repro.core.mission import Mission
from repro.core.pipeline import PipelineConfig
from repro.data.synthetic import SceneSpec, make_scene, revisit_frames

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
METHODS = ("space_only", "ground_only", "tiansuan", "kodan", "targetfuse")
SPEC = SceneSpec("golden", 384, (12, 18), (10, 24), cloud_fraction=0.2)


def _scenario_frames():
    rng = np.random.default_rng(42)
    img, b, c = make_scene(rng, SPEC)
    return revisit_frames(rng, img, b, c, 3)


def _run_summary(method, counters):
    space, ground = counters
    pcfg = PipelineConfig(method=method, score_thresh=0.25, seed=0)
    m = Mission(space, ground, pcfg)
    m.ingest(_scenario_frames())
    m.contact_window(3e6)
    return m.finalize().summary()


def _check_golden(got: dict, path: str, request, ctx: str):
    if request.config.getoption("--update-golden"):
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            json.dump(got, f, indent=2, sort_keys=True)
        pytest.skip(f"updated {path}")
    if not os.path.exists(path):
        pytest.fail(f"golden snapshot missing: {path} — run pytest with "
                    f"--update-golden to create it")
    with open(path) as f:
        want = json.load(f)
    _compare(got, want, ctx)


def _compare(got, want, ctx):
    assert type(want) is type(got) or isinstance(got, type(want)), \
        f"{ctx}: type drifted ({type(got)} vs {type(want)})"
    if isinstance(want, dict):
        assert set(got) == set(want), f"{ctx}: keys drifted"
        for k in want:
            _compare(got[k], want[k], f"{ctx}.{k}")
    elif isinstance(want, list):
        assert len(got) == len(want), f"{ctx}: length drifted"
        for i, (g, w) in enumerate(zip(got, want)):
            _compare(g, w, f"{ctx}[{i}]")
    elif isinstance(want, bool) or isinstance(want, str):
        assert got == want, f"{ctx}: {got} != golden {want}"
    elif isinstance(want, int) and isinstance(got, int):
        assert got == want, f"{ctx}: {got} != golden {want}"
    else:
        assert got == pytest.approx(want, rel=1e-12, abs=1e-12), (
            f"{ctx}: {got} != golden {want}")


@pytest.mark.parametrize("method", METHODS)
def test_golden_summary(method, counters, request):
    got = _run_summary(method, counters)
    _check_golden(got, os.path.join(GOLDEN_DIR, f"{method}.json"),
                  request, method)


def test_golden_contact_plan_round(counters, request):
    """Scenario-driven ContactPlan rounds through the batched
    ground-segment core: per-satellite summaries plus the deterministic
    fleet facts (windows served, byte/energy aggregates) of a fixed-seed
    two-station constellation. Pins the whole contact tier — plan
    construction from scenario events, lane-stacked selection, the
    prefix drain, vectorized ledger charges, and the shared recount."""
    from repro.core.fleet import run_scenario
    from repro.data.scenarios import (FleetScenarioSpec, GroundStation,
                                      generate_scenario)
    space, ground = counters
    sc = generate_scenario(FleetScenarioSpec(
        n_sats=3, n_rounds=2, frames_per_pass=2,
        stations=(GroundStation("gs0"),
                  GroundStation("gs1", bandwidth_mbps=30.0, contact_s=240.0)),
        eclipse_fraction=0.35, seed=21))
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25, seed=0)
    results, fleet = run_scenario(space, ground, pcfg, sc, fleet=True)
    s = fleet.summary()
    got = {
        "per_sat": [r.summary() for r in results],
        "windows_served": s["windows_served"],
        "bytes_spent": s["bytes_spent"],
        "bytes_budget": s["bytes_budget"],
        "energy_spent_j": s["energy_spent_j"],
        "tiles_downlinked": s["tiles_downlinked"],
        "total_pred": s["total_pred"],
    }
    _check_golden(got, os.path.join(GOLDEN_DIR, "contact_plan_fleet.json"),
                  request, "contact_plan_fleet")


def test_golden_orbital_scenario(request):
    """Pins the orbital geometry engine end to end: Walker-delta
    construction, batched propagation, the elevation grid, segment-scan
    pass extraction, eclipse fractions, and the pass->contact pricing
    bridge, as the concrete per-round event stream of one fixed-seed
    ``geometry="orbital"`` scenario. Numeric drift anywhere in the
    subsystem (or in the shared elevation_bandwidth rule) fails here.

    Frames are pinned by count only — their content comes from the same
    seeded generators the toy path uses, which the per-policy summary
    goldens already cover."""
    from repro.data.scenarios import (FleetScenarioSpec, GroundStation,
                                      generate_scenario)
    from repro.orbits import default_sites

    sites = default_sites(4)
    sc = generate_scenario(FleetScenarioSpec(
        n_sats=4, n_rounds=3, frames_per_pass=1,
        stations=tuple(GroundStation(f"gs{k}", site=sites[k])
                       for k in range(4)),
        scene_mix=(SPEC,), seed=5, geometry="orbital", min_elev_deg=5.0))
    got = {
        "n_frames": sc.n_frames,
        "rounds": [{
            "harvest_j": [p.harvest_j for p in r.passes],
            "sunlit": [p.sunlit for p in r.passes],
            "contacts": [{
                "sat": c.sat, "station": c.station.name,
                "bandwidth_mbps": c.bandwidth_mbps,
                "budget_bytes": c.budget_bytes,
            } for c in r.contacts],
        } for r in sc.rounds],
    }
    _check_golden(got, os.path.join(GOLDEN_DIR, "orbital_scenario.json"),
                  request, "orbital_scenario")
