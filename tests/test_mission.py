"""Mission API tests: policy parity against the frozen pre-refactor
monolith, streaming contact windows, budget edge cases, and
registry/stage extensibility.
"""
import inspect

import numpy as np
import pytest

from repro.core._legacy import run_pipeline_legacy
from repro.core.mission import Mission, Stage, default_ingest_stages
from repro.core.pipeline import (PipelineConfig, PipelineResult, budgets_for,
                                 run_pipeline)
from repro.core.policies import (Selection, SelectionPolicy,
                                 available_policies, get_policy,
                                 register_policy)
from repro.data.synthetic import SceneSpec, make_scene, revisit_frames

SPEC = SceneSpec("mini", 384, (12, 18), (10, 24), cloud_fraction=0.2)
METHODS = ("space_only", "ground_only", "tiansuan", "kodan", "targetfuse")


# `counters` comes from tests/conftest.py (session-scoped: the same
# trained pair serves the mission, fleet, invariant, and golden suites)


@pytest.fixture(scope="module")
def frames():
    rng = np.random.default_rng(7)
    img, b, c = make_scene(rng, SPEC)
    return revisit_frames(rng, img, b, c, 3)


def _assert_bit_identical(a: PipelineResult, b: PipelineResult):
    np.testing.assert_array_equal(a.per_tile_pred, b.per_tile_pred)
    np.testing.assert_array_equal(a.per_tile_true, b.per_tile_true)
    assert a.summary() == b.summary()


# ---------------------------------------------------------------------------
# policy parity: Mission executor vs frozen pre-refactor monolith
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_engine", (True, False),
                         ids=("engine", "reference"))
@pytest.mark.parametrize("method", METHODS)
def test_mission_bit_identical_to_legacy(method, use_engine, frames, counters):
    space, ground = counters
    pcfg = PipelineConfig(method=method, score_thresh=0.25,
                          use_engine=use_engine)
    got = Mission(space, ground, pcfg).run(frames)
    want = run_pipeline_legacy(frames, space, ground, pcfg)
    _assert_bit_identical(got, want)


def test_run_pipeline_is_mission_wrapper(frames, counters):
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    _assert_bit_identical(run_pipeline(frames, space, ground, pcfg),
                          Mission(space, ground, pcfg).run(frames))


def test_executor_has_no_method_branching():
    """The acceptance criterion: zero ``pcfg.method`` branching in the
    executor — dispatch is registry-only."""
    import repro.core.mission as mission
    src = inspect.getsource(mission)
    assert "method ==" not in src
    assert "method in (" not in src
    assert "method in [" not in src


def test_all_five_baselines_are_registered_policies():
    assert set(METHODS) <= set(available_policies())
    for m in METHODS:
        assert get_policy(m).name == m


# ---------------------------------------------------------------------------
# tiansuan ground-credit audit (satellite task)
# ---------------------------------------------------------------------------

def _tiansuan_cfg(**kw):
    # tiny energy budget -> the onboard cap leaves active tiles
    # unprocessed; ample bandwidth -> they all join the downlink queue
    return PipelineConfig(method="tiansuan", score_thresh=0.25,
                          energy_budget_j=8_000.0, bandwidth_mbps=500.0,
                          **kw)


@pytest.mark.parametrize("use_engine", (True, False),
                         ids=("engine", "reference"))
def test_tiansuan_unprocessed_downlink_credit(use_engine, frames, counters):
    """Audited PR-1 behaviour: energy-capped unprocessed tiles join the
    indiscriminate downlink queue and spend bytes, but their ground
    counts are never credited (the ``processed_mask`` conjunct). The
    default preserves that bit-for-bit; ``tiansuan_credit_unprocessed``
    credits every downlinked tile."""
    space, ground = counters
    legacy_cfg = _tiansuan_cfg(use_engine=use_engine)
    m = Mission(space, ground, legacy_cfg)
    r_legacy = m.run(frames)
    _assert_bit_identical(r_legacy,
                          run_pipeline_legacy(frames, space, ground,
                                              legacy_cfg))

    seg = m._segments[0]
    down = seg.selection.downlink
    unproc_down = down[~seg.processed[down]]
    assert len(unproc_down) > 0, "scenario must exercise the energy cap"
    # bytes were spent on these tiles ...
    assert seg.bytes_requested >= len(down) * m.tile_bytes - 1e-6
    # ... but the default (paper-parity) behaviour credits none of them
    assert np.all(r_legacy.per_tile_pred[unproc_down] == 0.0)

    fixed_cfg = _tiansuan_cfg(use_engine=use_engine,
                              tiansuan_credit_unprocessed=True)
    m2 = Mission(space, ground, fixed_cfg)
    r_fixed = m2.run(frames)
    seg2 = m2._segments[0]
    # same downlink selection, same bytes — only crediting changes
    np.testing.assert_array_equal(seg2.selection.downlink, down)
    assert r_fixed.bytes_downlinked == r_legacy.bytes_downlinked
    np.testing.assert_array_equal(r_fixed.per_tile_pred[unproc_down],
                                  seg2.counts_gd[unproc_down])
    others = np.ones(seg.n, bool)
    others[unproc_down] = False
    np.testing.assert_array_equal(r_fixed.per_tile_pred[others],
                                  r_legacy.per_tile_pred[others])


# ---------------------------------------------------------------------------
# budget edge cases (satellite task)
# ---------------------------------------------------------------------------

def test_budgets_for_degenerate_inputs():
    pcfg = PipelineConfig()
    tile_bytes = float(pcfg.real_tile_px ** 2 * 3)
    assert budgets_for(pcfg, 0) == (0.0, 0.0, tile_bytes)
    assert budgets_for(PipelineConfig(tiles_per_day=0.0), 100) == \
        (0.0, 0.0, tile_bytes)
    assert budgets_for(PipelineConfig(tiles_per_day=-5.0), 100) == \
        (0.0, 0.0, tile_bytes)


@pytest.mark.parametrize("use_engine", (True, False),
                         ids=("engine", "reference"))
@pytest.mark.parametrize("method", METHODS)
def test_empty_frames(method, use_engine, counters):
    space, ground = counters
    pcfg = PipelineConfig(method=method, use_engine=use_engine)
    r = run_pipeline([], space, ground, pcfg)
    assert r.tiles_total == 0
    assert r.tiles_downlinked == 0
    assert r.tiles_processed_space == 0
    assert r.bytes_downlinked == 0.0
    assert r.per_tile_pred.shape == (0,)


@pytest.mark.parametrize("method", METHODS)
def test_zero_tiles_per_day_empty_selection(method, frames, counters):
    space, ground = counters
    pcfg = PipelineConfig(method=method, score_thresh=0.25,
                          tiles_per_day=0.0)
    r = run_pipeline(frames, space, ground, pcfg)
    assert r.tiles_processed_space == 0  # zero energy -> nothing onboard
    if method != "kodan":  # kodan is bandwidth-oblivious by design
        assert r.tiles_downlinked == 0
    assert r.energy_budget_j == 0.0


@pytest.mark.parametrize("method", ("ground_only", "tiansuan", "targetfuse"))
def test_byte_budget_below_one_tile(method, frames, counters):
    space, ground = counters
    pcfg = PipelineConfig(method=method, score_thresh=0.25,
                          bandwidth_mbps=1e-6)  # budget << one tile
    _, byte_budget, tile_bytes = budgets_for(pcfg, 48)
    assert byte_budget < tile_bytes
    r = run_pipeline(frames, space, ground, pcfg)
    assert r.tiles_downlinked == 0
    assert r.bytes_downlinked == 0.0


# ---------------------------------------------------------------------------
# PipelineResult typing + summary (satellite task)
# ---------------------------------------------------------------------------

def test_pipeline_result_optional_and_summary():
    r = PipelineResult(cmae=0.5, total_true=10.0, total_pred=9.0,
                       bytes_downlinked=1.0, bytes_budget=2.0,
                       tiles_processed_space=3, tiles_downlinked=1,
                       tiles_total=4, energy_spent_j=5.0,
                       energy_budget_j=6.0)
    assert r.per_tile_pred is None and r.per_tile_true is None
    s = r.summary()
    assert s["cmae"] == 0.5 and s["tiles_total"] == 4
    assert not any(k.startswith("per_tile") for k in s)
    assert "per_tile_pred" not in repr(r)


# ---------------------------------------------------------------------------
# streaming: multi-ingest, multi-window, carried budgets
# ---------------------------------------------------------------------------

def test_streaming_two_windows_budget_consistent(frames, counters):
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    m = Mission(space, ground, pcfg)

    ing1 = m.ingest(frames)
    w1 = m.contact_window()
    ing2 = m.ingest(frames)
    w2 = m.contact_window()
    r = m.result()

    # budgets accumulate across passes/windows
    assert m.ledger.budget_j == pytest.approx(
        ing1.energy_granted_j + ing2.energy_granted_j)
    assert r.bytes_budget == pytest.approx(w1.budget_bytes + w2.budget_bytes)
    assert r.tiles_total == ing1.n_tiles + ing2.n_tiles
    # spend never exceeds the offered window budgets
    assert m.bytes_spent <= r.bytes_budget + 1e-6
    assert w1.bytes_spent <= w1.budget_bytes + 1e-6
    assert w2.bytes_spent <= w2.budget_bytes + 1e-6
    # per-tile outputs cover every ingested tile
    assert r.per_tile_pred.shape == (r.tiles_total,)
    # one-shot over the same first pass agrees with window 1's segment
    one = Mission(space, ground, pcfg).run(frames)
    np.testing.assert_array_equal(one.per_tile_pred,
                                  m._segments[0].pred)


def test_streaming_window_order_is_fifo(frames, counters):
    """Two pending segments drain FIFO within one window; the second
    sees only leftover bytes."""
    space, ground = counters
    pcfg = PipelineConfig(method="ground_only", score_thresh=0.25)
    m = Mission(space, ground, pcfg)
    m.ingest(frames)
    m.ingest(frames)
    n = m._segments[0].n
    tile_bytes = m.tile_bytes
    rep = m.contact_window(budget_bytes=tile_bytes * (n + 2))
    # first segment downlinks n tiles; second only the 2 leftover slots
    assert len(m._segments[0].selection.downlink) == n
    assert len(m._segments[1].selection.downlink) == 2
    assert rep.tiles_downlinked == n + 2
    assert rep.bytes_spent <= rep.budget_bytes + 1e-6


def test_finalize_flushes_pending_onboard_only(frames, counters):
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    m = Mission(space, ground, pcfg)
    m.ingest(frames)
    assert m.pending_segments == 1
    r = m.finalize()
    assert m.pending_segments == 0
    assert r.tiles_downlinked == 0  # zero-byte window: nothing transmits
    assert r.bytes_budget == 0.0
    # dynamic_conf: leftovers are counted in space, so onboard results land
    assert r.tiles_processed_space > 0
    assert r.total_pred > 0


def test_finalize_idempotent(frames, counters):
    """finalize() twice (and contact_window() in between) is a no-op:
    no double flush, no byte-budget inflation, no raise."""
    space, ground = counters
    m = Mission(space, ground,
                PipelineConfig(method="targetfuse", score_thresh=0.25))
    m.ingest(frames)
    r1 = m.finalize()
    s1 = r1.summary()
    # an offered window after finalize neither drains nor accrues budget
    w = m.contact_window(1e9)
    assert w.segments == 0 and w.budget_bytes == 0.0
    assert w.bytes_spent == 0.0 and w.tiles_downlinked == 0
    r2 = m.finalize()
    assert r2.summary() == s1
    np.testing.assert_array_equal(r2.per_tile_pred, r1.per_tile_pred)
    assert m.bytes_budget == r1.bytes_budget  # not inflated by the window


def test_ingest_after_finalize_resumes_stream(frames, counters):
    space, ground = counters
    m = Mission(space, ground,
                PipelineConfig(method="targetfuse", score_thresh=0.25))
    m.ingest(frames)
    r1 = m.finalize()
    m.ingest(frames)
    assert m.pending_segments == 1
    w = m.contact_window()  # a real window again
    assert w.segments == 1
    r2 = m.finalize()
    assert r2.tiles_total == 2 * r1.tiles_total


def test_ingest_report_fields(frames, counters):
    space, ground = counters
    m = Mission(space, ground,
                PipelineConfig(method="targetfuse", score_thresh=0.25))
    ing = m.ingest(frames)
    assert ing.n_frames == len(frames)
    assert ing.n_tiles == (384 // 128) ** 2 * len(frames)
    assert 0 < ing.tiles_processed_space <= ing.n_tiles
    assert ing.energy_granted_j > 0 and ing.byte_entitlement > 0


# ---------------------------------------------------------------------------
# extensibility: policies and stages register without touching core
# ---------------------------------------------------------------------------

def test_custom_policy_registers_and_runs(frames, counters):
    @register_policy("_test_discard_all")
    class DiscardAll(SelectionPolicy):
        def select(self, ctx, budget_bytes):
            return Selection(np.zeros(ctx.n, bool), np.zeros(0, np.int64),
                             np.zeros(ctx.n, bool), 0.0)

    assert "_test_discard_all" in available_policies()
    space, ground = counters
    r = Mission(space, ground,
                PipelineConfig(method="_test_discard_all",
                               score_thresh=0.25)).run(frames)
    assert r.total_pred == 0.0
    assert r.tiles_downlinked == 0
    assert r.tiles_processed_space > 0  # onboard stages still ran


def test_unknown_policy_rejected(counters):
    space, ground = counters
    with pytest.raises(ValueError, match="unknown selection policy"):
        Mission(space, ground, PipelineConfig(method="nope"))


def test_custom_stage_inserts_into_graph(frames, counters):
    calls = []

    class Probe(Stage):
        name = "probe"

        def run(self, mission, seg, window=None):
            calls.append(seg.n)

    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    m = Mission(space, ground, pcfg,
                ingest_stages=default_ingest_stages() + [Probe()])
    m.ingest(frames)
    m.ingest(frames)
    assert calls == [m._segments[0].n, m._segments[1].n]
