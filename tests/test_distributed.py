"""Distribution tests: sharding rules, cell plans, tiny-mesh dry-run via
subprocess (needs its own XLA device-count env), elastic resharding."""
import functools
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, all_cells, get_config, get_shapes
from repro.models import lm
from repro.sharding.rules import param_specs, rules_for, spec_for_path

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_spec_for_path_rank_alignment():
    rules = [(r"wq$", P(None, None, "model"))]
    assert spec_for_path("blocks/attn/wq", 3, rules) == P(None, None, "model")
    # un-stacked (2D) weight right-aligns
    assert spec_for_path("attn/wq", 2, rules) == P(None, "model")
    assert spec_for_path("other", 2, rules) == P(None, None)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_cover_all_archs(arch):
    """Every param leaf gets a spec of matching rank; big matmul weights
    actually get model-sharded."""
    from repro.configs import reduced
    from repro.models import convnext, detector, dit, resnet, unet, vit
    cfg = get_config(arch)
    if cfg.family == "lm":
        sds = jax.eval_shape(functools.partial(lm.init, cfg=cfg),
                             jax.random.PRNGKey(0))
    elif cfg.family == "vision":
        mod = {"vit": vit, "convnext": convnext, "resnet": resnet}[cfg.kind]
        sds = jax.eval_shape(functools.partial(mod.init, cfg=cfg),
                             jax.random.PRNGKey(0))
        if cfg.kind == "resnet":
            sds = sds[0]
    else:
        mod = dit if cfg.kind == "dit" else unet
        sds = jax.eval_shape(functools.partial(mod.init, cfg=cfg),
                             jax.random.PRNGKey(0))
    specs = param_specs(sds, cfg)
    leaves_s = jax.tree_util.tree_leaves(sds)
    leaves_p = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_s) == len(leaves_p)
    n_sharded_bytes = 0
    n_total_bytes = 0
    for s, p in zip(leaves_s, leaves_p):
        assert len(p) == s.ndim, (p, s.shape)
        b = int(np.prod(s.shape)) * s.dtype.itemsize
        n_total_bytes += b
        if any(ax is not None for ax in p):
            n_sharded_bytes += b
    assert n_sharded_bytes / n_total_bytes > 0.8, "most weight bytes sharded"


def test_cell_plans_build_for_all_cells():
    """Every (arch x shape) builds a CellPlan with consistent trees —
    without any device allocation (pure eval_shape)."""
    from repro.launch.steps import build_cell
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch, shape in all_cells():
        plan = build_cell(arch, shape, mesh)
        assert len(plan.args_sds) == len(plan.in_shardings), (arch, shape)
        jax.tree_util.tree_map(lambda a, b: None, plan.args_sds,
                               jax.tree_util.tree_map(lambda x: x, plan.args_sds))


def test_input_specs_are_abstract():
    from repro.launch.steps import input_specs
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sds = input_specs("qwen3-8b", "train_4k", mesh)
    for leaf in jax.tree_util.tree_leaves(sds):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


@pytest.mark.parametrize("cell", [("vit-l16", "serve_b1"),
                                  ("dit-s2", "gen_fast"),
                                  ("qwen2-moe-a2.7b", "decode_32k")])
def test_dryrun_tiny_mesh_subprocess(cell):
    """Full lower+compile of representative cells on an 8-device tiny
    mesh (subprocess so the device-count env doesn't leak)."""
    arch, shape = cell
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "tinymulti"],
        capture_output=True, text=True, env=env, timeout=1200)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "[ok]" in r.stdout


def test_collective_parser():
    from repro.launch.roofline import parse_collectives
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[2,1024]{1,0} %x), dims={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%sum
  %cp = f32[8,8]{1,0} collective-permute(f32[8,8]{1,0} %z)
  %not_a_coll = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
"""
    c = parse_collectives(hlo)
    assert c["all-gather"] == 16 * 1024 * 2
    assert c["all-reduce"] == 256 * 4 * 2  # 2x ring factor
    assert c["collective-permute"] == 64 * 4
    assert c["counts"]["all-gather"] == 1
    assert c["total"] == c["all-gather"] + c["all-reduce"] + c["collective-permute"]


def test_elastic_reshard_roundtrip():
    from repro.runtime.supervisor import reshard_state
    mesh1 = jax.make_mesh((1,), ("data",))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    out = reshard_state(state, mesh1, lambda s: {"w": P(None, None)})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["w"]))


def test_lm_train_driver_runs_and_resumes(tmp_path):
    """launch.train end-to-end on CPU incl. checkpoint-resume."""
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-8b",
           "--steps", "6", "--batch", "2", "--seq", "32",
           "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"]
    r1 = subprocess.run(cmd, capture_output=True, text=True, env=env,
                        timeout=600)
    assert r1.returncode == 0, r1.stdout[-2000:] + r1.stderr[-2000:]
    assert "resumed_from=None" in r1.stdout
    # second run resumes from the final checkpoint (no steps left to run
    # -> resumed_from=6 and immediately done) — extend max steps instead
    cmd2 = cmd[:6] + ["12"] + cmd[7:]
    r2 = subprocess.run(cmd2, capture_output=True, text=True, env=env,
                        timeout=600)
    assert r2.returncode == 0, r2.stdout[-2000:] + r2.stderr[-2000:]
    assert "resumed_from=6" in r2.stdout
