"""Parity + equivalence tests for the device-resident pipeline engine.

The engine path (fused frame program, moments reuse, incremental
k-means++ init, fixed-shape counting batches) must reproduce the seed
host-orchestrated path prediction-for-prediction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import dedup as dd
from repro.core import engine, tiling
from repro.core.cascade import (build_target_pool, count_tiles_batched,
                                count_tiles_batched_ref)
from repro.core.pipeline import PipelineConfig, run_pipeline
from repro.data.synthetic import (SceneSpec, boxes_to_targets,
                                  clip_boxes_to_tile, make_scene,
                                  revisit_frames)

SPEC = SceneSpec("mini", 384, (12, 18), (10, 24), cloud_fraction=0.2)
METHODS = ("space_only", "ground_only", "tiansuan", "kodan", "targetfuse")


# `counters` comes from tests/conftest.py (session-scoped, identical
# recipe — one training serves the engine/mission/fleet/golden suites)


@pytest.fixture(scope="module")
def frames():
    rng = np.random.default_rng(7)
    img, b, c = make_scene(rng, SPEC)
    return revisit_frames(rng, img, b, c, 3)


# ---------------------------------------------------------------------------
# end-to-end parity: engine vs pre-refactor reference path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_engine_matches_reference_path(method, frames, counters):
    space, ground = counters
    res = {}
    for use_engine in (False, True):
        pcfg = PipelineConfig(method=method, score_thresh=0.25,
                              use_engine=use_engine)
        res[use_engine] = run_pipeline(frames, space, ground, pcfg)
    np.testing.assert_allclose(res[True].per_tile_pred,
                               res[False].per_tile_pred, atol=1e-5)
    assert abs(res[True].cmae - res[False].cmae) < 1e-5
    assert res[True].tiles_total == res[False].tiles_total
    assert res[True].tiles_processed_space == res[False].tiles_processed_space
    assert res[True].tiles_downlinked == res[False].tiles_downlinked


def test_prepared_frames_match_per_frame_tiling(frames):
    """Fused tile+resize+moments program == the seed per-frame host loop."""
    prep = engine.prepare_frames(frames, 128, 64, 48)
    sp, gd = [], []
    for img, _, _ in frames:
        t = tiling.tile_image(jnp.asarray(img), 128)
        sp.append(np.asarray(tiling.resize_tiles(t, 64)))
        gd.append(np.asarray(tiling.resize_tiles(t, 48)))
    sp, gd = np.concatenate(sp), np.concatenate(gd)
    assert prep.n == sp.shape[0]
    np.testing.assert_allclose(np.asarray(prep.tiles_sp)[:prep.n], sp,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(prep.tiles_gd)[:prep.n], gd,
                               atol=1e-6)
    # device arrays are padded to a power-of-two bucket with zero tiles
    assert prep.tiles_sp.shape[0] == dd.bucket_size(prep.n)
    assert float(jnp.abs(prep.tiles_sp[prep.n:]).sum()) == 0.0
    # ROI statistic from the moments == the seed's ad-hoc jnp.std pass
    raw_sd = np.asarray(jnp.mean(jnp.std(jnp.asarray(sp), axis=(1, 2)),
                                 axis=-1))
    np.testing.assert_allclose(prep.roi_std, raw_sd, atol=1e-5)


def test_prepared_frames_groups_mixed_resolutions():
    """Frames of different sizes are bucketed per shape, order preserved."""
    rng = np.random.default_rng(3)
    small = SceneSpec("s", 256, (4, 8), (10, 24), cloud_fraction=0.0)
    frames = []
    for spec in (SPEC, small, SPEC):
        img, b, c = make_scene(rng, spec)
        frames += revisit_frames(rng, img, b, c, 1)
    prep = engine.prepare_frames(frames, 128, 64, 48)
    expect, true = [], []
    from repro.data.synthetic import tile_counts
    for img, b, _ in frames:
        t = tiling.tile_image(jnp.asarray(img), 128)
        expect.append(np.asarray(tiling.resize_tiles(t, 64)))
        true.append(tile_counts(b, img.shape[0], 128))
    np.testing.assert_allclose(np.asarray(prep.tiles_sp)[:prep.n],
                               np.concatenate(expect), atol=1e-6)
    np.testing.assert_array_equal(prep.true, np.concatenate(true))


# ---------------------------------------------------------------------------
# component equivalence
# ---------------------------------------------------------------------------

def test_incremental_kmeanspp_matches_scan_init():
    """O(N·D)-per-pick init picks the same centroids as the seed's
    O(N·K·D) full-rescore scan."""
    x = jax.random.normal(jax.random.PRNGKey(3), (200, 9))
    for k in (2, 5, 16, 40):
        a = np.asarray(dd._kmeanspp_init(x, k, jax.random.PRNGKey(1)))
        b = np.asarray(dd._kmeanspp_init_scan(x, k, jax.random.PRNGKey(1)))
        np.testing.assert_allclose(a, b, atol=1e-6)


def test_dedup_from_moments_matches_dedup(frames):
    from repro.kernels import ops as kops
    tiles = jnp.concatenate([tiling.resize_tiles(
        tiling.tile_image(jnp.asarray(f[0]), 128), 64) for f in frames])
    key = jax.random.PRNGKey(0)
    a = dd.dedup(tiles, 5, key)
    b = dd.dedup_from_moments(kops.tile_moments(tiles), 5, key)
    np.testing.assert_array_equal(np.asarray(a.assign), np.asarray(b.assign))
    np.testing.assert_array_equal(np.asarray(a.rep_idx), np.asarray(b.rep_idx))


def test_fixed_shape_count_batching_matches_reference(counters):
    (sp, sp_cfg), _ = counters
    rng = np.random.default_rng(2)
    for n in (1, 5, 70):
        tiles = rng.random((n, sp_cfg.input_size, sp_cfg.input_size, 3)
                           ).astype(np.float32)
        c0, f0 = count_tiles_batched_ref(sp, sp_cfg, tiles, score_thresh=0.25)
        c1, f1 = count_tiles_batched(sp, sp_cfg, tiles, score_thresh=0.25)
        np.testing.assert_allclose(c1, c0, atol=1e-5)
        np.testing.assert_allclose(f1, f0, atol=1e-5)


def test_count_batching_empty_input(counters):
    (sp, sp_cfg), _ = counters
    tiles = np.zeros((0, sp_cfg.input_size, sp_cfg.input_size, 3), np.float32)
    c, f = count_tiles_batched(sp, sp_cfg, tiles)
    assert c.shape == (0,) and f.shape == (0,)


def test_vectorized_target_pool_matches_loop():
    """build_target_pool == the seed's nested (ty, tx) Python loops."""
    from repro.models import detector
    cfg = reduced(get_config("targetfuse-space"))
    rng = np.random.default_rng(5)
    scenes = [make_scene(rng, SPEC) for _ in range(2)]
    xs, ys = build_target_pool(cfg, scenes, 128)
    grid = detector.grid_size(cfg)
    scale = cfg.input_size / 128
    ex, ey = [], []
    for img, boxes, classes in scenes:
        g = img.shape[0] // 128
        t = np.asarray(tiling.resize_tiles(
            tiling.tile_image(jnp.asarray(img), 128), cfg.input_size))
        for ty in range(g):
            for tx in range(g):
                b, c = clip_boxes_to_tile(boxes, classes, tx, ty, 128)
                ex.append(t[ty * g + tx])
                ey.append(boxes_to_targets(b, c, grid, cfg.n_anchors,
                                           cfg.n_classes, cfg.input_size,
                                           scale))
    np.testing.assert_array_equal(xs, np.stack(ex))
    np.testing.assert_allclose(ys, np.stack(ey), atol=1e-6)
