"""Per-kernel Pallas (interpret=True) vs pure-jnp oracle, swept over
shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.iou import iou_matrix
from repro.kernels.kmeans_assign import kmeans_assign
from repro.kernels.tile_moments import tile_moments


def _key(i=0):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (1, 128, 1, 1, 128),
    (2, 256, 4, 2, 128),
    (1, 384, 8, 8, 128),
    (2, 128, 6, 2, 256),
])
@pytest.mark.parametrize("causal", [False, True])
def test_flash_attention_matches_ref(b, s, hq, hkv, d, causal):
    q = jax.random.normal(_key(0), (b, s, hq, d), jnp.float32)
    k = jax.random.normal(_key(1), (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(_key(2), (b, s, hkv, d), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    exp = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    q = jax.random.normal(_key(0), (1, 128, 2, 128), jnp.bfloat16)
    k = jax.random.normal(_key(1), (1, 128, 2, 128), jnp.bfloat16)
    v = jax.random.normal(_key(2), (1, 128, 2, 128), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    exp = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(out.astype(np.float32), exp.astype(np.float32),
                               atol=3e-2, rtol=3e-2)


def test_flash_attention_block_shapes():
    """Different BlockSpec tilings must agree."""
    q = jax.random.normal(_key(0), (1, 512, 2, 128), jnp.float32)
    k = jax.random.normal(_key(1), (1, 512, 1, 128), jnp.float32)
    v = jax.random.normal(_key(2), (1, 512, 1, 128), jnp.float32)
    a = flash_attention(q, k, v, causal=True, bq=128, bk=128, interpret=True)
    b2 = flash_attention(q, k, v, causal=True, bq=256, bk=128, interpret=True)
    c = flash_attention(q, k, v, causal=True, bq=128, bk=256, interpret=True)
    np.testing.assert_allclose(a, b2, atol=1e-5)
    np.testing.assert_allclose(a, c, atol=1e-5)


# ---------------------------------------------------------------------------
# kmeans assignment
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,k", [(64, 9, 4), (1000, 9, 16), (513, 32, 7),
                                   (256, 128, 64)])
def test_kmeans_assign(n, d, k):
    x = jax.random.normal(_key(0), (n, d), jnp.float32)
    c = jax.random.normal(_key(1), (k, d), jnp.float32)
    a1, d1 = kmeans_assign(x, c, interpret=True)
    a2, d2 = ref.kmeans_assign(x, c)
    assert bool(jnp.all(a1 == a2))
    np.testing.assert_allclose(d1, d2, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# tile moments
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,h,w,c", [(16, 32, 32, 3), (100, 16, 16, 3),
                                     (7, 64, 64, 1), (130, 8, 8, 4)])
def test_tile_moments(n, h, w, c):
    t = jax.random.uniform(_key(0), (n, h, w, c), jnp.float32)
    m1 = tile_moments(t, interpret=True)
    m2 = ref.tile_moments(t)
    np.testing.assert_allclose(m1, m2, atol=1e-4, rtol=1e-4)


def test_tile_moments_invariance():
    """Color moments are invariant to rotation/flip (the dedup feature
    contract from paper §III-C)."""
    t = jax.random.uniform(_key(0), (4, 32, 32, 3), jnp.float32)
    m = ref.tile_moments(t)
    m_rot = ref.tile_moments(jnp.rot90(t, axes=(1, 2)))
    m_flip = ref.tile_moments(t[:, ::-1])
    np.testing.assert_allclose(m, m_rot, atol=1e-5)
    np.testing.assert_allclose(m, m_flip, atol=1e-5)


# ---------------------------------------------------------------------------
# IoU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", [(10, 10), (128, 64), (200, 300), (1, 5)])
def test_iou_matrix(n, m, rng):
    def boxes(k, seed):
        b = jax.random.uniform(_key(seed), (k, 4), jnp.float32)
        return b.at[:, 2:].set(b[:, :2] + jnp.abs(b[:, 2:]) + 0.01)
    a = boxes(n, 0)
    b = boxes(m, 1)
    i1 = iou_matrix(a, b, interpret=True)
    i2 = ref.iou_matrix(a, b)
    np.testing.assert_allclose(i1, i2, atol=1e-5)
    assert float(jnp.max(i1)) <= 1.0 + 1e-6
    assert float(jnp.min(i1)) >= 0.0


def test_iou_self_diagonal():
    b = jnp.array([[0., 0., 2., 2.], [1., 1., 4., 5.]])
    i = iou_matrix(b, b, interpret=True)
    np.testing.assert_allclose(jnp.diag(i), jnp.ones(2), atol=1e-6)


# ---------------------------------------------------------------------------
# int8 matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (100, 200, 150),
                                   (256, 512, 384), (1, 64, 1)])
def test_int8_matmul(m, k, n):
    xq = jax.random.randint(_key(0), (m, k), -127, 128, jnp.int8)
    wq = jax.random.randint(_key(1), (k, n), -127, 128, jnp.int8)
    xs = jax.random.uniform(_key(2), (m,)) + 0.1
    ws = jax.random.uniform(_key(3), (n,)) + 0.1
    r1 = int8_matmul(xq, wq, xs, ws, interpret=True)
    r2 = ref.int8_matmul(xq, wq, xs, ws)
    np.testing.assert_allclose(r1, r2, rtol=1e-6)


def test_quantize_roundtrip_accuracy():
    from repro.kernels.ops import quantize_int8
    x = jax.random.normal(_key(0), (64, 256), jnp.float32)
    w = jax.random.normal(_key(1), (256, 128), jnp.float32)
    xq, xs = quantize_int8(x, axis=1)
    wq, ws = quantize_int8(w, axis=0)
    approx = ref.int8_matmul(xq, wq, xs, ws)
    exact = x @ w
    rel = float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact))
    assert rel < 0.02, rel
