"""Tests for repro.core.xfer — the content-keyed host->device transfer
cache: counter correctness, the clear-at-capacity overflow policy, the
large-array bypass, and thread safety under the GroundSegment
worker-vs-foreground pattern."""
import threading

import numpy as np
import pytest

import repro.core.xfer as xfer


@pytest.fixture(autouse=True)
def _isolated_cache():
    """Each test starts from an empty cache and zeroed counters, and
    leaves the module clean for the fleet tests that gate on them."""
    xfer.clear_cache()
    xfer.reset_transfer_stats()
    yield
    xfer.clear_cache()
    xfer.reset_transfer_stats()


def test_counters_track_puts_and_reuses():
    a = np.arange(8, dtype=np.int32)
    b = np.arange(8, dtype=np.float32)  # same shape, different dtype
    da = xfer.device_constant(a)
    db = xfer.device_constant(b)
    assert xfer.transfer_stats() == {"device_puts": 2, "cache_reuses": 0}
    # content-identical requests reuse the resident (fresh host buffer
    # included — the key is by value, not identity)
    assert xfer.device_constant(a) is da
    assert xfer.device_constant(np.arange(8, dtype=np.int32)) is da
    assert xfer.device_constant(b) is db
    assert xfer.transfer_stats() == {"device_puts": 2, "cache_reuses": 3}
    assert xfer.cache_size() == 2
    np.testing.assert_array_equal(np.asarray(da), a)


def test_overflow_clears_at_capacity(monkeypatch):
    assert xfer._MAX_ENTRIES == 4096  # the documented production cap
    monkeypatch.setattr(xfer, "_MAX_ENTRIES", 8)
    for i in range(8):
        xfer.device_constant(np.full(4, i, dtype=np.int64))
    assert xfer.cache_size() == 8
    # the 9th distinct value clears the full cache, then inserts itself
    d = xfer.device_constant(np.full(4, 99, dtype=np.int64))
    assert xfer.cache_size() == 1
    assert xfer.transfer_stats()["device_puts"] == 9
    # the survivor is the newcomer; evicted values re-upload
    assert xfer.device_constant(np.full(4, 99, dtype=np.int64)) is d
    xfer.device_constant(np.full(4, 0, dtype=np.int64))
    assert xfer.transfer_stats() == {"device_puts": 10, "cache_reuses": 1}


def test_large_arrays_bypass_cache_but_count():
    big = np.zeros((xfer._MAX_ITEM_BYTES // 8) + 1, dtype=np.float64)
    d1 = xfer.device_constant(big)
    d2 = xfer.device_constant(big)
    assert d1 is not d2
    assert xfer.cache_size() == 0
    assert xfer.transfer_stats() == {"device_puts": 2, "cache_reuses": 0}


def test_thread_safety_under_worker_contention():
    """Two threads hammer device_constant the way a recount worker and
    the foreground round do: a shared pool of repeating control-plane
    values plus per-thread unique ones. Every call must be accounted as
    exactly one put or one reuse, with no exceptions and correct
    values."""
    shared = [np.arange(16, dtype=np.int32) + k for k in range(4)]
    n_iters, errs = 200, []

    def worker(tid):
        try:
            for i in range(n_iters):
                arr = shared[i % len(shared)]
                got = xfer.device_constant(arr)
                np.testing.assert_array_equal(np.asarray(got), arr)
                uniq = np.array([tid, i], dtype=np.int64)
                xfer.device_constant(uniq)
        except BaseException as e:  # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errs == []
    stats = xfer.transfer_stats()
    total_calls = 2 * n_iters * 2
    assert stats["device_puts"] + stats["cache_reuses"] == total_calls
    # the 4 shared values and the 400 unique ones were each put at least
    # once; a racy double-put of a shared value is tolerated (both
    # threads miss before either inserts) but reuses must dominate
    assert stats["device_puts"] >= 404
    assert stats["cache_reuses"] >= 300
    assert xfer.cache_size() >= 404


def test_record_transfer_counts_external_puts():
    xfer.record_transfer()
    xfer.record_transfer(3)
    assert xfer.transfer_stats() == {"device_puts": 4, "cache_reuses": 0}
