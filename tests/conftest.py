import os
import sys

# tests see the single real CPU device (the dry-run subprocesses set
# their own XLA_FLAGS); keep determinism + quiet logs
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # _hypothesis_fallback

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
