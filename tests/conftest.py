import os
import sys

# tests see the single real CPU device (the dry-run subprocesses set
# their own XLA_FLAGS); keep determinism + quiet logs
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # _hypothesis_fallback

import numpy as np
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="rewrite tests/golden/*.json snapshots from the current "
             "pipeline instead of comparing against them")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: hypothesis-heavy property tests (deselect with -m 'not slow')")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def counters():
    """Train-once reduced counter pair shared by the Mission/fleet/golden
    suites (fixed seeds: every test sees identical parameters)."""
    import jax

    from repro.configs import get_config, reduced
    from repro.core.cascade import fit_counter
    from repro.data.synthetic import SceneSpec, make_scene

    spec = SceneSpec("mini", 384, (12, 18), (10, 24), cloud_fraction=0.2)
    gen = np.random.default_rng(0)
    scenes = [make_scene(gen, spec) for _ in range(4)]
    sp_cfg = reduced(get_config("targetfuse-space"))
    gd_cfg = reduced(get_config("targetfuse-ground"))
    sp, _ = fit_counter(sp_cfg, scenes, 128, 150, jax.random.PRNGKey(0))
    gd, _ = fit_counter(gd_cfg, scenes, 128, 300, jax.random.PRNGKey(1))
    return (sp, sp_cfg), (gd, gd_cfg)
