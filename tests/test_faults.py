"""Fault-injection / degraded-mode tests (repro.core.faults).

The acceptance gates of the robustness PR:

* ``FaultPlan.none()`` (and ``faults=None``) is bit-equal — per-tile
  predictions, summaries, and every ledger lane — to the fault-free
  runtime for all five policies on both the engine and reference
  execution paths and both the batched and FIFO-reference contact paths.
* Every fault class degrades *deterministically*: a faulty run through
  the batched ContactPlan executor equals the same faulty run through
  the scalar FIFO reference, including the fault counters.
* Degradation semantics: dead-window budgets fold forward, corrupted
  segments refund (or stay charged, per policy) and retry within the
  bound, ledgers never go negative and never double-credit, the async
  watchdog arm recovers injected worker crashes/stalls bit-equal to the
  synchronous arm, and ``finalize()`` stays safe after mid-round
  exceptions.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.contact import GroundSegment
from repro.core.faults import FaultPlan, scenario_faults
from repro.core.fleet import Fleet, run_scenario
from repro.core.pipeline import PipelineConfig
from repro.core.throttle import clamp_budget_bytes
from repro.data.scenarios import (FleetScenarioSpec, GroundStation,
                                  generate_scenario)
from repro.data.synthetic import SceneSpec, make_scene, revisit_frames

METHODS = ("space_only", "ground_only", "tiansuan", "kodan", "targetfuse")
SCENE = SceneSpec("faults", 384, (10, 18), (10, 24), cloud_fraction=0.25)
# wall-clock/throughput summary keys that legitimately differ run-to-run
TIMING_KEYS = ("ingest_s", "tiles_per_s", "tiles_per_s_per_sat", "contact_s",
               "windows_per_s", "bytes_downlinked_per_s", "recount_s",
               "recount_wait_s", "recount_hidden_frac",
               "ingest_dispatch_s", "device_compute_s", "host_fetch_s",
               "ingest_hidden_frac")


@pytest.fixture(scope="module")
def scenario():
    """2 satellites x 3 rounds, two stations per round — every round has
    multiple windows so drops/truncations/corruptions have structure to
    act on without blowing up the suite's runtime."""
    return generate_scenario(FleetScenarioSpec(
        n_sats=2, n_rounds=3, frames_per_pass=1,
        stations=(GroundStation("gs0"),
                  GroundStation("gs1", bandwidth_mbps=30.0, contact_s=240.0)),
        scene_mix=(SCENE,), seed=3))


def _frames(seed: int, n_frames: int = 1):
    rng = np.random.default_rng(seed)
    img, b, c = make_scene(rng, SCENE)
    return revisit_frames(rng, img, b, c, n_frames)


def _assert_same(a, b, ctx=""):
    np.testing.assert_array_equal(a.per_tile_pred, b.per_tile_pred,
                                  err_msg=f"{ctx}: per-tile preds differ")
    assert a.summary() == b.summary(), (
        f"{ctx}: summaries differ:\n{a.summary()}\n{b.summary()}")


def _assert_ledgers_equal(fa: Fleet, fb: Fleet, ctx=""):
    for f in ("budget_j", "e_cap", "e_com", "e_agg", "e_down",
              "bytes_budget", "bytes_requested", "bytes_spent"):
        np.testing.assert_array_equal(
            getattr(fa.ledger, f)[:fa.n_sats],
            getattr(fb.ledger, f)[:fb.n_sats],
            err_msg=f"{ctx}: ledger lane {f} differs")


def _summary_sans_timing(fleet: Fleet) -> dict:
    s = fleet.summary()
    for k in TIMING_KEYS:
        s.pop(k, None)
    return s


# ---------------------------------------------------------------------------
# the parity gate: FaultPlan.none() is bit-equal to the fault-free runtime
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_engine", (True, False),
                         ids=("engine", "reference"))
@pytest.mark.parametrize("contact_reference", (False, True),
                         ids=("batched", "fifo"))
@pytest.mark.parametrize("method", METHODS)
def test_none_plan_is_bit_exact(method, contact_reference, use_engine,
                                scenario, counters):
    """faults=None vs FaultPlan.none(): identical predictions, summaries,
    and ledger lanes on every policy x execution path x contact path."""
    space, ground = counters
    pcfg = PipelineConfig(method=method, score_thresh=0.25,
                          use_engine=use_engine)
    got, fn = run_scenario(space, ground, pcfg, scenario,
                           contact_reference=contact_reference)
    want, fz = run_scenario(space, ground, pcfg, scenario,
                            contact_reference=contact_reference,
                            faults=FaultPlan.none())
    ctx = f"{method}/{'fifo' if contact_reference else 'batched'}"
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"none-plan {ctx} sat{i}")
    _assert_ledgers_equal(fn, fz, f"none-plan {ctx}")
    assert _summary_sans_timing(fn) == _summary_sans_timing(fz)
    assert fz.summary()["faults_active"] is False
    assert all(v == 0 for v in vars(fz.fault_stats).values())


# ---------------------------------------------------------------------------
# faulty batched == faulty FIFO reference (the differential gate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ("targetfuse", "kodan"))
def test_faulty_batched_matches_reference(method, scenario, counters):
    space, ground = counters
    pcfg = PipelineConfig(method=method, score_thresh=0.25)
    fp = FaultPlan(seed=11, drop_rate=0.2, truncate_rate=0.3,
                   corrupt_rate=0.4, blackout_rate=0.2)
    got, fb = run_scenario(space, ground, pcfg, scenario, faults=fp)
    want, fr = run_scenario(space, ground, pcfg, scenario, faults=fp,
                            contact_reference=True)
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"faulty {method} sat{i}")
    _assert_ledgers_equal(fb, fr, f"faulty {method}")
    assert _summary_sans_timing(fb) == _summary_sans_timing(fr)
    # the schedule actually fired (otherwise this test gates nothing)
    s = fb.summary()
    assert s["fault_segments_corrupted"] > 0
    assert s["fault_blackout_passes"] > 0


def test_faulty_run_is_replayable(scenario, counters):
    """Same seed, same scenario -> byte-identical faulty run (the draws
    are pure functions of (seed, kind, key); no RNG state)."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    fp = FaultPlan(seed=7, drop_rate=0.3, corrupt_rate=0.3)
    a, fa = run_scenario(space, ground, pcfg, scenario, faults=fp)
    b, fb = run_scenario(space, ground, pcfg, scenario, faults=fp)
    for x, y in zip(a, b):
        _assert_same(x, y, "replay")
    assert _summary_sans_timing(fa) == _summary_sans_timing(fb)


# ---------------------------------------------------------------------------
# window drop + plan repair (budget folds forward)
# ---------------------------------------------------------------------------

def test_explicit_drop_folds_budget_into_next_window(counters):
    """Dropping a window re-lands its explicit budget on the same sat's
    next surviving window: bit-equal to offering one merged window."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    b1, b2 = 4e5, 6e5

    faulty = Fleet(space, ground, pcfg, n_sats=1,
                   faults=FaultPlan(window_drops={(0, 0)}))
    faulty.ingest([_frames(31, 2)])
    reps = faulty.contact_round(windows=[(0, b1), (0, b2)])
    assert len(reps) == 1  # the dropped window never executes

    clean = Fleet(space, ground, pcfg, n_sats=1)
    clean.ingest([_frames(31, 2)])
    clean.contact_round(windows=[(0, b1 + b2)])

    for a, b in zip(faulty.finalize(), clean.finalize()):
        _assert_same(a, b, "drop-fold")
    _assert_ledgers_equal(faulty, clean, "drop-fold")
    assert faulty.fault_stats.windows_dropped == 1
    assert faulty.fault_stats.budget_folded == b1
    assert faulty.fault_stats.budget_lost == 0.0


def test_drop_with_no_heir_loses_budget(counters):
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    fleet = Fleet(space, ground, pcfg, n_sats=2,
                  faults=FaultPlan(window_drops={(0, 1)}))
    fleet.ingest([_frames(32), _frames(33)])
    reps = fleet.contact_round(windows=[(0, 2e5), (1, 3e5)])
    assert [s for s, _ in reps] == [0]
    assert fleet.fault_stats.windows_dropped == 1
    assert fleet.fault_stats.budget_lost == 3e5
    assert float(fleet.ledger.bytes_budget[1]) == 0.0
    fleet.finalize()


def test_station_outage_drops_all_its_windows(scenario, counters):
    """A station outage span kills every window that station offers in
    those rounds — and the run stays batched-vs-reference exact."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    fp = FaultPlan(station_outages=(("gs0", 0, 1),))
    got, fb = run_scenario(space, ground, pcfg, scenario, faults=fp)
    want, fr = run_scenario(space, ground, pcfg, scenario, faults=fp,
                            contact_reference=True)
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"outage sat{i}")
    _assert_ledgers_equal(fb, fr, "outage")
    # gs0 serves one window per round; rounds 0 and 1 are in the span
    assert fb.summary()["fault_windows_dropped"] == 2
    assert fp.station_out("gs0", 1) and not fp.station_out("gs0", 2)
    assert not fp.station_out("gs1", 0)


# ---------------------------------------------------------------------------
# mid-window truncation
# ---------------------------------------------------------------------------

def test_explicit_truncation_cuts_budget_at_segment(counters):
    """Truncation at pending position t: segments before t drain
    normally (bit-equal to the clean run), later ones see a dead link."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)

    faulty = Fleet(space, ground, pcfg, n_sats=1,
                   faults=FaultPlan(window_truncations={(0, 0): 1}))
    clean = Fleet(space, ground, pcfg, n_sats=1)
    for fl in (faulty, clean):
        for k in range(3):  # three pending segments FIFO in one window
            fl.ingest([_frames(41 + k)])
        fl.contact_round(windows=[(0, 1e9)])

    fs, cs = faulty.missions[0]._segments, clean.missions[0]._segments
    assert faulty.fault_stats.windows_truncated == 1
    assert fs[0].bytes_spent == cs[0].bytes_spent  # before the cut
    assert all(s.bytes_spent == 0.0 for s in fs[1:])  # after the cut
    assert float(faulty.ledger.bytes_spent[0]) == fs[0].bytes_spent
    faulty.finalize(), clean.finalize()


# ---------------------------------------------------------------------------
# corrupted segments: refund policies, bounded retry, permanent loss
# ---------------------------------------------------------------------------

def test_corruption_refund_policy_reconciles_ledger(counters):
    """"refund": the wasted transmission's bytes AND radio energy are
    refunded with the exact inverse charge; "charge": they stay spent.
    Either way the ground never credits the corrupted bytes."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)

    def run(policy):
        fl = Fleet(space, ground, pcfg, n_sats=1,
                   faults=FaultPlan(segment_corruptions={(0, 0, 0)},
                                    max_retries=0, refund_policy=policy))
        fl.ingest([_frames(51)])
        fl.contact_round(windows=[(0, 1e9)])
        fl.finalize()
        return fl

    clean = Fleet(space, ground, pcfg, n_sats=1)
    clean.ingest([_frames(51)])
    clean.contact_round(windows=[(0, 1e9)])
    clean.finalize()
    spent = float(clean.ledger.bytes_spent[0])
    assert spent > 0.0

    refunded = run("refund")
    assert refunded.fault_stats.segments_lost == 1
    assert refunded.fault_stats.bytes_wasted == spent
    assert refunded.fault_stats.bytes_refunded == spent
    assert float(refunded.ledger.bytes_spent[0]) == 0.0
    assert float(refunded.ledger.e_down[0]) == 0.0

    charged = run("charge")
    assert charged.fault_stats.bytes_refunded == 0.0
    assert charged.fault_stats.bytes_wasted == spent
    assert float(charged.ledger.bytes_spent[0]) == spent
    np.testing.assert_array_equal(charged.ledger.e_down[:1],
                                  clean.ledger.e_down[:1])

    # lost downlink-side: the ground credits nothing for those tiles
    for fl in (refunded, charged):
        seg = fl.missions[0]._segments[0]
        down = seg.selection.downlink
        assert len(down) and (seg.counts_gd == 0.0).all()


def test_retry_recovers_within_bound(counters):
    """A twice-corrupted segment retries with linear backoff and, on the
    third transmission, delivers — final predictions equal the clean
    run's, and retries never exceed max_retries."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    # corrupt the segment's first two transmissions: round 0, and its
    # backoff-delayed retry in round 1; round 3 (backoff 2) delivers
    fp = FaultPlan(segment_corruptions={(0, 0, 0), (1, 0, 0)},
                   max_retries=2)
    faulty = Fleet(space, ground, pcfg, n_sats=1, faults=fp)
    clean = Fleet(space, ground, pcfg, n_sats=1)
    for fl in (faulty, clean):
        fl.ingest([_frames(61)])
        for _ in range(4):
            fl.contact_round(windows=[(0, 1e9)])
    seg = faulty.missions[0]._segments[0]
    assert seg.retries == 2 <= fp.max_retries
    assert faulty.fault_stats.segments_requeued == 2
    assert faulty.fault_stats.segments_lost == 0
    [fa], [ca] = faulty.finalize(), clean.finalize()
    np.testing.assert_array_equal(fa.per_tile_pred, ca.per_tile_pred)
    fs, cs = fa.summary(), ca.summary()
    # the recovered run re-transmitted the corrupted segment twice: its
    # downlink traffic exceeds the clean run's by exactly the waste
    assert fs.pop("bytes_downlinked") == (cs.pop("bytes_downlinked")
                                          + faulty.fault_stats.bytes_wasted)
    assert fs == cs

    # the identical schedule with retries disabled loses the segment
    lost = Fleet(space, ground, pcfg, n_sats=1, faults=fp.with_retries(0))
    lost.ingest([_frames(61)])
    for _ in range(4):
        lost.contact_round(windows=[(0, 1e9)])
    assert lost.fault_stats.segments_lost == 1
    assert lost.fault_stats.segments_requeued == 0
    assert (lost.fault_stats.bytes_delivered
            < faulty.fault_stats.bytes_delivered)
    lost.finalize()


def test_finalize_drains_backoff_held_segments(counters):
    """A re-queued segment still waiting out its backoff when the
    scenario ends drains through the (never-faulted) finalize flush —
    nothing pends afterwards, and its onboard results still land."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    fl = Fleet(space, ground, pcfg, n_sats=1,
               faults=FaultPlan(segment_corruptions={(0, 0, 0)},
                                max_retries=3))
    fl.ingest([_frames(71)])
    fl.contact_round(windows=[(0, 1e9)])  # corrupts; backoff holds it
    assert fl.pending_segments == [1]
    res = fl.finalize()
    assert fl.pending_segments == [0]
    assert len(res[0].per_tile_pred) == fl.missions[0]._segments[0].n


# ---------------------------------------------------------------------------
# blackouts
# ---------------------------------------------------------------------------

def test_blackout_skips_pass_and_matches_oracle(scenario, counters):
    """Blacked-out passes ingest nothing and charge nothing; the fleet
    path equals the looped-Mission oracle under the same draws."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    fp = FaultPlan(seed=5, drop_rate=0.3, blackout_rate=0.3)
    got, fb = run_scenario(space, ground, pcfg, scenario, faults=fp)
    want, _ = run_scenario(space, ground, pcfg, scenario, faults=fp,
                           fleet=False)
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"blackout sat{i}")
    assert fb.summary()["fault_blackout_passes"] > 0


def test_oracle_rejects_segment_granular_faults(scenario, counters):
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    with pytest.raises(ValueError, match="oracle"):
        run_scenario(space, ground, pcfg, scenario, fleet=False,
                     faults=FaultPlan(corrupt_rate=0.5))


# ---------------------------------------------------------------------------
# async ground worker: crash / stall + watchdog recovery
# ---------------------------------------------------------------------------

def test_watchdog_recovers_injected_crash_bit_exact(scenario, counters):
    """An injected worker crash is absorbed by the watchdog (synchronous
    recount retry) — the async arm stays bit-equal to the synchronous
    arm, fault counters aside."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    fp = FaultPlan(worker_faults={0: "crash"})
    got, fa = run_scenario(space, ground, pcfg, scenario, faults=fp,
                           async_ground=True, watchdog_s=5.0)
    want, fs = run_scenario(space, ground, pcfg, scenario, faults=fp)
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"crash-recovery sat{i}")
    _assert_ledgers_equal(fa, fs, "crash-recovery")
    assert fa.summary()["fault_worker_crashes"] == 1
    assert fa.summary()["fault_watchdog_recoveries"] == 1
    # worker faults target the async worker; the sync arm has none
    assert fs.summary()["fault_worker_crashes"] == 0


def test_watchdog_recovers_stalled_worker(scenario, counters):
    """A stalled worker blows the watchdog timeout: it is cancelled and
    the recount re-runs synchronously, bit-equal to the sync arm."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    fp = FaultPlan(worker_faults={0: "stall"}, stall_s=5.0)
    got, fa = run_scenario(space, ground, pcfg, scenario, faults=fp,
                           async_ground=True, watchdog_s=0.05)
    want, _ = run_scenario(space, ground, pcfg, scenario, faults=fp)
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"stall-recovery sat{i}")
    assert fa.summary()["fault_worker_stalls"] == 1
    assert fa.summary()["fault_watchdog_recoveries"] == 1


def test_watchdog_recovery_at_depth2(scenario, counters):
    """Crash and stall on two different queued rounds of a depth-2
    pipeline: each recovers independently at its own retirement, and
    the run stays bit-equal to the synchronous arm."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    fp = FaultPlan(worker_faults={0: "crash", 1: "stall"}, stall_s=5.0)
    got, fa = run_scenario(space, ground, pcfg, scenario, faults=fp,
                           async_depth=2, watchdog_s=0.05)
    want, fs = run_scenario(space, ground, pcfg, scenario, faults=fp)
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"depth2-recovery sat{i}")
    _assert_ledgers_equal(fa, fs, "depth2-recovery")
    s = fa.summary()
    assert s["fault_worker_crashes"] == 1
    assert s["fault_worker_stalls"] == 1
    assert s["fault_watchdog_recoveries"] >= 2
    assert s["recount_max_in_flight"] == 2


def test_watchdog_abandoned_worker_writes_nothing(counters, monkeypatch):
    """Regression (watchdog race): a GENUINELY slow worker — not an
    injected stall — that the watchdog abandons mid-recount must write
    nothing when it finally limps home. Pre-fix, the cancel event was
    only checked on the injected-stall path, so the abandoned worker's
    late (here: garbage) counts landed on top of the recovery's."""
    import repro.core.contact as contact_mod
    space, ground = counters
    pcfg = PipelineConfig(method="ground_only", score_thresh=0.25)
    slow = Fleet(space, ground, pcfg, n_sats=1, async_ground=True,
                 watchdog_s=0.05)
    sync = Fleet(space, ground, pcfg, n_sats=1)
    real = contact_mod.count_tiles_multi

    def slow_garbage_off_main(params, cfg, parts, **kw):
        res = real(params, cfg, parts, **kw)
        if threading.current_thread() is not threading.main_thread():
            time.sleep(0.4)  # blow the watchdog while "counting"...
            return [(c + 100.0, aux) for c, aux in res]  # ...then garbage
        return res

    monkeypatch.setattr(contact_mod, "count_tiles_multi",
                        slow_garbage_off_main)
    for fl in (slow, sync):
        fl.ingest([_frames(91, 2)])
        fl.contact_round(windows=[(0, 4e6)])
    slow.ground_segment.sync()  # watchdog fires -> synchronous recovery
    assert slow.summary()["fault_watchdog_recoveries"] == 1
    time.sleep(0.6)  # give the abandoned worker time to limp home
    _assert_same(slow.results()[0], sync.results()[0],
                 "abandoned-worker write barrier")


def test_recovery_accounting_stall_no_double_count(counters):
    """Regression (accounting skew): the abandoned worker's wall clock
    must NOT land in ``recount_s`` on top of the recovery's (the old
    double count), and the synchronous recovery must land in ``wait_s``
    — a recovered round hides exactly nothing."""
    space, ground = counters
    pcfg = PipelineConfig(method="ground_only", score_thresh=0.25)
    fp = FaultPlan(worker_faults={0: "stall"}, stall_s=0.6)
    fleet = Fleet(space, ground, pcfg, n_sats=1, async_ground=True,
                  watchdog_s=0.05, faults=fp)
    fleet.ingest([_frames(92, 2)])
    fleet.contact_round(windows=[(0, 4e6)])
    fleet.ground_segment.sync()
    time.sleep(0.8)  # the abandoned worker finishes well after recovery
    gseg = fleet.ground_segment
    assert fleet.summary()["fault_watchdog_recoveries"] == 1
    assert gseg.wait_s <= gseg.recount_s
    assert gseg.recount_s < 0.5, (
        "the abandoned worker's stall leaked into recount_s")
    assert gseg.hidden_fraction == 0.0


def test_recovery_accounting_crash_hides_nothing(counters):
    """Regression (accounting skew, other direction): a WorkerCrash
    recovery recounts synchronously — that blocked time must land in
    ``wait_s``, so the recovered round reports 0% hidden rather than
    pretending the recount overlapped anything."""
    space, ground = counters
    pcfg = PipelineConfig(method="ground_only", score_thresh=0.25)
    fp = FaultPlan(worker_faults={0: "crash"})
    fleet = Fleet(space, ground, pcfg, n_sats=1, async_ground=True,
                  watchdog_s=5.0, faults=fp)
    fleet.ingest([_frames(93, 2)])
    fleet.contact_round(windows=[(0, 4e6)])
    fleet.ground_segment.sync()
    gseg = fleet.ground_segment
    assert fleet.summary()["fault_watchdog_recoveries"] == 1
    assert gseg.wait_s <= gseg.recount_s
    assert gseg.hidden_fraction == 0.0


# ---------------------------------------------------------------------------
# lifecycle: context managers, mid-round exceptions, ledger integrity
# ---------------------------------------------------------------------------

def test_ground_segment_context_manager_joins_worker(counters):
    space, ground = counters
    pcfg = PipelineConfig(method="ground_only", score_thresh=0.25)
    fleet = Fleet(space, ground, pcfg, n_sats=1, async_ground=True)
    assert isinstance(fleet.ground_segment, GroundSegment)
    with fleet:
        fleet.ingest([_frames(81, 2)])
        fleet.contact_round(windows=[(0, 4e6)])
        assert fleet.ground_segment.rounds_deferred == 1
    # clean exit synced: no round left in flight
    assert fleet.ground_segment.in_flight == 0


def test_exceptional_exit_closes_without_raising(counters):
    """An exception inside the `with` block tears the worker down via
    close() — no secondary error, no leaked thread, close idempotent."""
    space, ground = counters
    pcfg = PipelineConfig(method="ground_only", score_thresh=0.25)
    fleet = Fleet(space, ground, pcfg, n_sats=1, async_ground=True)

    def boom(*a, **k):
        raise RuntimeError("recount exploded")

    with pytest.raises(RuntimeError, match="user error"):
        with fleet:
            fleet.ingest([_frames(82)])
            fleet.missions[0].contact_stages[3].run = boom  # Aggregate
            fleet.contact_round(windows=[(0, 2e6)])
            raise RuntimeError("user error")
    assert fleet.ground_segment.in_flight == 0
    fleet.close()  # idempotent
    fleet.close()


def test_close_with_multiple_rounds_in_flight(counters):
    """An exceptional exit with a FULL depth-3 pipeline (two stalled
    workers still sleeping) cancels every queued round and returns
    without raising — no leaked threads, no late writes, idempotent."""
    space, ground = counters
    pcfg = PipelineConfig(method="ground_only", score_thresh=0.25)
    fp = FaultPlan(worker_faults={0: "stall", 1: "stall"}, stall_s=1.0)
    fleet = Fleet(space, ground, pcfg, n_sats=1, async_depth=3,
                  watchdog_s=0.1, faults=fp)
    with pytest.raises(RuntimeError, match="user error"):
        with fleet:
            for k in range(2):
                fleet.ingest([_frames(94 + k)])
                fleet.contact_round(windows=[(0, 2e6)])
            assert fleet.ground_segment.in_flight == 2
            raise RuntimeError("user error")
    assert fleet.ground_segment.in_flight == 0
    assert fleet.ground_segment.max_in_flight == 2
    fleet.close()  # idempotent
    fleet.close()


def test_finalize_safe_after_worker_exception(counters):
    """A real (non-injected) worker failure surfaces exactly once at
    sync with every ledger lane intact — recounts charge nothing — and
    the fleet still finalizes afterwards."""
    space, ground = counters
    pcfg = PipelineConfig(method="ground_only", score_thresh=0.25)
    broken = Fleet(space, ground, pcfg, n_sats=1, async_ground=True)
    clean = Fleet(space, ground, pcfg, n_sats=1)
    for fl in (broken, clean):
        fl.ingest([_frames(83, 2)])

    def boom(*a, **k):
        raise RuntimeError("recount exploded")

    broken.missions[0].contact_stages[3].run = boom  # Aggregate
    broken.contact_round(windows=[(0, 4e6)])
    clean.contact_round(windows=[(0, 4e6)])
    with pytest.raises(RuntimeError, match="recount exploded"):
        broken.ground_segment.sync()
    # the failed round changed no ledger lane vs the healthy run
    _assert_ledgers_equal(broken, clean, "post-exception")
    del broken.missions[0].contact_stages[3].run  # heal the stage
    res = broken.finalize()
    assert broken.pending_segments == [0]
    assert len(res) == 1


# ---------------------------------------------------------------------------
# budget clamping at the accrual seam (denormal underflow regression)
# ---------------------------------------------------------------------------

def test_clamp_budget_bytes_kills_denormals():
    tiny = float(np.finfo(np.float64).tiny)
    assert clamp_budget_bytes(5e-324) == 0.0          # denormal -> exact 0
    assert clamp_budget_bytes(tiny / 2) == 0.0
    assert clamp_budget_bytes(0.0) == 0.0
    assert clamp_budget_bytes(-1.0) == 0.0            # never negative
    assert clamp_budget_bytes(tiny) == tiny           # smallest normal kept
    assert clamp_budget_bytes(123.5) == 123.5         # normal scale: no-op


def test_denormal_window_budget_clamps_at_accrual(counters):
    """A denormal window budget accrues as exactly 0.0 through
    ``accrue_window_budgets`` (and spends nothing) instead of leaking a
    subnormal into the ledger lane."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    fleet = Fleet(space, ground, pcfg, n_sats=1)
    fleet.ingest([_frames(91)])
    [(_, rep)] = fleet.contact_round(windows=[(0, 5e-324)])
    assert rep.budget_bytes == 0.0
    assert rep.bytes_spent == 0.0
    assert float(fleet.ledger.bytes_budget[0]) == 0.0
    assert float(fleet.ledger.bytes_spent[0]) == 0.0
    fleet.finalize()


# ---------------------------------------------------------------------------
# FaultPlan construction, draws, scenario sizing
# ---------------------------------------------------------------------------

def test_fault_plan_validation():
    with pytest.raises(ValueError, match="refund_policy"):
        FaultPlan(refund_policy="ignore")
    with pytest.raises(ValueError, match="max_retries"):
        FaultPlan(max_retries=-1)
    with pytest.raises(ValueError, match="drop_rate"):
        FaultPlan(drop_rate=1.5)
    with pytest.raises(ValueError, match="station outage"):
        FaultPlan(station_outages=(("gs0", 3, 1),))
    with pytest.raises(ValueError, match="crash"):
        FaultPlan(worker_faults={0: "explode"}).worker_fault(0)


def test_fault_plan_none_is_empty_and_draws_are_pure():
    assert FaultPlan.none().empty
    assert not FaultPlan(drop_rate=0.1).empty
    assert not FaultPlan(window_drops={(0, 0)}).empty
    fp = FaultPlan(seed=9, drop_rate=0.5, corrupt_rate=0.5)
    # draw order can never perturb the schedule: pure (seed, key) fns
    a = [fp.window_dropped(r, w) for r in range(4) for w in range(4)]
    _ = fp.segment_corrupted(2, 1, 0)
    b = [fp.window_dropped(r, w) for r in range(4) for w in range(4)]
    assert a == b
    assert any(a) and not all(a)  # the rate actually bites both ways
    # distinct fault classes draw independently even on the same key
    fp2 = FaultPlan(seed=9, drop_rate=0.5, truncate_rate=0.5)
    drops = [fp2.window_dropped(r, 0) for r in range(32)]
    truncs = [fp2.truncated_at(r, 0, 4) is not None for r in range(32)]
    assert drops != truncs


def test_scenario_faults_sizes_outages_to_spec():
    spec = FleetScenarioSpec(
        n_sats=2, n_rounds=6,
        stations=(GroundStation("gs0"), GroundStation("gs1")), seed=4)
    fp = spec.fault_plan(outage_rate=1.0, drop_rate=0.1)
    assert fp.seed == spec.seed
    assert len(fp.station_outages) == len(spec.stations)
    names = {n for n, _, _ in fp.station_outages}
    assert names == {"gs0", "gs1"}
    for _, first, last in fp.station_outages:
        assert 0 <= first <= last < spec.n_rounds
    # deterministic in the seed
    assert fp == spec.fault_plan(outage_rate=1.0, drop_rate=0.1)
    assert scenario_faults(spec, 99).empty  # all rates default to 0
