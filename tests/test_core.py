"""Unit + hypothesis property tests for the paper's core techniques."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest of the suite runs
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import tiling
from repro.core.dedup import dedup, expanded_counts, features, kmeans
from repro.core.energy import (ATLAS, RPI4, EnergyLedger, FleetLedger,
                               detector_gflops, max_tiles_within_budget,
                               max_tiles_within_budget_vec)
from repro.core.metrics import ap50, cmae
from repro.core.throttle import (POLICIES, contact_budget_bytes, throttle,
                                 throttle_padded)


# ---------------------------------------------------------------------------
# tiling + Algorithm 1
# ---------------------------------------------------------------------------

def test_tile_image_shapes():
    img = jnp.arange(12 * 12 * 3, dtype=jnp.float32).reshape(12, 12, 3)
    t = tiling.tile_image(img, 4)
    assert t.shape == (9, 4, 4, 3)
    # first tile is the top-left block
    np.testing.assert_array_equal(t[0], img[:4, :4])
    # row-major ordering
    np.testing.assert_array_equal(t[1], img[:4, 4:8])


def test_tile_image_pads():
    img = jnp.ones((10, 10, 3))
    t = tiling.tile_image(img, 4)
    assert t.shape == (9, 4, 4, 3)


def test_resize_tiles():
    t = jnp.ones((5, 16, 16, 3))
    r = tiling.resize_tiles(t, 8)
    assert r.shape == (5, 8, 8, 3)
    np.testing.assert_allclose(r, 1.0, atol=1e-6)


@given(opt=st.integers(80, 480))
@settings(max_examples=20, deadline=None)
def test_ternary_search_finds_unimodal_peak(opt):
    """Algorithm 1 on any unimodal mAP curve lands within eps of the peak."""
    f = lambda s: -abs(s - opt) / 100.0
    s_best, cache = tiling.optimal_tile_size(f, 64, 512, eps=16)
    assert abs(s_best - opt) <= 24
    assert len(cache) < 25  # logarithmic, not exhaustive


def test_ternary_search_monotone_edge():
    s_best, _ = tiling.optimal_tile_size(lambda s: s / 512, 64, 512, eps=8)
    assert s_best > 480  # monotone increasing -> right edge


# ---------------------------------------------------------------------------
# dedup
# ---------------------------------------------------------------------------

def test_dedup_groups_duplicates():
    key = jax.random.PRNGKey(0)
    # 4 bases with genuinely distinct color statistics (different mean
    # brightness per channel) — as distinct geographic contexts are
    levels = jnp.asarray([[0.1, 0.2, 0.1], [0.8, 0.2, 0.2],
                          [0.2, 0.8, 0.4], [0.6, 0.6, 0.9]])
    base = (levels[:, None, None, :]
            + 0.05 * jax.random.uniform(key, (4, 16, 16, 3)))
    # 3 near-copies of each of the 4 distinct tiles (revisit frames)
    tiles = jnp.concatenate([
        base + 0.01 * jax.random.normal(jax.random.PRNGKey(i), base.shape)
        for i in range(3)
    ])
    res = dedup(jnp.clip(tiles, 0, 1), k=4, key=jax.random.PRNGKey(1))
    assert int(res.rep_mask.sum()) <= 4
    # each duplicate lands in its base's cluster
    a = np.asarray(res.assign)
    for j in range(4):
        assert len({a[j], a[j + 4], a[j + 8]}) == 1


def test_expanded_counts():
    key = jax.random.PRNGKey(0)
    tiles = jax.random.uniform(key, (12, 8, 8, 3))
    res = dedup(tiles, k=3, key=key)
    rep_counts = jnp.arange(12.0)
    exp = expanded_counts(rep_counts, res)
    assert exp.shape == (12,)
    # every tile inherits its cluster representative's count
    a, r = np.asarray(res.assign), np.asarray(res.rep_idx)
    for i in range(12):
        assert float(exp[i]) == float(rep_counts[r[a[i]]])


def test_kmeans_reduces_distortion():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (200, 9))
    _, _, d0 = kmeans(x, 8, key, iters=1)
    _, _, d10 = kmeans(x, 8, key, iters=10)
    assert float(d10.sum()) <= float(d0.sum()) + 1e-3


# ---------------------------------------------------------------------------
# throttle (Algorithm 2) — property tests
# ---------------------------------------------------------------------------

@given(
    n=st.integers(1, 64),
    budget=st.floats(0, 5e5),
    conf_p=st.floats(0.0, 0.5),
    dq=st.floats(0.0, 0.5),
    policy=st.sampled_from(POLICIES),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_throttle_invariants(n, budget, conf_p, dq, policy, seed):
    rng = np.random.default_rng(seed)
    conf = jnp.asarray(rng.random(n), jnp.float32)
    sizes = jnp.asarray(1000 + 9000 * rng.random(n), jnp.float32)
    conf_q = conf_p + dq
    r = throttle(conf, sizes, budget, conf_p, conf_q, policy)
    discard, space, down, dropped = map(np.asarray,
                                        (r.discard, r.space, r.downlink, r.dropped))
    # partition: every tile in exactly one bucket
    total = discard.astype(int) + space.astype(int) + down.astype(int) + dropped.astype(int)
    assert (total == 1).all()
    # byte budget respected
    assert float(r.bytes_used) <= budget + 1e-3
    # nothing below conf_p is kept
    c = np.asarray(conf)
    assert not (space & (c < conf_p)).any()
    assert not (down & (c < conf_p)).any()
    # high-confidence tiles are never downlinked
    assert not (down & (c > conf_q)).any()
    # fixed_conf is the only policy that drops middles
    if policy != "fixed_conf":
        assert not dropped.any()


def test_throttle_dynamic_prefers_high_conf():
    conf = jnp.asarray([0.30, 0.50, 0.40, 0.20])
    sizes = jnp.full(4, 100.0)
    r = throttle(conf, sizes, 200.0, 0.1, 0.9, "dynamic_conf")
    down = np.asarray(r.downlink)
    assert down[1] and down[2] and not down[0] and not down[3]


def test_throttle_low_conf_first_prefers_low():
    conf = jnp.asarray([0.30, 0.50, 0.40, 0.20])
    sizes = jnp.full(4, 100.0)
    r = throttle(conf, sizes, 200.0, 0.1, 0.9, "low_conf_first")
    down = np.asarray(r.downlink)
    assert down[3] and down[0] and not down[1] and not down[2]


def test_throttle_active_mask():
    conf = jnp.asarray([0.5, 0.5, 0.5])
    sizes = jnp.full(3, 100.0)
    active = jnp.asarray([True, False, True])
    r = throttle(conf, sizes, 1e9, 0.1, 0.9, "dynamic_conf", active=active)
    assert not bool(np.asarray(r.downlink)[1])
    assert not bool(np.asarray(r.space)[1])


def test_contact_budget():
    # paper §II: 6 min at 100 Mbps ~ 4.39 GB (they quote decimal-ish GB)
    b = contact_budget_bytes(100.0, 360.0)
    assert abs(b - 4.5e9) < 1e8


def test_throttle_padded_exact_bucket_boundary():
    """n == n_pad is the no-padding boundary: the padded wrapper must be
    bit-identical to the raw call, and a budget of exactly k tiles must
    admit exactly k (the cumsum <= budget edge)."""
    rng = np.random.default_rng(3)
    n = 64  # == the default dedup bucket floor
    conf = rng.uniform(0.2, 0.5, n)  # all middles for p=0.1, q=0.6
    tile_bytes = 1000.0
    for policy in POLICIES:
        space_p, down_p = throttle_padded(conf, tile_bytes, 7 * tile_bytes,
                                          0.1, 0.6, policy, n_pad=n)
        r = throttle(jnp.asarray(conf), jnp.full(n, tile_bytes),
                     7 * tile_bytes, 0.1, 0.6, policy)
        np.testing.assert_array_equal(space_p, np.asarray(r.space))
        np.testing.assert_array_equal(down_p, np.asarray(r.downlink))
        assert int(down_p.sum()) == 7  # exact-budget boundary admits k tiles


def test_throttle_padded_pad_slots_inert():
    """Bucket padding (n_pad > n) never changes the real slots."""
    rng = np.random.default_rng(4)
    conf = rng.uniform(0.0, 1.0, 19)
    for n_pad in (19, 32, 64, 256):
        space, down = throttle_padded(conf, 1000.0, 5000.0, 0.1, 0.6,
                                      "dynamic_conf", n_pad=n_pad)
        ref_s, ref_d = throttle_padded(conf, 1000.0, 5000.0, 0.1, 0.6,
                                       "dynamic_conf", n_pad=19)
        np.testing.assert_array_equal(space, ref_s)
        np.testing.assert_array_equal(down, ref_d)


def test_throttle_padded_rejects_lossy_bucket():
    with pytest.raises(ValueError, match="n_pad=8 < n=16"):
        throttle_padded(np.full(16, 0.5), 1000.0, 1e6, 0.1, 0.6,
                        n_pad=8)


def test_contact_budget_degenerate_windows():
    """Zero/negative contact time (or bandwidth) -> zero budget, never a
    negative one."""
    assert contact_budget_bytes(50.0, 0.0) == 0.0
    assert contact_budget_bytes(50.0, -360.0) == 0.0
    assert contact_budget_bytes(-50.0, 360.0) == 0.0
    assert contact_budget_bytes(-50.0, -360.0) == 0.0  # no sign flip
    assert contact_budget_bytes(50.0, 360.0) > 0.0


def test_throttle_jits():
    conf = jnp.asarray(np.random.default_rng(0).random(128), jnp.float32)
    sizes = jnp.full(128, 1000.0)
    f = jax.jit(lambda c, s, b: throttle(c, s, b, 0.1, 0.6, "dynamic_conf"))
    r = f(conf, sizes, jnp.float32(20000.0))
    assert float(r.bytes_used) <= 20000.0


# ---------------------------------------------------------------------------
# energy
# ---------------------------------------------------------------------------

def test_energy_profiles_match_paper():
    # RPi4 ~2x more energy-efficient per tile than Atlas (paper Fig. 8)
    ratio = ATLAS.joules_per_gflop / RPI4.joules_per_gflop
    assert 1.8 < ratio < 2.3


def test_energy_cap_reproduces_22pct_regime():
    """150 KJ on RPi4 covers ~20-25% of a 100K-tile day (paper §I)."""
    from repro.configs import get_config
    g = detector_gflops(get_config("targetfuse-space"))
    cap = max_tiles_within_budget(150_000.0, g, RPI4)
    assert 0.15 < cap / 100_000.0 < 0.35, cap


def test_ledger_accounting():
    led = EnergyLedger(budget_j=1000.0)
    led.charge_compute(10, 5.0, RPI4)
    led.charge_downlink(1e6, 50.0)
    assert led.spent > 0
    assert abs(led.remaining - (1000.0 - led.spent)) < 1e-9
    # E_com dominates E_cap/E_agg (paper: >60% on compute+downlink)
    led.charge_capture(100)
    led.charge_aggregate(1000)
    assert led.e_com + led.e_down > 0.6 * led.spent


def test_fleet_ledger_lanes_match_scalar_ledger():
    """The stacked fleet ledger is bit-equal to N scalar ledgers fed the
    same per-lane op sequence — vectorized or through lane views."""
    fleet = FleetLedger(3)
    scalars = [EnergyLedger(budget_j=0.0) for _ in range(3)]
    grants = np.array([100.0, 1e-3, 987.654321])
    fleet.grant(grants)
    fleet.charge_capture(np.array([2, 0, 7]))
    fleet.charge_compute(np.array([5, 0, 3]), 4.2, RPI4)
    for led, g, ni, nt in zip(scalars, grants, (2, 0, 7), (5, 0, 3)):
        led.grant(float(g))
        led.charge_capture(ni)
        led.charge_compute(nt, 4.2, RPI4)
    # scalar charges through a view hit the same lanes
    fleet.energy_view(1).charge_downlink(1e6, 50.0)
    scalars[1].charge_downlink(1e6, 50.0)
    for i, led in enumerate(scalars):
        assert fleet.budget_j[i] == led.budget_j
        assert fleet.spent[i] == led.spent
        assert fleet.remaining[i] == led.remaining
        view = fleet.energy_view(i)
        assert view.spent == led.spent and view.remaining == led.remaining


def test_fleet_ledger_byte_views_read_write():
    fleet = FleetLedger(2)
    bv = fleet.bytes_view(1)
    bv.budget += 10.0
    bv.spent = 4.0
    assert fleet.bytes_budget[1] == 10.0 and fleet.bytes_spent[1] == 4.0
    assert fleet.bytes_budget[0] == 0.0
    assert bv.requested == 0.0


def test_max_tiles_vec_matches_scalar():
    budgets = np.array([0.0, 1.0, 123.456, 9e4])
    vec = max_tiles_within_budget_vec(budgets, 3.3, ATLAS)
    for b, v in zip(budgets, vec):
        assert int(v) == max_tiles_within_budget(float(b), 3.3, ATLAS)
    assert (max_tiles_within_budget_vec(budgets, 0.0, ATLAS) == 0).all()
    # astronomical grants must clamp, never wrap negative (int64 cast)
    huge = max_tiles_within_budget_vec(np.array([1e30]), 3.3, ATLAS)
    assert huge[0] > 0 and huge[0] >= 2 ** 61


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_cmae():
    assert cmae([1, 2, 3], [1, 2, 3]) == 0.0
    assert abs(cmae([0, 0, 0], [1, 2, 3]) - 1.0) < 1e-9
    assert abs(cmae([2, 2, 4], [1, 2, 3]) - (2 / 6)) < 1e-9


def test_ap50_perfect_and_empty():
    gt = [np.array([[0, 0, 10, 10], [20, 20, 30, 30]], np.float32)]
    pred = [gt[0].copy()]
    scores = [np.array([0.9, 0.8], np.float32)]
    assert ap50(pred, scores, gt) > 0.95
    assert ap50([np.zeros((0, 4))], [np.zeros(0)], gt) == 0.0


@given(st.integers(1, 30), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_cmae_scale_invariance(n, seed):
    rng = np.random.default_rng(seed)
    y = rng.random(n) * 10
    g = rng.random(n) * 10 + 0.1
    assert abs(cmae(3 * y, 3 * g) - cmae(y, g)) < 1e-9
