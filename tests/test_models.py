"""Per-architecture smoke tests: REDUCED config of the same family, one
forward / train step on CPU, asserting output shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import (convnext, detector, diffusion, dit, lm, resnet,
                          unet, vit)

KEY = jax.random.PRNGKey(0)

LM_ARCHS = ["phi4-mini-3.8b", "qwen3-8b", "qwen2-moe-a2.7b", "deepseek-v2-lite-16b"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = reduced(get_config(arch))
    params = lm.init(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, 1)
    (loss, metrics), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
        params, cfg, tokens, labels)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_prefill_decode_consistency(arch):
    cfg = reduced(get_config(arch))
    params = lm.init(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    full, _ = lm.forward_train(params, cfg, tokens)
    logits_p, cache = lm.prefill(params, cfg, tokens[:, :6])
    cache = jax.tree_util.tree_map(
        lambda c: jnp.pad(c, [(0, 0), (0, 0), (0, 12 - c.shape[2])]
                          + [(0, 0)] * (c.ndim - 3)), cache)
    np.testing.assert_allclose(logits_p, full[:, 5], atol=2e-4)
    for pos in range(6, 9):
        logits_d, cache = lm.decode_step(params, cfg, tokens[:, pos:pos + 1],
                                         cache, pos)
        np.testing.assert_allclose(logits_d, full[:, pos], atol=2e-4)


def test_mla_absorb_equivalence():
    """Weight-absorbed MLA decode == naive decompress decode."""
    cfg = reduced(get_config("deepseek-v2-lite-16b"))
    params = lm.init(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    _, cache = lm.prefill(params, cfg, tokens[:, :4])
    cache = jax.tree_util.tree_map(
        lambda c: jnp.pad(c, [(0, 0), (0, 0), (0, 4)] + [(0, 0)] * (c.ndim - 3)),
        cache)
    la, _ = lm.decode_step(params, cfg, tokens[:, 4:5], cache, 4, absorb=True)
    ln_, _ = lm.decode_step(params, cfg, tokens[:, 4:5], cache, 4, absorb=False)
    np.testing.assert_allclose(la, ln_, atol=2e-4)


def test_lm_scan_unroll_equivalence():
    import dataclasses
    cfg = reduced(get_config("qwen3-8b"))
    params = lm.init(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    l1, _ = lm.forward_train(params, cfg, tokens)
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    l2, _ = lm.forward_train(params, cfg2, tokens)
    np.testing.assert_allclose(l1, l2, atol=1e-5)


def test_moe_routes_to_multiple_experts():
    cfg = reduced(get_config("qwen2-moe-a2.7b"))
    params = lm.init(KEY, cfg)
    from repro.models.moe import moe_block
    blk = jax.tree_util.tree_map(lambda a: a[0], params["blocks_moe"])
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    out, aux = moe_block(blk["moe"], cfg, x)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    # permutation-of-tokens equivariance (same group): routing is per-token
    perm = jax.random.permutation(KEY, 16)
    out_p, _ = moe_block(blk["moe"], cfg, x[:, perm])
    np.testing.assert_allclose(out_p, out[:, perm], atol=1e-4)


@pytest.mark.parametrize("arch,mod", [("vit-l16", vit), ("vit-h14", vit),
                                      ("convnext-b", convnext)])
def test_vision_smoke(arch, mod):
    cfg = reduced(get_config(arch))
    params = mod.init(KEY, cfg)
    img = jax.random.uniform(KEY, (2, cfg.img_res, cfg.img_res, 3))

    def loss(p):
        lg = mod.forward(p, cfg, img, train=True)
        return jnp.mean(jax.nn.logsumexp(lg, -1))

    l, g = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l))
    logits = mod.forward(params, cfg, img)
    assert logits.shape == (2, cfg.n_classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_vit_resolution_change():
    """cls_384-style finetune shape: pos-emb interpolation path."""
    cfg = reduced(get_config("vit-l16"))
    params = vit.init(KEY, cfg)
    img = jax.random.uniform(KEY, (1, cfg.img_res * 2, cfg.img_res * 2, 3))
    logits = vit.forward(params, cfg, img)
    assert logits.shape == (1, cfg.n_classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_resnet_smoke_and_bn_state():
    cfg = reduced(get_config("resnet-152"))
    params, state = resnet.init(KEY, cfg)
    img = jax.random.uniform(KEY, (4, cfg.img_res, cfg.img_res, 3))
    logits, new_state = resnet.forward(params, state, cfg, img, train=True)
    assert logits.shape == (4, cfg.n_classes)
    # running stats moved
    leaves0 = jax.tree_util.tree_leaves(state)
    leaves1 = jax.tree_util.tree_leaves(new_state)
    moved = any(float(jnp.max(jnp.abs(a - b))) > 0 for a, b in zip(leaves0, leaves1))
    assert moved
    logits_eval, _ = resnet.forward(params, new_state, cfg, img, train=False)
    assert np.isfinite(np.asarray(logits_eval)).all()


def test_dit_smoke_train_and_sample():
    cfg = reduced(get_config("dit-s2"))
    params = dit.init(KEY, cfg)
    lr = cfg.img_res // cfg.latent_factor
    lat = jax.random.normal(KEY, (2, lr, lr, cfg.latent_ch))
    y = jnp.array([1, 2])

    def loss(p):
        def eps_fn(x, t):
            return dit.forward(p, cfg, x, t, y, train=True)[0]
        return diffusion.train_loss(eps_fn, lat, KEY)

    l, g = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l))
    out = diffusion.sample(lambda x, t: dit.forward(params, cfg, x, t, y)[0],
                           KEY, lat.shape, 4)
    assert out.shape == lat.shape
    assert np.isfinite(np.asarray(out)).all()


def test_dit_resolution_agnostic():
    """gen_1024-style: larger latent grid with the same params."""
    cfg = reduced(get_config("dit-s2"))
    params = dit.init(KEY, cfg)
    lr = cfg.img_res // cfg.latent_factor * 2
    lat = jax.random.normal(KEY, (1, lr, lr, cfg.latent_ch))
    eps, _ = dit.forward(params, cfg, lat, jnp.array([3]), jnp.array([0]))
    assert eps.shape == lat.shape


def test_unet_smoke():
    cfg = reduced(get_config("unet-sd15"))
    params = unet.init(KEY, cfg)
    lr = cfg.img_res // cfg.latent_factor
    lat = jax.random.normal(KEY, (2, lr, lr, cfg.latent_ch))
    ctx = jax.random.normal(KEY, (2, cfg.ctx_len, cfg.ctx_dim))

    def loss(p):
        def eps_fn(x, t):
            return unet.forward(p, cfg, x, t, ctx, train=True)
        return diffusion.train_loss(eps_fn, lat, KEY)

    l, g = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l))


@pytest.mark.parametrize("arch", ["targetfuse-space", "targetfuse-ground",
                                  "ssd-mobilenetv2"])
def test_detector_smoke(arch):
    cfg = reduced(get_config(arch))
    params = detector.init(KEY, cfg)
    img = jax.random.uniform(KEY, (2, cfg.input_size, cfg.input_size, 3))
    raw = detector.forward(params, cfg, img)
    g = detector.grid_size(cfg)
    assert raw.shape == (2, g, g, cfg.n_anchors, 5 + cfg.n_classes)
    cnt, conf = detector.count_and_confidence(raw, cfg, input_size=cfg.input_size)
    assert cnt.shape == (2,) and conf.shape == (2,)
    assert (np.asarray(conf) >= 0).all() and (np.asarray(conf) <= 1).all()


def test_all_full_configs_instantiate_shapes_only():
    """FULL configs must at least eval_shape-init (no allocation)."""
    import functools
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        if cfg.family == "lm":
            sds = jax.eval_shape(functools.partial(lm.init, cfg=cfg), KEY)
        elif cfg.family == "vision":
            mod = {"vit": vit, "convnext": convnext, "resnet": resnet}[cfg.kind]
            sds = jax.eval_shape(functools.partial(mod.init, cfg=cfg), KEY)
        else:
            mod = dit if cfg.kind == "dit" else unet
            sds = jax.eval_shape(functools.partial(mod.init, cfg=cfg), KEY)
        n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(sds))
        # within 25% of the config's analytic count (analytic is approximate
        # for conv nets)
        assert 0.5 < n / cfg.n_params < 2.0, (arch, n, cfg.n_params)
