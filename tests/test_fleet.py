"""Fleet engine tests: differential exact-parity against looped
sequential Missions, stacked-ledger consistency, rotation semantics,
the batched capture/counting helpers, the vmapped multi-satellite dedup
core, and the sharded (device-mesh) fleet runtime.

The sharded differential gates need multiple host devices — run them via

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python -m pytest tests/test_fleet.py -k sharded

(scripts/ci.sh does); under plain tier-1 they skip.
"""
import jax
import numpy as np
import pytest

import repro.core.dedup as dd
from repro.core.cascade import (count_tiles_batched, count_tiles_multi)
from repro.core.engine import prepare_frames, prepare_frames_multi
from repro.core.fleet import Fleet, run_scenario
from repro.core.fleet_sharding import FleetSharding, sats_mesh
from repro.core.mission import Mission
from repro.core.pipeline import PipelineConfig
from repro.data.scenarios import (FleetScenarioSpec, GroundStation,
                                  generate_scenario)
from repro.data.synthetic import SceneSpec, make_scene, revisit_frames

METHODS = ("space_only", "ground_only", "tiansuan", "kodan", "targetfuse")

SCENE_A = SceneSpec("trackA", 384, (10, 18), (10, 24), cloud_fraction=0.25)
SCENE_B = SceneSpec("trackB", 256, (6, 12), (10, 20), cloud_fraction=0.2)


@pytest.fixture(scope="module")
def scenario():
    """3 satellites x 3 rounds, two stations with variable bandwidth,
    heterogeneous scene mixes, eclipse/sunlit harvest profile."""
    return generate_scenario(FleetScenarioSpec(
        n_sats=3, n_rounds=3, frames_per_pass=2,
        stations=(GroundStation("gs0"),
                  GroundStation("gs1", bandwidth_mbps=30.0, contact_s=240.0)),
        scene_mix=(SCENE_A, SCENE_B),
        eclipse_fraction=0.35, seed=11))


def _assert_same(a, b, ctx=""):
    np.testing.assert_array_equal(a.per_tile_pred, b.per_tile_pred,
                                  err_msg=f"{ctx}: per-tile preds differ")
    np.testing.assert_array_equal(a.per_tile_true, b.per_tile_true,
                                  err_msg=f"{ctx}: per-tile truth differs")
    assert a.summary() == b.summary(), (
        f"{ctx}: summaries differ:\n{a.summary()}\n{b.summary()}")


# ---------------------------------------------------------------------------
# the acceptance gate: fleet exact-equal to N sequential Missions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_fleet_parity_all_policies(method, scenario, counters):
    space, ground = counters
    pcfg = PipelineConfig(method=method, score_thresh=0.25)
    got, fleet = run_scenario(space, ground, pcfg, scenario, fleet=True)
    want, missions = run_scenario(space, ground, pcfg, scenario, fleet=False)
    assert len(got) == len(want) == scenario.spec.n_sats
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"{method} sat{i}")
    # the stacked fleet ledger matches every oracle Mission's scalar one
    for i, m in enumerate(missions):
        assert fleet.ledger.budget_j[i] == m.ledger.budget_j
        assert fleet.ledger.spent[i] == m.ledger.spent
        assert fleet.ledger.e_com[i] == m.ledger.e_com
        assert fleet.ledger.bytes_budget[i] == m.bytes_budget
        assert fleet.ledger.bytes_requested[i] == m.bytes_requested
        assert fleet.ledger.bytes_spent[i] == m.bytes_spent


def test_fleet_parity_reference_path(scenario, counters):
    """use_engine=False satellites fall back to sequential Mission
    ingest inside the fleet — still exact-equal to the oracle."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25,
                          use_engine=False)
    got, _ = run_scenario(space, ground, pcfg, scenario, fleet=True)
    want, _ = run_scenario(space, ground, pcfg, scenario, fleet=False)
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"reference sat{i}")


def test_fleet_heterogeneous_policies(scenario, counters):
    """A fleet mixing all five policies (one per satellite, wrapping)
    stays satellite-wise exact-equal to the per-policy oracles."""
    space, ground = counters
    n = scenario.spec.n_sats
    pcfgs = [PipelineConfig(method=METHODS[i % len(METHODS)],
                            score_thresh=0.25) for i in range(n)]
    got, _ = run_scenario(space, ground, pcfgs, scenario, fleet=True)
    want, _ = run_scenario(space, ground, pcfgs, scenario, fleet=False)
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"mixed sat{i} ({pcfgs[i].method})")


def test_fleet_empty_pass_parity(counters):
    """A satellite with an empty pass in a round matches its oracle."""
    space, ground = counters
    rng = np.random.default_rng(2)
    img, b, c = make_scene(rng, SCENE_B)
    frames = revisit_frames(rng, img, b, c, 2)
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)

    fleet = Fleet(space, ground, pcfg, n_sats=2)
    fleet.ingest([frames, []])
    fleet.contact_round(windows=[(0, 2e6), (1, 2e6)])
    got = fleet.finalize()

    want = []
    for fr in (frames, []):
        m = Mission(space, ground, pcfg)
        m.ingest(fr)
        m.contact_window(2e6)
        want.append(m.finalize())
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"empty-pass sat{i}")
    assert got[1].tiles_total == 0


# ---------------------------------------------------------------------------
# streaming semantics
# ---------------------------------------------------------------------------

def test_contact_round_rotation(counters):
    """Default contact_round serves satellites round-robin."""
    space, ground = counters
    rng = np.random.default_rng(3)
    img, b, c = make_scene(rng, SCENE_B)
    pcfg = PipelineConfig(method="space_only", score_thresh=0.25)
    fleet = Fleet(space, ground, pcfg, n_sats=3)

    served = []
    for _ in range(4):
        fleet.ingest([revisit_frames(rng, img, b, c, 1) for _ in range(3)])
        served += [sat for sat, _ in fleet.contact_round(stations=1)]
    assert served == [0, 1, 2, 0]
    # multi-station rounds serve distinct satellites
    fleet2 = Fleet(space, ground, pcfg, n_sats=3)
    fleet2.ingest([revisit_frames(rng, img, b, c, 1) for _ in range(3)])
    assert sorted(s for s, _ in fleet2.contact_round(stations=2)) == [0, 1]
    # more stations than satellites: the rotation wraps, windows are
    # never silently dropped (a sat may get two in one round)
    assert [s for s, _ in fleet2.contact_round(stations=4)] == [2, 0, 1, 2]


def test_contact_round_same_sat_twice_keeps_both_reports(counters):
    """Two windows to one satellite in a round (more stations than
    satellites) return BOTH reports in window order: the first drains
    the pending passes, the second finds nothing left."""
    space, ground = counters
    rng = np.random.default_rng(8)
    img, b, c = make_scene(rng, SCENE_B)
    pcfg = PipelineConfig(method="ground_only", score_thresh=0.25)
    fleet = Fleet(space, ground, pcfg, n_sats=1)
    fleet.ingest([revisit_frames(rng, img, b, c, 1)])
    tb = fleet.missions[0].tile_bytes
    reps = fleet.contact_round(windows=[(0, 2 * tb), (0, 2 * tb)])
    assert [sat for sat, _ in reps] == [0, 0]
    assert reps[0][1].segments == 1 and reps[0][1].tiles_downlinked == 2
    assert reps[1][1].segments == 0 and reps[1][1].bytes_spent == 0.0
    # same drain as the sequential oracle
    m = Mission(space, ground, pcfg)
    rng2 = np.random.default_rng(8)
    img2, b2, c2 = make_scene(rng2, SCENE_B)
    m.ingest(revisit_frames(rng2, img2, b2, c2, 1))
    m.contact_window(2 * tb)
    m.contact_window(2 * tb)
    _assert_same(fleet.finalize()[0], m.finalize(), "double-window sat0")


def test_fleet_finalize_drains_all(scenario, counters):
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    fleet = Fleet(space, ground, pcfg, n_sats=scenario.spec.n_sats)
    for rnd in scenario.rounds:
        fleet.ingest(rnd.frames_per_sat(fleet.n_sats),
                     rnd.harvest_per_sat(fleet.n_sats))
    assert all(p > 0 for p in fleet.pending_segments)
    fleet.finalize()
    assert fleet.pending_segments == [0] * fleet.n_sats
    # idempotent, like Mission.finalize
    again = fleet.finalize()
    assert len(again) == fleet.n_sats


def test_fleet_summary_aggregates(scenario, counters):
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    results, fleet = run_scenario(space, ground, pcfg, scenario, fleet=True)
    s = fleet.summary()
    assert s["n_sats"] == scenario.spec.n_sats
    assert s["tiles_total"] == sum(r.tiles_total for r in results)
    assert s["total_true"] == sum(r.total_true for r in results)
    assert s["bytes_spent"] <= s["bytes_budget"] + 1e-6
    # the energy cap governs compute: counting spend never overdraws the
    # granted harvest fleet-wide (capture is charged unconditionally —
    # imaging happens even through an eclipse round's zero grant — so
    # e_cap is outside the cap; remaining floors at 0)
    led = fleet.ledger
    assert (led.e_com <= led.budget_j + 1e-9).all()
    assert (led.remaining >= 0.0).all()


# ---------------------------------------------------------------------------
# batched helpers: shared-bucket capture and shared-batch counting
# ---------------------------------------------------------------------------

def test_prepare_frames_multi_matches_single(counters):
    space, ground = counters
    sp_size = space[1].input_size
    gd_size = ground[1].input_size
    rng = np.random.default_rng(5)
    workloads = []
    for k in (2, 1, 3):
        img, b, c = make_scene(rng, SCENE_A)
        workloads.append(revisit_frames(rng, img, b, c, k))
    workloads.insert(1, [])  # an idle satellite
    multi = prepare_frames_multi(workloads, 128, sp_size, gd_size)
    for w, got in zip(workloads, multi):
        want = prepare_frames(w, 128, sp_size, gd_size)
        assert got.n == want.n
        np.testing.assert_array_equal(np.asarray(got.tiles_sp)[:got.n],
                                      np.asarray(want.tiles_sp)[:want.n])
        np.testing.assert_array_equal(np.asarray(got.tiles_gd)[:got.n],
                                      np.asarray(want.tiles_gd)[:want.n])
        np.testing.assert_array_equal(np.asarray(got.moments)[:got.n],
                                      np.asarray(want.moments)[:want.n])
        np.testing.assert_array_equal(got.roi_std, want.roi_std)
        np.testing.assert_array_equal(got.true, want.true)


def test_prepare_frames_multi_mixed_resolutions(counters):
    """Workloads of different frame resolutions share buckets per
    resolution and still split back exactly."""
    space, ground = counters
    sp_size = space[1].input_size
    gd_size = ground[1].input_size
    rng = np.random.default_rng(6)
    wa, wb = [], []
    ia, ba, ca = make_scene(rng, SCENE_A)
    ib, bb, cb = make_scene(rng, SCENE_B)
    wa = revisit_frames(rng, ia, ba, ca, 2)
    wb = revisit_frames(rng, ib, bb, cb, 3)
    multi = prepare_frames_multi([wa, wb], 128, sp_size, gd_size)
    for w, got in zip((wa, wb), multi):
        want = prepare_frames(w, 128, sp_size, gd_size)
        assert got.n == want.n
        np.testing.assert_array_equal(np.asarray(got.tiles_sp)[:got.n],
                                      np.asarray(want.tiles_sp)[:want.n])
        np.testing.assert_array_equal(got.roi_std, want.roi_std)
        np.testing.assert_array_equal(got.true, want.true)


def test_dedup_multi_matches_sequential_core():
    """The vmapped multi-satellite dedup core is bit-equal (documented
    tolerance: 0.0 on CPU) to per-satellite `dedup_from_moments` across
    mixed shape buckets, paddings, and keys."""
    import jax.numpy as jnp
    rng = np.random.default_rng(9)
    shapes = ((128, 100, 10), (128, 128, 20), (256, 200, 4), (128, 37, 5),
              (128, 100, 10))  # a duplicate workload shares its bucket
    parts = [(jnp.asarray(rng.random((n_pad, 9)).astype(np.float32)), k,
              jax.random.PRNGKey(k), n)
             for n_pad, n, k in shapes]
    got = dd.dedup_multi(parts)
    for (mo, k, key, n), res in zip(parts, got):
        want = dd.dedup_from_moments(mo, k, key, n=n)
        for f in res._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(res, f)), np.asarray(getattr(want, f)),
                err_msg=f"dedup_multi.{f} diverges at n={n} k={k}")


def test_fleet_strict_parity_matches_batched_dedup(scenario, counters):
    """strict_parity=True (sequential per-sat dedup core) and the
    default batched dedup produce identical fleets on CPU — the
    documented zero-tolerance parity story."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    got, fl = run_scenario(space, ground, pcfg, scenario, fleet=True)
    want, fs = run_scenario(space, ground, pcfg, scenario, fleet=True,
                            strict_parity=True)
    assert fl.summary()["dedup_batched"] is True
    assert fs.summary()["dedup_batched"] is False
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"strict-parity sat{i}")


def test_fleet_summary_reports_runtime_facts(scenario, counters):
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    results, fleet = run_scenario(space, ground, pcfg, scenario, fleet=True)
    s = fleet.summary()
    assert s["n_devices"] == 1  # no mesh attached
    assert s["dedup_batched"] is True
    assert s["ingest_s"] > 0.0
    assert s["tiles_per_s"] == pytest.approx(
        sum(r.tiles_total for r in results) / s["ingest_s"])
    assert s["tiles_per_s_per_sat"] == pytest.approx(
        s["tiles_per_s"] / scenario.spec.n_sats)


def test_count_tiles_batched_size_tiers_match_direct(counters):
    """Tiered small-n batching is per-sample: every tier boundary yields
    the same counts as the one-shot full-batch forward."""
    from repro.core.cascade import _tier_batch, count_tiles
    (params, cfg), _ = counters
    assert [_tier_batch(n, 64) for n in (1, 8, 9, 16, 17, 63, 64, 65)] == \
        [8, 8, 16, 16, 32, 64, 64, 64]
    rng = np.random.default_rng(11)
    tiles = rng.random((70, cfg.input_size, cfg.input_size, 3)
                       ).astype(np.float32)
    for n in (1, 5, 8, 9, 16, 17, 33, 63, 64, 65, 70):
        import jax.numpy as jnp
        want_c, want_f = count_tiles(params, cfg, jnp.asarray(tiles[:n]),
                                     0.25)
        got_c, got_f = count_tiles_batched(params, cfg, tiles,
                                           idx=np.arange(n),
                                           score_thresh=0.25)
        np.testing.assert_array_equal(got_c, np.asarray(want_c))
        np.testing.assert_array_equal(got_f, np.asarray(want_f))


def test_count_tiles_multi_matches_batched(counters):
    (params, cfg), _ = counters
    rng = np.random.default_rng(7)
    tiles_a = rng.random((40, cfg.input_size, cfg.input_size, 3),
                         ).astype(np.float32)
    tiles_b = rng.random((16, cfg.input_size, cfg.input_size, 3),
                         ).astype(np.float32)
    parts = [(tiles_a, np.arange(0, 40, 2)),
             (tiles_b, np.array([], np.int64)),
             (tiles_b, np.array([3, 0, 15]))]
    multi = count_tiles_multi(params, cfg, parts, score_thresh=0.25)
    assert len(multi) == len(parts)
    for (tiles, idx), (c, f) in zip(parts, multi):
        want_c, want_f = count_tiles_batched(params, cfg, tiles, idx=idx,
                                             score_thresh=0.25)
        np.testing.assert_array_equal(c, want_c)
        np.testing.assert_array_equal(f, want_f)


# ---------------------------------------------------------------------------
# sharded fleet runtime: device-mesh differential gates
# (need >= 4 host devices: XLA_FLAGS=--xla_force_host_platform_device_count=4)
# ---------------------------------------------------------------------------

requires_mesh = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="sharded gates need XLA_FLAGS="
           "--xla_force_host_platform_device_count=4 (scripts/ci.sh sets it)")


def _assert_lanes_equal(a: Fleet, b: Fleet, ctx=""):
    for f in ("budget_j", "e_cap", "e_com", "e_agg", "e_down",
              "bytes_budget", "bytes_requested", "bytes_spent"):
        np.testing.assert_array_equal(
            getattr(a.ledger, f)[:a.n_sats], getattr(b.ledger, f)[:b.n_sats],
            err_msg=f"{ctx}: ledger lane {f} differs")


def test_off_mesh_sharding_is_noop():
    """FleetSharding without a mesh degrades to identity (the ctx.py
    pattern): single-device fleets run the pre-sharding code path."""
    sh = FleetSharding(None)
    assert not sh.on_mesh and sh.n_devices == 1
    assert sh.pad(5) == 5
    arr = np.arange(6.0)
    assert sh.shard(arr) is arr and sh.device_put(arr) is arr
    assert sats_mesh(1) is None


@requires_mesh
@pytest.mark.parametrize("method", METHODS)
def test_fleet_sharded_parity_all_policies(method, scenario, counters):
    """The acceptance gate: the mesh-sharded fleet (4 host devices) is
    bit-equal to the single-device fleet — per-tile preds, summaries,
    and ledger lanes — for every registered policy."""
    space, ground = counters
    mesh = sats_mesh(4)
    pcfg = PipelineConfig(method=method, score_thresh=0.25)
    got, fs = run_scenario(space, ground, pcfg, scenario, fleet=True,
                           mesh=mesh)
    want, f1 = run_scenario(space, ground, pcfg, scenario, fleet=True)
    assert fs.summary()["n_devices"] == 4
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"sharded {method} sat{i}")
    _assert_lanes_equal(fs, f1, f"sharded {method}")


@requires_mesh
def test_fleet_sharded_uneven_lane_padding(counters):
    """n_sats=6 over 4 devices: lane padding to 8 never perturbs real
    lanes — preds, summaries, and all ledger lanes match the unsharded
    fleet, and pad lanes stay zero."""
    space, ground = counters
    mesh = sats_mesh(4)
    sc = generate_scenario(FleetScenarioSpec(
        n_sats=6, n_rounds=2, frames_per_pass=1,
        stations=(GroundStation("gs0"),
                  GroundStation("gs1", bandwidth_mbps=30.0)),
        scene_mix=(SCENE_A, SCENE_B), eclipse_fraction=0.35, seed=13))
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    got, fs = run_scenario(space, ground, pcfg, sc, fleet=True, mesh=mesh)
    want, f1 = run_scenario(space, ground, pcfg, sc, fleet=True)
    assert fs.ledger.n_lanes == 8 and f1.ledger.n_lanes == 6
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"uneven sat{i}")
    _assert_lanes_equal(fs, f1, "uneven")
    for f in ("budget_j", "e_cap", "e_com", "e_agg", "e_down",
              "bytes_budget", "bytes_requested", "bytes_spent"):
        assert (getattr(fs.ledger, f)[6:] == 0.0).all(), \
            f"pad lanes of {f} were written"
    ss, s1 = fs.summary(), f1.summary()
    assert ss["n_devices"] == 4 and s1["n_devices"] == 1
    for s in (ss, s1):  # wall-clock/throughput legitimately differ
        for key in ("n_devices", "ingest_s", "tiles_per_s",
                    "tiles_per_s_per_sat", "contact_s", "windows_per_s",
                    "bytes_downlinked_per_s", "recount_s", "recount_wait_s",
                    "recount_hidden_frac"):
            s.pop(key)
    assert ss == s1


@requires_mesh
def test_fleet_sharded_matches_oracle_missions(scenario, counters):
    """Transitively: sharded fleet == looped sequential Missions."""
    space, ground = counters
    mesh = sats_mesh(4)
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    got, _ = run_scenario(space, ground, pcfg, scenario, fleet=True,
                          mesh=mesh)
    want, _ = run_scenario(space, ground, pcfg, scenario, fleet=False)
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"sharded-vs-oracle sat{i}")


@requires_mesh
def test_sharded_helpers_match_unsharded(counters):
    """prepare_frames_multi / count_tiles_multi / dedup_multi with a
    mesh context are bit-equal to their unsharded outputs."""
    import jax.numpy as jnp
    space, ground = counters
    sh = FleetSharding(sats_mesh(4))
    sp_size = space[1].input_size
    gd_size = ground[1].input_size
    rng = np.random.default_rng(17)
    workloads = []
    for k in (2, 1, 3, 2, 1):
        img, b, c = make_scene(rng, SCENE_A)
        workloads.append(revisit_frames(rng, img, b, c, k))
    multi = prepare_frames_multi(workloads, 128, sp_size, gd_size,
                                 sharding=sh)
    plain = prepare_frames_multi(workloads, 128, sp_size, gd_size)
    for got, want in zip(multi, plain):
        assert got.n == want.n
        np.testing.assert_array_equal(np.asarray(got.tiles_sp)[:got.n],
                                      np.asarray(want.tiles_sp)[:want.n])
        np.testing.assert_array_equal(np.asarray(got.moments)[:got.n],
                                      np.asarray(want.moments)[:want.n])
        np.testing.assert_array_equal(got.roi_std, want.roi_std)

    (params, cfg), _ = counters
    tiles = rng.random((96, cfg.input_size, cfg.input_size, 3)
                       ).astype(np.float32)
    parts = [(tiles, np.arange(0, 96, 3)), (tiles, np.array([5, 2, 77]))]
    for (c1, f1), (c2, f2) in zip(
            count_tiles_multi(params, cfg, parts, score_thresh=0.25,
                              sharding=sh),
            count_tiles_multi(params, cfg, parts, score_thresh=0.25)):
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))

    dparts = [(jnp.asarray(rng.random((128, 9)).astype(np.float32)), 8,
               jax.random.PRNGKey(s), 100 + s) for s in range(5)]
    for got, want in zip(dd.dedup_multi(dparts, sharding=sh),
                         dd.dedup_multi(dparts)):
        for f in got._fields:
            np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                          np.asarray(getattr(want, f)))
