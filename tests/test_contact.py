"""ContactPlan / batched ground-segment tests.

The acceptance gate of the contact-tier redesign: executing a round
through the lane-stacked batched planner (``Fleet.contact_round``) is
bit-equal — per-tile predictions, summaries, and every ledger lane — to
draining each window through the scalar FIFO stage loop
(``Fleet.contact_round_reference``) and to the sequential looped-Mission
oracle, for all five policies on both the engine and reference execution
paths. Plus: plan-build-time validation of malformed windows, the
select_batch default adapter for third-party policies, the vmapped
batched throttle's bit-parity, and the bounded depth-k recount pipeline
(``async_depth``) — every depth 0/1/2/3 bit-equal to the synchronous
fallback, backpressure bounding the in-flight count, and the
watchdog-abandoned-worker write barrier.
"""
import numpy as np
import pytest

from repro.core.contact import ContactPlan
from repro.core.fleet import Fleet, run_scenario
from repro.core.mission import Mission
from repro.core.pipeline import PipelineConfig
from repro.core.policies import (PolicyContextBatch, Selection,
                                 SelectionPolicy, available_policies,
                                 register_policy)
from repro.core.throttle import throttle_padded, throttle_padded_batch
from repro.data.scenarios import (FleetScenarioSpec, GroundStation,
                                  generate_scenario)
from repro.data.synthetic import SceneSpec, make_scene, revisit_frames

METHODS = ("space_only", "ground_only", "tiansuan", "kodan", "targetfuse")
SCENE = SceneSpec("contact", 384, (10, 18), (10, 24), cloud_fraction=0.25)


@pytest.fixture(scope="module")
def scenario():
    """3 satellites x 3 rounds, two stations per round (so one satellite
    gets two windows in some rounds and lanes stack per drain step)."""
    return generate_scenario(FleetScenarioSpec(
        n_sats=3, n_rounds=3, frames_per_pass=2,
        stations=(GroundStation("gs0"),
                  GroundStation("gs1", bandwidth_mbps=30.0, contact_s=240.0)),
        scene_mix=(SCENE,), eclipse_fraction=0.35, seed=23))


def _assert_same(a, b, ctx=""):
    np.testing.assert_array_equal(a.per_tile_pred, b.per_tile_pred,
                                  err_msg=f"{ctx}: per-tile preds differ")
    assert a.summary() == b.summary(), (
        f"{ctx}: summaries differ:\n{a.summary()}\n{b.summary()}")


def _assert_ledgers_equal(fa: Fleet, fb: Fleet, ctx=""):
    for f in ("budget_j", "e_cap", "e_com", "e_agg", "e_down",
              "bytes_budget", "bytes_requested", "bytes_spent"):
        np.testing.assert_array_equal(
            getattr(fa.ledger, f)[:fa.n_sats],
            getattr(fb.ledger, f)[:fb.n_sats],
            err_msg=f"{ctx}: ledger lane {f} differs")


# ---------------------------------------------------------------------------
# plan construction + validation (fail at build time, not in the drain)
# ---------------------------------------------------------------------------

def test_plan_builders_roundtrip():
    plan = ContactPlan.build([(0, 1e6), (2, None), (1, 0.0)], n_sats=3)
    assert plan.n_windows == 3 and plan.n_sats == 3
    assert plan.window_budget(0) == 1e6
    assert plan.window_budget(1) is None          # pending entitlement
    assert plan.window_budget(2) == 0.0
    assert list(plan.sats) == [0, 2, 1]
    assert len(plan.stations) == 3

    rot, ptr = ContactPlan.rotating(3, stations=4, start=2,
                                    budget_bytes=5.0)
    assert list(rot.sats) == [2, 0, 1, 2]         # wraps, never drops
    assert ptr == 0
    assert all(rot.window_budget(w) == 5.0 for w in range(4))
    rot2, ptr2 = ContactPlan.rotating(3, stations=1, start=ptr)
    assert list(rot2.sats) == [0] and ptr2 == 1
    assert rot2.window_budget(0) is None

    empty = ContactPlan.build([], n_sats=2)
    assert empty.n_windows == 0


def test_plan_from_scenario_contacts(scenario):
    rnd = scenario.rounds[0]
    plan = rnd.contact_plan(scenario.spec.n_sats)
    assert plan.n_windows == len(rnd.contacts)
    for w, c in enumerate(rnd.contacts):
        assert int(plan.sats[w]) == c.sat
        assert plan.window_budget(w) == c.budget_bytes
        assert plan.stations[w] == c.station.name


@pytest.mark.parametrize("windows,err", [
    ([(3, 1e6)], "outside"),             # sat index >= n_sats
    ([(-1, 1e6)], "outside"),            # negative sat index
    ([(0, float("nan"))], "non-finite"),
    ([(1, float("inf"))], "non-finite"),
    ([(0, -5.0)], "negative"),
])
def test_plan_build_rejects_malformed_windows(windows, err):
    with pytest.raises(ValueError, match=err):
        ContactPlan.build(windows, n_sats=3)


def test_rotating_rejects_malformed_fleet_shape():
    """Regression: ``rotating(n_sats=0, ...)`` used to escape as a bare
    ``ZeroDivisionError`` from the round-robin modulus instead of the
    build-time ValueError every other malformed-plan path raises."""
    with pytest.raises(ValueError, match="n_sats"):
        ContactPlan.rotating(0, stations=2)
    with pytest.raises(ValueError, match="n_sats"):
        ContactPlan.rotating(-3, stations=1)
    with pytest.raises(ValueError, match="stations"):
        ContactPlan.rotating(2, stations=-1)
    # the degenerate-but-valid edges still build
    plan, ptr = ContactPlan.rotating(1, stations=0)
    assert plan.n_windows == 0 and ptr == 0
    plan, ptr = ContactPlan.rotating(1, stations=2)
    assert list(plan.sats) == [0, 0] and ptr == 0


def test_contact_round_rejects_malformed_windows_at_build_time(counters):
    """The Fleet entry point fails BEFORE any budget state mutates."""
    space, ground = counters
    fleet = Fleet(space, ground, PipelineConfig(method="space_only"),
                  n_sats=2)
    for bad in ([(2, 1e6)], [(0, -1.0)], [(1, float("nan"))]):
        with pytest.raises(ValueError):
            fleet.contact_round(windows=bad)
    assert (fleet.ledger.bytes_budget == 0.0).all()
    # and a plan built for a different fleet size is rejected
    with pytest.raises(ValueError, match="fleet"):
        fleet.contact_round(plan=ContactPlan.build([(0, 1.0)], n_sats=5))


def test_plan_validates_array_construction():
    with pytest.raises(ValueError, match="aligned"):
        ContactPlan(sats=np.zeros(2, np.int64), budgets=np.zeros(3),
                    entitlement=np.zeros(2, bool), stations=("a", "b"),
                    n_sats=4)
    with pytest.raises(ValueError, match="integers"):
        ContactPlan(sats=np.zeros(2, np.float64), budgets=np.zeros(2),
                    entitlement=np.zeros(2, bool), stations=("a", "b"),
                    n_sats=4)
    with pytest.raises(ValueError, match="station labels"):
        ContactPlan(sats=np.zeros(2, np.int64), budgets=np.zeros(2),
                    entitlement=np.zeros(2, bool), stations=("a",),
                    n_sats=4)


# ---------------------------------------------------------------------------
# the acceptance gate: batched planner == FIFO reference == oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_batched_plan_matches_fifo_reference(method, scenario, counters):
    """Bit-equality (max deviation 0.0) of the lane-stacked batched
    planner against the scalar FIFO window loop for every policy."""
    space, ground = counters
    pcfg = PipelineConfig(method=method, score_thresh=0.25)
    got, fb = run_scenario(space, ground, pcfg, scenario, fleet=True)
    want, fr = run_scenario(space, ground, pcfg, scenario, fleet=True,
                            contact_reference=True)
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"{method} sat{i} batched-vs-reference")
    _assert_ledgers_equal(fb, fr, f"{method} batched-vs-reference")
    # transitively: batched plan == sequential looped Missions
    orc, _ = run_scenario(space, ground, pcfg, scenario, fleet=False)
    for i, (a, b) in enumerate(zip(got, orc)):
        _assert_same(a, b, f"{method} sat{i} batched-vs-oracle")


def test_batched_plan_reference_path_satellites(scenario, counters):
    """use_engine=False satellites fall back to the scalar window drain
    inside the batched round — still exact."""
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25,
                          use_engine=False)
    got, _ = run_scenario(space, ground, pcfg, scenario, fleet=True)
    want, _ = run_scenario(space, ground, pcfg, scenario, fleet=True,
                           contact_reference=True)
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"ref-path sat{i}")


def test_batched_plan_heterogeneous_policy_mix(scenario, counters):
    """Lanes of different policies in one round group per class and
    stay satellite-wise exact."""
    space, ground = counters
    n = scenario.spec.n_sats
    pcfgs = [PipelineConfig(method=METHODS[i % len(METHODS)],
                            score_thresh=0.25) for i in range(n)]
    got, fb = run_scenario(space, ground, pcfgs, scenario, fleet=True)
    want, fr = run_scenario(space, ground, pcfgs, scenario, fleet=True,
                            contact_reference=True)
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"mixed sat{i} ({pcfgs[i].method})")
    _assert_ledgers_equal(fb, fr, "mixed")


def test_legacy_windows_and_rotation_apis_still_exact(counters):
    """contact_round(windows=...) and the rotating default execute
    through the plan core unchanged — reports and ledgers match the
    scalar Mission drain."""
    space, ground = counters
    rng = np.random.default_rng(3)
    img, b, c = make_scene(rng, SCENE)
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    fleet = Fleet(space, ground, pcfg, n_sats=2)
    frames = [revisit_frames(rng, img, b, c, 1) for _ in range(2)]
    fleet.ingest(frames)
    reps = fleet.contact_round(stations=3, budget_bytes=2e6)
    assert [s for s, _ in reps] == [0, 1, 0]
    missions = [Mission(space, ground, pcfg) for _ in range(2)]
    for m, fr in zip(missions, frames):
        m.ingest(fr)
    want = [missions[0].contact_window(2e6), missions[1].contact_window(2e6),
            missions[0].contact_window(2e6)]
    for (sat, got_rep), want_rep in zip(reps, want):
        assert got_rep == want_rep
    for i, (a, b) in enumerate(zip(fleet.finalize(),
                                   [m.finalize() for m in missions])):
        _assert_same(a, b, f"legacy-api sat{i}")


# ---------------------------------------------------------------------------
# async overlap: deferred ground recount == synchronous fallback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ("targetfuse", "ground_only"))
def test_async_ground_overlap_is_exact(method, scenario, counters):
    space, ground = counters
    pcfg = PipelineConfig(method=method, score_thresh=0.25)
    got, fa = run_scenario(space, ground, pcfg, scenario, fleet=True,
                           async_ground=True)
    want, fs = run_scenario(space, ground, pcfg, scenario, fleet=True)
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"async {method} sat{i}")
    _assert_ledgers_equal(fa, fs, f"async {method}")
    sa, ss = fa.summary(), fs.summary()
    assert sa["async_ground"] is True and ss["async_ground"] is False
    assert fa.ground_segment.rounds_deferred > 0
    assert sa["recount_s"] > 0.0


def test_async_results_wait_for_recount(counters):
    """results() right after an async round returns completed
    predictions (the implicit sync), not half-written segments."""
    space, ground = counters
    rng = np.random.default_rng(5)
    img, b, c = make_scene(rng, SCENE)
    pcfg = PipelineConfig(method="ground_only", score_thresh=0.25)
    fleet = Fleet(space, ground, pcfg, n_sats=1, async_ground=True)
    sync = Fleet(space, ground, pcfg, n_sats=1)
    frames = revisit_frames(rng, img, b, c, 2)
    for fl in (fleet, sync):
        fl.ingest([frames])
        fl.contact_round(windows=[(0, 4e6)])
    a = fleet.results()[0]   # syncs internally
    b = sync.results()[0]
    _assert_same(a, b, "async results")


def test_async_worker_exception_surfaces_at_sync(counters):
    space, ground = counters
    rng = np.random.default_rng(6)
    img, b, c = make_scene(rng, SCENE)
    pcfg = PipelineConfig(method="ground_only", score_thresh=0.25)
    fleet = Fleet(space, ground, pcfg, n_sats=1, async_ground=True)
    fleet.ingest([revisit_frames(rng, img, b, c, 1)])

    def boom(*a, **k):
        raise RuntimeError("recount exploded")

    fleet.missions[0].contact_stages[3].run = boom  # Aggregate
    fleet.contact_round(windows=[(0, 2e6)])
    with pytest.raises(RuntimeError, match="recount exploded"):
        fleet.ground_segment.sync()
    # the error is consumed: the ground segment is usable again
    fleet.ground_segment.sync()


# ---------------------------------------------------------------------------
# bounded depth-k recount pipeline: every depth == the synchronous path
# ---------------------------------------------------------------------------

DEPTHS = (0, 1, 2, 3)


def _run_at_depth(space, ground, pcfg, scenario, depth, **kw):
    return run_scenario(space, ground, pcfg, scenario, fleet=True,
                        async_depth=depth, **kw)


@pytest.mark.parametrize("method", METHODS)
def test_depth_pipeline_bit_equal_engine(method, scenario, counters):
    """Depth 0/1/2/3 produce identical per-tile predictions, summaries,
    and ledger lanes through the batched (engine) executor, for every
    policy — the pipeline acceptance gate at 0.0 deviation."""
    space, ground = counters
    pcfg = PipelineConfig(method=method, score_thresh=0.25)
    want, f0 = _run_at_depth(space, ground, pcfg, scenario, 0)
    for depth in DEPTHS[1:]:
        got, fd = _run_at_depth(space, ground, pcfg, scenario, depth)
        for i, (a, b) in enumerate(zip(got, want)):
            _assert_same(a, b, f"{method} depth={depth} sat{i}")
        _assert_ledgers_equal(fd, f0, f"{method} depth={depth}")
        s = fd.summary()
        assert s["async_depth"] == depth and s["async_ground"] is True
        assert s["recount_max_in_flight"] <= depth
        assert s["recount_wait_s"] <= s["recount_s"]


@pytest.mark.parametrize("method", METHODS)
def test_depth_pipeline_bit_equal_reference_path(method, scenario, counters):
    """The same depth sweep with ``use_engine=False`` satellites (the
    scalar reference execution path inside the batched round)."""
    space, ground = counters
    pcfg = PipelineConfig(method=method, score_thresh=0.25,
                          use_engine=False)
    want, f0 = _run_at_depth(space, ground, pcfg, scenario, 0)
    for depth in (2, 3):
        got, fd = _run_at_depth(space, ground, pcfg, scenario, depth)
        for i, (a, b) in enumerate(zip(got, want)):
            _assert_same(a, b, f"{method} ref-path depth={depth} sat{i}")
        _assert_ledgers_equal(fd, f0, f"{method} ref-path depth={depth}")


def test_depth_backpressure_bounds_in_flight(scenario, counters):
    """The queue never exceeds the configured depth; with more contact
    rounds than depth, backpressure actually fills the pipeline."""
    space, ground = counters
    pcfg = PipelineConfig(method="ground_only", score_thresh=0.25)
    _, fd = _run_at_depth(space, ground, pcfg, scenario, 2)
    s = fd.summary()
    assert fd.ground_segment.rounds_deferred >= 3
    assert 1 <= s["recount_max_in_flight"] <= 2
    assert fd.ground_segment.in_flight == 0  # summary() synced
    # depth 0 defers nothing at all
    _, f0 = _run_at_depth(space, ground, pcfg, scenario, 0)
    assert f0.ground_segment.rounds_deferred == 0
    assert f0.summary()["recount_max_in_flight"] == 0


def test_depth_knob_validation(counters):
    space, ground = counters
    pcfg = PipelineConfig(method="ground_only")
    with pytest.raises(ValueError, match="depth"):
        Fleet(space, ground, pcfg, n_sats=1, async_depth=-1)
    with pytest.raises(ValueError, match="conflicts"):
        Fleet(space, ground, pcfg, n_sats=1, async_ground=True,
              async_depth=0)
    # async_ground alone is depth-1 shorthand; async_depth overrides
    assert Fleet(space, ground, pcfg, n_sats=1,
                 async_ground=True).ground_segment.depth == 1
    assert Fleet(space, ground, pcfg, n_sats=1,
                 async_depth=3).ground_segment.depth == 3
    assert Fleet(space, ground, pcfg, n_sats=1).ground_segment.depth == 0


def test_depth2_worker_exception_leaves_later_rounds_pending(counters):
    """A real worker failure surfaces exactly once at sync; rounds
    queued BEHIND the failed one stay pending and retire cleanly on the
    next sync — no work is silently dropped."""
    space, ground = counters
    rng = np.random.default_rng(9)
    img, b, c = make_scene(rng, SCENE)
    pcfg = PipelineConfig(method="ground_only", score_thresh=0.25)
    fleet = Fleet(space, ground, pcfg, n_sats=1, async_depth=2)

    fleet.ingest([revisit_frames(rng, img, b, c, 1)])
    bad_seg = fleet.missions[0]._segments[0]  # round 1 drains this one
    stage = fleet.missions[0].contact_stages[3]
    real_run = type(stage).run

    def boom_on_first(self, m, seg, window):
        if seg is bad_seg:
            raise RuntimeError("recount exploded")
        return real_run(self, m, seg, window)

    stage.run = boom_on_first.__get__(stage)
    fleet.contact_round(windows=[(0, 2e6)])
    fleet.ingest([revisit_frames(rng, img, b, c, 1)])
    fleet.contact_round(windows=[(0, 2e6)])
    with pytest.raises(RuntimeError, match="recount exploded"):
        fleet.ground_segment.sync()
    assert fleet.ground_segment.in_flight == 1  # round 2 still queued
    fleet.ground_segment.sync()  # retires cleanly
    assert fleet.ground_segment.in_flight == 0


# ---------------------------------------------------------------------------
# select_batch contract
# ---------------------------------------------------------------------------

@register_policy("_test_every_third")
class _EveryThirdPolicy(SelectionPolicy):
    """Scalar-only third-party policy: downlinks every third active
    tile within budget. No select_batch override — exercises the
    default adapter."""

    wants_onboard = True

    def select(self, ctx, budget_bytes):
        cand = np.where(ctx.active)[0][::3]
        k = int(budget_bytes // ctx.tile_bytes)
        down = cand[:k].astype(np.int64)
        credit = np.zeros(ctx.n, bool)
        credit[down] = True
        accept = ctx.processed & ~credit
        return Selection(accept, down, credit,
                         len(down) * ctx.tile_bytes)


def test_select_batch_default_adapter_matches_scalar(scenario, counters):
    """A plugin with only scalar select() runs unmodified under the
    batched planner (the adapter drains lanes through it)."""
    assert "_test_every_third" in available_policies()
    space, ground = counters
    pcfg = PipelineConfig(method="_test_every_third", score_thresh=0.25)
    got, _ = run_scenario(space, ground, pcfg, scenario, fleet=True)
    want, _ = run_scenario(space, ground, pcfg, scenario, fleet=True,
                           contact_reference=True)
    for i, (a, b) in enumerate(zip(got, want)):
        _assert_same(a, b, f"adapter sat{i}")


def test_policy_context_batch_lane_roundtrip():
    """lane(i) recovers bit-equal scalar contexts from the stack,
    whatever the lane lengths."""
    from repro.core.policies import PolicyContext
    rng = np.random.default_rng(0)
    pcfg = PipelineConfig()
    ctxs = []
    for n in (5, 0, 9):
        ctxs.append(PolicyContext(
            n=n, active=rng.random(n) > 0.3,
            rep_of=rng.integers(0, max(n, 1), n),
            conf=rng.random(n), counts_sp=rng.random(n) * 4,
            processed=rng.random(n) > 0.5, tile_bytes=519168.0, pcfg=pcfg))
    batch = PolicyContextBatch.stack(ctxs, policies=[None] * 3)
    assert batch.n_lanes == 3
    for i, c in enumerate(ctxs):
        lane = batch.lane(i)
        assert lane.n == c.n and lane.tile_bytes == c.tile_bytes
        for f in ("active", "rep_of", "conf", "counts_sp", "processed"):
            np.testing.assert_array_equal(getattr(lane, f), getattr(c, f))
    # pad slots are inert
    assert not batch.active[1].any() and not batch.processed[1].any()
    assert (batch.conf[0, 5:] == -1.0).all()
    assert (batch.rep_of[0, 5:] == -1).all()


def test_throttle_padded_batch_bit_equal_to_scalar():
    """The vmapped lane-stacked throttle returns the exact masks of the
    per-lane bucketed scalar call (documented tolerance 0.0), for every
    fill-order policy, ragged lane lengths, and shared padding."""
    rng = np.random.default_rng(7)
    lanes = [rng.random(n) for n in (17, 1, 0, 64, 33)]
    tile_bytes = [519168.0] * 5
    budgets = np.array([3 * 519168.0, 0.0, 1e18, 40 * 519168.0, 5e5])
    for policy in ("low_conf_first", "fixed_conf", "dynamic_conf"):
        got = throttle_padded_batch(lanes, tile_bytes, budgets,
                                    [0.10] * 5, [0.55] * 5, policy,
                                    n_pad=64)
        for (g_sp, g_dn), conf, budget in zip(got, lanes, budgets):
            w_sp, w_dn = throttle_padded(conf, 519168.0,
                                         np.float64(budget), 0.10, 0.55,
                                         policy,
                                         n_pad=max(len(conf), 1))
            np.testing.assert_array_equal(g_sp, w_sp,
                                          err_msg=f"{policy} space mask")
            np.testing.assert_array_equal(g_dn, w_dn,
                                          err_msg=f"{policy} downlink mask")
    with pytest.raises(ValueError, match="n_pad"):
        throttle_padded_batch(lanes, tile_bytes, budgets, [0.1] * 5,
                              [0.5] * 5, n_pad=8)


# ---------------------------------------------------------------------------
# contact-tier summary fields
# ---------------------------------------------------------------------------

def test_summary_contact_throughput_fields(scenario, counters):
    space, ground = counters
    pcfg = PipelineConfig(method="targetfuse", score_thresh=0.25)
    results, fleet = run_scenario(space, ground, pcfg, scenario, fleet=True)
    s = fleet.summary()
    n_windows = sum(len(r.contacts) for r in scenario.rounds)
    assert s["windows_served"] >= n_windows  # + the finalize flush round
    assert s["contact_s"] > 0.0
    assert s["windows_per_s"] == pytest.approx(
        s["windows_served"] / s["contact_s"])
    assert s["bytes_downlinked_per_s"] == pytest.approx(
        s["bytes_spent"] / s["contact_s"])
    assert s["async_ground"] is False
    assert s["recount_hidden_frac"] == 0.0
