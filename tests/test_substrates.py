"""Optimizer / checkpoint / supervisor / compression / data tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest of the suite runs
    from _hypothesis_fallback import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.optim.adamw import adamw, clip_by_global_norm, global_norm
from repro.optim.compress import (int8_roundtrip_tree, topk_roundtrip_tree)
from repro.optim.schedule import cosine_with_warmup
from repro.runtime.supervisor import (DeadlineBatcher, SimulatedFailure,
                                      SupervisorConfig, run_training)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    init, update = adamw(0.1)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_mixed_precision_state():
    init, update = adamw(1e-3)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = init(params)
    assert state.mu["w"].dtype == jnp.float32
    params2, state2, _ = update({"w": jnp.ones((4,), jnp.bfloat16)}, state, params)
    assert params2["w"].dtype == jnp.bfloat16


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, n = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_cosine_schedule():
    f = cosine_with_warmup(1.0, 10, 100)
    assert float(f(jnp.array(0))) == 0.0
    assert abs(float(f(jnp.array(10))) - 1.0) < 0.01
    assert float(f(jnp.array(100))) < 0.01


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_compression_unbiased():
    g = {"w": jax.random.normal(KEY, (64, 64))}
    dec = [int8_roundtrip_tree(g, jax.random.PRNGKey(i))["w"] for i in range(64)]
    mean = jnp.stack(dec).mean(0)
    rel = float(jnp.linalg.norm(mean - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.05, rel  # stochastic rounding is unbiased


def test_topk_error_feedback_recovers():
    g = {"w": jax.random.normal(KEY, (32, 32))}
    res = jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), g)
    acc = jnp.zeros((32, 32))
    for _ in range(20):  # same grad each round: EF must converge to it
        dec, res = topk_roundtrip_tree(g, res, frac=0.1)
        acc += dec["w"] / 20
    # with error feedback the *accumulated* transmitted grad approaches g
    rel = float(jnp.linalg.norm(acc - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.2, rel


@given(frac=st.floats(0.01, 1.0))
@settings(max_examples=10, deadline=None)
def test_topk_sparsity(frac):
    g = {"w": jax.random.normal(KEY, (100,))}
    res = {"w": jnp.zeros((100,), jnp.float32)}
    dec, _ = topk_roundtrip_tree(g, res, frac=frac)
    nz = int(jnp.sum(dec["w"] != 0))
    assert nz <= max(1, int(100 * frac))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3), jnp.bfloat16)},
            "step": jnp.array(7)}
    ckpt.save(str(tmp_path), 5, tree)
    step, restored = ckpt.restore(str(tmp_path), tree)
    assert step == 5
    np.testing.assert_array_equal(restored["a"], tree["a"])
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_ckpt_latest_and_gc(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4]
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_ckpt_ignores_uncommitted(tmp_path):
    tree = {"x": jnp.zeros(3)}
    ckpt.save(str(tmp_path), 1, tree)
    # fake a torn save: directory without COMMITTED marker
    os.makedirs(tmp_path / "step_00000009")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_ckpt_async(tmp_path):
    tree = {"x": jnp.arange(5.0)}
    t = ckpt.save(str(tmp_path), 3, tree, async_=True)
    t.join()
    step, restored = ckpt.restore(str(tmp_path), tree)
    assert step == 3


def test_ckpt_structure_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(2)})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"a": jnp.zeros(2), "b": jnp.zeros(2)})


# ---------------------------------------------------------------------------
# supervisor: fault tolerance
# ---------------------------------------------------------------------------

def _toy_problem():
    def step_fn(state, batch):
        params = state
        new = jax.tree_util.tree_map(lambda p: p * 0.9, params)
        return new, jnp.sum(new["w"])

    def data_fn(step):
        return None

    return {"w": jnp.full((2,), 100.0)}, step_fn, data_fn


def test_supervisor_checkpoint_restart(tmp_path):
    state, step_fn, data_fn = _toy_problem()
    cfg = SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=5, max_steps=20,
                           async_save=False, fail_at_step=12)
    with pytest.raises(SimulatedFailure):
        run_training(state, step_fn, data_fn, cfg)
    # node "restarts": same call, resumes from step 10, completes
    cfg2 = SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=5, max_steps=20,
                            async_save=False)
    final, report = run_training(state, step_fn, data_fn, cfg2)
    assert report.resumed_from == 10
    assert report.steps_run == 10  # only the remaining steps
    # final value equals an uninterrupted 20-step run
    expected = 100.0 * 0.9 ** 20
    np.testing.assert_allclose(final["w"], expected, rtol=1e-5)


def test_supervisor_rejects_nan_steps(tmp_path):
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 3:
            return state, jnp.float32(np.nan)
        return jax.tree_util.tree_map(lambda p: p - 1.0, state), jnp.float32(1.0)

    state = {"w": jnp.zeros(1)}
    cfg = SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=100, max_steps=5,
                           async_save=False)
    final, report = run_training(state, step_fn, lambda s: None, cfg)
    assert report.rejected_steps == 1
    np.testing.assert_allclose(final["w"], -4.0)  # 4 good steps applied


def test_deadline_batcher_drops_stragglers():
    clock = {"t": 0.0}

    def fake_clock():
        return clock["t"]

    def work(item):
        clock["t"] += 1.0  # each item takes 1s
        return item * 2

    b = DeadlineBatcher(deadline_s=2.5, clock=fake_clock)
    results, dropped = b.run([1, 2, 3, 4, 5], work)
    assert results == [2, 4, 6]
    assert dropped == [4, 5]


# ---------------------------------------------------------------------------
# synthetic data
# ---------------------------------------------------------------------------

def test_scene_ground_truth_consistency(rng):
    from repro.data.synthetic import XVIEW_LIKE, make_scene, tile_counts
    img, boxes, classes = make_scene(rng, XVIEW_LIKE)
    assert img.shape == (1024, 1024, 3)
    assert img.min() >= 0 and img.max() <= 1
    counts = tile_counts(boxes, 1024, 128)
    assert counts.sum() == len(boxes)


def test_revisit_preserves_count(rng):
    from repro.data.synthetic import UAVOD_LIKE, make_scene, revisit_frames
    img, boxes, classes = make_scene(rng, UAVOD_LIKE)
    frames = revisit_frames(rng, img, boxes, classes, 5)
    assert len(frames) == 5
    for f, b, c in frames:
        assert f.shape == img.shape
        # shifts may drop a few edge boxes but most objects persist
        assert len(b) >= 0.6 * len(boxes)


def test_boxes_to_targets(rng):
    from repro.data.synthetic import boxes_to_targets
    boxes = np.array([[10, 10, 30, 30], [50, 50, 60, 64]], np.float32)
    classes = np.array([0, 3])
    t = boxes_to_targets(boxes, classes, grid=8, n_anchors=3, n_classes=8,
                         input_size=64)
    assert t.shape == (8, 8, 3, 13)
    assert t[..., 4].sum() == 2  # two positives
    ys, xs, ans = np.where(t[..., 4] > 0)[:3]
    assert set(zip(ys.tolist(), xs.tolist())) == {(2, 2), (7, 6)}
