"""Assemble the final EXPERIMENTS.md: keeps the hand-written §Perf log,
regenerates §Dry-run/§Roofline tables from artifacts, summarizes
§Paper-repro from bench_output.txt.

  PYTHONPATH=src python scripts/finalize_experiments.py
"""
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.report import dryrun_table, roofline_table

ROOT = os.path.join(os.path.dirname(__file__), "..")


def paper_repro_section() -> str:
    path = os.path.join(ROOT, "bench_output.txt")
    if not os.path.exists(path):
        return "(bench_output.txt not found — run benchmarks first)"
    rows = {}
    for line in open(path):
        line = line.strip()
        if "," in line and not line.startswith(("name,", "#")):
            parts = line.split(",", 2)
            if len(parts) == 3:
                rows[parts[0]] = parts[2]

    def g(k, default="n/a"):
        return rows.get(k, default)

    lines = [
        "| paper claim | paper value | measured (this repro) |",
        "|---|---|---|",
        f"| error reduction vs Space-Only (Fig. 11, unlimited downlink) | 3.4x avg | {g('fig11_error_reduction_vs_space_only')} |",
        f"| bandwidth efficiency vs TIANSUAN (Fig. 7) | 9.6x | {g('fig7_bandwidth_efficiency_vs_tiansuan')} |",
        f"| clustering downlink-volume ratio (Fig. 12a) | ~0.33 | {g('fig12a_downlink_volume_ratio')} |",
        f"| RPi4 CMAE reduction vs Atlas (Fig. 9) | ~34% | {g('fig9_rpi4_cmae_reduction_pct')} |",
        f"| tile size has interior optimum + Alg. 1 finds it (Fig. 4) | — | {g('fig4_alg1_choice')} |",
        "",
        "Full per-figure CSV in `bench_output.txt` (method x bandwidth x "
        "energy x hardware x dataset sweeps, all five baselines).",
    ]
    return "\n".join(lines)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    src = open(path).read()
    head = src.split("## §Dry-run / §Roofline / §Paper-repro")[0]

    doc = head + """## §Dry-run

Both production meshes lower + compile for every (arch x shape) cell —
40/40 on the single-pod 16x16 (=256 chip) mesh and 40/40 on the
multi-pod 2x16x16 (=512 chip) mesh (plus the paper's own arch), with the
"pod" axis carrying cross-pod data parallelism. Logs:
`/tmp/matrix_single_v2.log`, `/tmp/matrix_multi_v2.log`; artifacts under
`artifacts/dryrun/`.

### single-pod (256 chips) — compile + memory + collective schedule

""" + dryrun_table("single") + """

### multi-pod (512 chips) — compile-proof pass

Multi-pod cells compile with scan-over-layers (fast compile; per-layer
costs are counted once per scan body, so FLOPs/useful columns are NOT
comparable to the single-pod table — the roofline analysis below is
single-pod per the assignment).

""" + dryrun_table("multi") + """

## §Roofline (single-pod, 256 chips)

Terms in seconds/step: compute = FLOPs/dev / 197e12, memory =
bytes/dev / 819e9 (floored at one pass over program args+outputs),
collective = collective-bytes/dev / 50e9. `useful` =
MODEL_FLOPS / (FLOPs/dev x 256); `roofline frac` = useful-FLOP time /
dominant-term time. CPU-backend bf16-emulation converts are subtracted
(see methodology); raw values live in the artifacts.

""" + roofline_table("single") + """

### Reading the table (post-hillclimb)

- **Train cells** sit at useful 0.76-1.00; the dominant term is the
  activation/gradient collective volume (qwen3 train: 1.10 s useful
  compute vs 4.38 s collective -> frac 0.25). Next lever (documented,
  not yet landed): bf16 collectives (CPU lowers them f32 — exactly 2x)
  and reduce-scatter+all-gather instead of all-reduce for TP
  activations (another 2x), which would put qwen3 train at frac ~0.5+.
- **LM decode cells** went from useful 0.01 to 0.82-0.90 (flash-decode
  cache layout); their absolute bound is ~1 ms/step — decode at 32k is
  HBM/ICI-bound by nature, and `roofline frac` ~0.1 reflects decode's
  intrinsically low arithmetic intensity, not waste.
- **Vision/DiT cells** run pure-DP where the batch covers the mesh
  (useful 0.94-1.00); what remains is the gradient all-reduce at
  1 image/chip — the classic DP floor.
- **UNet cells** are the weakest (useful 0.15-0.33): conv-heavy
  spatial models pay XLA resharding between conv (channel-TP) and
  attention (head-TP) layouts; a dedicated spatial-partitioning pass is
  the known fix and is left as future work (noted, baseline-only per
  the assignment).

## §Paper-repro (TargetFuse claims)

""" + paper_repro_section() + """

## §Memory fit (per-device, single-pod)

`memory_analysis()` argument bytes per device stay under HBM for every
cell (largest: qwen3-8b train_4k at ~5.1 GB/dev for params + optimizer
+ batch; deepseek long_500k cache at ~2.1 GB/dev). CPU-backend temp
bytes are an upper bound (the CPU scheduler keeps whole-layer
activations live; the TPU compiler with remat + donation does not) —
grad-accum (`--grad-accum`) and ZeRO-1 (`--zero1`) are provided and
lower+compile for the cells where tighter fits are needed.
"""
    open(path, "w").write(doc)
    print(f"EXPERIMENTS.md written ({len(doc)} chars)")


if __name__ == "__main__":
    main()
