#!/usr/bin/env bash
# CI entry point: tier-1 test suite + kernel micro-bench smoke run.
#
# Usage: scripts/ci.sh
# Perf trajectories land in BENCH_kernels_smoke.json for regression diffing.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== kernel bench smoke =="
python -m benchmarks.run kernels --strict --json BENCH_kernels_smoke.json

# Mission API drift gate: the examples are thin drivers over the public
# surface, so a smoke run catches API breakage that unit tests can miss.
echo "== example smoke: quickstart =="
timeout 600 python examples/quickstart.py

echo "== example smoke: constellation fleet path (2 sats, parity-checked) =="
timeout 600 python examples/constellation_sim.py --sats 2 --rounds 2 --check

echo "== fleet bench smoke (tiny config) =="
FLEET_BENCH_SATS=2 FLEET_BENCH_ROUNDS=1 FLEET_BENCH_ITERS=1 \
  FLEET_BENCH_JSON=BENCH_fleet_smoke.json \
  timeout 600 python -m benchmarks.run fleet --strict
