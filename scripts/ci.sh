#!/usr/bin/env bash
# CI entry point: tier-1 test suite + kernel micro-bench smoke run.
#
# Usage: scripts/ci.sh
# Perf trajectories land in BENCH_kernels_smoke.json for regression diffing.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# dev deps are best-effort: property tests use the real hypothesis when
# this succeeds and the deterministic tests/_hypothesis_fallback.py mini
# runner when it doesn't (air-gapped images) — they RUN either way
pip install -r requirements-dev.txt 2>/dev/null || \
  echo "(offline: property tests run on the fallback mini runner)"

# Hard gate: project-specific static analysis (thread-ownership races,
# host-sync-in-hot-path, determinism lints). Exits nonzero on any
# finding not waived in-source or carried by analysis_baseline.json.
echo "== static analysis (python -m repro.analysis) =="
python -m repro.analysis

# Best-effort: generic lint (unused imports, undefined names). The
# baked image may not ship ruff — requirements-dev pins it for
# environments that can install.
if command -v ruff >/dev/null 2>&1; then
  echo "== ruff (pinned, minimal rule set from pyproject.toml) =="
  ruff check src
else
  echo "(ruff unavailable: generic lint skipped; repro.analysis ran above)"
fi

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== property tests (hypothesis or the fallback runner) =="
python -m pytest -x -q tests/test_invariants.py

echo "== kernel bench smoke =="
python -m benchmarks.run kernels --strict --json BENCH_kernels_smoke.json

# Mission API drift gate: the examples are thin drivers over the public
# surface, so a smoke run catches API breakage that unit tests can miss.
echo "== example smoke: quickstart =="
timeout 600 python examples/quickstart.py

echo "== example smoke: constellation fleet path (2 sats, parity-checked,"
echo "   scenario-driven ContactPlans + overlapped ground recount) =="
timeout 600 python examples/constellation_sim.py --sats 2 --rounds 2 --check \
  --async-ground

echo "== example smoke: depth-2 recount pipeline (two rounds in flight,"
echo "   parity-checked against the synchronous path) =="
timeout 600 python examples/constellation_sim.py --sats 2 --rounds 2 --check \
  --async-depth 2

echo "== example smoke: round-pipelined ingest (deferred fetch tail,"
echo "   parity-checked against the looped-Mission oracle) =="
timeout 600 python examples/constellation_sim.py --sats 2 --rounds 3 --check \
  --ingest-overlap

echo "== example smoke: orbital geometry constellation (batched Keplerian"
echo "   propagation -> extracted passes -> ContactPlans, parity-checked) =="
timeout 600 python examples/constellation_sim.py --sats 2 --rounds 3 \
  --geometry orbital --check

echo "== example smoke: faulty constellation (seeded fault injection,"
echo "   batched-vs-FIFO-reference parity under faults) =="
timeout 600 python examples/constellation_sim.py --sats 2 --rounds 3 \
  --faults 17 --check

echo "== example smoke: collaborative serving on the ContactPlan stream =="
timeout 600 python examples/serve_collaborative.py --passes 2 --overlap

echo "== sharded fleet gates (4 forced host devices) =="
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
  timeout 900 python -m pytest -q tests/test_fleet.py -k "sharded"

echo "== example smoke: sharded constellation (2 devices, parity-checked) =="
XLA_FLAGS="--xla_force_host_platform_device_count=2" \
  timeout 600 python examples/constellation_sim.py --sats 3 --rounds 2 \
  --devices 2 --check

echo "== fleet bench smoke (tiny config, incl. sharded-path parity gate,"
echo "   the contact-plan batched/reference/async parity gate, the depth"
echo "   sweep, the ingest-overlap arms + transfer-cache churn gate, the"
echo "   jitguard steady-state recompilation gate, and the fault-sweep"
echo "   retry/watchdog parity gates) =="
FLEET_BENCH_SATS=2 FLEET_BENCH_ROUNDS=1 FLEET_BENCH_ITERS=1 \
  FLEET_BENCH_DEVICES=1,2 FLEET_BENCH_SHARD_SATS=3 \
  FLEET_BENCH_STATIONS=2 FLEET_BENCH_CONTACT_SATS=3 \
  FLEET_BENCH_ORBITAL_SATS=4 FLEET_BENCH_DEPTHS=0,1,2 \
  FLEET_BENCH_FAULT_SATS=2 FLEET_BENCH_FAULT_RATES=0,0.25 \
  FLEET_BENCH_OVERLAP=0,1 FLEET_BENCH_OVERLAP_SATS=3 \
  FLEET_BENCH_JSON=BENCH_fleet_smoke.json \
  timeout 900 python -m benchmarks.run fleet --strict

echo "== orbits bench smoke (tiny catalog; propagation/visibility/pass"
echo "   extraction/eclipse rows — throughput gate enforced on full size"
echo "   only, honest numbers recorded either way) =="
ORBITS_BENCH_SATS=64 ORBITS_BENCH_STEPS=128 ORBITS_BENCH_STATIONS=2 \
  ORBITS_BENCH_JSON=BENCH_orbits_smoke.json \
  timeout 900 python -m benchmarks.run orbits --strict
